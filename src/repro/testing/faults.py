"""Deterministic fault injection: corrupt containers + sabotage dispatch.

Two halves of one chaos harness:

  * :func:`corrupt` — seeded, reproducible corruption of a container's
    wire bytes, one function per fault class the serving quarantine must
    catch (``tests/golden/corrupt/`` freezes one blob per class with
    pinned seeds; the chaos soak draws fresh ones per run).  The map
    :data:`EXPECTED_FAULT` pins which
    :class:`~repro.serving.quarantine.PoisonedContainerError` fault class
    each corruption must surface as — the error taxonomy is a contract,
    tested like byte-identity is.
  * :class:`DispatcherFaultInjector` — the hook a
    :class:`~repro.serving.frontend.ServingFrontend` calls at the top of
    every watchdog-covered batch dispatch: raise on the nth dispatch,
    inject artificial latency, simulate a lost device, or hang outright
    (the watchdog's prey).  Counting is process-global per injector and
    thread-safe; every injected fault is logged so tests can assert the
    chaos actually happened.

:func:`chaos_replay` drives both through an open-loop request replay and
returns a per-request outcome report — the engine of
``tests/test_chaos.py`` and ``benchmarks/bench_serving.py --chaos``.
"""
from __future__ import annotations

import dataclasses
import struct
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.container import HEADER_BYTES

__all__ = [
    "CONTAINER_FAULTS",
    "EXPECTED_FAULT",
    "ChaosReport",
    "DispatcherFaultInjector",
    "InjectedDispatchError",
    "InjectedDeviceLossError",
    "chaos_replay",
    "corrupt",
    "offline_expected",
]

_HDR = struct.Struct("<4sHHHHIQIQHHI")  # mirrors core.container._HDR
_EXT3_SIZE = 4

# byte offsets of the header fields corruption targets (see container.py)
_OFF_VERSION = 4
_OFF_NUM_WINDOWS = 24
_OFF_MAX_SYMLEN = 36
_OFF_DOMAIN_ID = 38
_OFF_CRC = 40

#: every container fault class :func:`corrupt` can inject, in the order
#: the chaos soak cycles through them
CONTAINER_FAULTS: Tuple[str, ...] = (
    "flip-words",
    "flip-sidecar",
    "flip-crc",
    "flip-header",
    "truncate",
    "version-skew",
    "bad-magic",
    "reserved-flags",
    "wrong-table",
)

#: corruption -> the fault class(es) the quarantine must report it as.
#: "wrong-table" depends on routing: a flipped domain_id lands on
#: plan-mismatch when the new id resolves to differently-configured
#: tables, unroutable when it resolves to nothing.
EXPECTED_FAULT: Dict[str, Tuple[str, ...]] = {
    "flip-words": ("crc-mismatch",),
    "flip-sidecar": ("crc-mismatch",),
    "flip-crc": ("crc-mismatch",),
    "flip-header": ("header-mismatch",),
    "truncate": ("truncated",),
    "version-skew": ("bad-version",),
    "bad-magic": ("bad-magic",),
    "reserved-flags": ("reserved-flags",),
    "wrong-table": ("plan-mismatch", "unroutable"),
}


def _layout(data: bytes) -> Tuple[int, int, int]:
    """(payload_off, words_bytes, sidecar_bytes) of a well-formed blob."""
    (_, version, _, _, _, num_words, _, _, _, _, _, _) = _HDR.unpack_from(
        data, 0
    )
    off = HEADER_BYTES + (_EXT3_SIZE if version == 3 else 0)
    return off, num_words * 8, num_words


def corrupt(data: bytes, fault: str, seed: int = 0) -> bytes:
    """Return ``data`` corrupted with ``fault``, deterministically.

    ``data`` must be a well-formed container blob (the function reads its
    header to aim); the same ``(data, fault, seed)`` triple always
    produces the same corrupt bytes — a quarantine record is reproducible
    from its fault class and seed alone.
    """
    rng = np.random.default_rng(seed)
    buf = bytearray(data)
    off, words_bytes, sidecar_bytes = _layout(data)
    if fault == "flip-words":
        if not words_bytes:
            raise ValueError("container has no words to corrupt")
        pos = off + int(rng.integers(0, words_bytes))
        buf[pos] ^= 1 << int(rng.integers(0, 8))
    elif fault == "flip-sidecar":
        pos = off + words_bytes + int(rng.integers(0, sidecar_bytes))
        buf[pos] ^= 1 << int(rng.integers(0, 8))
    elif fault == "flip-crc":
        buf[_OFF_CRC + int(rng.integers(0, 4))] ^= 1 << int(
            rng.integers(0, 8)
        )
    elif fault == "flip-header":
        # num_windows: CRC-blind, caught only by the deep header-vs-grid
        # consistency check — the exact hole this fault class pins
        buf[_OFF_NUM_WINDOWS] ^= 0x01
    elif fault == "truncate":
        cut = int(rng.integers(8, len(buf)))
        del buf[cut:]
    elif fault == "version-skew":
        struct.pack_into("<H", buf, _OFF_VERSION, 9)
    elif fault == "bad-magic":
        buf[0:4] = b"JUNK"
    elif fault == "reserved-flags":
        (_, version, *_rest) = _HDR.unpack_from(data, 0)
        if version != 3:
            raise ValueError(
                "reserved-flags needs a v3 container (the flags word is "
                f"the v3 extension), got v{version}"
            )
        buf[HEADER_BYTES + 1] |= 0x80  # set flags bit 15 (reserved)
    elif fault == "wrong-table":
        buf[_OFF_DOMAIN_ID] ^= 0x01
    else:
        raise ValueError(
            f"unknown fault {fault!r}; choose from {CONTAINER_FAULTS}"
        )
    return bytes(buf)


# ---------------------------------------------------------------------------
# Dispatcher sabotage.
# ---------------------------------------------------------------------------
class InjectedDispatchError(RuntimeError):
    """A deliberately injected transient dispatch fault (retryable)."""


class InjectedDeviceLossError(RuntimeError):
    """A deliberately injected simulated device loss (retryable — the
    serving story for device loss is fail-over to a re-dispatch)."""


class DispatcherFaultInjector:
    """Sabotage hook for :class:`~repro.serving.frontend.ServingFrontend`.

    Pass as ``fault_injector=``; the frontend calls
    :meth:`on_dispatch` inside the watchdog window at the top of every
    micro-batch dispatch.  Dispatches are numbered 1, 2, 3, ... in call
    order (thread-safe), and each schedule keys on that number:

    ``fail_on``
        dispatch numbers that raise :class:`InjectedDispatchError` —
        a transient engine crash the retry policy should absorb.
    ``latency_on``
        ``{dispatch_number: seconds}`` of artificial stall before the
        engine call — deadline pressure without failure.
    ``hang_on``
        dispatch numbers that block until :meth:`release` (or
        ``hang_timeout_s`` as a test-deadlock backstop) — the watchdog's
        target.
    ``device_loss_on``
        dispatch numbers that raise :class:`InjectedDeviceLossError`.

    ``injected`` logs every fault actually fired as ``(n, kind)`` so a
    chaos test can assert its faults happened (a soak that silently
    injected nothing proves nothing).
    """

    def __init__(
        self,
        *,
        fail_on: Iterable[int] = (),
        latency_on: Optional[Dict[int, float]] = None,
        hang_on: Iterable[int] = (),
        device_loss_on: Iterable[int] = (),
        hang_timeout_s: float = 30.0,
    ):
        self.fail_on = set(fail_on)
        self.latency_on = dict(latency_on or {})
        self.hang_on = set(hang_on)
        self.device_loss_on = set(device_loss_on)
        self.hang_timeout_s = hang_timeout_s
        self._release = threading.Event()
        self._lock = threading.Lock()
        self._count = 0
        self.injected: List[Tuple[int, str]] = []

    @property
    def dispatches(self) -> int:
        """Dispatch calls observed so far."""
        with self._lock:
            return self._count

    def release(self) -> None:
        """Unblock every hung dispatch (hangs are one-shot per number)."""
        self._release.set()

    def on_dispatch(self, key: Any, members: Sequence[Any]) -> None:
        with self._lock:
            self._count += 1
            n = self._count
        if n in self.latency_on:
            with self._lock:
                self.injected.append((n, "latency"))
            time.sleep(self.latency_on[n])
        if n in self.hang_on:
            with self._lock:
                self.injected.append((n, "hang"))
            self._release.wait(self.hang_timeout_s)
        if n in self.device_loss_on:
            with self._lock:
                self.injected.append((n, "device-loss"))
            raise InjectedDeviceLossError(
                f"injected device loss on dispatch #{n} (queue {key!r})"
            )
        if n in self.fail_on:
            with self._lock:
                self.injected.append((n, "fail"))
            raise InjectedDispatchError(
                f"injected transient fault on dispatch #{n} (queue {key!r})"
            )


# ---------------------------------------------------------------------------
# The chaos soak driver.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ChaosReport:
    """Per-request accounting of one :func:`chaos_replay` run.

    The zero-silent-drops invariant is structural: every submitted
    request lands in exactly one of ``ok`` / ``poisoned`` /
    ``dispatch_failed`` / ``rejected`` / ``untyped_failures`` /
    ``hangs``, and their sum is ``total``.
    """

    total: int = 0
    clean: int = 0  # submitted uncorrupted
    corrupted: int = 0  # submitted with injected corruption
    ok: int = 0  # resolved with a result
    poisoned: int = 0  # typed poison outcome (future or admission)
    dispatch_failed: int = 0  # typed DispatchFailedError
    rejected: int = 0  # typed admission rejection (shed/expired/closed)
    untyped_failures: int = 0  # anything else — a chaos-test FAILURE
    hangs: int = 0  # futures that never resolved — a chaos-test FAILURE
    clean_mismatches: int = 0  # clean result != offline expected — FAILURE
    clean_ok: int = 0  # clean requests that resolved with a result
    outcomes: List[Tuple[int, str, str]] = dataclasses.field(
        default_factory=list
    )  # (request index, outcome, detail)

    @property
    def accounted(self) -> int:
        return (
            self.ok + self.poisoned + self.dispatch_failed + self.rejected
            + self.untyped_failures + self.hangs
        )


def chaos_replay(
    frontend,
    requests: Sequence[Any],
    *,
    corrupt_frac: float = 0.05,
    seed: int = 0,
    faults: Sequence[str] = CONTAINER_FAULTS,
    expected: Optional[Dict[int, Any]] = None,
    result_timeout_s: float = 120.0,
    deadline_ms: Optional[float] = None,
) -> ChaosReport:
    """Open-loop replay of ``requests`` with seeded payload corruption.

    ``requests`` are :class:`repro.serving.traffic.Request` records (only
    ``kind`` / ``signal`` / ``domain_id`` / ``container`` /
    ``dst_domain_id`` are read).  A deterministic ``corrupt_frac``
    fraction of the container-carrying requests (decode/transcode) is
    corrupted, cycling through ``faults``; every request is submitted
    (stragglers shed by admission count as typed rejections), then every
    future is awaited with a hard timeout — an unresolved future is a
    **hang**, the one outcome the chaos contract forbids outright.

    ``expected`` maps request index -> the offline engines' result for
    clean requests (``np.ndarray`` for decode/encode, container bytes for
    transcode/encode); mismatches count in ``clean_mismatches``.
    """
    from repro.core.container import ContainerFormatError
    from repro.serving.frontend import (
        DispatchFailedError,
        FrontendError,
    )
    from repro.serving.quarantine import PoisonedContainerError

    rng = np.random.default_rng(seed)
    report = ChaosReport(total=len(requests))
    corruptible = [
        i for i, r in enumerate(requests)
        if r.kind in ("decode", "transcode")
    ]
    n_corrupt = int(round(corrupt_frac * len(corruptible)))
    corrupt_idx = {
        int(i): faults[k % len(faults)]
        for k, i in enumerate(
            rng.choice(corruptible, size=n_corrupt, replace=False)
            if n_corrupt else []
        )
    }

    futures: List[Optional[Any]] = []
    admission: List[Optional[Tuple[str, str]]] = []
    for i, r in enumerate(requests):
        fault = corrupt_idx.get(i)
        if fault is None:
            report.clean += 1
        else:
            report.corrupted += 1
        fut = None
        outcome = None
        try:
            if r.kind == "encode":
                fut = frontend.submit_encode(
                    np.asarray(r.signal), r.domain_id,
                    deadline_ms=deadline_ms,
                )
            else:
                blob = r.container.to_bytes()
                if fault is not None:
                    try:
                        blob = corrupt(blob, fault, seed=seed + i)
                    except ValueError:
                        # version-gated fault (reserved-flags needs a v3
                        # blob): substitute a CRC flip so the request is
                        # still corrupted, deterministically
                        fault = "flip-crc"
                        corrupt_idx[i] = fault
                        blob = corrupt(blob, fault, seed=seed + i)
                if r.kind == "decode":
                    fut = frontend.submit_decode(
                        blob, deadline_ms=deadline_ms
                    )
                else:
                    fut = frontend.submit_transcode(
                        blob, r.dst_domain_id, deadline_ms=deadline_ms
                    )
        except (ContainerFormatError, PoisonedContainerError) as e:
            # typed poison caught at admission (header-visible corruption)
            outcome = ("poisoned", f"admission: {e}")
        except KeyError as e:
            # unroutable (e.g. wrong-table flipped to an unknown domain)
            outcome = ("poisoned", f"admission: {e}")
        except DispatchFailedError as e:
            outcome = ("dispatch-failed", f"admission: {e}")
        except FrontendError as e:
            outcome = ("rejected", f"admission: {e}")
        futures.append(fut)
        admission.append(outcome)

    frontend.flush()
    deadline = time.monotonic() + result_timeout_s
    for i, (fut, outcome) in enumerate(zip(futures, admission)):
        fault = corrupt_idx.get(i)
        if outcome is None:
            try:
                left = max(deadline - time.monotonic(), 0.0)
                result = fut.result(timeout=left)
                outcome = ("ok", "")
            except PoisonedContainerError as e:
                outcome = ("poisoned", str(e))
            except DispatchFailedError as e:
                outcome = ("dispatch-failed", str(e))
            except FrontendError as e:
                outcome = ("rejected", str(e))
            except TimeoutError:
                outcome = ("hang", "future never resolved")
            except BaseException as e:  # noqa: BLE001 — tallied as untyped
                outcome = ("untyped", repr(e))
        kind, detail = outcome
        if kind == "ok":
            report.ok += 1
            if fault is None:
                report.clean_ok += 1
                want = (expected or {}).get(i)
                if want is not None and not _results_equal(result, want):
                    report.clean_mismatches += 1
                    outcome = ("ok", "MISMATCH vs offline")
        elif kind == "poisoned":
            report.poisoned += 1
        elif kind == "dispatch-failed":
            report.dispatch_failed += 1
        elif kind == "rejected":
            report.rejected += 1
        elif kind == "hang":
            report.hangs += 1
        else:
            report.untyped_failures += 1
        report.outcomes.append((i, outcome[0], outcome[1]))
    return report


def offline_expected(requests: Sequence[Any], tables) -> Dict[int, Any]:
    """Index -> the offline (sync, unsharded) engines' result for every
    request in a :mod:`repro.serving.traffic` stream — the byte-identity
    oracle :func:`chaos_replay` compares clean results against
    (``np.ndarray`` for decode, container bytes for encode/transcode)."""
    from repro.serving.batch_decode import BatchDecoder
    from repro.serving.batch_encode import BatchEncoder
    from repro.serving.transcode import Transcoder

    dec = BatchDecoder(pipeline=False, devices=None)
    enc = BatchEncoder(pipeline=False, devices=None)
    tr = Transcoder(decoder=dec, encoder=enc)
    by_dec: Dict[int, List[int]] = {}
    by_enc: Dict[int, List[int]] = {}
    by_tr: Dict[Tuple[int, int], List[int]] = {}
    for i, r in enumerate(requests):
        if r.kind == "decode":
            by_dec.setdefault(r.domain_id, []).append(i)
        elif r.kind == "encode":
            by_enc.setdefault(r.domain_id, []).append(i)
        else:
            by_tr.setdefault((r.domain_id, r.dst_domain_id), []).append(i)
    expected: Dict[int, Any] = {}
    for d, idxs in by_dec.items():
        out = dec.decode(
            [requests[i].container for i in idxs], tables[d]
        ).to_host()
        expected.update(zip(idxs, out))
    for d, idxs in by_enc.items():
        out = enc.encode(
            [requests[i].signal for i in idxs], tables[d]
        ).to_host()
        expected.update((i, c.to_bytes()) for i, c in zip(idxs, out))
    for (src, dst), idxs in by_tr.items():
        out = tr.transcode(
            [requests[i].container for i in idxs],
            tables[src], tables[dst],
            dst_domain_ids=[dst] * len(idxs),
        ).to_host()
        expected.update((i, c.to_bytes()) for i, c in zip(idxs, out))
    return expected


def _results_equal(got: Any, want: Any) -> bool:
    to_bytes = getattr(got, "to_bytes", None)
    if to_bytes is not None:
        got = to_bytes()
    if isinstance(want, (bytes, bytearray)):
        return bytes(got) == bytes(want)
    return np.array_equal(np.asarray(got), np.asarray(want))
