"""Test-support machinery that ships with the package.

:mod:`repro.testing.faults` is the chaos/fault-injection harness — it
lives under ``src`` (not ``tests/``) because the serving layer's fault
taxonomy is a *contract*: operators reproduce a production quarantine
record by corrupting a blob the exact same deterministic way the test
suite does.
"""
