"""FPTC archive service: the serving front-end as a long-lived process.

Two modes over the same :class:`~repro.serving.frontend.ServingFrontend`
(tables for all four paper domains, deadline micro-batching, bounded
queues with explicit shedding):

  * **replay** — drive the front-end with synthetic open-loop traffic
    (:mod:`repro.serving.traffic`) and print the latency/goodput report;
    the self-contained way to see the service behave under load::

      PYTHONPATH=src python -m repro.launch.serve --replay --rate 100 \\
          --duration 2

  * **HTTP** (default) — a stdlib ``ThreadingHTTPServer`` front door;
    handler threads admit concurrently (the front-end's admission path is
    thread-safe), the dispatcher micro-batches behind them::

      PYTHONPATH=src python -m repro.launch.serve --port 8080

    ================================  =====================================
    ``POST /v1/encode?domain_id=K``   body: raw little-endian float32
                                      samples -> container bytes
    ``POST /v1/decode``               body: container bytes -> raw float32
                                      samples
    ``POST /v1/transcode?dst=K``      body: container bytes -> container
                                      bytes re-encoded under domain K
    ``GET /healthz``                  liveness
    ``GET /statz``                    front-end stats + queue depths (JSON)
    ================================  =====================================

    Requests may carry ``X-FPTC-Deadline-Ms``; a shed request gets **429**
    with the queue's depth/bound and a ``Retry-After`` (backpressure is a
    response, never a silent drop); an already-expired deadline gets
    **400**; decode of a domain the service has no tables for gets **404**.

    Fault handling (see the README's taxonomy table): a corrupt container
    gets **422** with the typed quarantine record (fault class + byte
    offset) — whether caught at admission (header faults) or by the
    per-request quarantine at dispatch (payload faults) — while its
    batch-mates are unaffected; a dispatch the watchdog/retry machinery
    gave up on gets **503** with ``dispatch-failed``.  ``GET /healthz``
    returns **200** with ``{"status": "ok"}`` when healthy and **503**
    with the degraded evidence (recent fault events, shed rate,
    quarantine/retry counters) when a watchdog restart, dispatcher crash
    or dispatch failure happened within the degraded window.

(The seed's LM inference driver moved to :mod:`repro.launch.serve_lm`.)
"""
from __future__ import annotations

import argparse
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro.core.container import ContainerFormatError
from repro.serving.frontend import (
    DeadlineExpiredError,
    DispatchFailedError,
    FrontendClosedError,
    FrontendConfig,
    QueueFullError,
    RetryPolicy,
    ServingFrontend,
)
from repro.serving.quarantine import PoisonedContainerError
from repro.serving.traffic import (
    TrafficConfig,
    build_domain_tables,
    generate,
    replay,
)


def build_frontend(args, fault_injector=None) -> ServingFrontend:
    tables = build_domain_tables(seed=args.seed)
    return ServingFrontend(
        tables,
        config=FrontendConfig(
            max_batch=args.max_batch,
            max_queue_depth=args.queue_depth,
            default_slo_ms=args.slo_ms,
            flush_slack_ms=args.slack_ms,
            quarantine=not args.no_quarantine,
            retry=RetryPolicy(max_retries=args.retries),
            watchdog_timeout_ms=args.watchdog_ms,
        ),
        pipeline=not args.no_pipeline,
        devices="auto",
        fault_injector=fault_injector,
    )


# ---------------------------------------------------------------------------
# HTTP mode.
# ---------------------------------------------------------------------------
def make_handler(frontend: ServingFrontend):
    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def log_message(self, fmt, *args):  # quiet access log
            pass

        def _reply(self, code: int, body: bytes,
                   content_type: str = "application/octet-stream",
                   extra=()):
            self.send_response(code)
            self.send_header("Content-Type", content_type)
            self.send_header("Content-Length", str(len(body)))
            for k, v in extra:
                self.send_header(k, v)
            self.end_headers()
            self.wfile.write(body)

        def _reply_json(self, code: int, obj, extra=()):
            self._reply(
                code, json.dumps(obj).encode(), "application/json", extra
            )

        def do_GET(self):
            path = urlparse(self.path).path
            if path == "/healthz":
                health = frontend.health()
                self._reply_json(
                    200 if health["status"] == "ok" else 503, health
                )
            elif path == "/statz":
                st = frontend.stats_snapshot()
                self._reply_json(200, {
                    "health": frontend.health(),
                    "stats": {
                        k: getattr(st, k)
                        for k in st.__dataclass_fields__
                    },
                    "mean_batch_size": st.mean_batch_size,
                    "inflight": frontend.inflight(),
                    "queues": {
                        repr(k): v
                        for k, v in frontend.queue_depths().items()
                    },
                    "fill_target": frontend.fill_target,
                })
            else:
                self._reply_json(404, {"error": f"no route {path}"})

        def do_POST(self):
            url = urlparse(self.path)
            query = parse_qs(url.query)
            body = self.rfile.read(
                int(self.headers.get("Content-Length", 0))
            )
            deadline = self.headers.get("X-FPTC-Deadline-Ms")
            deadline_ms = float(deadline) if deadline else None
            try:
                if url.path == "/v1/decode":
                    # raw wire bytes go straight to admission: under
                    # quarantine the frontend routes off the O(1) header
                    # peek and a corrupt payload poisons only this request
                    fut = frontend.submit_decode(
                        body, deadline_ms=deadline_ms
                    )
                    payload = fut.result().astype("<f4").tobytes()
                elif url.path == "/v1/encode":
                    domain_id = int(query.get("domain_id", ["0"])[0])
                    signal = np.frombuffer(body, dtype="<f4")
                    fut = frontend.submit_encode(
                        signal, domain_id, deadline_ms=deadline_ms
                    )
                    payload = fut.result().to_bytes()
                elif url.path == "/v1/transcode":
                    if "dst" not in query:
                        self._reply_json(
                            400, {"error": "transcode needs ?dst=<domain>"}
                        )
                        return
                    fut = frontend.submit_transcode(
                        body,
                        int(query["dst"][0]),
                        deadline_ms=deadline_ms,
                    )
                    payload = fut.result().to_bytes()
                else:
                    self._reply_json(404, {"error": f"no route {url.path}"})
                    return
            except QueueFullError as e:
                # explicit shed: tell the client how loaded we are and to
                # back off — never a silent drop
                self._reply_json(429, {
                    "error": "shed", "queue": repr(e.queue),
                    "depth": e.depth, "bound": e.bound,
                }, extra=[("Retry-After", "1")])
                return
            except DeadlineExpiredError as e:
                self._reply_json(400, {"error": str(e)})
                return
            except FrontendClosedError:
                self._reply_json(503, {"error": "shutting down"})
                return
            except (ContainerFormatError, PoisonedContainerError) as e:
                # the typed quarantine record: the request's payload is
                # bad, the rest of its batch completed untouched
                self._reply_json(422, {
                    "error": "poisoned-container",
                    "fault": e.fault,
                    "offset": e.offset,
                    "index": e.index,
                    "detail": str(e),
                })
                return
            except DispatchFailedError as e:
                # the serving machinery (not the payload) gave up —
                # resubmitting is safe
                self._reply_json(503, {
                    "error": "dispatch-failed", "detail": str(e),
                }, extra=[("Retry-After", "1")])
                return
            except (KeyError, ValueError) as e:
                self._reply_json(404, {"error": str(e)})
                return
            self._reply(200, payload)

    return Handler


def serve_http(frontend: ServingFrontend, host: str, port: int,
               ready: "threading.Event | None" = None) -> None:
    httpd = ThreadingHTTPServer((host, port), make_handler(frontend))
    print(f"FPTC archive service on http://{host}:{httpd.server_port} "
          f"(fill target {frontend.fill_target})", flush=True)
    if ready is not None:
        ready.set()
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        httpd.server_close()
        frontend.close(drain=True)


# ---------------------------------------------------------------------------
# Replay mode.
# ---------------------------------------------------------------------------
def run_replay(frontend: ServingFrontend, args) -> None:
    cfg = TrafficConfig(
        rate=args.rate,
        duration_s=args.duration,
        fixed_windows=8 if args.smoke else None,
        seed=args.seed,
    )
    requests = generate(cfg, frontend.tables)
    print(f"replaying {len(requests)} requests at {args.rate:g} rps "
          f"for {args.duration:g}s ...", flush=True)
    try:
        report = replay(frontend, requests, deadline_ms=args.slo_ms)
        stats = frontend.stats_snapshot()
    finally:
        frontend.close(drain=True)
    for k, v in report.summary().items():
        print(f"  {k:>16}: {v:.2f}" if isinstance(v, float) else
              f"  {k:>16}: {v}")
    print(f"  {'batches':>16}: {stats.batches} "
          f"(mean size {stats.mean_batch_size:.2f}; "
          f"{stats.fill_dispatches} fill / "
          f"{stats.deadline_dispatches} deadline / "
          f"{stats.forced_dispatches} forced)")
    print(f"  {'deadline misses':>16}: {stats.deadline_misses}")
    print(f"  {'max inflight':>16}: {stats.max_inflight}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--replay", action="store_true",
                    help="synthetic open-loop traffic instead of HTTP")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed-size replay")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--rate", type=float, default=100.0)
    ap.add_argument("--duration", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--queue-depth", type=int, default=256)
    ap.add_argument("--slo-ms", type=float, default=250.0)
    ap.add_argument("--slack-ms", type=float, default=5.0)
    ap.add_argument("--no-pipeline", action="store_true",
                    help="synchronous engines (debugging)")
    ap.add_argument("--no-quarantine", action="store_true",
                    help="batch-fatal container faults (offline contract)")
    ap.add_argument("--retries", type=int, default=2,
                    help="transient-fault retry budget per request")
    ap.add_argument("--watchdog-ms", type=float, default=10_000.0,
                    help="dispatcher watchdog timeout (0 disables)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rate, args.duration = 50.0, 0.5
        args.replay = True

    frontend = build_frontend(args)
    if args.replay:
        run_replay(frontend, args)
    else:
        serve_http(frontend, args.host, args.port)


if __name__ == "__main__":
    main()
