"""LM serving driver: batched prefill + decode loop on local devices.

  PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen1.5-4b --smoke \
      --batch 4 --prompt-len 64 --gen 32

(The FPTC archive service lives in :mod:`repro.launch.serve`; this module
keeps the seed's LM inference driver, CLI unchanged.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, get_smoke
from repro.distributed.train import make_serve_fns
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models.common import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    model = build_model(cfg)
    mesh = make_local_mesh(data=args.data, model=args.model_par)
    prefill_fn, decode_fn, policy, param_sh = make_serve_fns(model, mesh)

    max_len = args.prompt_len + args.gen
    rng = np.random.default_rng(0)
    with mesh:
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        params = jax.device_put(params, param_sh)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
                jnp.int32,
            )
        }
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_prefix, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
            )
        t0 = time.time()
        logits, cache = prefill_fn(params, batch, max_len)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        outs = [np.asarray(tok)]
        t0 = time.time()
        for i in range(args.gen - 1):
            pos = jnp.int32(args.prompt_len + i)
            logits, cache = decode_fn(params, cache, tok, pos)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    gen = np.concatenate(outs, axis=1)
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms "
          f"({args.batch * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample generations (first 12 token ids):")
    for row in gen[:4]:
        print("  ", row[:12].tolist())


if __name__ == "__main__":
    main()
