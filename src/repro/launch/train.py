"""End-to-end training driver.

Runs a real training loop on the local device(s): builds the model from
``--arch`` (reduced ``--smoke`` config by default on CPU), streams
deterministic token batches, checkpoints every ``--ckpt-every`` steps
(atomic, restartable), and resumes automatically from the newest checkpoint
— kill it mid-run and relaunch to exercise the fault-tolerance path.

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, get_smoke
from repro.data.pipeline import TokenPipeline
from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import CompressionConfig
from repro.distributed.elastic import StepTimer
from repro.distributed.optimizer import AdamW, AdamWConfig
from repro.distributed.train import make_train_step
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models.common import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-compress", action="store_true",
                    help="FPTC-compress checkpoint leaves")
    ap.add_argument("--compression", default="none",
                    choices=["none", "truncate", "truncate_int8"])
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    model = build_model(cfg)
    mesh = make_local_mesh(data=args.data, model=args.model_par)
    opt = AdamW(AdamWConfig(base_lr=args.lr, warmup=10,
                            total_steps=args.steps))
    ts = make_train_step(
        model, opt, mesh,
        compression=CompressionConfig(mode=args.compression),
    )

    pipe = TokenPipeline(
        vocab_size=cfg.vocab_size, batch_size=args.batch, seq_len=args.seq
    )

    with mesh:
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        params = jax.device_put(params, ts.param_shardings)
        opt_state = opt.init(params, with_residual=ts.compressor is not None)
        start_step = 0
        if args.ckpt_dir:
            restored = ckpt.restore_latest(
                args.ckpt_dir, {"params": params, "m": opt_state.m,
                                "v": opt_state.v}
            )
            if restored is not None:
                start_step, tree = restored
                params = jax.device_put(tree["params"], ts.param_shardings)
                opt_state = opt_state._replace(
                    m=tree["m"], v=tree["v"],
                    step=jnp.asarray(start_step, jnp.int32),
                )
                print(f"resumed from step {start_step}")

        timer = StepTimer()
        for step in range(start_step, args.steps):
            tokens, labels = pipe.batch(step)
            batch = {
                "tokens": jnp.asarray(tokens),
                "labels": jnp.asarray(labels),
            }
            if cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (args.batch, cfg.vision_prefix, cfg.d_model), jnp.bfloat16
                )
            if cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
                )
            timer.start()
            params, opt_state, metrics = ts.step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt, straggler = timer.stop()
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"step {step:5d} loss {loss:8.4f} "
                    f"gnorm {float(metrics['grad_norm']):8.3f} "
                    f"{dt*1e3:7.1f} ms" + ("  [straggler]" if straggler else "")
                , flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                host = jax.tree_util.tree_map(np.asarray, {
                    "params": params, "m": opt_state.m, "v": opt_state.v,
                })
                path = ckpt.save_checkpoint(
                    args.ckpt_dir, step + 1, host,
                    compress=args.ckpt_compress,
                )
                print(f"checkpointed -> {path}", flush=True)
    print("training done.")


if __name__ == "__main__":
    main()
