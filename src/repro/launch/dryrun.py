import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first two lines — before ANY other import (jax locks
#   the host-platform device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  For each cell we build abstract inputs
(ShapeDtypeStruct — zero allocation), jit the train/prefill/decode step with
production shardings, ``.lower().compile()``, and record:

  * memory_analysis()   — per-device bytes (proves the cell fits),
  * cost_analysis()     — HLO FLOPs / bytes for the roofline terms,
  * collective bytes    — parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operand
    sizes; cost_analysis does not report these).

Results are written incrementally to benchmarks/artifacts/dryrun/<cell>.json
so a partial sweep is never lost (the roofline report reads these files).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k [--multi-pod] [--compression truncate_int8]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_arch
from repro.distributed.compression import CompressionConfig
from repro.distributed.optimizer import AdamW, AdamWConfig
from repro.distributed.train import make_train_step, make_serve_fns
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.common import ParamSpec

ARTIFACT_DIR = "benchmarks/artifacts/dryrun"

# ---------------------------------------------------------------------------
# HLO collective-byte accounting
# ---------------------------------------------------------------------------
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:[%\w.\-]+)\s*=\s*((?:\w+\[[^\]]*\](?:,\s*\w+\[[^\]]*\])*|\([^)]*\)))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
    re.M,
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the optimized HLO."""
    out: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shapes_str, op = m.group(1), m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(shapes_str):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[op] = out.get(op, 0) + total
    return out


# ---------------------------------------------------------------------------
# Cell runners
# ---------------------------------------------------------------------------
def _abstract_from_specs(specs, shardings):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs, shardings,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def run_cell(
    arch_id: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    compression: str = "none",
    verbose: bool = True,
) -> Dict[str, Any]:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    t0 = time.time()

    if shape.kind == "train":
        comp = CompressionConfig(mode=compression)
        opt = AdamW(AdamWConfig(
            acc_dtype=jnp.bfloat16 if cfg.param_count() > 1e11
            else jnp.float32,
        ))
        ts = make_train_step(model, opt, mesh, compression=comp)
        with mesh:
            p_abs, o_abs, b_abs = ts.abstract_inputs(
                shape.global_batch, shape.seq_len
            )
            lowered = ts.step_fn.lower(p_abs, o_abs, b_abs)
            compiled = lowered.compile()
    else:
        prefill_fn, decode_fn, policy, param_sh = make_serve_fns(model, mesh)
        pspecs = model.param_specs()
        p_abs = _abstract_from_specs(pspecs, param_sh)
        with mesh:
            if shape.kind == "prefill":
                bspecs = model.batch_specs(shape.global_batch, shape.seq_len)
                b_sh = jax.tree_util.tree_map(
                    lambda s: policy.sharding_for(s.names, s.shape),
                    bspecs, is_leaf=lambda x: isinstance(x, ParamSpec),
                )
                b_abs = _abstract_from_specs(bspecs, b_sh)
                lowered = prefill_fn.lower(p_abs, b_abs, shape.seq_len)
            else:  # decode
                cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
                c_sh = shlib.resolve_param_specs(policy, cspecs)
                c_abs = _abstract_from_specs(cspecs, c_sh)
                tok = jax.ShapeDtypeStruct(
                    (shape.global_batch, 1), jnp.int32,
                    sharding=policy.sharding_for(
                        ("batch", None), (shape.global_batch, 1)
                    ),
                )
                pos = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = decode_fn.lower(p_abs, c_abs, tok, pos)
            compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # trip-count-aware costs (XLA's cost_analysis visits while bodies once —
    # see repro.analysis.hlo_cost)
    from repro.analysis import analyze_hlo

    hc = analyze_hlo(hlo)

    n_devices = 1
    for s in mesh.devices.shape:
        n_devices *= s

    result = {
        "arch": arch_id,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod,
        "n_devices": n_devices,
        "compression": compression,
        "compile_seconds": round(compile_s, 1),
        "flops": hc.flops,
        "bytes_accessed": hc.hbm_bytes,
        "collective_bytes": hc.collective_by_op,
        "collective_bytes_total": hc.collective_bytes,
        "collective_bytes_tpu": hc.collective_bytes_tpu,
        "num_whiles": hc.num_whiles,
        "unknown_trip_whiles": hc.unknown_trip_whiles,
        "xla_cost_analysis": {
            "flops_body_once": cost.get("flops", 0.0),
            "bytes_body_once": cost.get("bytes accessed", 0.0),
            "collective_result_bytes_body_once": sum(coll.values()),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            # live-state + transient estimate per device (args are donated,
            # alias'd outputs don't double-count)
            "resident_estimate_bytes": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
        },
        "params_total": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    if verbose:
        print(json.dumps({k: v for k, v in result.items()
                          if k not in ("collective_bytes",)}, indent=None))
        print("memory_analysis:", mem)
    return result, hlo


def save_result(result: Dict[str, Any], hlo_text: Optional[str] = None):
    import gzip
    import os as _os

    _os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = (
        f"{result['arch']}__{result['shape']}__"
        f"{'multipod' if result['multi_pod'] else 'singlepod'}"
        + (f"__{result['compression']}"
           if result["compression"] != "none" else "")
    )
    path = _os.path.join(ARTIFACT_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    if hlo_text is not None:
        # keep the optimized HLO so cost-model improvements can re-analyze
        # without recompiling
        with gzip.open(_os.path.join(ARTIFACT_DIR, name + ".hlo.gz"),
                       "wt") as f:
            f.write(hlo_text)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "replicated_f32", "truncate", "truncate_int8"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    todo = []
    if args.all:
        for cell in cells():
            if cell.skip:
                print(f"SKIP {cell.arch_id} x {cell.shape.name}: {cell.skip}")
                continue
            todo.append((cell.arch_id, cell.shape.name))
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        todo.append((args.arch, args.shape))

    failures = []
    for arch_id, shape_name in todo:
        import os as _os

        name = (f"{arch_id}__{shape_name}__"
                f"{'multipod' if args.multi_pod else 'singlepod'}"
                + (f"__{args.compression}"
                   if args.compression != "none" else "") + ".json")
        if args.skip_existing and _os.path.exists(
            _os.path.join(ARTIFACT_DIR, name)
        ):
            print(f"EXISTS {name}")
            continue
        print(f"=== {arch_id} x {shape_name} "
              f"({'multi' if args.multi_pod else 'single'}-pod, "
              f"compression={args.compression}) ===", flush=True)
        try:
            result, hlo = run_cell(
                arch_id, shape_name, multi_pod=args.multi_pod,
                compression=args.compression,
            )
            path = save_result(result, hlo)
            print(f"saved {path}", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append((arch_id, shape_name))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete.")


if __name__ == "__main__":
    main()
