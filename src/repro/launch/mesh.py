"""Production mesh factory.

A FUNCTION (not a module constant) so importing this module never touches
jax device state — the dry-run sets XLA_FLAGS *before* first jax init.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh for tests (requires >= pod*data*model local devices)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
