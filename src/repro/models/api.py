"""Model API: one interface over all ten architectures.

``build_model(cfg)`` returns a :class:`Model` with:
  * ``param_specs()``      — ParamSpec tree (drives init, sharding, dry-run)
  * ``loss(params, batch)``— next-token CE loss (train_step's core)
  * ``prefill(params, batch, max_len)`` — full-sequence forward + KV cache
  * ``decode_step(params, cache, tokens, pos)`` — one-token serve step
  * ``cache_specs(batch, max_len)`` — ParamSpec tree for the decode cache
  * ``batch_specs(batch, seq)`` — ParamSpec tree for input batches

Batches are dicts: tokens/labels int32[B, S]; VLM adds patch_embeds
f32[B, P, d]; audio adds frames f32[B, F, d].
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import transformer as tfm
from repro.models.common import ParamSpec, rms_norm, rope
from repro.models.config import ArchConfig

PyTree = Any

__all__ = ["Model", "build_model"]


def stack_specs(count: int, tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: ParamSpec(
            (count,) + s.shape, ("layers",) + s.names, dtype=s.dtype,
            init=s.init, scale=s.scale,
        ),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                   mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token CE in fp32; logits [B, S, V], labels int32 [B, S]."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(
        jnp.sum(jnp.exp(logits - m), axis=-1)
    )
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # ----------------------------------------------------------------- specs
    def param_specs(self) -> PyTree:
        cfg = self.cfg
        if cfg.family == "ssm":
            return self._rwkv_specs()
        if cfg.family == "audio":
            return self._whisper_specs()
        d, v = cfg.d_model, cfg.vocab_size
        specs: Dict[str, Any] = {
            "embed": ParamSpec(
                (v, d), ("vocab", "embed_fsdp"), dtype=jnp.bfloat16,
                init="embed", scale=0.02,
            ),
            "final_norm": ParamSpec((d,), (None,), dtype=jnp.bfloat16,
                                    init="ones"),
        }
        if not cfg.tie_embeddings:
            specs["unembed"] = ParamSpec(
                (d, v), ("hidden", "vocab"), dtype=jnp.bfloat16,
                scale=1.0 / math.sqrt(d),
            )
        for gi, g in enumerate(tfm.layer_groups(cfg)):
            specs[f"group{gi}"] = stack_specs(
                g.count, tfm.layer_specs(cfg, g.kind)
            )
        return specs

    def batch_specs(self, batch: int, seq: int) -> Dict[str, ParamSpec]:
        cfg = self.cfg
        b: Dict[str, ParamSpec] = {
            "tokens": ParamSpec((batch, seq), ("batch", None),
                                dtype=jnp.int32),
            "labels": ParamSpec((batch, seq), ("batch", None),
                                dtype=jnp.int32),
        }
        if cfg.family == "vlm" and cfg.vision_prefix:
            b["patch_embeds"] = ParamSpec(
                (batch, cfg.vision_prefix, cfg.d_model),
                ("batch", None, None), dtype=jnp.bfloat16,
            )
        if cfg.family == "audio":
            b["frames"] = ParamSpec(
                (batch, cfg.encoder_seq, cfg.d_model),
                ("batch", None, None), dtype=jnp.bfloat16,
            )
        return b

    # ----------------------------------------------------------- embeddings
    def _embed(self, params, tokens):
        x = params["embed"][tokens]
        if self.cfg.embed_scale:
            x = x * jnp.asarray(
                math.sqrt(self.cfg.d_model), x.dtype
            )
        return constrain(x, ("batch", "seq", None))

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"])
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = x @ w
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(
                logits / cfg.logit_softcap
            )
        return constrain(logits, ("batch", "seq", None))

    # ----------------------------------------------------------------- loss
    def loss(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.family == "ssm":
            return self._rwkv_loss(params, batch)
        if cfg.family == "audio":
            return self._whisper_loss(params, batch)
        tokens, labels = batch["tokens"], batch["labels"]
        x = self._embed(params, tokens)
        prefix = 0
        if cfg.family == "vlm" and cfg.vision_prefix:
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(x.dtype), x], axis=1
            )
            prefix = cfg.vision_prefix
        x = self._run_groups_train(params, x)
        if prefix:
            x = x[:, prefix:]
        logits = self._logits(params, x)
        return _cross_entropy(logits, labels)

    def _run_groups_train(self, params, x):
        cfg = self.cfg
        sin, cos = rope(
            jnp.arange(x.shape[1]), cfg.head_dim if not cfg.mla
            else cfg.mla_qk_rope_dim, cfg.rope_theta,
        )
        for gi, g in enumerate(tfm.layer_groups(cfg)):
            windows = jnp.asarray(g.windows, dtype=jnp.int32)

            def body(carry, xs, _kind=g.kind):
                lp, win = xs
                out = tfm.layer_apply_train(
                    cfg, _kind, lp, carry, sin, cos, win
                )
                return out, None

            body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, (params[f"group{gi}"], windows))
        return x

    # -------------------------------------------------------------- serving
    def cache_specs(self, batch: int, max_len: int) -> PyTree:
        cfg = self.cfg
        if cfg.family == "ssm":
            return self._rwkv_cache_specs(batch)
        if cfg.family == "audio":
            return self._whisper_cache_specs(batch, max_len)
        dt = jnp.bfloat16
        caches = {}
        for gi, g in enumerate(tfm.layer_groups(cfg)):
            if cfg.mla:
                c = {
                    "ckv": ParamSpec(
                        (g.count, batch, max_len, cfg.mla_kv_lora_rank),
                        ("layers", "batch", "seq", None), dtype=dt,
                        init="zeros",
                    ),
                    "kr": ParamSpec(
                        (g.count, batch, max_len, cfg.mla_qk_rope_dim),
                        ("layers", "batch", "seq", None), dtype=dt,
                        init="zeros",
                    ),
                }
            else:
                t = max_len
                if cfg.family == "hybrid" and cfg.window:
                    t = min(max_len, cfg.window)  # ring buffer
                c = {
                    "k": ParamSpec(
                        (g.count, batch, t, cfg.num_kv_heads, cfg.head_dim),
                        ("layers", "batch", "seq", "kv_heads", None),
                        dtype=dt, init="zeros",
                    ),
                    "v": ParamSpec(
                        (g.count, batch, t, cfg.num_kv_heads, cfg.head_dim),
                        ("layers", "batch", "seq", "kv_heads", None),
                        dtype=dt, init="zeros",
                    ),
                }
            if cfg.hybrid_parallel:
                from repro.models.ssm import _dims

                d_in, _, n, k = _dims(cfg)
                c["conv"] = ParamSpec(
                    (g.count, batch, k - 1, d_in),
                    ("layers", "batch", None, "ffn"), dtype=dt, init="zeros",
                )
                c["ssm"] = ParamSpec(
                    (g.count, batch, d_in, n),
                    ("layers", "batch", "ffn", None), dtype=jnp.float32,
                    init="zeros",
                )
            caches[f"group{gi}"] = c
        return caches

    def decode_step(self, params, cache, tokens, pos):
        """tokens int32[B, 1]; pos int32 scalar.  Returns (logits, cache)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return self._rwkv_decode(params, cache, tokens, pos)
        if cfg.family == "audio":
            return self._whisper_decode(params, cache, tokens, pos)
        x = self._embed(params, tokens)
        sin, cos = rope(
            jnp.full((tokens.shape[0], 1), pos),
            cfg.head_dim if not cfg.mla else cfg.mla_qk_rope_dim,
            cfg.rope_theta,
        )
        new_cache = {}
        for gi, g in enumerate(tfm.layer_groups(cfg)):
            windows = jnp.asarray(g.windows, dtype=jnp.int32)

            def body(carry, xs, _kind=g.kind):
                lp, win, lcache = xs
                out, nc = tfm.layer_apply_decode(
                    cfg, _kind, lp, carry, sin, cos, win, lcache, pos
                )
                return out, nc

            x, nc = jax.lax.scan(
                body, x, (params[f"group{gi}"], windows, cache[f"group{gi}"])
            )
            new_cache[f"group{gi}"] = nc
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    def prefill(self, params, batch, max_len: int):
        """Run the full prompt, return (last-token logits, decode cache)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return self._rwkv_prefill(params, batch, max_len)
        if cfg.family == "audio":
            return self._whisper_prefill(params, batch, max_len)
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        if cfg.family == "vlm" and cfg.vision_prefix:
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(x.dtype), x], axis=1
            )
        s = x.shape[1]
        sin, cos = rope(
            jnp.arange(s),
            cfg.head_dim if not cfg.mla else cfg.mla_qk_rope_dim,
            cfg.rope_theta,
        )
        cache = {}
        for gi, g in enumerate(tfm.layer_groups(cfg)):
            windows = jnp.asarray(g.windows, dtype=jnp.int32)

            def body(carry, xs, _kind=g.kind):
                lp, win = xs
                h = rms_norm(
                    carry, lp["ln1"], offset=1.0 if cfg.post_block_norms else 0.0
                )
                h = constrain(h, ("batch", None, None))  # bf16 gather point
                attn_fn = (
                    tfm.mla_apply_train if cfg.mla else tfm.gqa_apply_train
                )
                attn_out, kv = attn_fn(cfg, lp["attn"], h, sin, cos, win)
                lc = {}
                if cfg.mla:
                    lc["ckv"], lc["kr"] = kv
                else:
                    lc["k"], lc["v"] = kv
                if cfg.hybrid_parallel:
                    from repro.models.ssm import mamba_prefill_state

                    ssm_out, conv_s, ssm_s = mamba_prefill_state(
                        cfg, lp["ssm"], h
                    )
                    lc["conv"], lc["ssm"] = conv_s, ssm_s
                    attn_out = 0.5 * (
                        rms_norm(attn_out, lp["attn_norm"])
                        + rms_norm(ssm_out, lp["ssm_norm"])
                    )
                if cfg.post_block_norms:
                    attn_out = rms_norm(attn_out, lp["ln1_post"], offset=1.0)
                xx = carry + attn_out
                h2 = rms_norm(
                    xx, lp["ln2"], offset=1.0 if cfg.post_block_norms else 0.0
                )
                h2 = constrain(h2, ("batch", None, None))  # bf16 gather point
                ffn_out = (
                    tfm.moe_apply(cfg, lp["ffn"], h2)
                    if _kind == "moe"
                    else tfm.ffn_apply(cfg, lp["ffn"], h2)
                )
                if cfg.post_block_norms:
                    ffn_out = rms_norm(ffn_out, lp["ln2_post"], offset=1.0)
                return xx + ffn_out, lc

            x, kvs = jax.lax.scan(body, x, (params[f"group{gi}"], windows))
            cache[f"group{gi}"] = self._pad_prefill_cache(kvs, s, max_len)
        logits = self._logits(params, x[:, -1:, :])[:, 0]
        return logits, cache

    def _pad_prefill_cache(self, kvs: Dict[str, jnp.ndarray], s: int,
                           max_len: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        out = {}
        for k, v in kvs.items():
            if k in ("conv", "ssm"):
                out[k] = v
                continue
            if cfg.family == "hybrid" and cfg.window and k in ("k", "v"):
                w = min(max_len, cfg.window)
                tail = v[:, :, -w:]
                out[k] = jnp.roll(tail, shift=s % w, axis=2)
                continue
            pad = max_len - v.shape[2]
            if pad > 0:
                widths = [(0, 0)] * v.ndim
                widths[2] = (0, pad)
                v = jnp.pad(v, widths)
            out[k] = v
        return out

    # ------------------------------------------------------------- RWKV-6
    def _rwkv_specs(self):
        from repro.models.rwkv import rwkv_layer_specs

        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab_size
        return {
            "embed": ParamSpec((v, d), ("vocab", "embed_fsdp"),
                               dtype=jnp.bfloat16, init="embed", scale=0.02),
            "final_norm": ParamSpec((d,), (None,), dtype=jnp.bfloat16,
                                    init="ones"),
            "unembed": ParamSpec((d, v), ("hidden", "vocab"),
                                 dtype=jnp.bfloat16,
                                 scale=1.0 / math.sqrt(d)),
            "layers": stack_specs(cfg.num_layers, rwkv_layer_specs(cfg)),
        }

    def _rwkv_cache_specs(self, batch):
        from repro.models.rwkv import rwkv_heads

        cfg = self.cfg
        h, hd = rwkv_heads(cfg)
        L, d = cfg.num_layers, cfg.d_model
        return {
            "shift1": ParamSpec((L, batch, d), ("layers", "batch", None),
                                dtype=jnp.bfloat16, init="zeros"),
            "shift2": ParamSpec((L, batch, d), ("layers", "batch", None),
                                dtype=jnp.bfloat16, init="zeros"),
            "wkv": ParamSpec((L, batch, h, hd, hd),
                             ("layers", "batch", "heads", None, None),
                             dtype=jnp.float32, init="zeros"),
        }

    def _rwkv_run(self, params, x, state=None, collect_state=False):
        from repro.models.rwkv import rwkv_layer_train

        cfg = self.cfg

        def body(carry, xs):
            if state is None:
                lp = xs
                st = None
            else:
                lp, st = xs
            out, new_st = rwkv_layer_train(cfg, lp, carry, st)
            return out, new_st if collect_state else None

        xs = params["layers"] if state is None else (params["layers"], state)
        x, states = jax.lax.scan(jax.checkpoint(body), x, xs)
        return x, states

    def _rwkv_loss(self, params, batch):
        x = self._embed(params, batch["tokens"])
        x, _ = self._rwkv_run(params, x)
        logits = self._logits(params, x)
        return _cross_entropy(logits, batch["labels"])

    def _rwkv_prefill(self, params, batch, max_len):
        del max_len  # constant-size state
        cfg = self.cfg
        from repro.models.rwkv import rwkv_heads

        b = batch["tokens"].shape[0]
        h, hd = rwkv_heads(cfg)
        zero_state = (
            jnp.zeros((cfg.num_layers, b, cfg.d_model), jnp.bfloat16),
            jnp.zeros((cfg.num_layers, b, cfg.d_model), jnp.bfloat16),
            jnp.zeros((cfg.num_layers, b, h, hd, hd), jnp.float32),
        )
        x = self._embed(params, batch["tokens"])
        x, states = self._rwkv_run(
            params, x, state=zero_state, collect_state=True
        )
        logits = self._logits(params, x[:, -1:, :])[:, 0]
        s1, s2, wkv = states
        return logits, {"shift1": s1, "shift2": s2, "wkv": wkv}

    def _rwkv_decode(self, params, cache, tokens, pos):
        del pos
        x = self._embed(params, tokens)
        x, states = self._rwkv_run(
            params, x,
            state=(cache["shift1"], cache["shift2"], cache["wkv"]),
            collect_state=True,
        )
        s1, s2, wkv = states
        logits = self._logits(params, x)[:, 0]
        return logits, {"shift1": s1, "shift2": s2, "wkv": wkv}

    # ------------------------------------------------------------- whisper
    def _whisper_specs(self):
        from repro.models import encdec

        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab_size
        return {
            "embed": ParamSpec((v, d), ("vocab", "embed_fsdp"),
                               dtype=jnp.bfloat16, init="embed", scale=0.02),
            "pos_embed": ParamSpec((40960, d), (None, "embed_fsdp"),
                                   dtype=jnp.bfloat16, init="embed",
                                   scale=0.01),
            "enc_pos_embed": ParamSpec(
                (cfg.encoder_seq, d), (None, "embed_fsdp"),
                dtype=jnp.bfloat16, init="embed", scale=0.01,
            ),
            "final_norm": ParamSpec((d,), (None,), dtype=jnp.bfloat16,
                                    init="ones"),
            "enc_final_norm": ParamSpec((d,), (None,), dtype=jnp.bfloat16,
                                        init="ones"),
            "unembed": ParamSpec((d, v), ("hidden", "vocab"),
                                 dtype=jnp.bfloat16,
                                 scale=1.0 / math.sqrt(d)),
            "encoder": stack_specs(
                cfg.encoder_layers, encdec.encoder_layer_specs(cfg)
            ),
            "decoder": stack_specs(
                cfg.num_layers, encdec.decoder_layer_specs(cfg)
            ),
        }

    def _whisper_encode(self, params, frames):
        from repro.models.encdec import encoder_layer_apply

        cfg = self.cfg
        x = frames.astype(jnp.bfloat16) + params["enc_pos_embed"][None]
        x = constrain(x, ("batch", "seq", None))

        def body(carry, lp):
            return encoder_layer_apply(cfg, lp, carry), None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"])
        return rms_norm(x, params["enc_final_norm"])

    def _whisper_loss(self, params, batch):
        from repro.models.encdec import decoder_layer_train

        cfg = self.cfg
        enc_out = self._whisper_encode(params, batch["frames"])
        tokens, labels = batch["tokens"], batch["labels"]
        s = tokens.shape[1]
        x = params["embed"][tokens] + params["pos_embed"][None, :s]
        x = constrain(x, ("batch", "seq", None))
        sin, cos = rope(jnp.arange(s), cfg.head_dim, cfg.rope_theta)

        def body(carry, lp):
            out, _, _ = decoder_layer_train(cfg, lp, carry, enc_out, sin, cos)
            return out, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["decoder"])
        logits = self._logits(params, x)
        return _cross_entropy(logits, labels)

    def _whisper_cache_specs(self, batch, max_len):
        cfg = self.cfg
        dt = jnp.bfloat16
        L = cfg.num_layers
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        return {
            "k": ParamSpec((L, batch, max_len, kv, hd),
                           ("layers", "batch", "seq", "kv_heads", None),
                           dtype=dt, init="zeros"),
            "v": ParamSpec((L, batch, max_len, kv, hd),
                           ("layers", "batch", "seq", "kv_heads", None),
                           dtype=dt, init="zeros"),
            "ck": ParamSpec((L, batch, cfg.encoder_seq, kv, hd),
                            ("layers", "batch", "seq", "kv_heads", None),
                            dtype=dt, init="zeros"),
            "cv": ParamSpec((L, batch, cfg.encoder_seq, kv, hd),
                            ("layers", "batch", "seq", "kv_heads", None),
                            dtype=dt, init="zeros"),
        }

    def _whisper_prefill(self, params, batch, max_len):
        from repro.models.encdec import decoder_layer_train

        cfg = self.cfg
        enc_out = self._whisper_encode(params, batch["frames"])
        tokens = batch["tokens"]
        s = tokens.shape[1]
        x = params["embed"][tokens] + params["pos_embed"][None, :s]
        sin, cos = rope(jnp.arange(s), cfg.head_dim, cfg.rope_theta)

        def body(carry, lp):
            out, (k, v), (ck, cv) = decoder_layer_train(
                cfg, lp, carry, enc_out, sin, cos
            )
            return out, {"k": k, "v": v, "ck": ck, "cv": cv}

        x, kvs = jax.lax.scan(body, x, params["decoder"])
        cache = self._pad_prefill_cache(
            {"k": kvs["k"], "v": kvs["v"]}, s, max_len
        )
        cache["ck"], cache["cv"] = kvs["ck"], kvs["cv"]
        logits = self._logits(params, x[:, -1:, :])[:, 0]
        return logits, cache

    def _whisper_decode(self, params, cache, tokens, pos):
        from repro.models.encdec import decoder_layer_decode

        cfg = self.cfg
        x = params["embed"][tokens] + params["pos_embed"][None, pos][None]
        sin, cos = rope(
            jnp.full((tokens.shape[0], 1), pos), cfg.head_dim, cfg.rope_theta
        )

        def body(carry, xs):
            lp, lc = xs
            out, nc = decoder_layer_decode(cfg, lp, carry, lc, sin, cos, pos)
            return out, nc

        x, nc = jax.lax.scan(body, x, (params["decoder"], cache))
        logits = self._logits(params, x)[:, 0]
        return logits, nc


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
