"""Architecture configuration — drives the composable model library."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One LM architecture (assigned-pool entry or reduced smoke config)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention variants
    qkv_bias: bool = False  # qwen1.5
    attn_softcap: Optional[float] = None  # gemma2 (50.0)
    logit_softcap: Optional[float] = None  # gemma2 (30.0)
    window: Optional[int] = None  # sliding-window size for local layers
    local_global_pattern: Optional[Tuple[str, ...]] = None  # e.g. ("local","global")
    rope_theta: float = 10000.0
    post_block_norms: bool = False  # gemma2 post-attn/post-ffn norms
    ffn_activation: str = "silu"  # silu | gelu
    gated_ffn: bool = True  # False: classic 2-matrix MLP (whisper)
    embed_scale: bool = False  # gemma2: embeddings scaled by sqrt(d)

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 1
    moe_num_shared: int = 0
    moe_d_ff: Optional[int] = None  # expert FFN width (deepseek: 2048)
    moe_first_dense: int = 0  # leading dense layers (deepseek: 3)
    moe_every: int = 1  # MoE block every k-th layer

    # MLA (deepseek-v3)
    mla: bool = False
    mla_q_lora_rank: int = 1536
    mla_kv_lora_rank: int = 512
    mla_qk_nope_dim: int = 128
    mla_qk_rope_dim: int = 64
    mla_v_dim: int = 128

    # SSM / RWKV / hybrid
    ssm_state: int = 0  # mamba state size (hymba: 16)
    ssm_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_size: int = 64
    hybrid_parallel: bool = False  # hymba: parallel attn + ssm heads

    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500  # audio frames after conv stub
    cross_attention: bool = False

    # VLM
    vision_prefix: int = 0  # number of (stubbed) patch embeddings

    # training
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(
                self, "head_dim", self.d_model // self.num_heads
            )
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(
                f"{self.name}: H={self.num_heads} not a multiple of "
                f"KV={self.num_kv_heads}"
            )

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """True for sub-quadratic archs (SSM / hybrid w/ sliding window)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----
    def param_count(self, active_only: bool = False) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        h, kv = self.num_heads, self.num_kv_heads
        n_layers = self.num_layers

        if self.mla:
            qk_dim = self.mla_qk_nope_dim + self.mla_qk_rope_dim
            attn = (
                d * self.mla_q_lora_rank
                + self.mla_q_lora_rank * h * qk_dim
                + d * (self.mla_kv_lora_rank + self.mla_qk_rope_dim)
                + self.mla_kv_lora_rank
                * h
                * (self.mla_qk_nope_dim + self.mla_v_dim)
                + h * self.mla_v_dim * d
            )
        elif self.family == "ssm":  # rwkv6
            # r,k,v,g,w,o projections + channel-mix
            attn = 6 * d * d
        else:
            attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            if self.hybrid_parallel:
                d_in = self.ssm_expand * d
                attn += 2 * d * d_in + d_in * d + d_in * (
                    2 * self.ssm_state + 1
                )

        if self.family == "ssm":
            ffn_dense = int(1.5 * 2 * d * ff)  # rwkv channel mix (k,v,r)
        elif self.ffn_activation in ("silu", "gelu"):
            ffn_dense = 3 * d * ff  # gated
        else:
            ffn_dense = 2 * d * ff

        total = 0
        active = 0
        for layer in range(n_layers):
            is_moe = (
                self.moe_num_experts > 0
                and layer >= self.moe_first_dense
                and (layer - self.moe_first_dense) % self.moe_every == 0
            )
            if is_moe:
                eff = self.moe_d_ff or ff
                routed = self.moe_num_experts * 3 * d * eff
                shared = self.moe_num_shared * 3 * d * eff
                router = d * self.moe_num_experts
                total += attn + routed + shared + router
                active += (
                    attn + self.moe_top_k * 3 * d * eff + shared + router
                )
            else:
                total += attn + ffn_dense
                active += attn + ffn_dense
        emb = v * d * (1 if self.tie_embeddings else 2)
        total += emb + d
        active += emb + d
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + ffn_dense)
            total += enc
            active += enc
        if self.cross_attention:
            ca = n_layers * (2 * d * d + 2 * d * (kv * hd))
            total += ca
            active += ca
        return active if active_only else total
