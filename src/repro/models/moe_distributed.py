"""Expert-parallel MoE via shard_map: scatter dispatch + all-to-all.

The dense one-hot dispatch in ``transformer.moe_apply`` materializes an
[E, T, C] tensor — fine for smoke tests, catastrophic at deepseek-v3 scale
(256 experts x 1M tokens).  At scale we switch to the TPU-native
expert-parallel pattern, written explicitly with shard_map so the collective
schedule is deterministic and visible to the roofline analysis:

  1. each (data, model) shard routes a disjoint slice of its tokens
     (model-axis slice of the data-shard — tokens are replicated over the
     model axis on entry, so each model shard takes 1/|model| of them);
  2. position-in-expert is computed by **sort-rank** (argsort by expert id,
     segment-relative ranks) — O(T log T), no [T, E] one-hot;
  3. tokens are scattered into a per-shard [E, C, d] send buffer;
  4. ``all_to_all`` over the model axis exchanges expert shards:
     [E, C, d] -> [E/m, C*m, d] — every chip now holds *its* experts' tokens;
  5. expert FFNs run as batched matmuls over the local expert dim
     (weights EP-sharded over "model", FSDP-gathered over ("pod","data"));
  6. reverse all_to_all + gather-back + gate-weighted combine;
  7. psum over "model" reassembles the full token slice.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig

__all__ = ["moe_apply_sharded", "sort_rank"]

FULL_EP = True  # see moe_apply_sharded docstring


def sort_rank(expert_ids: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """rank[i] = #(j < i with expert_ids[j] == expert_ids[i]), via argsort.

    No [T, E] materialization: sort by expert, compute segment-relative
    ranks with a cummax over segment starts, invert the permutation.
    """
    n = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    seg_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    rank_sorted = idx - seg_start
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    return rank


def _gate(cfg: ArchConfig, logits: jnp.ndarray):
    gates, chosen = jax.lax.top_k(logits, cfg.moe_top_k)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, chosen


def moe_apply_sharded(cfg: ArchConfig, p: Dict[str, Any], x: jnp.ndarray,
                      policy) -> jnp.ndarray:
    """x: [B, S, d] -> [B, S, d], expert-parallel.

    Two EP layouts (§Perf iteration 6):

      * **full EP** (E divisible by |data|x|model|, e.g. deepseek's 256
        experts on a 16x16 pod): each chip owns whole experts, weights are
        NEVER gathered (the FSDP per-layer expert gathers were ~40% of
        deepseek's collective bytes), and tokens route with a single
        all-to-all over the fused (data, model) axes.  Output returns via
        psum_scatter so each model shard receives exactly its sequence
        shard (half the wire of a full psum).
      * **model-axis EP** (small E, e.g. llama4's 16): experts over "model"
        only, FSDP-gathered over data, all-to-all over "model".
    """
    mesh = policy.mesh
    # inside an enclosing manual region (the pod-manual compressed train
    # step) shard_map must receive the CONTEXT abstract mesh — its pod axis
    # is already Manual; the concrete Mesh would mismatch.
    ctx_mesh = jax.sharding.get_abstract_mesh()
    if ctx_mesh.axis_names and set(mesh.axis_names) <= set(
        ctx_mesh.axis_names
    ):
        smap_mesh = None  # infer from context (handles nested manual axes)
    else:
        smap_mesh = mesh
    fsdp_axes = policy.fsdp_axes
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    t_total = b * s
    ne, topk = cfg.moe_num_experts, cfg.moe_top_k
    nm = mesh.shape["model"]
    ndp = 1
    for a in fsdp_axes:
        ndp *= mesh.shape[a]
    t_loc = t_total // ndp
    t_eff = max(t_loc // nm, 1)
    cap = -(-2 * t_eff * topk // ne)
    cap = max(8, -(-cap // 8) * 8)  # round up to 8

    # full EP shards whole experts over ("data", "model"); "pod" stays pure
    # data-parallel (expert replicas per pod — the grads are exactly what the
    # FPTC pod-axis compression reduces).  FULL_EP can be forced off: the
    # vmap'd compressed-DP path trips a GSPMD crash on the full-EP block
    # (batched 2-stage all_to_all), so compressed runs use model-axis EP.
    n_data = mesh.shape.get("data", 1)
    full_ep = (
        FULL_EP
        and "data" in mesh.axis_names
        and n_data > 1
        and ne % (n_data * nm) == 0
    )

    def route(xj, router):
        logits = xj.astype(jnp.float32) @ router
        gates, chosen = _gate(cfg, logits)
        e_flat = chosen.reshape(-1).astype(jnp.int32)
        g_flat = gates.reshape(-1)
        rank = sort_rank(e_flat, ne)
        keep = rank < cap
        slot = jnp.where(keep, rank, cap - 1)
        return e_flat, g_flat, keep, slot

    def combine(back, e_flat, slot, keep, g_flat, dtype):
        y_dup = back[e_flat, slot] * keep[:, None].astype(dtype)
        return jnp.sum(
            y_dup.reshape(t_eff, topk, d)
            * g_flat.reshape(t_eff, topk, 1).astype(dtype),
            axis=1,
        )

    if full_ep:
        ep_axes = ("data", "model")
        # Inside an enclosing pod-manual region the SPMD partitioner cannot
        # build device groups for a fused-axis all_to_all (fatal check in
        # spmd_partitioner_util) — use a hierarchical 2-stage exchange
        # (data hop, then model hop; ~1.9x the flat wire, matching how a 2D
        # torus runs all-to-all anyway).  Flat fused a2a is kept for the
        # non-nested path.
        nested = smap_mesh is None

        def a2a_fwd(buf):
            if not nested:
                return jax.lax.all_to_all(
                    buf, ep_axes, split_axis=0, concat_axis=1, tiled=True
                )
            buf = jax.lax.all_to_all(
                buf, "data", split_axis=0, concat_axis=1, tiled=True
            )
            return jax.lax.all_to_all(
                buf, "model", split_axis=0, concat_axis=1, tiled=True
            )

        def a2a_rev(buf):
            if not nested:
                return jax.lax.all_to_all(
                    buf, ep_axes, split_axis=1, concat_axis=0, tiled=True
                )
            buf = jax.lax.all_to_all(
                buf, "model", split_axis=1, concat_axis=0, tiled=True
            )
            return jax.lax.all_to_all(
                buf, "data", split_axis=1, concat_axis=0, tiled=True
            )

        def block(x_loc, model_id, router, wi, wg, wo):
            # x_loc: [T_loc, d]; wi/wg: [E/(ndp*m), d, eff] — whole experts
            # model_id: int32[1], this shard's model-axis index (passed as a
            # sharded iota — lax.axis_index inside nested shard_map trips a
            # Shardy hoisting bug under remat; see §Perf iteration 7 notes)
            j = model_id[0]
            xj = jax.lax.dynamic_slice(x_loc, (j * t_eff, 0), (t_eff, d))
            e_flat, g_flat, keep, slot = route(xj, router)
            xdup = jnp.repeat(xj, topk, axis=0)
            send = jnp.zeros((ne, cap, d), x_loc.dtype)
            send = send.at[e_flat, slot].add(
                xdup * keep[:, None].astype(x_loc.dtype)
            )
            recv = a2a_fwd(send)  # [E/(nd*m), cap*nd*m, d]
            h = jax.nn.silu(
                jnp.einsum("ecd,edf->ecf", recv, wg)
            ) * jnp.einsum("ecd,edf->ecf", recv, wi)
            out_e = jnp.einsum("ecf,efd->ecd", h, wo)
            back = a2a_rev(out_e)  # [E, cap, d]
            y = combine(back, e_flat, slot, keep, g_flat, x_loc.dtype)
            out = jnp.zeros((x_loc.shape[0], d), x_loc.dtype)
            out = jax.lax.dynamic_update_slice(out, y, (j * t_eff, 0))
            if nested:
                # psum_scatter's transpose rule trips the same partitioner
                # fatal inside a pod-manual region; fall back to psum there
                return jax.lax.psum(out, "model")
            # each model shard needs only its sequence shard downstream
            # (SP residual): psum_scatter = half the wire of psum
            return jax.lax.psum_scatter(
                out, "model", scatter_dimension=0, tiled=True
            )

        bp = fsdp_axes
        wspec = P(("data", "model"), None, None)
        model_ids = jnp.arange(nm, dtype=jnp.int32)
        out2 = jax.shard_map(
            block,
            mesh=smap_mesh,
            in_specs=(P(bp, None), P("model"), P(None, None),
                      wspec, wspec, wspec),
            out_specs=P(bp, None) if nested else P(bp + ("model",), None),
            axis_names=set(bp) | {"data", "model"},
            check_vma=False,
        )(x2, model_ids, p["router"], p["wi"], p["wg"], p["wo"])
        return out2.reshape(b, s, d)

    def block(x_loc, model_id, router, wi, wg, wo):
        # x_loc: [T_loc, d]; wi/wg: [E/m, d/ndp, eff]; wo: [E/m, eff, d/ndp]
        j = model_id[0]
        xj = jax.lax.dynamic_slice(
            x_loc, (j * t_eff, 0), (t_eff, d)
        )  # [T_eff, d]
        e_flat, g_flat, keep, slot = route(xj, router)

        xdup = jnp.repeat(xj, topk, axis=0)  # [T_eff*k, d]
        send = jnp.zeros((ne, cap, d), x_loc.dtype)
        send = send.at[e_flat, slot].add(
            xdup * keep[:, None].astype(x_loc.dtype)
        )
        # exchange: every model shard receives its experts' tokens
        recv = jax.lax.all_to_all(
            send, "model", split_axis=0, concat_axis=1, tiled=True
        )  # [E/m, cap*m, d]

        # FSDP gather of expert weights over (pod, data)
        if fsdp_axes:
            wi = jax.lax.all_gather(wi, fsdp_axes, axis=1, tiled=True)
            wg = jax.lax.all_gather(wg, fsdp_axes, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, fsdp_axes, axis=2, tiled=True)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, wg)) * jnp.einsum(
            "ecd,edf->ecf", recv, wi
        )
        out_e = jnp.einsum("ecf,efd->ecd", h, wo)  # [E/m, cap*m, d]

        back = jax.lax.all_to_all(
            out_e, "model", split_axis=1, concat_axis=0, tiled=True
        )  # [E, cap, d]
        y = combine(back, e_flat, slot, keep, g_flat, x_loc.dtype)
        out = jnp.zeros((x_loc.shape[0], d), x_loc.dtype)
        out = jax.lax.dynamic_update_slice(out, y, (j * t_eff, 0))
        return jax.lax.psum(out, "model")

    bp = fsdp_axes if fsdp_axes else None
    manual = set(fsdp_axes) | {"model"}
    model_ids = jnp.arange(nm, dtype=jnp.int32)
    out2 = jax.shard_map(
        block,
        mesh=smap_mesh,
        in_specs=(
            P(bp, None),
            P("model"),
            P(None, None),
            P("model", bp, None),
            P("model", bp, None),
            P("model", None, bp),
        ),
        out_specs=P(bp, None),
        axis_names=manual,
        check_vma=False,
    )(x2, model_ids, p["router"], p["wi"], p["wg"], p["wo"])
    return out2.reshape(b, s, d)
