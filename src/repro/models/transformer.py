"""Composable decoder-only transformer: GQA / MoE / MLA / local-global.

One scan-based stack serves granite, minitron, gemma2, qwen1.5, llama4-scout,
deepseek-v3 and the internvl2 LM backbone.  Per-layer structural differences
are handled two ways:
  * *parameter-identical* variation (gemma2 local/global alternation) rides
    through the scan as a per-layer ``window`` array;
  * *parameter-structural* variation (deepseek's leading dense layers before
    the MoE stack) becomes separate scan groups with their own stacked params.

Everything is pure JAX; sharding is expressed through logical dim names on
ParamSpecs plus ``repro.distributed.sharding.constrain`` calls on activations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import (
    ParamSpec,
    apply_rope,
    attention,
    decode_attention,
    rms_norm,
    rope,
)
from repro.models.config import ArchConfig

PyTree = Any


# ---------------------------------------------------------------------------
# Attention blocks
# ---------------------------------------------------------------------------
def gqa_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.bfloat16
    p = {
        "wq": ParamSpec((d, h, hd), ("hidden", "heads", None), dtype=dt),
        "wk": ParamSpec((d, kv, hd), ("hidden", "kv_heads", None), dtype=dt),
        "wv": ParamSpec((d, kv, hd), ("hidden", "kv_heads", None), dtype=dt),
        "wo": ParamSpec((h, hd, d), ("heads", None, "hidden"), dtype=dt),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((h, hd), ("heads", None), dtype=dt, init="zeros")
        p["bk"] = ParamSpec((kv, hd), ("kv_heads", None), dtype=dt, init="zeros")
        p["bv"] = ParamSpec((kv, hd), ("kv_heads", None), dtype=dt, init="zeros")
    return p


def gqa_qkv(cfg: ArchConfig, p, x, sin, cos):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def gqa_apply_train(cfg: ArchConfig, p, x, sin, cos, window: jnp.ndarray):
    """Full-sequence attention (training / prefill). window: int32 scalar,
    0 => global."""
    q, k, v = gqa_qkv(cfg, p, x, sin, cos)
    win = None
    if cfg.window is not None or cfg.local_global_pattern is not None:
        # dynamic per-layer window rides through the scan as a traced scalar;
        # 0 means "global" and is mapped to an effectively-infinite window.
        win = jnp.where(window > 0, window, jnp.int32(2**30))
    out = attention(
        q, k, v, causal=True, window=win, softcap=cfg.attn_softcap,
        q_chunk=1024,
    )
    # bf16 dot output => the TP partial-sum all-reduce runs in bf16 (§Perf
    # iteration 5); MXU still accumulates fp32 within each partial.
    out = jnp.einsum(
        "bshk,hkd->bsd", out, p["wo"], preferred_element_type=out.dtype
    )
    return constrain(out, ("batch", "seq", None)), (k, v)


def gqa_apply_decode(cfg: ArchConfig, p, x, sin, cos, window, kc, vc, pos):
    """Single-token decode; kc/vc: [B, T, KV, hd] caches, pos: int32."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    t = kc.shape[1]
    if cfg.family == "hybrid" and cfg.window:
        slot = pos % t  # ring buffer (sliding-window cache)
    else:
        slot = pos
    kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
    if cfg.family == "hybrid" and cfg.window:
        # ring cache: every valid slot is in-window by construction
        valid = jnp.minimum(pos + 1, t)
        out = decode_attention(
            q, kc, vc, valid, softcap=cfg.attn_softcap, window=None
        )
    else:
        win = None
        if cfg.window or cfg.local_global_pattern:
            win = jnp.where(window > 0, window, jnp.int32(2**30))
        out = decode_attention(
            q, kc, vc, pos + 1, softcap=cfg.attn_softcap, window=win,
        )
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (kc, vc)


# ---------------------------------------------------------------------------
# MLA (deepseek-v3) attention
# ---------------------------------------------------------------------------
def mla_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.mla_q_lora_rank, cfg.mla_kv_lora_rank
    nope, rpe, vd = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_dim
    dt = jnp.bfloat16
    return {
        "wq_a": ParamSpec((d, qr), ("hidden", "rank"), dtype=dt),
        "q_norm": ParamSpec((qr,), ("rank",), dtype=dt, init="ones"),
        "wq_b": ParamSpec((qr, h, nope + rpe), ("rank", "heads", None), dtype=dt),
        "wkv_a": ParamSpec((d, kvr + rpe), ("hidden", "rank"), dtype=dt),
        "kv_norm": ParamSpec((kvr,), ("rank",), dtype=dt, init="ones"),
        "wkv_b": ParamSpec((kvr, h, nope + vd), ("rank", "heads", None), dtype=dt),
        "wo": ParamSpec((h, vd, d), ("heads", None, "hidden"), dtype=dt),
    }


def mla_apply_train(cfg: ArchConfig, p, x, sin, cos, window):
    del window
    nope, rpe, vd = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_dim
    kvr = cfg.mla_kv_lora_rank
    cq = rms_norm(x @ p["wq_a"], p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, sin, cos)

    ckv_full = x @ p["wkv_a"]  # [B, S, kvr + rpe]
    c_kv = rms_norm(ckv_full[..., :kvr], p["kv_norm"])
    k_rope = apply_rope(ckv_full[..., None, kvr:], sin, cos)  # [B,S,1,rpe]

    kvx = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"])
    k_nope, v = kvx[..., :nope], kvx[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (rpe,))], -1
    )
    qf = jnp.concatenate([q_nope, q_rope], -1)
    qf = constrain(qf, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    out = attention(
        qf, k, v, causal=True, q_chunk=1024,
        scale=1.0 / math.sqrt(nope + rpe),
    )
    out = jnp.einsum(
        "bshk,hkd->bsd", out[..., :vd], p["wo"],
        preferred_element_type=out.dtype,
    )
    return constrain(out, ("batch", "seq", None)), (c_kv, k_rope[:, :, 0, :])


def mla_apply_decode(cfg: ArchConfig, p, x, sin, cos, window, ckv_c, kr_c, pos):
    """Absorbed-matmul MLA decode: attention runs in the *latent* space, so
    the cache stays [B, T, kv_lora] (+[B, T, rope]) — deepseek's own inference
    optimization, which is also what makes the latent FPTC-compressible."""
    del window
    nope, rpe, vd = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_dim
    kvr = cfg.mla_kv_lora_rank
    b = x.shape[0]
    cq = rms_norm(x @ p["wq_a"], p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])  # s == 1
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, sin, cos)

    ckv_full = x @ p["wkv_a"]
    c_kv_new = rms_norm(ckv_full[..., :kvr], p["kv_norm"])  # [B,1,kvr]
    k_rope_new = apply_rope(ckv_full[..., None, kvr:], sin, cos)[:, :, 0, :]

    ckv_c = jax.lax.dynamic_update_slice(ckv_c, c_kv_new, (0, pos, 0))
    kr_c = jax.lax.dynamic_update_slice(kr_c, k_rope_new, (0, pos, 0))

    # absorb: q_nope' = q_nope @ wkv_b[:, :, :nope]^T  -> latent space
    wkb_k = p["wkv_b"][..., :nope]  # [kvr, H, nope]
    wkb_v = p["wkv_b"][..., nope:]  # [kvr, H, vd]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wkb_k)  # [B,1,H,kvr]

    scale = 1.0 / math.sqrt(nope + rpe)
    scores = (
        jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32), ckv_c.astype(jnp.float32))
        + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32), kr_c.astype(jnp.float32))
    ) * scale  # [B,H,1,T]
    t = ckv_c.shape[1]
    mask = jnp.arange(t)[None, None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhst,btr->bshr", probs.astype(ckv_c.dtype), ckv_c)
    out = jnp.einsum("bshr,rhk->bshk", out_lat, wkb_v)  # [B,1,H,vd]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (ckv_c, kr_c)


# ---------------------------------------------------------------------------
# FFN blocks
# ---------------------------------------------------------------------------
def ffn_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = jnp.bfloat16
    p = {
        "wi": ParamSpec((d, ff), ("hidden", "ffn"), dtype=dt),
        "wo": ParamSpec((ff, d), ("ffn", "hidden"), dtype=dt),
    }
    if cfg.gated_ffn:
        p["wg"] = ParamSpec((d, ff), ("hidden", "ffn"), dtype=dt)
    return p


def _act(cfg: ArchConfig, x):
    if cfg.ffn_activation == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def ffn_apply(cfg: ArchConfig, p, x):
    if cfg.gated_ffn:
        h = _act(cfg, x @ p["wg"]) * (x @ p["wi"])
    else:
        h = _act(cfg, x @ p["wi"])
    h = constrain(h, ("batch", None, "ffn"))
    # bf16 dot output => bf16 TP reduce (§Perf iteration 5)
    down = jnp.einsum(
        "bsf,fd->bsd", h, p["wo"], preferred_element_type=h.dtype
    )
    return constrain(down, ("batch", "seq", None))


# ---------------------------------------------------------------------------
# MoE block (GShard-style dense dispatch via one-hot combine)
# ---------------------------------------------------------------------------
def moe_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    eff = cfg.moe_d_ff or cfg.d_ff
    ne = cfg.moe_num_experts
    dt = jnp.bfloat16
    p = {
        "router": ParamSpec((d, ne), ("hidden", None), dtype=jnp.float32),
        "wi": ParamSpec((ne, d, eff), ("experts", "hidden", None), dtype=dt),
        "wg": ParamSpec((ne, d, eff), ("experts", "hidden", None), dtype=dt),
        "wo": ParamSpec((ne, eff, d), ("experts", None, "hidden"), dtype=dt),
    }
    if cfg.moe_num_shared:
        p["shared"] = ffn_specs(
            cfg, d_ff=eff * cfg.moe_num_shared
        )
    return p


def moe_apply(cfg: ArchConfig, p, x):
    """Top-k routed experts + optional shared expert.

    Two dispatch paths:
      * **sharded** (a ShardingPolicy with a >1 "model" axis is active):
        expert-parallel shard_map with sort-rank dispatch + all_to_all —
        see ``moe_distributed`` (no [T, E, C] materialization; required at
        deepseek-v3 scale);
      * **dense fallback** (smoke tests, single device): capacity-based
        one-hot einsums (the classic GShard pattern).
    """
    from repro.distributed.sharding import current_policy

    policy = current_policy()
    if policy is not None and policy.axis_sizes.get("model", 1) > 1:
        nshards = policy.axis_sizes.get("model", 1)
        for a in policy.fsdp_axes:
            nshards *= policy.axis_sizes[a]
    if (
        policy is not None
        and getattr(policy, "allow_shard_map", True)
        and policy.axis_sizes.get("model", 1) > 1
        and cfg.moe_num_experts % policy.axis_sizes["model"] == 0
        and (x.shape[0] * x.shape[1]) // nshards >= 8  # enough tokens/shard
    ):
        from repro.models.moe_distributed import moe_apply_sharded

        out = moe_apply_sharded(cfg, p, x, policy)
        if cfg.moe_num_shared:
            out = out + ffn_apply(cfg, p["shared"], x)
        return out

    b, s, d = x.shape
    ne, topk = cfg.moe_num_experts, cfg.moe_top_k
    xf = x.reshape(b * s, d)
    n_tok = b * s

    logits = (xf.astype(jnp.float32) @ p["router"])  # [T, E]
    gates, chosen = jax.lax.top_k(logits, topk)  # [T, k]
    gates = jax.nn.softmax(gates, axis=-1)

    capacity = max(int(2 * n_tok * topk / ne), 4)
    capacity = min(capacity, n_tok)

    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(chosen, ne, dtype=jnp.int32)  # [T, k, E]
    flat_onehot = onehot.reshape(n_tok * topk, ne)
    pos_in_expert = (
        jnp.cumsum(flat_onehot, axis=0) - flat_onehot
    )  # [T*k, E]
    pos_in_expert = jnp.sum(pos_in_expert * flat_onehot, axis=-1).reshape(
        n_tok, topk
    )
    keep = pos_in_expert < capacity

    # dispatch: [T, k, E] x slot one-hot [T, k, C] -> [E, C, T] combine tensor
    slot_onehot = jax.nn.one_hot(
        jnp.where(keep, pos_in_expert, capacity), capacity, dtype=x.dtype
    )  # [T, k, C] (dropped tokens one-hot to nothing)
    dispatch = jnp.einsum(
        "tke,tkc->etc", onehot.astype(x.dtype), slot_onehot
    )  # [E, T, C] -> wait: etc = [E, T, C]
    expert_in = jnp.einsum("etc,td->ecd", dispatch, xf)  # [E, C, d]
    expert_in = constrain(expert_in, ("experts", None, None))

    h = _act(cfg, jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", expert_in, p["wi"]
    )
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"])  # [E, C, d]
    expert_out = constrain(expert_out, ("experts", None, None))

    combine = jnp.einsum(
        "tk,tke,tkc->tce",
        gates.astype(x.dtype),
        onehot.astype(x.dtype),
        slot_onehot,
    )  # [T, C, E] combine weights (gate where kept, 0 where dropped)
    out = jnp.einsum("tce,ecd->td", combine, expert_out).reshape(b, s, d)
    if cfg.moe_num_shared:
        out = out + ffn_apply(cfg, p["shared"], x)
    return out


# ---------------------------------------------------------------------------
# Decoder layer (dense or moe ffn; gqa or mla attention; optional ssm branch)
# ---------------------------------------------------------------------------
def layer_specs(cfg: ArchConfig, kind: str) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    dt = jnp.bfloat16
    p: Dict[str, Any] = {
        "ln1": ParamSpec((d,), (None,), dtype=dt, init="ones"),
        "ln2": ParamSpec((d,), (None,), dtype=dt, init="ones"),
    }
    if cfg.post_block_norms:
        p["ln1_post"] = ParamSpec((d,), (None,), dtype=dt, init="ones")
        p["ln2_post"] = ParamSpec((d,), (None,), dtype=dt, init="ones")
    p["attn"] = mla_specs(cfg) if cfg.mla else gqa_specs(cfg)
    if cfg.hybrid_parallel:
        from repro.models.ssm import mamba_specs

        p["ssm"] = mamba_specs(cfg)
        p["ssm_norm"] = ParamSpec((d,), (None,), dtype=dt, init="ones")
        p["attn_norm"] = ParamSpec((d,), (None,), dtype=dt, init="ones")
    p["ffn"] = moe_specs(cfg) if kind == "moe" else ffn_specs(cfg)
    return p


def layer_apply_train(cfg: ArchConfig, kind: str, p, x, sin, cos, window):
    """Returns (x_out, cache_contrib) — cache ignored in training.

    The residual stream is sequence-sharded over "model" (SP); each block
    gathers to full sequence at an explicit bf16 boundary after its norm and
    reduce-scatters on exit.  (§Perf iteration 3 tried a single entry-gather
    per layer: collective bytes were unchanged but full-seq liveness across
    both blocks quadrupled temp memory — refuted, reverted.)
    """
    h = rms_norm(x, p["ln1"], offset=1.0 if cfg.post_block_norms else 0.0)
    h = constrain(h, ("batch", None, None))  # bf16 seq all-gather point
    attn_fn = mla_apply_train if cfg.mla else gqa_apply_train
    attn_out, _ = attn_fn(cfg, p["attn"], h, sin, cos, window)
    if cfg.hybrid_parallel:
        from repro.models.ssm import mamba_apply_train

        ssm_out = mamba_apply_train(cfg, p["ssm"], h)
        attn_out = 0.5 * (
            rms_norm(attn_out, p["attn_norm"]) + rms_norm(ssm_out, p["ssm_norm"])
        )
    if cfg.post_block_norms:
        attn_out = rms_norm(attn_out, p["ln1_post"], offset=1.0)
    x = x + attn_out
    h = rms_norm(x, p["ln2"], offset=1.0 if cfg.post_block_norms else 0.0)
    h = constrain(h, ("batch", None, None))  # bf16 seq all-gather point
    ffn_out = moe_apply(cfg, p["ffn"], h) if kind == "moe" else ffn_apply(
        cfg, p["ffn"], h
    )
    if cfg.post_block_norms:
        ffn_out = rms_norm(ffn_out, p["ln2_post"], offset=1.0)
    x = x + ffn_out
    return constrain(x, ("batch", "seq", None))


def layer_apply_decode(cfg, kind, p, x, sin, cos, window, cache, pos):
    """cache: dict of this layer's state tensors; returns (x, new_cache)."""
    h = rms_norm(x, p["ln1"], offset=1.0 if cfg.post_block_norms else 0.0)
    if cfg.mla:
        attn_out, (c1, c2) = mla_apply_decode(
            cfg, p["attn"], h, sin, cos, window, cache["ckv"], cache["kr"], pos
        )
        new_cache = {"ckv": c1, "kr": c2}
    else:
        attn_out, (kc, vc) = gqa_apply_decode(
            cfg, p["attn"], h, sin, cos, window, cache["k"], cache["v"], pos
        )
        new_cache = {"k": kc, "v": vc}
    if cfg.hybrid_parallel:
        from repro.models.ssm import mamba_apply_decode

        ssm_out, conv_s, ssm_s = mamba_apply_decode(
            cfg, p["ssm"], h, cache["conv"], cache["ssm"]
        )
        new_cache["conv"] = conv_s
        new_cache["ssm"] = ssm_s
        attn_out = 0.5 * (
            rms_norm(attn_out, p["attn_norm"]) + rms_norm(ssm_out, p["ssm_norm"])
        )
    if cfg.post_block_norms:
        attn_out = rms_norm(attn_out, p["ln1_post"], offset=1.0)
    x = x + attn_out
    h = rms_norm(x, p["ln2"], offset=1.0 if cfg.post_block_norms else 0.0)
    ffn_out = moe_apply(cfg, p["ffn"], h) if kind == "moe" else ffn_apply(
        cfg, p["ffn"], h
    )
    if cfg.post_block_norms:
        ffn_out = rms_norm(ffn_out, p["ln2_post"], offset=1.0)
    return x + ffn_out, new_cache


# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayerGroup:
    kind: str  # "dense" | "moe"
    count: int
    windows: Tuple[int, ...]  # per-layer window (0 = global)


def layer_groups(cfg: ArchConfig) -> List[LayerGroup]:
    def window_for(layer_idx: int) -> int:
        if cfg.local_global_pattern:
            pat = cfg.local_global_pattern
            return (
                cfg.window or 0
            ) if pat[layer_idx % len(pat)] == "local" else 0
        if cfg.window:
            return cfg.window
        return 0

    groups: List[LayerGroup] = []
    if cfg.moe_num_experts > 0:
        nd = cfg.moe_first_dense
        if nd:
            groups.append(
                LayerGroup("dense", nd, tuple(window_for(i) for i in range(nd)))
            )
        rest = cfg.num_layers - nd
        groups.append(
            LayerGroup(
                "moe", rest, tuple(window_for(nd + i) for i in range(rest))
            )
        )
    else:
        groups.append(
            LayerGroup(
                "dense",
                cfg.num_layers,
                tuple(window_for(i) for i in range(cfg.num_layers)),
            )
        )
    return groups
