"""Mamba-style selective SSM block — the SSM half of hymba's hybrid heads.

Standard Mamba-1 formulation: input gating, short causal conv, selective
(input-dependent) dt/B/C, diagonal state recurrence:

    h_t = exp(dt_t * A) . h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t

Training runs the recurrence as a ``lax.scan`` over time (state is tiny:
[B, d_inner, N] with N = ssm_state = 16); decode is a single step.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.config import ArchConfig

__all__ = ["mamba_specs", "mamba_apply_train", "mamba_apply_decode"]


def _dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    dt_rank = max(cfg.d_model // 16, 1)
    return d_in, dt_rank, cfg.ssm_state, cfg.ssm_conv


def mamba_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_in, dt_rank, n, k = _dims(cfg)
    dt = jnp.bfloat16
    return {
        "w_in": ParamSpec((d, 2 * d_in), ("hidden", "ffn"), dtype=dt),
        "conv_w": ParamSpec((k, d_in), ("conv", "ffn"), dtype=dt),
        "conv_b": ParamSpec((d_in,), ("ffn",), dtype=dt, init="zeros"),
        "w_x": ParamSpec((d_in, dt_rank + 2 * n), ("ffn", None), dtype=dt),
        "w_dt": ParamSpec((dt_rank, d_in), (None, "ffn"), dtype=dt),
        "dt_bias": ParamSpec((d_in,), ("ffn",), dtype=jnp.float32, init="zeros"),
        "A_log": ParamSpec(
            (d_in, n), ("ffn", "state"), dtype=jnp.float32, init="zeros"
        ),
        "D": ParamSpec((d_in,), ("ffn",), dtype=jnp.float32, init="ones"),
        "w_out": ParamSpec((d_in, d), ("ffn", "hidden"), dtype=dt),
    }


def _ssm_inputs(cfg: ArchConfig, p, x_conv):
    """x_conv: [B, S, d_in] post-conv activations -> (dt, B, C)."""
    d_in, dt_rank, n, _ = _dims(cfg)
    xproj = x_conv @ p["w_x"]  # [B, S, dt_rank + 2n]
    dt_low = xproj[..., :dt_rank]
    b_mat = xproj[..., dt_rank : dt_rank + n].astype(jnp.float32)
    c_mat = xproj[..., dt_rank + n :].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_low @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"]
    )  # [B, S, d_in]
    return dt, b_mat, c_mat


def _causal_conv(p, x, k: int):
    """Depthwise causal conv along time: x [B, S, d_in]."""
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * p["conv_w"][i][None, None, :]
        for i in range(k)
    )
    return out + p["conv_b"]


def mamba_apply_train(cfg: ArchConfig, p, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, d] -> [B, S, d]; recurrence scanned over S."""
    d_in, dt_rank, n, k = _dims(cfg)
    xz = x @ p["w_in"]  # [B, S, 2*d_in]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = jax.nn.silu(_causal_conv(p, xs, k))
    dt, b_mat, c_mat = _ssm_inputs(cfg, p, xs)
    a_mat = -jnp.exp(p["A_log"])  # [d_in, n]

    def step(h, inputs):
        xs_t, dt_t, b_t, c_t = inputs  # [B,d_in],[B,d_in],[B,n],[B,n]
        decay = jnp.exp(dt_t[..., None] * a_mat[None])  # [B, d_in, n]
        h = decay * h + (dt_t * xs_t.astype(jnp.float32))[..., None] * b_t[
            :, None, :
        ]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    b, s, _ = x.shape
    h0 = jnp.zeros((b, d_in, n), jnp.float32)
    inputs = (
        jnp.moveaxis(xs, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b_mat, 1, 0),
        jnp.moveaxis(c_mat, 1, 0),
    )
    # chunked + per-chunk remat (see rwkv.py — §Perf iteration 4): avoids
    # storing every per-step [B, d_in, N] state for backward
    chunk = 128
    if s % chunk == 0 and s > chunk:
        nchunks = s // chunk
        inputs = jax.tree_util.tree_map(
            lambda a: a.reshape((nchunks, chunk) + a.shape[1:]), inputs
        )

        @jax.checkpoint
        def chunk_step(h, inp_chunk):
            return jax.lax.scan(step, h, inp_chunk)

        _, ys = jax.lax.scan(chunk_step, h0, inputs)
        ys = ys.reshape((s,) + ys.shape[2:])
    else:
        _, ys = jax.lax.scan(step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1)  # [B, S, d_in]
    y = y + xs.astype(jnp.float32) * p["D"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"]


def mamba_prefill_state(cfg: ArchConfig, p, x: jnp.ndarray):
    """Run train path AND return final (conv_state, ssm_state) for decode."""
    d_in, dt_rank, n, k = _dims(cfg)
    xz = x @ p["w_in"]
    xs_pre, z = jnp.split(xz, 2, axis=-1)
    conv_state = xs_pre[:, -(k - 1) :, :]  # last k-1 pre-conv activations
    xs = jax.nn.silu(_causal_conv(p, xs_pre, k))
    dt, b_mat, c_mat = _ssm_inputs(cfg, p, xs)
    a_mat = -jnp.exp(p["A_log"])

    def step(h, inputs):
        xs_t, dt_t, b_t, c_t = inputs
        decay = jnp.exp(dt_t[..., None] * a_mat[None])
        h = decay * h + (dt_t * xs_t.astype(jnp.float32))[..., None] * b_t[
            :, None, :
        ]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    b = x.shape[0]
    h0 = jnp.zeros((b, d_in, n), jnp.float32)
    hT, ys = jax.lax.scan(
        step,
        h0,
        (
            jnp.moveaxis(xs, 1, 0),
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(b_mat, 1, 0),
            jnp.moveaxis(c_mat, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1)
    y = y + xs.astype(jnp.float32) * p["D"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"], conv_state, hT


def mamba_apply_decode(
    cfg: ArchConfig,
    p,
    x: jnp.ndarray,  # [B, 1, d]
    conv_state: jnp.ndarray,  # [B, k-1, d_in] rolling pre-conv window
    ssm_state: jnp.ndarray,  # [B, d_in, n] fp32
):
    d_in, dt_rank, n, k = _dims(cfg)
    xz = x @ p["w_in"]
    xs_new, z = jnp.split(xz, 2, axis=-1)  # [B,1,d_in]
    window = jnp.concatenate([conv_state, xs_new], axis=1)  # [B, k, d_in]
    conv_out = (
        jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    )[:, None, :]
    xs = jax.nn.silu(conv_out)  # [B,1,d_in]
    dt, b_mat, c_mat = _ssm_inputs(cfg, p, xs)
    a_mat = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt[:, 0, :, None] * a_mat[None])  # [B, d_in, n]
    h = decay * ssm_state + (
        dt[:, 0] * xs[:, 0].astype(jnp.float32)
    )[..., None] * b_mat[:, 0][:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0])[:, None, :]
    y = y + xs.astype(jnp.float32) * p["D"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"], window[:, 1:, :], h
