"""RWKV-6 ("Finch") — attention-free stack with data-dependent decay.

Time-mix recurrence per head (head size 64):

    S_t = diag(w_t) . S_{t-1} + k_t^T v_t          (state [hd, hd])
    o_t = r_t . (S_{t-1} + diag(u) . k_t^T v_t)

with **data-dependent decay** w_t = exp(-exp(w_base + tanh(x_t A) B)) — the
headline Finch feature (arXiv:2404.05892).  Token-shift lerps use static
learned mixes for r/k/v/g (the paper's full DDLERP LoRA stack on every mix is
collapsed to its static term; the decay LoRA is kept — a deliberate repro
simplification: the static mixes dominate quality, the decay LoRA is the
headline mechanism).
Channel-mix is the standard squared-ReLU RWKV FFN.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ParamSpec, rms_norm
from repro.models.config import ArchConfig

__all__ = [
    "rwkv_layer_specs",
    "rwkv_layer_train",
    "rwkv_layer_decode",
    "rwkv_heads",
]

_DECAY_LORA = 64


def rwkv_heads(cfg: ArchConfig) -> Tuple[int, int]:
    hd = cfg.rwkv_head_size
    return cfg.d_model // hd, hd


def rwkv_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    h, hd = rwkv_heads(cfg)
    ff = cfg.d_ff
    dt = jnp.bfloat16
    return {
        "ln1": ParamSpec((d,), (None,), dtype=dt, init="ones"),
        "ln2": ParamSpec((d,), (None,), dtype=dt, init="ones"),
        "tm": {  # time mix
            "mix_r": ParamSpec((d,), (None,), dtype=dt, init="zeros"),
            "mix_k": ParamSpec((d,), (None,), dtype=dt, init="zeros"),
            "mix_v": ParamSpec((d,), (None,), dtype=dt, init="zeros"),
            "mix_g": ParamSpec((d,), (None,), dtype=dt, init="zeros"),
            "mix_w": ParamSpec((d,), (None,), dtype=dt, init="zeros"),
            "wr": ParamSpec((d, d), ("hidden", "heads"), dtype=dt),
            "wk": ParamSpec((d, d), ("hidden", "heads"), dtype=dt),
            "wv": ParamSpec((d, d), ("hidden", "heads"), dtype=dt),
            "wg": ParamSpec((d, d), ("hidden", "heads"), dtype=dt),
            "w_base": ParamSpec((d,), (None,), dtype=jnp.float32, init="zeros"),
            "wA": ParamSpec((d, _DECAY_LORA), ("hidden", "rank"), dtype=dt),
            "wB": ParamSpec((_DECAY_LORA, d), ("rank", "hidden"), dtype=dt),
            "u": ParamSpec((h, hd), (None, None), dtype=jnp.float32, init="zeros"),
            "gn": ParamSpec((d,), (None,), dtype=dt, init="ones"),
            "wo": ParamSpec((d, d), ("heads", "hidden"), dtype=dt),
        },
        "cm": {  # channel mix
            "mix_k": ParamSpec((d,), (None,), dtype=dt, init="zeros"),
            "mix_r": ParamSpec((d,), (None,), dtype=dt, init="zeros"),
            "wk": ParamSpec((d, ff), ("hidden", "ffn"), dtype=dt),
            "wv": ParamSpec((ff, d), ("ffn", "hidden"), dtype=dt),
            "wr": ParamSpec((d, d), ("hidden", "hidden"), dtype=dt),
        },
    }


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """Shifted-by-one sequence: [prev, x_0, ..., x_{S-2}]."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, xs, mix):
    return x + (xs - x) * jax.nn.sigmoid(mix)[None, None, :]


def _decay(tm, xw):
    """Data-dependent per-channel decay in (0, 1)."""
    lora = jnp.tanh(xw @ tm["wA"]) @ tm["wB"]
    return jnp.exp(
        -jnp.exp(tm["w_base"][None, None] + lora.astype(jnp.float32))
    )  # [B, S, d]


def _time_mix_inputs(cfg, tm, x, prev_x):
    xs = _token_shift(x, prev_x) if x.shape[1] > 1 else prev_x[:, None, :]
    r = _lerp(x, xs, tm["mix_r"]) @ tm["wr"]
    k = _lerp(x, xs, tm["mix_k"]) @ tm["wk"]
    v = _lerp(x, xs, tm["mix_v"]) @ tm["wv"]
    g = _lerp(x, xs, tm["mix_g"]) @ tm["wg"]
    w = _decay(tm, _lerp(x, xs, tm["mix_w"]))
    return r, k, v, g, w


def rwkv_layer_train(cfg: ArchConfig, p, x, state=None):
    """x: [B, S, d].  state: optional (shift1, shift2, wkv) for chunked
    streaming; returns (x_out, new_state)."""
    b, s, d = x.shape
    h, hd = rwkv_heads(cfg)
    if state is None:
        shift1 = jnp.zeros((b, d), x.dtype)
        shift2 = jnp.zeros((b, d), x.dtype)
        wkv0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    else:
        shift1, shift2, wkv0 = state

    # ---- time mix ----
    xn = rms_norm(x, p["ln1"])
    r, k, v, g, w = _time_mix_inputs(cfg, p["tm"], xn, shift1)
    rh = r.reshape(b, s, h, hd)
    kh = k.reshape(b, s, h, hd).astype(jnp.float32)
    vh = v.reshape(b, s, h, hd).astype(jnp.float32)
    wh = w.reshape(b, s, h, hd)  # fp32 decay
    u = p["tm"]["u"]  # [h, hd]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # [B,h,hd] each
        kv = k_t[..., :, None] * v_t[..., None, :]  # [B,h,hd,hd]
        o = jnp.einsum(
            "bhk,bhkv->bhv", r_t.astype(jnp.float32), S + u[None, :, :, None] * kv
        )
        S = w_t[..., :, None] * S + kv
        return S, o

    # Chunked, per-chunk-rematerialized recurrence (§Perf iteration 4):
    # a flat scan makes the backward pass store EVERY per-step state
    # ([T, B, H, 64, 64] fp32 — tens of GB per layer).  Scanning over
    # chunks with jax.checkpoint saves only the T/CHUNK boundary states
    # and recomputes inside the chunk (recompute is cheap: the recurrence
    # is ~0.5% of layer FLOPs).
    inputs = (
        jnp.moveaxis(rh, 1, 0),
        jnp.moveaxis(kh, 1, 0),
        jnp.moveaxis(vh, 1, 0),
        jnp.moveaxis(wh, 1, 0),
    )
    chunk = 128
    if s % chunk == 0 and s > chunk:
        nchunks = s // chunk
        inputs = jax.tree_util.tree_map(
            lambda a: a.reshape((nchunks, chunk) + a.shape[1:]), inputs
        )

        @jax.checkpoint
        def chunk_step(S, inp_chunk):
            return jax.lax.scan(step, S, inp_chunk)

        wkvT, os = jax.lax.scan(chunk_step, wkv0, inputs)
        os = os.reshape((s,) + os.shape[2:])
    else:
        wkvT, os = jax.lax.scan(step, wkv0, inputs)
    o = jnp.moveaxis(os, 0, 1).reshape(b, s, d)  # [B,S,d]
    o = rms_norm(o.astype(x.dtype), p["tm"]["gn"]) * jax.nn.silu(g)
    x = x + o @ p["tm"]["wo"]
    new_shift1 = xn[:, -1, :]

    # ---- channel mix ----
    xn2 = rms_norm(x, p["ln2"])
    xs2 = _token_shift(xn2, shift2) if s > 1 else shift2[:, None, :]
    kc = _lerp(xn2, xs2, p["cm"]["mix_k"]) @ p["cm"]["wk"]
    kc = jnp.square(jax.nn.relu(kc))
    rc = jax.nn.sigmoid(_lerp(xn2, xs2, p["cm"]["mix_r"]) @ p["cm"]["wr"])
    x = x + rc * (kc @ p["cm"]["wv"])
    x = constrain(x, ("batch", "seq", None))
    return x, (new_shift1, xn2[:, -1, :], wkvT)


def rwkv_layer_decode(cfg: ArchConfig, p, x, state):
    """Single-token step: x [B, 1, d]; state (shift1 [B,d], shift2, wkv)."""
    out, new_state = rwkv_layer_train(cfg, p, x, state)
    return out, new_state
