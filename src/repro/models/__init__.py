from repro.models.api import Model, build_model
from repro.models.config import ArchConfig

__all__ = ["Model", "build_model", "ArchConfig"]
