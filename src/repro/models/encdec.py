"""Whisper-style encoder-decoder backbone (audio frontend is a STUB).

Per the assignment, the conv/mel frontend is stubbed: ``input_specs()``
provides precomputed frame embeddings [B, encoder_seq, d] directly.  The
encoder is a bidirectional transformer; the decoder adds causal self-attention
(with KV cache for serving) and cross-attention over the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import (
    ParamSpec,
    attention,
    decode_attention,
    rms_norm,
    rope,
)
from repro.models.config import ArchConfig
from repro.models.transformer import ffn_apply, ffn_specs, gqa_specs

__all__ = [
    "encoder_layer_specs",
    "decoder_layer_specs",
    "encoder_layer_apply",
    "decoder_layer_train",
    "decoder_layer_decode",
    "cross_attn_specs",
]


def cross_attn_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    return gqa_specs(cfg)


def encoder_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    dt = jnp.bfloat16
    return {
        "ln1": ParamSpec((d,), (None,), dtype=dt, init="ones"),
        "ln2": ParamSpec((d,), (None,), dtype=dt, init="ones"),
        "attn": gqa_specs(cfg),
        "ffn": ffn_specs(cfg),
    }


def decoder_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    dt = jnp.bfloat16
    return {
        "ln1": ParamSpec((d,), (None,), dtype=dt, init="ones"),
        "ln_cross": ParamSpec((d,), (None,), dtype=dt, init="ones"),
        "ln2": ParamSpec((d,), (None,), dtype=dt, init="ones"),
        "attn": gqa_specs(cfg),
        "cross": cross_attn_specs(cfg),
        "ffn": ffn_specs(cfg),
    }


def _proj_qkv(cfg, p, xq, xkv, sin=None, cos=None):
    from repro.models.common import apply_rope

    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if sin is not None:
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    return q, k, v


def encoder_layer_apply(cfg: ArchConfig, p, x):
    """Bidirectional self-attention encoder layer."""
    h = rms_norm(x, p["ln1"])
    q, k, v = _proj_qkv(cfg, p["attn"], h, h)
    out = attention(q, k, v, causal=False, q_chunk=1024)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
    h = rms_norm(x, p["ln2"])
    x = x + ffn_apply(cfg, p["ffn"], h)
    return constrain(x, ("batch", "seq", None))


def decoder_layer_train(cfg: ArchConfig, p, x, enc_out, sin, cos):
    """Causal self-attn + cross-attn + FFN (training / prefill)."""
    h = rms_norm(x, p["ln1"])
    q, k, v = _proj_qkv(cfg, p["attn"], h, h, sin, cos)
    out = attention(q, k, v, causal=True, q_chunk=1024)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
    self_cache = (k, v)

    h = rms_norm(x, p["ln_cross"])
    qc, kc, vc = _proj_qkv(cfg, p["cross"], h, enc_out)
    outc = attention(qc, kc, vc, causal=False, q_chunk=1024)
    x = x + jnp.einsum("bshk,hkd->bsd", outc, p["cross"]["wo"])
    cross_cache = (kc, vc)

    h = rms_norm(x, p["ln2"])
    x = x + ffn_apply(cfg, p["ffn"], h)
    return constrain(x, ("batch", "seq", None)), self_cache, cross_cache


def decoder_layer_decode(cfg: ArchConfig, p, x, cache, sin, cos, pos):
    """Single-token decode: self-attn against cache + cross-attn against
    the precomputed encoder K/V."""
    h = rms_norm(x, p["ln1"])
    q, k, v = _proj_qkv(cfg, p["attn"], h, h, sin, cos)
    kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
    out = decode_attention(q, kc, vc, pos + 1)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])

    h = rms_norm(x, p["ln_cross"])
    qc = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"])
    if cfg.qkv_bias:
        qc = qc + p["cross"]["bq"]
    enc_len = cache["ck"].shape[1]
    outc = decode_attention(
        qc, cache["ck"], cache["cv"], jnp.int32(enc_len)
    )
    x = x + jnp.einsum("bshk,hkd->bsd", outc, p["cross"]["wo"])

    h = rms_norm(x, p["ln2"])
    x = x + ffn_apply(cfg, p["ffn"], h)
    new_cache = dict(cache)
    new_cache["k"] = kc
    new_cache["v"] = vc
    return x, new_cache
