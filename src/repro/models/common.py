"""Shared model-library primitives: param specs, norms, RoPE, attention.

Everything is pure JAX (no flax): a model is (param_specs, apply_fns).
Parameters are nested dicts of arrays; each leaf has a matching
:class:`ParamSpec` carrying shape, dtype, init scale and **logical dim
names**.  The distributed layer (repro.distributed.sharding) resolves logical
names to mesh axes with divisibility checks — the same spec tree drives both
real initialization (smoke tests) and abstract ShapeDtypeStruct trees (the
multi-pod dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "init_params",
    "abstract_params",
    "rms_norm",
    "layer_norm",
    "rope",
    "apply_rope",
    "attention",
    "decode_attention",
    "Dense",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + logical dim names + init."""

    shape: Tuple[int, ...]
    names: Tuple[str, ...]  # logical dim names, len == len(shape)
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override; default fan-in

    def __post_init__(self):
        if len(self.shape) != len(self.names):
            raise ValueError(f"shape {self.shape} vs names {self.names}")

    def initializer(self, key: jax.Array) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "embed":
            std = self.scale or 1.0
            return (
                jax.random.normal(key, self.shape, jnp.float32) * std
            ).astype(self.dtype)
        # fan-in normal
        fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
        std = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return (
            jax.random.normal(key, self.shape, jnp.float32) * std
        ).astype(self.dtype)


def init_params(specs: PyTree, key: jax.Array) -> PyTree:
    """Materialize a param tree from its spec tree (host/smoke-test use)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [s.initializer(k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(specs: PyTree) -> PyTree:
    """ShapeDtypeStruct tree for the dry-run (no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             offset: float = 0.0) -> jnp.ndarray:
    """RMSNorm: fp32 statistics, NO full-width fp32 tensors.

    Only the [.., 1]-shaped inverse-RMS is fp32; the normalize/scale
    multiplies happen in the input dtype.  GSPMD places the sequence-parallel
    all-gather on the norm output — if any [B, S, d] fp32 intermediate
    exists, the partitioner gathers *that* and activation collective bytes
    double (EXPERIMENTS.md §Perf iterations 2/5).
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)  # [..., 1], tiny
    w = (offset + weight.astype(jnp.float32)).astype(x.dtype)
    return x * inv * w


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (
        normed * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(positions: jnp.ndarray, dim: int, theta: float = 10000.0):
    """Rotary embedding tables: (sin, cos) of shape [..., dim/2]."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray):
    """x: [..., S, H, D]; sin/cos: [..., S, D/2] broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    s = sin[..., None, :]
    c = cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window / softcap / cross-attention)
# ---------------------------------------------------------------------------
def _softcap(scores: jnp.ndarray, cap: Optional[float]):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def attention(
    q: jnp.ndarray,  # [B, S, H, D]
    k: jnp.ndarray,  # [B, T, KV, D]
    v: jnp.ndarray,  # [B, T, KV, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding-window size (local attention)
    softcap: Optional[float] = None,
    q_chunk: int = 1024,
    q_offset: int = 0,  # absolute position of q[0] relative to k[0]
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Chunked (flash-style) multi-head GQA attention, pure JAX.

    Queries are processed in chunks via ``lax.scan`` so peak score memory is
    [B, H, q_chunk, T] — required for 32k prefill to fit per-chip HBM.  GQA:
    H must be a multiple of KV; heads are grouped.
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    dv = v.shape[-1]  # may differ from d (MLA: qk_dim != v_dim)
    groups = h // kv
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    # --- TP layout selection (perf iteration #1, EXPERIMENTS.md §Perf) ----
    # GQA with kv_heads not divisible by the model axis makes GSPMD
    # replicate the [B, H, C, T] score tensor via giant all-gathers inside
    # the layer scan.  When expanding KV to the full query-head count makes
    # heads shardable, do so (transient, sharded over model after the
    # constraint); otherwise shard the KV sequence axis (flash-decoding
    # style — GSPMD inserts the partial-softmax reductions).
    from repro.distributed.sharding import constrain as _constrain
    from repro.distributed.sharding import current_policy as _policy

    pol = _policy()
    nm = pol.axis_sizes.get("model", 1) if pol is not None else 1
    if nm > 1 and kv % nm != 0 and h % nm == 0 and groups > 1:
        k = jnp.repeat(k, groups, axis=2)  # [B, T, H, D]
        v = jnp.repeat(v, groups, axis=2)
        kv, groups = h, 1
        k = _constrain(k, ("batch", None, "heads", None))
        v = _constrain(v, ("batch", None, "heads", None))
    elif nm > 1 and kv % nm != 0:
        k = _constrain(k, ("batch", "seq", None, None))
        v = _constrain(v, ("batch", "seq", None, None))

    q = q.reshape(b, s, kv, groups, d)

    def chunk_attn(q_chunk_arr, start):
        # q_chunk_arr: [B, C, KV, G, D]
        c = q_chunk_arr.shape[1]
        # operands stay bf16 on the wire; accumulation is fp32 (MXU-native)
        scores = jnp.einsum(
            "bckgd,btkd->bkgct", q_chunk_arr * jnp.asarray(scale, q.dtype),
            k, preferred_element_type=jnp.float32,
        )  # [B, KV, G, C, T] fp32
        scores = _softcap(scores, softcap)
        qpos = start + q_offset + jnp.arange(c)[:, None]
        kpos = jnp.arange(t)[None, :]
        mask = jnp.ones((c, t), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum(
            "bkgct,btkd->bckgd", probs.astype(v.dtype), v
        )
        return out  # [B, C, KV, G, D]

    if s <= q_chunk:
        out = chunk_attn(q, 0)
    else:
        nchunks = s // q_chunk
        rem = s - nchunks * q_chunk
        qs = q[:, : nchunks * q_chunk].reshape(
            b, nchunks, q_chunk, kv, groups, d
        )

        def body(_, xs):
            qc, idx = xs
            return None, chunk_attn(qc, idx * q_chunk)

        _, outs = jax.lax.scan(
            body, None, (jnp.moveaxis(qs, 1, 0), jnp.arange(nchunks))
        )
        out = jnp.moveaxis(outs, 0, 1).reshape(
            b, nchunks * q_chunk, kv, groups, dv
        )
        if rem:
            tail = chunk_attn(q[:, nchunks * q_chunk :], nchunks * q_chunk)
            out = jnp.concatenate([out, tail], axis=1)
    return out.reshape(b, s, h, dv)


def decode_attention(
    q: jnp.ndarray,  # [B, 1, H, D]
    k_cache: jnp.ndarray,  # [B, T, KV, D]
    v_cache: jnp.ndarray,  # [B, T, KV, D]
    cache_len: jnp.ndarray,  # int32[] — valid prefix of the cache
    *,
    softcap: Optional[float] = None,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token decode attention against a (possibly padded) KV cache."""
    b, _, h, d = q.shape
    t = k_cache.shape[1]
    kv = k_cache.shape[2]
    groups = h // kv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, kv, groups, d)
    scores = jnp.einsum(
        "bkgd,btkd->bkgt", qg.astype(jnp.float32) * scale,
        k_cache.astype(jnp.float32),
    )
    scores = _softcap(scores, softcap)
    kpos = jnp.arange(t)[None, None, None, :]
    mask = kpos < cache_len
    if window is not None:
        mask &= kpos >= cache_len - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, v_cache.shape[-1])


# ---------------------------------------------------------------------------
# Dense helper
# ---------------------------------------------------------------------------
class Dense:
    """Tiny helper to declare a (kernel, optional bias) pair of ParamSpecs."""

    @staticmethod
    def spec(
        d_in: int,
        d_out: int,
        names: Tuple[str, str],
        *,
        bias: bool = False,
        dtype=jnp.bfloat16,
        scale: Optional[float] = None,
    ) -> Dict[str, ParamSpec]:
        p = {"w": ParamSpec((d_in, d_out), names, dtype=dtype, scale=scale)}
        if bias:
            p["b"] = ParamSpec((d_out,), (names[1],), dtype=dtype, init="zeros")
        return p

    @staticmethod
    def apply(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
        y = x @ p["w"]
        if "b" in p:
            y = y + p["b"]
        return y
