"""Megakernel block-size autotuner with a persisted on-disk tuning cache.

``BLOCK_WORDS``/``BLOCK_WINDOWS`` in the decode megakernel and the encode
kernel's rows-per-grid-step were hand-picked constants; this module sweeps
candidate blocks (interpret mode on CPU, real kernels on TPU) and records
the winner in a :class:`TuningCache`:

  * **keyed like the serving ``PlanCache``** — by (kind, backend,
    plan key, bucket shape), so a tuned entry is exactly as specific as
    the jit specialization it configures;
  * **persisted** — JSON under the ``FPTC_TUNING_CACHE`` directory (unset:
    in-memory only), written atomically (tmp + ``os.replace``), loaded
    lazily; corrupt files and stale/invalid entries are *rejected and
    re-tuned*, never trusted;
  * **thread-safe** — one ``RLock`` around the in-memory map and all file
    IO, mirroring the PlanCache discipline (the engines' staging worker
    may race the dispatch thread into a lookup).

``kernels/ops.py`` consults :func:`tuned_blocks` at trace time when the
caller didn't pin blocks explicitly; the serving engines pass the global
:func:`epoch` counter (bumped on every store) as a static jit argument, so
a newly-tuned entry *retraces* the affected bucket shapes instead of being
silently shadowed by an older specialization.  Block sizes change kernel
scheduling only — never bytes (pinned by the warm-vs-cold cache
byte-identity tests).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence

__all__ = [
    "TuningCache",
    "default_cache",
    "set_default_cache",
    "epoch",
    "tuned_blocks",
    "tune",
    "decode_block_candidates",
    "encode_block_candidates",
    "tune_decode_bucket",
    "tune_encode_bucket",
]

ENV_DIR = "FPTC_TUNING_CACHE"
CACHE_VERSION = 1
_CACHE_FILE = "fptc_tuning.json"
# sanity range for any persisted block size: rejects corrupt/stale entries
_MAX_BLOCK = 1 << 20

Blocks = Dict[str, int]


def _entry_key(
    kind: str, backend: str, plan_key: Sequence, shape: Sequence[int]
) -> str:
    plan = ",".join(str(int(p)) for p in plan_key)
    shp = "x".join(str(int(s)) for s in shape)
    return f"{kind}|{backend}|plan({plan})|shape({shp})"


def _valid_blocks(blocks) -> bool:
    if not isinstance(blocks, dict) or not blocks:
        return False
    for k, v in blocks.items():
        if not isinstance(k, str):
            return False
        if not isinstance(v, int) or isinstance(v, bool):
            return False
        if not 1 <= v <= _MAX_BLOCK:
            return False
    return True


class TuningCache:
    """Thread-safe, optionally-persisted map: tuning key -> winning blocks.

    ``directory=None`` resolves ``FPTC_TUNING_CACHE``; when that is unset
    too the cache is memory-only (same API, nothing touches disk).
    """

    def __init__(self, directory: Optional[str] = None):
        if directory is None:
            directory = os.environ.get(ENV_DIR, "").strip() or None
        self.directory = directory
        self._lock = threading.RLock()
        self._entries: Dict[str, dict] = {}
        self._loaded = False
        self.hits = 0
        self.misses = 0

    # -- persistence --------------------------------------------------------
    @property
    def path(self) -> Optional[str]:
        if self.directory is None:
            return None
        return os.path.join(self.directory, _CACHE_FILE)

    def _load_locked(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        path = self.path
        if path is None or not os.path.exists(path):
            return
        try:
            with open(path, "r", encoding="utf-8") as f:
                data = json.load(f)
        except (OSError, ValueError):
            # corrupt file: start empty — winners re-tune and overwrite
            return
        if not isinstance(data, dict) or data.get("version") != CACHE_VERSION:
            return  # stale schema: reject wholesale, re-tune
        entries = data.get("entries")
        if not isinstance(entries, dict):
            return
        for key, entry in entries.items():
            if (
                isinstance(key, str)
                and isinstance(entry, dict)
                and _valid_blocks(entry.get("blocks"))
            ):
                self._entries[key] = entry
            # invalid entries are dropped here → lookup misses → re-tuned

    def _save_locked(self) -> None:
        path = self.path
        if path is None:
            return
        os.makedirs(self.directory, exist_ok=True)
        payload = {"version": CACHE_VERSION, "entries": self._entries}
        fd, tmp = tempfile.mkstemp(
            dir=self.directory, prefix=_CACHE_FILE, suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)  # atomic: readers see old or new, whole
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- the map ------------------------------------------------------------
    def lookup(
        self,
        kind: str,
        backend: str,
        plan_key: Sequence,
        shape: Sequence[int],
    ) -> Optional[Blocks]:
        key = _entry_key(kind, backend, plan_key, shape)
        with self._lock:
            self._load_locked()
            entry = self._entries.get(key)
            if entry is None or not _valid_blocks(entry.get("blocks")):
                if entry is not None:
                    del self._entries[key]  # invalid in-memory entry
                self.misses += 1
                return None
            self.hits += 1
            return dict(entry["blocks"])

    def store(
        self,
        kind: str,
        backend: str,
        plan_key: Sequence,
        shape: Sequence[int],
        blocks: Blocks,
        *,
        sample_s: Optional[float] = None,
    ) -> None:
        if not _valid_blocks(blocks):
            raise ValueError(f"refusing to store invalid blocks {blocks!r}")
        key = _entry_key(kind, backend, plan_key, shape)
        entry = {"blocks": dict(blocks)}
        if sample_s is not None:
            entry["sample_s"] = float(sample_s)
        with self._lock:
            self._load_locked()
            self._entries[key] = entry
            self._save_locked()
        _bump_epoch()

    def __len__(self) -> int:
        with self._lock:
            self._load_locked()
            return len(self._entries)


# ---------------------------------------------------------------------------
# The process-default cache + the epoch the engines key their jits on.
# ---------------------------------------------------------------------------
_STATE_LOCK = threading.Lock()
_DEFAULT: Optional[TuningCache] = None
_DEFAULT_DIR: Optional[str] = None
_PINNED = False  # set_default_cache() pins: env re-resolution must not undo
_EPOCH = 0


def _bump_epoch() -> None:
    global _EPOCH
    with _STATE_LOCK:
        _EPOCH += 1


def epoch() -> int:
    """Monotone counter bumped on every cache store / default-cache swap.

    The serving engines pass it as a static argument to their kernel-path
    bucket jits, so tuning results that land after a shape was first traced
    still take effect (the jit retraces and the trace-time
    :func:`tuned_blocks` consult sees the new entry) — without it, an older
    specialization would silently shadow the tuned blocks.
    """
    with _STATE_LOCK:
        return _EPOCH


def default_cache() -> TuningCache:
    """The process-wide cache (re-resolves ``FPTC_TUNING_CACHE`` when the
    env changes, so tests and the CI leg can repoint it; an explicit
    :func:`set_default_cache` pin wins over the env until reset)."""
    global _DEFAULT, _DEFAULT_DIR
    env_dir = os.environ.get(ENV_DIR, "").strip() or None
    with _STATE_LOCK:
        if _DEFAULT is None or (not _PINNED and _DEFAULT_DIR != env_dir):
            _DEFAULT = TuningCache(env_dir)
            _DEFAULT_DIR = env_dir
            global _EPOCH
            _EPOCH += 1
        return _DEFAULT


def set_default_cache(cache: Optional[TuningCache]) -> None:
    """Pin (or with ``None`` reset to env resolution) the process-default
    cache explicitly — the pin survives later ``FPTC_TUNING_CACHE``
    changes until reset."""
    global _DEFAULT, _DEFAULT_DIR, _PINNED
    with _STATE_LOCK:
        _DEFAULT = cache
        _DEFAULT_DIR = cache.directory if cache is not None else None
        _PINNED = cache is not None
        global _EPOCH
        _EPOCH += 1


def tuned_blocks(
    kind: str,
    plan_key: Sequence,
    shape: Sequence[int],
    *,
    backend: Optional[str] = None,
) -> Blocks:
    """The kernels' consult path: the winning blocks for this (backend,
    plan key, bucket shape), or ``{}`` when nothing is tuned (callers then
    keep their built-in defaults)."""
    if backend is None:
        import jax

        backend = jax.default_backend()
    blocks = default_cache().lookup(kind, backend, plan_key, shape)
    return blocks or {}


# ---------------------------------------------------------------------------
# The sweep.
# ---------------------------------------------------------------------------
# Single-flight registry for in-progress tunes: concurrent tune() calls on
# the same (cache, key) coalesce onto one sweep instead of each running the
# candidates AND each store()-ing (every store bumps the epoch, and every
# epoch bump retraces the kernel-path bucket jits — N racing front-end
# submitters would turn one tune into N sweeps and N retrace storms).
_TUNE_LOCK = threading.Lock()
_TUNE_INFLIGHT: Dict[tuple, threading.Event] = {}
def decode_block_candidates(
    words: int, windows: int
) -> List[Blocks]:
    """Default decode sweep grid: block_words x block_windows, clipped to
    the bucket (oversized candidates would all alias the same clipped
    kernel) and deduplicated."""
    out: List[Blocks] = []
    seen = set()
    for bw in (256, 512, 1024, 2048):
        for bn in (128, 256, 512):
            cand = (
                min(bw, max(int(words), 1)),
                min(bn, max(int(windows), 1)),
            )
            if cand in seen:
                continue
            seen.add(cand)
            out.append({"block_words": cand[0], "block_windows": cand[1]})
    return out


def encode_block_candidates(rows: int) -> List[Blocks]:
    out: List[Blocks] = []
    seen = set()
    for br in (1, 2, 4, 8):
        r = min(br, max(int(rows), 1))
        if r in seen:
            continue
        seen.add(r)
        out.append({"block_rows": r})
    return out


def tune(
    kind: str,
    plan_key: Sequence,
    shape: Sequence[int],
    runner: Callable[[Blocks], None],
    candidates: Iterable[Blocks],
    *,
    cache: Optional[TuningCache] = None,
    backend: Optional[str] = None,
    trials: int = 3,
    warmup: int = 1,
    force: bool = False,
    rank: Optional[Callable[[Blocks], float]] = None,
    top_k: Optional[int] = None,
) -> Blocks:
    """Sweep ``candidates``, record the winner, return its blocks.

    ``runner(blocks)`` must execute ONE dispatch with the candidate blocks
    and block until the device finishes (compile cost is excluded by the
    ``warmup`` calls).  The cache is consulted first: a valid hit returns
    immediately *without running anything* (``force=True`` re-tunes).
    ``rank`` (e.g. a cost-model prediction) optionally orders candidates
    and ``top_k`` prunes the sweep to the model's best guesses.
    """
    if cache is None:
        cache = default_cache()
    if backend is None:
        import jax

        backend = jax.default_backend()
    flight_key = (id(cache), _entry_key(kind, backend, plan_key, shape))
    while True:
        if not force:
            hit = cache.lookup(kind, backend, plan_key, shape)
            if hit is not None:
                return hit
        with _TUNE_LOCK:
            done = _TUNE_INFLIGHT.get(flight_key)
            if done is None:
                _TUNE_INFLIGHT[flight_key] = done = threading.Event()
                break  # we lead this key's sweep
        # same key already tuning: wait, then take its fresh entry — even
        # under force (the entry postdates our call, so it IS a re-tune)
        done.wait()
        hit = cache.lookup(kind, backend, plan_key, shape)
        if hit is not None:
            return hit
        # the leader failed; loop and lead the sweep ourselves
    try:
        return _tune_locked(
            kind, plan_key, shape, runner, candidates, cache=cache,
            backend=backend, trials=trials, warmup=warmup, rank=rank,
            top_k=top_k,
        )
    finally:
        with _TUNE_LOCK:
            _TUNE_INFLIGHT.pop(flight_key, None)
        done.set()


def _tune_locked(
    kind: str,
    plan_key: Sequence,
    shape: Sequence[int],
    runner: Callable[[Blocks], None],
    candidates: Iterable[Blocks],
    *,
    cache: TuningCache,
    backend: str,
    trials: int,
    warmup: int,
    rank: Optional[Callable[[Blocks], float]],
    top_k: Optional[int],
) -> Blocks:
    """The sweep body; the caller holds this key's single-flight lease."""
    cands = list(candidates)
    if not cands:
        raise ValueError("tune() needs at least one candidate")
    if rank is not None:
        cands.sort(key=rank)
        if top_k is not None:
            cands = cands[: max(int(top_k), 1)]
    best: Optional[Blocks] = None
    best_t = float("inf")
    for blocks in cands:
        for _ in range(max(warmup, 0)):
            runner(blocks)
        times = []
        for _ in range(max(trials, 1)):
            t0 = time.perf_counter()
            runner(blocks)
            times.append(time.perf_counter() - t0)
        t = sorted(times)[len(times) // 2]
        if t < best_t:
            best, best_t = blocks, t
    assert best is not None
    cache.store(
        kind, backend, plan_key, shape, best, sample_s=best_t
    )
    return dict(best)


# ---------------------------------------------------------------------------
# Concrete sweeps over the fused kernels (the CLI / CI warm path).
# ---------------------------------------------------------------------------
def _synthetic_stream(tables, num_words: int, num_windows: int):
    """Representative packed words for a decode sweep: encode a random
    signal under ``tables`` (so symbol statistics match the codebook),
    then clip/pad the word stream to the requested bucket shape (SymLen
    words decode independently, so truncation stays well-formed; padding
    words carry symlen 0 and emit nothing)."""
    import numpy as np

    from repro.core import codec

    cfg = tables.config
    rng = np.random.default_rng(7)
    signal = rng.standard_normal(num_windows * cfg.n).astype(np.float32)
    container = codec.encode(signal, tables)
    hi, lo = container.words_u32()
    w = min(container.num_words, num_words)
    out_hi = np.zeros(num_words, np.uint32)
    out_lo = np.zeros(num_words, np.uint32)
    out_sl = np.zeros(num_words, np.int32)
    out_hi[:w] = hi[:w]
    out_lo[:w] = lo[:w]
    out_sl[:w] = container.symlen[:w]
    return out_hi, out_lo, out_sl, int(container.max_symlen)


def tune_decode_bucket(
    tables,
    *,
    num_words: int,
    num_windows: int,
    cache: Optional[TuningCache] = None,
    cost_model=None,
    trials: int = 3,
    force: bool = False,
    top_k: Optional[int] = None,
) -> Blocks:
    """Sweep the decode megakernel's (block_words, block_windows) for one
    (plan key, bucket shape); interpret mode on CPU, real on TPU."""
    import jax.numpy as jnp

    from repro.core import dct
    from repro.core.quantize import quant_grid
    from repro.kernels import ops as kops
    from repro.serving.engine import symlen_bucket

    cfg = tables.config
    hi, lo, sl, max_sl = _synthetic_stream(tables, num_words, num_windows)
    dev_tables = tables.device_tables()
    lut, _ = quant_grid(tables.quant)
    basis = dct.idct_basis(cfg.n, cfg.e)
    hi, lo, sl = jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(sl)
    ms = symlen_bucket(max_sl)
    # the EXACT key ops.decode_bucket_fused consults at trace time — block
    # choice depends on shapes, not domain identity
    plan_key = (cfg.n, cfg.e, cfg.l_max, ms)

    def runner(blocks: Blocks) -> None:
        out = kops.decode_bucket_fused(
            hi, lo, sl, dev_tables, lut, basis,
            l_max=cfg.l_max, max_symlen=ms, num_windows=num_windows,
            n=cfg.n, e=cfg.e,
            block_words=blocks["block_words"],
            block_windows=blocks["block_windows"],
        )
        out.block_until_ready()

    rank = None
    if cost_model is not None:
        rank = lambda b: cost_model.decode_bucket_cost(  # noqa: E731
            num_words, num_windows, e=cfg.e, n=cfg.n, max_symlen=ms,
            block_words=b["block_words"], block_windows=b["block_windows"],
        )
    return tune(
        "decode", plan_key, (num_words, num_windows),
        runner, decode_block_candidates(num_words, num_windows),
        cache=cache, trials=trials, force=force, rank=rank, top_k=top_k,
    )


def tune_encode_bucket(
    tables,
    *,
    rows: int,
    num_windows: int,
    chunk_size: Optional[int] = None,
    cache: Optional[TuningCache] = None,
    cost_model=None,
    trials: int = 3,
    force: bool = False,
    top_k: Optional[int] = None,
) -> Blocks:
    """Sweep the encode megakernel's rows-per-grid-step for one
    (plan key, bucket shape)."""
    import numpy as np

    import jax.numpy as jnp

    from repro.core import dct
    from repro.kernels import ops as kops

    cfg = tables.config
    width = num_windows * cfg.n
    sp = num_windows * cfg.e
    chunk = sp if chunk_size is None else min(int(chunk_size), sp)
    # the EXACT key ops.encode_bucket_fused consults at trace time
    plan_key = (cfg.n, cfg.e, chunk)
    rng = np.random.default_rng(11)
    signals = jnp.asarray(
        rng.standard_normal((rows, width)).astype(np.float32)
    )
    counts = jnp.full((rows,), sp, dtype=jnp.int32)
    dev_tables = tables.device_tables()
    basis = dct.dct_basis(cfg.n, cfg.e)

    def runner(blocks: Blocks) -> None:
        out = kops.encode_bucket_fused(
            signals, counts, dev_tables, basis,
            n=cfg.n, e=cfg.e, chunk_size=chunk, check_gaps=False,
            block_rows=blocks["block_rows"],
        )
        out[3].block_until_ready()

    rank = None
    if cost_model is not None:
        rank = lambda b: cost_model.encode_bucket_cost(  # noqa: E731
            rows, num_windows, e=cfg.e, n=cfg.n,
            block_rows=b["block_rows"],
        )
    return tune(
        "encode", plan_key, (rows, width),
        runner, encode_block_candidates(rows),
        cache=cache, trials=trials, force=force, rank=rank, top_k=top_k,
    )


# ---------------------------------------------------------------------------
# CLI: pre-populate the cache for a grid of serving bucket shapes.
# ---------------------------------------------------------------------------
def _main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    parser = argparse.ArgumentParser(
        description="Warm the FPTC kernel tuning cache "
        f"(${ENV_DIR} or --cache-dir) for common serving bucket shapes."
    )
    parser.add_argument(
        "--cache-dir", default=None,
        help=f"cache directory (default: ${ENV_DIR})",
    )
    parser.add_argument(
        "--datasets", nargs="*", default=["load_power", "temperature"],
        help="calibration datasets to tune plans for",
    )
    parser.add_argument(
        "--smoke", action="store_true",
        help="small shapes + fewer trials (CI-sized)",
    )
    parser.add_argument("--force", action="store_true", help="re-tune hits")
    args = parser.parse_args(argv)

    from repro.core import DOMAIN_DEFAULTS, calibrate
    from repro.data import make_signal
    from repro.data.signals import domain_of
    from repro.tuning.cost_model import default_cost_model

    import numpy as np

    cache = TuningCache(args.cache_dir) if args.cache_dir else default_cache()
    cm = default_cost_model()
    trials = 1 if args.smoke else 3
    shapes = (
        [(4096, 512), (16384, 2048)]
        if args.smoke
        else [(4096, 512), (16384, 2048), (65536, 8192)]
    )
    enc_shapes = [(8, 32), (16, 128)] if args.smoke else [
        (8, 32), (16, 128), (32, 512)
    ]
    for dataset in args.datasets:
        dom = domain_of(dataset)
        calib = np.concatenate(
            [make_signal(dataset, 65536, seed=90 + i) for i in range(2)]
        )
        tables = calibrate(calib, DOMAIN_DEFAULTS[dom])
        for words, windows in shapes:
            blocks = tune_decode_bucket(
                tables, num_words=words, num_windows=windows,
                cache=cache, cost_model=cm, trials=trials,
                force=args.force, top_k=4 if args.smoke else None,
            )
            print(f"decode {dataset} ({words}w,{windows}win): {blocks}")
        for rows, windows in enc_shapes:
            blocks = tune_encode_bucket(
                tables, rows=rows, num_windows=windows,
                cache=cache, cost_model=cm, trials=trials,
                force=args.force,
            )
            print(f"encode {dataset} ({rows}r,{windows}win): {blocks}")
    where = cache.path or "(memory only)"
    print(f"tuning cache: {len(cache)} entries at {where}")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(_main())
