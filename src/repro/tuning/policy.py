"""Declarative bucket-edge policies for the serving engines.

The engines used to hard-code power-of-two padding (``engine.p2``) for
every traced axis: concatenated decode words, window counts, encode batch
rows.  Power-of-two edges bound compile counts at O(log sizes) but waste
up to half a bucket — ~25% of words measured at batch 16.  A
:class:`BucketPolicy` makes the ladder a declarative config (the
flag-driven build-config idiom): per octave ``[2**k, 2**(k+1))`` the
ladder carries ``multipliers`` edges, so

  * ``p2``           — multipliers ``(1,)``: exactly the old rounding;
  * ``half-octave``  — ``(1, 1.5)``: expected padding waste ~18%;
  * ``cost-balanced`` — a geometric ladder whose density the
    :class:`repro.tuning.cost_model.CostModel` picks (where denser edges
    stop paying for their extra jit specializations; 4/octave on the CPU
    profile, expected waste ~8%).

Every policy keeps the compile count bounded: at most ``len(multipliers)``
edges per octave, so specializations stay O(density * log sizes).  Policies
change *padding only* — decoded samples and per-row packed words never
depend on the bucket edge (the byte-identity suites run under all three).

Engines resolve ``policy=None`` through :meth:`BucketPolicy.of`, which
reads ``FPTC_BUCKET_POLICY`` (default ``p2``) — one env var flips every
default-constructed engine, mirroring ``FPTC_USE_KERNELS``.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import List, Optional, Tuple, Union

__all__ = [
    "BucketPolicy",
    "P2",
    "HALF_OCTAVE",
    "COST_BALANCED",
    "cost_balanced_policy",
    "POLICY_NAMES",
]

PolicyArg = Union[None, str, "BucketPolicy"]


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """One bucket-edge ladder: ``multipliers`` edges per octave.

    ``round(x)`` returns the smallest ladder edge >= x; edges are
    ``ceil(m * 2**k)`` for each multiplier ``m in [1, 2)`` and octave
    ``k`` (plus the next octave's base), so rounding is monotonic,
    idempotent on edges, and never below the input.
    """

    name: str
    multipliers: Tuple[float, ...] = (1.0,)

    def __post_init__(self):
        if not self.multipliers:
            raise ValueError("a BucketPolicy needs at least one multiplier")
        for m in self.multipliers:
            if not 1.0 <= m < 2.0:
                raise ValueError(
                    f"multipliers must lie in [1, 2), got {m} "
                    f"(policy {self.name!r})"
                )

    def round(self, x: int) -> int:
        """Smallest ladder edge >= max(x, 1)."""
        x = max(int(x), 1)
        if x <= 1:
            return 1
        k = (x - 1).bit_length() - 1  # 2**k < x <= 2**(k+1)
        best = 1 << (k + 1)
        base = 1 << k
        for m in self.multipliers:
            edge = int(math.ceil(m * base))
            if x <= edge < best:
                best = edge
        return best

    def edges(self, lo: int, hi: int) -> List[int]:
        """Every distinct ladder edge covering sizes in ``[lo, hi]`` — the
        bound on bucket-shape (hence jit-specialization) variants."""
        lo, hi = max(int(lo), 1), max(int(hi), 1)
        out, seen = [], set()
        x = lo
        while True:
            e = self.round(x)
            if e not in seen:
                seen.add(e)
                out.append(e)
            if e >= hi:
                break
            x = e + 1
        return out

    def max_variants(self, lo: int, hi: int) -> int:
        """Upper bound on distinct bucket edges for sizes in ``[lo, hi]``
        (== compile-count bound per traced axis under this policy)."""
        return len(self.edges(lo, hi))

    # -- resolution ---------------------------------------------------------
    @staticmethod
    def of(policy: PolicyArg) -> "BucketPolicy":
        """Resolve an engine's ``policy`` argument: a :class:`BucketPolicy`
        passes through, a name looks up the registry, ``None`` reads
        ``FPTC_BUCKET_POLICY`` (default ``p2``)."""
        if isinstance(policy, BucketPolicy):
            return policy
        if policy is None:
            policy = os.environ.get("FPTC_BUCKET_POLICY", "").strip() or "p2"
        return _named(policy)


P2 = BucketPolicy("p2", (1.0,))
HALF_OCTAVE = BucketPolicy("half-octave", (1.0, 1.5))


def cost_balanced_policy(cost_model=None) -> BucketPolicy:
    """Build the ``cost-balanced`` ladder from a cost model: a geometric
    ladder of ``d = cost_model.edges_per_octave()`` edges per octave
    (``2**(j/d)`` multipliers), the density where the padded-work saving
    of one more edge stops covering its extra jit specialization."""
    if cost_model is None:
        from repro.tuning.cost_model import default_cost_model

        cost_model = default_cost_model()
    d = max(int(cost_model.edges_per_octave()), 1)
    return BucketPolicy(
        "cost-balanced",
        tuple(2.0 ** (j / d) for j in range(d)),
    )


# the default cost-balanced ladder for this process's backend; engines
# wanting a freshly-seeded/calibrated model call cost_balanced_policy(model)
COST_BALANCED = cost_balanced_policy()

POLICY_NAMES = ("p2", "half-octave", "cost-balanced")


def _named(name: str) -> BucketPolicy:
    key = name.strip().lower().replace("_", "-")
    if key == "p2":
        return P2
    if key in ("half-octave", "halfoctave"):
        return HALF_OCTAVE
    if key in ("cost-balanced", "costbalanced"):
        # the import-time constant, NOT a fresh build: re-deriving the
        # ladder from a since-calibrated model mid-process would shift
        # bucket edges under live engines and unbound their compile count
        return COST_BALANCED
    raise ValueError(
        f"unknown bucket policy {name!r} — expected one of {POLICY_NAMES} "
        "or a BucketPolicy instance"
    )
