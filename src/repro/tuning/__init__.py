"""Cost-model-driven autotuning for the serving engines and megakernels.

Three hand-tuned shape decisions used to live as folklore constants:

  * **bucket edges** — every engine padded to powers of two, wasting a
    measured ~25% of words at batch 16; :mod:`repro.tuning.policy` makes
    the ladder a declarative :class:`BucketPolicy` (``p2`` / ``half-octave``
    / ``cost-balanced``) with the compile count still bounded;
  * **megakernel block sizes** — ``BLOCK_WORDS``/``BLOCK_WINDOWS`` (and the
    encode kernel's rows-per-step) are now swept by
    :func:`repro.tuning.autotune.tune` and persisted in an on-disk
    :class:`TuningCache` (``FPTC_TUNING_CACHE``) keyed like the serving
    ``PlanCache`` by (backend, plan key, bucket shape);
  * **shard splits** — the scheduler's contiguous equal-count partition is
    replaced by a greedy cost-balanced partition over per-signal cost
    predicted by :class:`repro.tuning.cost_model.CostModel`.

None of these change produced bytes: policies and blocks move *when and
where* work runs (padding is invisible to decoded samples and per-row
packing), which is why the byte-identity suites run under every policy and
with the tuning cache both cold and warm.
"""
from repro.tuning.cost_model import (
    BackendProfile,
    CostModel,
    default_cost_model,
)
from repro.tuning.policy import (
    BucketPolicy,
    COST_BALANCED,
    HALF_OCTAVE,
    P2,
    cost_balanced_policy,
)
from repro.tuning.autotune import (
    TuningCache,
    default_cache,
    epoch,
    set_default_cache,
    tune,
    tuned_blocks,
)

__all__ = [
    "BackendProfile",
    "CostModel",
    "default_cost_model",
    "BucketPolicy",
    "P2",
    "HALF_OCTAVE",
    "COST_BALANCED",
    "cost_balanced_policy",
    "TuningCache",
    "default_cache",
    "set_default_cache",
    "epoch",
    "tune",
    "tuned_blocks",
]
