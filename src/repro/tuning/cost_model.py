"""Per-backend dispatch cost model for the serving engines' shape decisions.

The model predicts the wall cost of one fused bucket dispatch from
(words, windows, batch, block sizes, backend) with a plain roofline:

    t = flops / peak_flops + bytes / hbm_bps
        + grid_steps * step_overhead_s + dispatch_overhead_s

The analytic flop/byte counts mirror what the kernels actually trace (the
slot-loop Huffman decode, the 256-level LUT select, the MXU iDCT / DCT,
the one-hot codeword matmul, the chunk pack) and can be *seeded* — rescaled
so the analytic count matches an :func:`repro.analysis.analyze_hlo` /
:func:`repro.analysis.analyze_jaxpr` (or XLA ``cost_analysis()``) estimate
of the real traced program — and *refined* by on-device timing samples
(:meth:`CostModel.observe`; the autotuner feeds these automatically).

Three consumers:

  * :func:`repro.tuning.policy.cost_balanced_policy` picks the bucket-edge
    density where the padded-work saving of a denser ladder stops paying
    for its extra jit specializations;
  * ``serving.engine.BucketScheduler`` splits each key group's members into
    per-device shards balanced by :meth:`CostModel.signal_decode_cost` /
    :meth:`signal_encode_cost` instead of equal counts;
  * :func:`repro.tuning.autotune.tune` ranks candidate megakernel block
    sizes with :meth:`decode_bucket_cost` / :meth:`encode_bucket_cost`
    before (or instead of) timing them.

All numbers are *relative* by design — shard balancing and candidate
ranking only need ordering, and the seeding/calibration hooks tighten the
absolute scale where it matters.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Dict, Optional, Tuple

__all__ = ["BackendProfile", "CostModel", "default_cost_model"]


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    """Static roofline numbers for one backend.

    The defaults are deliberately coarse (order-of-magnitude peaks for a
    server CPU, an A100-class GPU and a v5e-class TPU); they set the
    *ratios* between compute, memory and launch overhead that the policy
    and tuner decisions depend on, and timing calibration absorbs the rest.
    """

    backend: str
    peak_flops: float  # FLOP/s
    hbm_bps: float  # bytes/s
    dispatch_overhead_s: float  # per fused dispatch (host->device launch)
    step_overhead_s: float  # per grid step inside a kernel
    compile_cost_s: float  # per new jit specialization


_PROFILES: Dict[str, BackendProfile] = {
    "cpu": BackendProfile("cpu", 5e10, 2e10, 3e-5, 2e-7, 0.5),
    "gpu": BackendProfile("gpu", 2e13, 1.5e12, 1e-5, 5e-8, 0.8),
    "tpu": BackendProfile("tpu", 2e14, 8e11, 2e-6, 2e-8, 1.0),
}

# analytic per-unit op counts, mirroring the traced kernels:
#   huffman slot step: ~l_max compare/shift ops per (word, slot) iteration
_HUFFMAN_OPS_PER_SLOT = 16.0
#   LUT dequant: the fused kernel's 256-way masked select per level
_LUT_OPS_PER_LEVEL = 256.0
#   chunk pack: segment-sum + searchsorted word materialization per symbol
_PACK_OPS_PER_SYMBOL = 24.0


def _ceil_div(a: int, b: int) -> int:
    return -(-int(a) // max(int(b), 1))


def _round_up(a: int, b: int) -> int:
    return _ceil_div(a, b) * max(int(b), 1)


class CostModel:
    """Predicts fused-dispatch cost; thread-safe (engines share one).

    ``seed(kind, flops, hbm_bytes, **shape)`` rescales the analytic model
    so its raw counts reproduce a measured estimate of the same shape;
    ``observe(kind, predicted_s, measured_s)`` records a wall-time sample
    whose running median multiplies later predictions of that kind.
    """

    def __init__(
        self,
        profile: Optional[BackendProfile] = None,
        *,
        backend: Optional[str] = None,
    ):
        if profile is None:
            if backend is None:
                import jax

                backend = jax.default_backend()
            profile = _PROFILES.get(backend, _PROFILES["cpu"])
        self.profile = profile
        self._lock = threading.Lock()
        # kind -> (flops scale, bytes scale) from HLO/jaxpr seeding
        self._seed: Dict[str, Tuple[float, float]] = {}
        # kind -> measured/predicted wall-time ratios (bounded history)
        self._samples: Dict[str, deque] = {}

    # -- analytic op counts -------------------------------------------------
    def decode_flops(
        self,
        words: int,
        windows: int,
        *,
        e: int,
        n: int,
        max_symlen: int = 8,
    ) -> float:
        """Raw FLOP count of one fused bucket decode: slot-loop Huffman
        over the words, 256-level LUT dequant and the iDCT matmul over the
        windows (padding words/windows pay full price — that is the point:
        the model sees the cost of a policy's padding)."""
        huff = float(words) * max(max_symlen, 1) * _HUFFMAN_OPS_PER_SLOT
        dequant = float(windows) * e * _LUT_OPS_PER_LEVEL
        idct = 2.0 * float(windows) * e * n
        return huff + dequant + idct

    def decode_bytes(
        self, words: int, windows: int, *, e: int, n: int
    ) -> float:
        """Boundary HBM traffic of one bucket decode: the packed words
        (hi/lo/symlen, 12 B each) in, the window tensor out."""
        return 12.0 * float(words) + 4.0 * float(windows) * n

    def encode_flops(
        self, rows: int, windows_per_row: int, *, e: int, n: int
    ) -> float:
        """Raw FLOP count of one fused bucket encode: DCT matmul, the
        one-hot codeword lookup matmuls and the chunk pack, all over the
        padded ``rows x windows_per_row`` bucket."""
        syms = float(rows) * windows_per_row * e
        dct = 2.0 * float(rows) * windows_per_row * n * e
        onehot = 2.0 * syms * 256.0 * 2.0  # code + length lookup matmuls
        pack = syms * _PACK_OPS_PER_SYMBOL
        return dct + onehot + pack

    def encode_bytes(
        self, rows: int, windows_per_row: int, *, e: int, n: int
    ) -> float:
        samples_in = 4.0 * float(rows) * windows_per_row * n
        words_out = 12.0 * float(rows) * windows_per_row * e / 4.0
        return samples_in + words_out

    # -- seeding / calibration ---------------------------------------------
    def seed(
        self, kind: str, flops: float, hbm_bytes: float, **shape
    ) -> None:
        """Rescale the analytic model so its raw counts for ``shape``
        reproduce a measured (HLO / jaxpr / ``cost_analysis()``) estimate.

        ``kind`` is ``"decode"`` or ``"encode"``; ``shape`` carries the
        same keywords the corresponding ``*_flops`` method takes.
        """
        if kind == "decode":
            raw_f = self.decode_flops(**shape)
            raw_b = self.decode_bytes(
                **{k: v for k, v in shape.items() if k != "max_symlen"}
            )
        elif kind == "encode":
            raw_f = self.encode_flops(**shape)
            raw_b = self.encode_bytes(**shape)
        else:
            raise ValueError(f"unknown cost kind {kind!r}")
        with self._lock:
            self._seed[kind] = (
                flops / max(raw_f, 1.0),
                hbm_bytes / max(raw_b, 1.0),
            )

    def seed_from_cost(self, kind: str, cost, **shape) -> None:
        """Seed from an :class:`repro.analysis.HloCost` (what
        ``analyze_hlo``/``analyze_jaxpr`` return)."""
        self.seed(kind, cost.flops, cost.hbm_bytes, **shape)

    def observe(self, kind: str, predicted_s: float, measured_s: float):
        """Record one on-device timing sample for ``kind``; the running
        median of measured/predicted multiplies later predictions."""
        if predicted_s <= 0 or measured_s <= 0:
            return
        with self._lock:
            self._samples.setdefault(kind, deque(maxlen=64)).append(
                measured_s / predicted_s
            )

    def calibration(self, kind: str) -> float:
        with self._lock:
            samples = sorted(self._samples.get(kind, ()))
        if not samples:
            return 1.0
        return samples[len(samples) // 2]

    def _scales(self, kind: str) -> Tuple[float, float]:
        with self._lock:
            return self._seed.get(kind, (1.0, 1.0))

    # -- bucket dispatch predictions ---------------------------------------
    def decode_bucket_cost(
        self,
        words: int,
        windows: int,
        *,
        e: int,
        n: int,
        max_symlen: int = 8,
        block_words: int = 512,
        block_windows: int = 256,
    ) -> float:
        """Predicted seconds for one fused decode dispatch of a bucket of
        ``words`` packed words / ``windows`` output windows, run with the
        given megakernel block sizes (blocks shrink to the bucket when
        larger, exactly as ``decode_fused`` does, then pad the axes to
        block multiples — so oversized blocks are charged their padding
        and undersized blocks their extra grid steps)."""
        block_words = min(max(block_words, 1), max(words, 1))
        block_windows = min(max(block_windows, 1), max(windows, 1))
        wp = _round_up(max(words, 1), block_words)
        nwp = _round_up(max(windows, 1), block_windows)
        steps = _ceil_div(wp, block_words) + _ceil_div(nwp, block_windows)
        sf, sb = self._scales("decode")
        flops = sf * self.decode_flops(
            wp, nwp, e=e, n=n, max_symlen=max_symlen
        )
        nbytes = sb * self.decode_bytes(wp, nwp, e=e, n=n)
        p = self.profile
        t = (
            flops / p.peak_flops
            + nbytes / p.hbm_bps
            + steps * p.step_overhead_s
            + p.dispatch_overhead_s
        )
        return t * self.calibration("decode")

    def encode_bucket_cost(
        self,
        rows: int,
        windows_per_row: int,
        *,
        e: int,
        n: int,
        block_rows: int = 1,
    ) -> float:
        """Predicted seconds for one fused encode dispatch: ``rows``
        (batch-padded) signal rows of ``windows_per_row`` windows each,
        ``block_rows`` rows per grid step."""
        block_rows = min(max(block_rows, 1), max(rows, 1))
        kp = _round_up(max(rows, 1), block_rows)
        steps = _ceil_div(kp, block_rows)
        sf, sb = self._scales("encode")
        flops = sf * self.encode_flops(kp, windows_per_row, e=e, n=n)
        nbytes = sb * self.encode_bytes(kp, windows_per_row, e=e, n=n)
        p = self.profile
        t = (
            flops / p.peak_flops
            + nbytes / p.hbm_bps
            + steps * p.step_overhead_s
            + p.dispatch_overhead_s
        )
        return t * self.calibration("encode")

    # -- per-signal costs (shard balancing) --------------------------------
    def signal_decode_cost(
        self,
        words: int,
        windows: int,
        *,
        e: int,
        n: int,
        max_symlen: int = 8,
    ) -> float:
        """One signal's share of a decode bucket — what the scheduler's
        cost-balanced shard split weighs (relative units)."""
        sf, _ = self._scales("decode")
        return sf * self.decode_flops(
            words, windows, e=e, n=n, max_symlen=max_symlen
        )

    def signal_encode_cost(
        self, windows: int, *, e: int, n: int
    ) -> float:
        """One signal's share of an encode bucket (relative units)."""
        sf, _ = self._scales("encode")
        return sf * self.encode_flops(1, windows, e=e, n=n)

    # -- policy support -----------------------------------------------------
    def edges_per_octave(
        self,
        *,
        ref_words: int = 1 << 16,
        ref_dispatches: int = 1 << 17,
        max_density: int = 4,
    ) -> int:
        """Bucket-edge density where a denser ladder stops paying.

        Going from ``d`` to ``d + 1`` edges per octave shrinks the expected
        padded fraction of every dispatch (for a geometric ladder of ratio
        ``r = 2**(1/d)`` the expected occupancy of a uniformly-sized bucket
        is ``(1 - 1/r) / ln r``) but adds roughly one jit specialization
        per octave in use.  Accept the denser ladder while the padded-word
        seconds saved over ``ref_dispatches`` dispatches of a
        ``ref_words``-word bucket exceed one ``compile_cost_s`` —
        ``ref_dispatches`` is the amortization horizon of a long-lived
        serving process, which is who pays for bucket padding.
        """
        import math

        def waste(d: int) -> float:
            r = 2.0 ** (1.0 / d)
            return 1.0 - (1.0 - 1.0 / r) / math.log(r)

        p = self.profile
        per_word_s = (
            self.decode_flops(1, 0, e=1, n=1) / p.peak_flops
            + 12.0 / p.hbm_bps
        )
        d = 1
        while d < max_density:
            saved = (
                (waste(d) - waste(d + 1))
                * ref_words
                * per_word_s
                * ref_dispatches
            )
            if saved < p.compile_cost_s:
                break
            d += 1
        return d


_DEFAULTS: Dict[str, CostModel] = {}
_DEFAULTS_LOCK = threading.Lock()


def default_cost_model(backend: Optional[str] = None) -> CostModel:
    """Process-wide shared model per backend (engines constructed with
    ``cost_model=None`` resolve here, so seeding/calibrating the default
    model steers every default-constructed engine)."""
    if backend is None:
        import jax

        backend = jax.default_backend()
    with _DEFAULTS_LOCK:
        cm = _DEFAULTS.get(backend)
        if cm is None:
            cm = _DEFAULTS[backend] = CostModel(backend=backend)
        return cm
