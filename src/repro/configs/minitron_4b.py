"""minitron-4b [dense] — pruned nemotron (arXiv:2407.14679; hf)."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    head_dim=128,
    rope_theta=10000.0,
)

SMOKE = ARCH.replace(
    name="minitron-4b-smoke", num_layers=2, d_model=48, num_heads=3,
    num_kv_heads=1, d_ff=96, vocab_size=512, head_dim=16,
)
