"""internvl2-26b [vlm] — InternViT (STUB) + InternLM2-20B backbone
(arXiv:2404.16821; hf).  input_specs() provides precomputed patch
embeddings; only the LM backbone is built/lowered."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    vision_prefix=64,
    rope_theta=1000000.0,
)

SMOKE = ARCH.replace(
    name="internvl2-26b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16, vision_prefix=4,
)
