"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay
(arXiv:2404.05892; hf)."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # d_model / rwkv_head_size
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_size=64,
)

SMOKE = ARCH.replace(
    name="rwkv6-3b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512, rwkv_head_size=16,
)
