"""llama4-scout-17b-16e [moe] — 16 experts top-1 + shared expert
(hf:meta-llama/Llama-4-Scout-17B-16E; unverified)."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    moe_num_experts=16,
    moe_top_k=1,
    moe_num_shared=1,
    moe_d_ff=8192,
    rope_theta=500000.0,
)

SMOKE = ARCH.replace(
    name="llama4-scout-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    moe_num_experts=4, moe_d_ff=128,
)
