"""granite-8b [dense] — llama-arch code model (arXiv:2405.04324; hf)."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    head_dim=128,
    rope_theta=10000.0,
)

SMOKE = ARCH.replace(
    name="granite-8b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
)
