"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer
(arXiv:2411.13676; hf).  Attention uses a sliding window (the few global
layers of the released model are approximated as windowed);
the SSM half is a Mamba-style selective SSM with state 16."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    window=1024,
    hybrid_parallel=True,
    ssm_state=16,
    ssm_expand=2,
    rope_theta=10000.0,
)

SMOKE = ARCH.replace(
    name="hymba-1.5b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16, window=32,
    ssm_state=4,
)
