"""gemma2-27b [dense] — local+global alternating attention, logit softcap
(arXiv:2408.00118; hf)."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    window=4096,
    local_global_pattern=("local", "global"),
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_block_norms=True,
    ffn_activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
)

SMOKE = ARCH.replace(
    name="gemma2-27b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16, window=32,
)
