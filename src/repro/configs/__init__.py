"""Assigned architecture registry: ``--arch <id>`` resolution + shape sets.

Every architecture module defines ``ARCH`` (the exact assigned config) and
``SMOKE`` (a reduced same-family config for CPU tests).  Shapes follow the
assignment: train_4k / prefill_32k / decode_32k / long_500k, where decode
shapes lower ``serve_step`` (one token against a seq_len KV cache) and
long_500k only runs for sub-quadratic families (quadratic-attention
families skip it by design — the 500k point exists to show the
sub-quadratic scaling, not to OOM a dense-attention smoke host).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.models.config import ArchConfig

__all__ = ["ARCH_IDS", "SHAPES", "get_arch", "get_smoke", "cells", "Cell"]

ARCH_IDS = (
    "granite_8b",
    "minitron_4b",
    "gemma2_27b",
    "qwen15_4b",
    "rwkv6_3b",
    "llama4_scout_17b_a16e",
    "deepseek_v3_671b",
    "internvl2_26b",
    "hymba_15b",
    "whisper_tiny",
)

# canonical external ids (dashes) -> module names
_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class Cell:
    arch_id: str
    shape: Shape
    skip: Optional[str] = None  # reason string when not runnable


def get_arch(arch_id: str) -> ArchConfig:
    mod_name = _ALIASES.get(arch_id, arch_id)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.ARCH


def get_smoke(arch_id: str) -> ArchConfig:
    mod_name = _ALIASES.get(arch_id, arch_id)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def cells() -> Tuple[Cell, ...]:
    """All 40 (arch x shape) cells with skip annotations."""
    out = []
    for aid in ARCH_IDS:
        cfg = get_arch(aid)
        for shape in SHAPES.values():
            skip = None
            if shape.name == "long_500k" and not cfg.supports_long_decode:
                skip = (
                    "quadratic/global attention at 500k context "
                    "(assignment: run long_500k only for SSM/hybrid)"
                )
            out.append(Cell(aid, shape, skip))
    return tuple(out)
