"""whisper-tiny [audio] — enc-dec backbone; conv/mel frontend is a STUB
(arXiv:2212.04356; unverified).  input_specs() provides precomputed frame
embeddings [B, 1500, 384]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    encoder_layers=4,
    encoder_seq=1500,
    cross_attention=True,
    ffn_activation="gelu",
    gated_ffn=False,
    qkv_bias=True,
)

SMOKE = ARCH.replace(
    name="whisper-tiny-smoke", num_layers=2, d_model=32, num_heads=2,
    num_kv_heads=2, d_ff=64, vocab_size=512, head_dim=16,
    encoder_layers=2, encoder_seq=64,
)
