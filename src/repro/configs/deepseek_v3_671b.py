"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8
(arXiv:2412.19437; hf).

MTP (multi-token prediction) head is not modeled — it is a training
objective add-on orthogonal to the FPTC integration.
The dense d_ff (first 3 layers) is 18432 per the HF config; the assigned
"d_ff=2048" is the routed-expert width (moe_d_ff).
"""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    head_dim=128,
    mla=True,
    mla_q_lora_rank=1536,
    mla_kv_lora_rank=512,
    mla_qk_nope_dim=128,
    mla_qk_rope_dim=64,
    mla_v_dim=128,
    moe_num_experts=256,
    moe_top_k=8,
    moe_num_shared=1,
    moe_d_ff=2048,
    moe_first_dense=3,
    rope_theta=10000.0,
)

SMOKE = ARCH.replace(
    name="deepseek-v3-smoke", num_layers=3, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=192, vocab_size=512, head_dim=16,
    mla_q_lora_rank=32, mla_kv_lora_rank=16, mla_qk_nope_dim=16,
    mla_qk_rope_dim=8, mla_v_dim=16,
    moe_num_experts=8, moe_top_k=2, moe_d_ff=64, moe_first_dense=1,
)
