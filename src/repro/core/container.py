"""Compressed container: header + SymLen words + symlen sidecar.

The container is the unit of archival/transmission.  Header fields make every
container self-describing (given the domain's calibrated tables, which are
deployed once per domain — paper §3.4, Fig. 4).

Byte layout (little-endian), common header (all versions):
  magic           4 bytes  b"FPTC"
  version         u16      1, 2 or 3
  l_max           u16
  n, e            u16, u16
  num_words       u32
  num_symbols     u64      (v3: the CODED symbol count, post-suppression)
  num_windows     u32
  signal_length   u64
  max_symlen      u16
  domain_id       u16
  crc             u32      (fault detection; coverage is version-dependent)

Version 1/2 payload:
  words           num_words * 8 bytes (uint64 LE)
  symlen          num_words * 1 byte  (uint8; symlen <= 64)

Version 3 adds a 4-byte extension header immediately after the common
header, before the payload:
  flags           u16      bits 0-1: predictor id (0 none / 1 delta /
                           2 linear2); bit 2: zero-plane suppression;
                           bits 3-15 reserved, must be zero
  predict_bands   u16      leading coefficient bands the predictor covers

and, when flag bit 2 (zero planes) is set, two bitmaps after the symlen
sidecar:
  zrow bitmap     ceil(num_windows / 8) bytes (LSB-first per byte)
  zcol bitmap     ceil(e / 8) bytes

**v3 design notes** (ROADMAP item 3).  v3 is a *lossless re-coding of the
quantized levels* — reconstruction at a given quant table is bit-identical
to v2; only the entropy-coded byte count changes.  Two optional stages, both
applied to the level grid ``[num_windows, e]`` before entropy coding:

  1. *Windowed prediction* (cuSZ+-style): bands ``k < predict_bands`` store
     the mod-256 residual against the previous window's level (delta) or a
     two-point linear extrapolation (linear2), with a virtual all-128
     history before the first window.  Smooth domains pile the residual
     histogram onto 128, which the canonical Huffman stage converts into
     shorter codes.  Exact math: ``repro.core.quantize.predict_levels`` /
     ``unpredict_levels``.
  2. *Zero-plane suppression* (FZ-GPU-style): window rows and coefficient
     columns whose coded symbols are ALL the zero bin are dropped from the
     stream entirely and recorded as the two bitmaps — the bit-transposed
     zero indicator planes.  The surviving cells keep row-major order, so
     ``num_symbols`` shrinks to ``(rows kept) * (cols kept)``.  Layout
     contract: ``repro.core.symlen.zero_plane_masks`` / ``v3_expand_index``.

The Huffman book of a v3 domain is calibrated on the *coded* symbols, so a
v3 container must decode with v3-calibrated tables — the coding triple is
part of the container's plan key and of table validation.

Checksum: version 2 writes one crc32 over words || symlen, so bit flips in
either the payload words or the sidecar fail loudly at ``from_bytes``;
version 3 extends the coverage to words || symlen || zrow || zcol.
Version-1 containers (whose crc covered only the symlen sidecar — payload
flips decoded silently to garbage) are still readable with the legacy
sidecar-only check.

**Forever-decode promise:** every version this module has ever written
(v1, v2, v3) stays readable by ``from_bytes`` permanently; the golden-blob
suite (tests/golden/) pins byte-exact decode of all of them.  Parsing is
zero-copy on the hot decode-staging path: header and payload sections are
sliced as ``memoryview``s and wrapped with ``np.frombuffer`` (no bytes
copies); the returned arrays alias — and keep alive — the input buffer.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Optional, Tuple

import numpy as np

__all__ = ["Container", "HEADER_BYTES", "SUPPORTED_VERSIONS"]

_MAGIC = b"FPTC"
_VERSION = 2  # default wire version for trivially-coded containers
_V3 = 3  # written iff the coding triple is non-trivial
_HDR = struct.Struct("<4sHHHHIQIQHHI")
_EXT3 = struct.Struct("<HH")  # v3 extension: flags, predict_bands
HEADER_BYTES = _HDR.size
SUPPORTED_VERSIONS = (1, 2, 3)

_FLAG_PRED_MASK = 0x0003  # bits 0-1: predictor id
_FLAG_ZPLANES = 0x0004  # bit 2: zero-plane suppression


def _pack_bitmap(mask: np.ndarray) -> bytes:
    """bool[N] -> ceil(N/8) bytes, LSB-first within each byte."""
    return np.packbits(
        np.asarray(mask, dtype=bool), bitorder="little"
    ).tobytes()


def _unpack_bitmap(buf, n: int) -> np.ndarray:
    """ceil(n/8) bytes -> bool[n] (LSB-first)."""
    bits = np.unpackbits(
        np.frombuffer(buf, dtype=np.uint8), bitorder="little"
    )
    return bits[:n].astype(bool)


@dataclasses.dataclass
class Container:
    words: np.ndarray  # uint64[W]
    symlen: np.ndarray  # uint8[W]
    num_symbols: int
    num_windows: int
    signal_length: int
    n: int
    e: int
    l_max: int
    domain_id: int = 0
    # --- v3 coding state (all defaults give the classic v2 container) ---
    predictor: int = 0  # 0 none / 1 delta / 2 linear2
    predict_bands: int = 0
    zero_planes: bool = False
    zrow: Optional[np.ndarray] = None  # bool[num_windows] when zero_planes
    zcol: Optional[np.ndarray] = None  # bool[e] when zero_planes

    @property
    def num_words(self) -> int:
        return int(self.words.shape[0])

    @property
    def max_symlen(self) -> int:
        return int(self.symlen.max()) if self.symlen.size else 0

    @property
    def coding(self) -> Tuple[int, int, bool]:
        """The (pred_id, predict_bands, zero_planes) coding triple — matches
        ``CodecConfig.coding`` of the tables that encoded this container."""
        return (self.predictor, self.predict_bands, bool(self.zero_planes))

    @property
    def version(self) -> int:
        """Wire version ``to_bytes`` will emit: 3 iff any v3 stage is on."""
        return _V3 if self.coding != (0, 0, False) else _VERSION

    @property
    def plan_key(self) -> Tuple[int, int, int, int, Tuple[int, int, bool]]:
        """Grouping key for batched decoding: containers sharing a
        (domain_id, n, e, l_max, coding) decode with the same tables, iDCT
        basis, coding transform and kernel specialization, so they can ride
        one fused dispatch."""
        return (self.domain_id, self.n, self.e, self.l_max, self.coding)

    def words_u32(self) -> Tuple[np.ndarray, np.ndarray]:
        """Payload words as the (hi, lo) uint32 pair the device path consumes
        (TPU int64 is emulated; see core.symlen)."""
        from repro.core.symlen import words_to_u32

        return words_to_u32(self.words)

    @property
    def compressed_bytes(self) -> int:
        total = HEADER_BYTES + self.num_words * 8 + self.num_words
        if self.version == _V3:
            total += _EXT3.size
            if self.zero_planes:
                total += (self.num_windows + 7) // 8 + (self.e + 7) // 8
        return total

    @property
    def original_bytes(self) -> int:
        return self.signal_length * 4  # float32 samples

    @property
    def compression_ratio(self) -> float:
        return self.original_bytes / max(self.compressed_bytes, 1)

    def to_bytes(self) -> bytes:
        words_b = self.words.astype("<u8").tobytes()
        symlen_b = self.symlen.astype(np.uint8).tobytes()
        version = self.version
        ext = b""
        bitmaps = b""
        if version == _V3:
            if not (0 <= self.predictor <= 2):
                raise ValueError(f"bad predictor id {self.predictor}")
            flags = self.predictor & _FLAG_PRED_MASK
            if self.zero_planes:
                flags |= _FLAG_ZPLANES
                if self.zrow is None or self.zcol is None:
                    raise ValueError(
                        "zero_planes container needs zrow/zcol masks"
                    )
                if len(self.zrow) != self.num_windows or len(
                    self.zcol
                ) != self.e:
                    raise ValueError("zrow/zcol mask length mismatch")
                bitmaps = _pack_bitmap(self.zrow) + _pack_bitmap(self.zcol)
            ext = _EXT3.pack(flags, self.predict_bands)
        crc = zlib.crc32(symlen_b, zlib.crc32(words_b))
        if bitmaps:
            crc = zlib.crc32(bitmaps, crc)
        hdr = _HDR.pack(
            _MAGIC,
            version,
            self.l_max,
            self.n,
            self.e,
            self.num_words,
            self.num_symbols,
            self.num_windows,
            self.signal_length,
            self.max_symlen,
            self.domain_id,
            crc,
        )
        return hdr + ext + words_b + symlen_b + bitmaps

    @classmethod
    def from_bytes(cls, data) -> "Container":
        """Parse a serialized container from any bytes-like buffer.

        Zero-copy: payload sections are referenced through ``memoryview``
        slices (``np.frombuffer``), not copied — the hot decode-staging path
        reads them exactly once while bucketing, so a copy here would be
        pure overhead.  The returned arrays are read-only views keeping
        ``data`` alive.
        """
        mv = memoryview(data)
        (
            magic,
            version,
            l_max,
            n,
            e,
            num_words,
            num_symbols,
            num_windows,
            signal_length,
            max_symlen,
            domain_id,
            crc,
        ) = _HDR.unpack_from(mv, 0)
        if magic != _MAGIC:
            raise ValueError("bad magic — not an FPTC container")
        if version not in SUPPORTED_VERSIONS:
            raise ValueError(
                f"unsupported container version {version}; this build reads "
                f"versions {SUPPORTED_VERSIONS} (the forever-decode set)"
            )
        off = HEADER_BYTES
        predictor, predict_bands, zero_planes = 0, 0, False
        if version == _V3:
            flags, predict_bands = _EXT3.unpack_from(mv, off)
            off += _EXT3.size
            predictor = flags & _FLAG_PRED_MASK
            zero_planes = bool(flags & _FLAG_ZPLANES)
            if flags & ~(_FLAG_PRED_MASK | _FLAG_ZPLANES):
                raise ValueError(
                    f"v3 container sets reserved flag bits "
                    f"{flags:#06x} — written by a newer build?"
                )
        words = np.frombuffer(mv, dtype="<u8", count=num_words, offset=off)
        off += num_words * 8
        symlen = np.frombuffer(
            mv, dtype=np.uint8, count=num_words, offset=off
        )
        off += num_words
        zrow = zcol = None
        crc_calc = zlib.crc32(symlen, zlib.crc32(words))
        if version == 1:  # legacy: crc covered only the symlen sidecar
            crc_calc = zlib.crc32(symlen)
        if zero_planes:
            nrow_b = (num_windows + 7) // 8
            ncol_b = (e + 7) // 8
            bitmaps = mv[off: off + nrow_b + ncol_b]
            zrow = _unpack_bitmap(bitmaps[:nrow_b], num_windows)
            zcol = _unpack_bitmap(bitmaps[nrow_b:], e)
            crc_calc = zlib.crc32(bitmaps, crc_calc)
        if crc_calc != crc:
            raise ValueError("payload CRC mismatch — corrupt container")
        c = cls(
            words=words,
            symlen=symlen,
            num_symbols=num_symbols,
            num_windows=num_windows,
            signal_length=signal_length,
            n=n,
            e=e,
            l_max=l_max,
            domain_id=domain_id,
            predictor=predictor,
            predict_bands=predict_bands,
            zero_planes=zero_planes,
            zrow=zrow,
            zcol=zcol,
        )
        if c.max_symlen != max_symlen:
            raise ValueError("max_symlen header mismatch — corrupt container")
        return c
