"""Compressed container: header + SymLen words + symlen sidecar.

The container is the unit of archival/transmission.  Header fields make every
container self-describing (given the domain's calibrated tables, which are
deployed once per domain — paper §3.4, Fig. 4).

Byte layout (little-endian):
  magic           4 bytes  b"FPTC"
  version         u16
  l_max           u16
  n, e            u16, u16
  num_words       u32
  num_symbols     u64
  num_windows     u32
  signal_length   u64
  max_symlen      u16
  domain_id       u16
  reserved        u32      (checksum — fault detection; see below)
  words           num_words * 8 bytes (uint64 LE)
  symlen          num_words * 1 byte  (uint8; symlen <= 64)

Checksum: version 2 writes one crc32 over words || symlen, so bit flips in
either the payload words or the sidecar fail loudly at ``from_bytes``.
Version-1 containers (whose crc covered only the symlen sidecar — payload
flips decoded silently to garbage) are still readable with the legacy
sidecar-only check.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Tuple

import numpy as np

__all__ = ["Container", "HEADER_BYTES"]

_MAGIC = b"FPTC"
_VERSION = 2  # v2: crc covers words + symlen; v1 (symlen only) still reads
_HDR = struct.Struct("<4sHHHHIQIQHHI")
HEADER_BYTES = _HDR.size


@dataclasses.dataclass
class Container:
    words: np.ndarray  # uint64[W]
    symlen: np.ndarray  # uint8[W]
    num_symbols: int
    num_windows: int
    signal_length: int
    n: int
    e: int
    l_max: int
    domain_id: int = 0

    @property
    def num_words(self) -> int:
        return int(self.words.shape[0])

    @property
    def max_symlen(self) -> int:
        return int(self.symlen.max()) if self.symlen.size else 0

    @property
    def plan_key(self) -> Tuple[int, int, int, int]:
        """Grouping key for batched decoding: containers sharing a
        (domain_id, n, e, l_max) decode with the same tables, iDCT basis and
        kernel specialization, so they can ride one fused dispatch."""
        return (self.domain_id, self.n, self.e, self.l_max)

    def words_u32(self) -> Tuple[np.ndarray, np.ndarray]:
        """Payload words as the (hi, lo) uint32 pair the device path consumes
        (TPU int64 is emulated; see core.symlen)."""
        from repro.core.symlen import words_to_u32

        return words_to_u32(self.words)

    @property
    def compressed_bytes(self) -> int:
        return HEADER_BYTES + self.num_words * 8 + self.num_words

    @property
    def original_bytes(self) -> int:
        return self.signal_length * 4  # float32 samples

    @property
    def compression_ratio(self) -> float:
        return self.original_bytes / max(self.compressed_bytes, 1)

    def to_bytes(self) -> bytes:
        words_b = self.words.astype("<u8").tobytes()
        symlen_b = self.symlen.astype(np.uint8).tobytes()
        hdr = _HDR.pack(
            _MAGIC,
            _VERSION,
            self.l_max,
            self.n,
            self.e,
            self.num_words,
            self.num_symbols,
            self.num_windows,
            self.signal_length,
            self.max_symlen,
            self.domain_id,
            zlib.crc32(symlen_b, zlib.crc32(words_b)),
        )
        return hdr + words_b + symlen_b

    @classmethod
    def from_bytes(cls, data: bytes) -> "Container":
        (
            magic,
            version,
            l_max,
            n,
            e,
            num_words,
            num_symbols,
            num_windows,
            signal_length,
            max_symlen,
            domain_id,
            crc,
        ) = _HDR.unpack_from(data, 0)
        if magic != _MAGIC:
            raise ValueError("bad magic — not an FPTC container")
        if version not in (1, _VERSION):
            raise ValueError(f"unsupported container version {version}")
        off = HEADER_BYTES
        words = np.frombuffer(data, dtype="<u8", count=num_words, offset=off)
        off += num_words * 8
        symlen = np.frombuffer(data, dtype=np.uint8, count=num_words, offset=off)
        if version == 1:  # legacy: crc covered only the symlen sidecar
            expect = zlib.crc32(symlen.tobytes())
        else:
            expect = zlib.crc32(symlen.tobytes(), zlib.crc32(words.tobytes()))
        if expect != crc:
            raise ValueError("payload CRC mismatch — corrupt container")
        c = cls(
            words=words.copy(),
            symlen=symlen.copy(),
            num_symbols=num_symbols,
            num_windows=num_windows,
            signal_length=signal_length,
            n=n,
            e=e,
            l_max=l_max,
            domain_id=domain_id,
        )
        if c.max_symlen != max_symlen:
            raise ValueError("max_symlen header mismatch — corrupt container")
        return c
