"""Compressed container: header + SymLen words + symlen sidecar.

The container is the unit of archival/transmission.  Header fields make every
container self-describing (given the domain's calibrated tables, which are
deployed once per domain — paper §3.4, Fig. 4).

Byte layout (little-endian), common header (all versions):
  magic           4 bytes  b"FPTC"
  version         u16      1, 2 or 3
  l_max           u16
  n, e            u16, u16
  num_words       u32
  num_symbols     u64      (v3: the CODED symbol count, post-suppression)
  num_windows     u32
  signal_length   u64
  max_symlen      u16
  domain_id       u16
  crc             u32      (fault detection; coverage is version-dependent)

Version 1/2 payload:
  words           num_words * 8 bytes (uint64 LE)
  symlen          num_words * 1 byte  (uint8; symlen <= 64)

Version 3 adds a 4-byte extension header immediately after the common
header, before the payload:
  flags           u16      bits 0-1: predictor id (0 none / 1 delta /
                           2 linear2); bit 2: zero-plane suppression;
                           bits 3-15 reserved, must be zero
  predict_bands   u16      leading coefficient bands the predictor covers

and, when flag bit 2 (zero planes) is set, two bitmaps after the symlen
sidecar:
  zrow bitmap     ceil(num_windows / 8) bytes (LSB-first per byte)
  zcol bitmap     ceil(e / 8) bytes

**v3 design notes** (ROADMAP item 3).  v3 is a *lossless re-coding of the
quantized levels* — reconstruction at a given quant table is bit-identical
to v2; only the entropy-coded byte count changes.  Two optional stages, both
applied to the level grid ``[num_windows, e]`` before entropy coding:

  1. *Windowed prediction* (cuSZ+-style): bands ``k < predict_bands`` store
     the mod-256 residual against the previous window's level (delta) or a
     two-point linear extrapolation (linear2), with a virtual all-128
     history before the first window.  Smooth domains pile the residual
     histogram onto 128, which the canonical Huffman stage converts into
     shorter codes.  Exact math: ``repro.core.quantize.predict_levels`` /
     ``unpredict_levels``.
  2. *Zero-plane suppression* (FZ-GPU-style): window rows and coefficient
     columns whose coded symbols are ALL the zero bin are dropped from the
     stream entirely and recorded as the two bitmaps — the bit-transposed
     zero indicator planes.  The surviving cells keep row-major order, so
     ``num_symbols`` shrinks to ``(rows kept) * (cols kept)``.  Layout
     contract: ``repro.core.symlen.zero_plane_masks`` / ``v3_expand_index``.

The Huffman book of a v3 domain is calibrated on the *coded* symbols, so a
v3 container must decode with v3-calibrated tables — the coding triple is
part of the container's plan key and of table validation.

Checksum: version 2 writes one crc32 over words || symlen, so bit flips in
either the payload words or the sidecar fail loudly at ``from_bytes``;
version 3 extends the coverage to words || symlen || zrow || zcol.
Version-1 containers (whose crc covered only the symlen sidecar — payload
flips decoded silently to garbage) are still readable with the legacy
sidecar-only check.

**Forever-decode promise:** every version this module has ever written
(v1, v2, v3) stays readable by ``from_bytes`` permanently; the golden-blob
suite (tests/golden/) pins byte-exact decode of all of them.  Parsing is
zero-copy on the hot decode-staging path: header and payload sections are
sliced as ``memoryview``s and wrapped with ``np.frombuffer`` (no bytes
copies); the returned arrays alias — and keep alive — the input buffer.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "Container",
    "ContainerFormatError",
    "ContainerHeader",
    "HEADER_BYTES",
    "SUPPORTED_VERSIONS",
    "FAULT_BAD_MAGIC",
    "FAULT_BAD_VERSION",
    "FAULT_RESERVED_FLAGS",
    "FAULT_CRC_MISMATCH",
    "FAULT_HEADER_MISMATCH",
    "FAULT_TRUNCATED",
]

_MAGIC = b"FPTC"
_VERSION = 2  # default wire version for trivially-coded containers
_V3 = 3  # written iff the coding triple is non-trivial
_HDR = struct.Struct("<4sHHHHIQIQHHI")
_EXT3 = struct.Struct("<HH")  # v3 extension: flags, predict_bands
HEADER_BYTES = _HDR.size
SUPPORTED_VERSIONS = (1, 2, 3)

_FLAG_PRED_MASK = 0x0003  # bits 0-1: predictor id
_FLAG_ZPLANES = 0x0004  # bit 2: zero-plane suppression

# Wire-format fault classes (the serving quarantine taxonomy — see
# repro.serving.quarantine for the full error→HTTP contract).
FAULT_BAD_MAGIC = "bad-magic"
FAULT_BAD_VERSION = "bad-version"
FAULT_RESERVED_FLAGS = "reserved-flags"
FAULT_CRC_MISMATCH = "crc-mismatch"
FAULT_HEADER_MISMATCH = "header-mismatch"
FAULT_TRUNCATED = "truncated"

# Byte offsets of the header fields inside _HDR (for fault records).
_OFF_MAGIC = 0
_OFF_VERSION = 4
_OFF_SIGNAL_LENGTH = 28
_OFF_MAX_SYMLEN = 36
_OFF_CRC = 40


class ContainerFormatError(ValueError):
    """A buffer failed container wire-format validation.

    ``ValueError`` subclass so every legacy ``except ValueError`` call site
    keeps working; additionally carries the machine-readable quarantine
    record: the fault class (one of the ``FAULT_*`` constants), the byte
    ``offset`` of the offending field where known (``None`` otherwise), and
    the container's ``index`` within its submitted batch when the caller
    supplied one.
    """

    def __init__(self, message, *, fault, offset=None, index=None):
        super().__init__(message)
        self.fault = fault
        self.offset = offset
        self.index = index

    def __str__(self):
        where = []
        if self.index is not None:
            where.append(f"container[{self.index}]")
        if self.offset is not None:
            where.append(f"byte offset {self.offset}")
        loc = f" ({', '.join(where)})" if where else ""
        return f"[{self.fault}] {self.args[0]}{loc}"


@dataclasses.dataclass(frozen=True)
class ContainerHeader:
    """The parsed common header — what ``Container.peek`` returns.

    Admission-time routing (the serving frontend needs a plan key before it
    is worth paying for the full CRC pass) reads only this."""

    version: int
    n: int
    e: int
    l_max: int
    domain_id: int
    num_words: int
    num_symbols: int
    num_windows: int
    signal_length: int
    max_symlen: int
    coding: Tuple[int, int, bool]

    @property
    def plan_key(self) -> Tuple[int, int, int, int, Tuple[int, int, bool]]:
        return (self.domain_id, self.n, self.e, self.l_max, self.coding)


def _pack_bitmap(mask: np.ndarray) -> bytes:
    """bool[N] -> ceil(N/8) bytes, LSB-first within each byte."""
    return np.packbits(
        np.asarray(mask, dtype=bool), bitorder="little"
    ).tobytes()


def _unpack_bitmap(buf, n: int) -> np.ndarray:
    """ceil(n/8) bytes -> bool[n] (LSB-first)."""
    bits = np.unpackbits(
        np.frombuffer(buf, dtype=np.uint8), bitorder="little"
    )
    return bits[:n].astype(bool)


@dataclasses.dataclass
class Container:
    words: np.ndarray  # uint64[W]
    symlen: np.ndarray  # uint8[W]
    num_symbols: int
    num_windows: int
    signal_length: int
    n: int
    e: int
    l_max: int
    domain_id: int = 0
    # --- v3 coding state (all defaults give the classic v2 container) ---
    predictor: int = 0  # 0 none / 1 delta / 2 linear2
    predict_bands: int = 0
    zero_planes: bool = False
    zrow: Optional[np.ndarray] = None  # bool[num_windows] when zero_planes
    zcol: Optional[np.ndarray] = None  # bool[e] when zero_planes

    @property
    def num_words(self) -> int:
        return int(self.words.shape[0])

    @property
    def max_symlen(self) -> int:
        return int(self.symlen.max()) if self.symlen.size else 0

    @property
    def coding(self) -> Tuple[int, int, bool]:
        """The (pred_id, predict_bands, zero_planes) coding triple — matches
        ``CodecConfig.coding`` of the tables that encoded this container."""
        return (self.predictor, self.predict_bands, bool(self.zero_planes))

    @property
    def version(self) -> int:
        """Wire version ``to_bytes`` will emit: 3 iff any v3 stage is on."""
        return _V3 if self.coding != (0, 0, False) else _VERSION

    @property
    def plan_key(self) -> Tuple[int, int, int, int, Tuple[int, int, bool]]:
        """Grouping key for batched decoding: containers sharing a
        (domain_id, n, e, l_max, coding) decode with the same tables, iDCT
        basis, coding transform and kernel specialization, so they can ride
        one fused dispatch."""
        return (self.domain_id, self.n, self.e, self.l_max, self.coding)

    def words_u32(self) -> Tuple[np.ndarray, np.ndarray]:
        """Payload words as the (hi, lo) uint32 pair the device path consumes
        (TPU int64 is emulated; see core.symlen)."""
        from repro.core.symlen import words_to_u32

        return words_to_u32(self.words)

    @property
    def compressed_bytes(self) -> int:
        total = HEADER_BYTES + self.num_words * 8 + self.num_words
        if self.version == _V3:
            total += _EXT3.size
            if self.zero_planes:
                total += (self.num_windows + 7) // 8 + (self.e + 7) // 8
        return total

    @property
    def original_bytes(self) -> int:
        return self.signal_length * 4  # float32 samples

    @property
    def compression_ratio(self) -> float:
        return self.original_bytes / max(self.compressed_bytes, 1)

    def to_bytes(self) -> bytes:
        words_b = self.words.astype("<u8").tobytes()
        symlen_b = self.symlen.astype(np.uint8).tobytes()
        version = self.version
        ext = b""
        bitmaps = b""
        if version == _V3:
            if not (0 <= self.predictor <= 2):
                raise ValueError(f"bad predictor id {self.predictor}")
            flags = self.predictor & _FLAG_PRED_MASK
            if self.zero_planes:
                flags |= _FLAG_ZPLANES
                if self.zrow is None or self.zcol is None:
                    raise ValueError(
                        "zero_planes container needs zrow/zcol masks"
                    )
                if len(self.zrow) != self.num_windows or len(
                    self.zcol
                ) != self.e:
                    raise ValueError("zrow/zcol mask length mismatch")
                bitmaps = _pack_bitmap(self.zrow) + _pack_bitmap(self.zcol)
            ext = _EXT3.pack(flags, self.predict_bands)
        crc = zlib.crc32(symlen_b, zlib.crc32(words_b))
        if bitmaps:
            crc = zlib.crc32(bitmaps, crc)
        hdr = _HDR.pack(
            _MAGIC,
            version,
            self.l_max,
            self.n,
            self.e,
            self.num_words,
            self.num_symbols,
            self.num_windows,
            self.signal_length,
            self.max_symlen,
            self.domain_id,
            crc,
        )
        return hdr + ext + words_b + symlen_b + bitmaps

    @staticmethod
    def _parse_header(mv: memoryview, index):
        """Validate and unpack the common (+v3 ext) header of ``mv``.

        Returns ``(header, payload_off, flags_faulty_checked)`` where
        ``payload_off`` is the byte offset of the words section.  Raises
        :class:`ContainerFormatError` (fault class + byte offset + batch
        ``index``) on every malformed-header path, including truncation —
        the quarantine layer keys off these records.
        """
        if len(mv) < HEADER_BYTES:
            raise ContainerFormatError(
                f"truncated container: {len(mv)} bytes is shorter than the "
                f"{HEADER_BYTES}-byte header",
                fault=FAULT_TRUNCATED,
                offset=len(mv),
                index=index,
            )
        (
            magic,
            version,
            l_max,
            n,
            e,
            num_words,
            num_symbols,
            num_windows,
            signal_length,
            max_symlen,
            domain_id,
            crc,
        ) = _HDR.unpack_from(mv, 0)
        if magic != _MAGIC:
            raise ContainerFormatError(
                "bad magic — not an FPTC container",
                fault=FAULT_BAD_MAGIC,
                offset=_OFF_MAGIC,
                index=index,
            )
        if version not in SUPPORTED_VERSIONS:
            raise ContainerFormatError(
                f"unsupported container version {version}; this build reads "
                f"versions {SUPPORTED_VERSIONS} (the forever-decode set)",
                fault=FAULT_BAD_VERSION,
                offset=_OFF_VERSION,
                index=index,
            )
        off = HEADER_BYTES
        predictor, predict_bands, zero_planes = 0, 0, False
        if version == _V3:
            if len(mv) < off + _EXT3.size:
                raise ContainerFormatError(
                    f"truncated container: {len(mv)} bytes cuts off the "
                    f"v3 extension header",
                    fault=FAULT_TRUNCATED,
                    offset=len(mv),
                    index=index,
                )
            flags, predict_bands = _EXT3.unpack_from(mv, off)
            off += _EXT3.size
            predictor = flags & _FLAG_PRED_MASK
            zero_planes = bool(flags & _FLAG_ZPLANES)
            if flags & ~(_FLAG_PRED_MASK | _FLAG_ZPLANES):
                raise ContainerFormatError(
                    f"v3 container sets reserved flag bits "
                    f"{flags:#06x} — written by a newer build?",
                    fault=FAULT_RESERVED_FLAGS,
                    offset=HEADER_BYTES,
                    index=index,
                )
        expected = off + num_words * 9
        if zero_planes:
            expected += (num_windows + 7) // 8 + (e + 7) // 8
        if len(mv) < expected:
            raise ContainerFormatError(
                f"truncated container: have {len(mv)} bytes, header "
                f"promises {expected}",
                fault=FAULT_TRUNCATED,
                offset=len(mv),
                index=index,
            )
        hdr = ContainerHeader(
            version=version,
            n=n,
            e=e,
            l_max=l_max,
            domain_id=domain_id,
            num_words=num_words,
            num_symbols=num_symbols,
            num_windows=num_windows,
            signal_length=signal_length,
            max_symlen=max_symlen,
            coding=(predictor, predict_bands, zero_planes),
        )
        return hdr, off, crc

    @classmethod
    def peek(cls, data, *, index=None) -> ContainerHeader:
        """Header-only parse: O(1), no CRC pass over the payload.

        The serving frontend routes raw bytes to a (kind, plan) queue at
        admission with this — the full :meth:`from_bytes` validation runs
        later at staging, inside the quarantine boundary.  Raises the same
        typed :class:`ContainerFormatError` records for malformed headers
        and truncation.
        """
        return cls._parse_header(memoryview(data), index)[0]

    @classmethod
    def from_bytes(cls, data, *, index=None) -> "Container":
        """Parse a serialized container from any bytes-like buffer.

        Zero-copy: payload sections are referenced through ``memoryview``
        slices (``np.frombuffer``), not copied — the hot decode-staging path
        reads them exactly once while bucketing, so a copy here would be
        pure overhead.  The returned arrays are read-only views keeping
        ``data`` alive.

        All validation failures raise :class:`ContainerFormatError` (a
        ``ValueError``) carrying the fault class, the byte offset of the
        offending field where known, and ``index`` (the container's position
        in its batch, when the caller supplies one) — the serving quarantine
        turns these into per-request outcomes.
        """
        mv = memoryview(data)
        hdr, off, crc = cls._parse_header(mv, index)
        version = hdr.version
        predictor, predict_bands, zero_planes = hdr.coding
        num_words = hdr.num_words
        words = np.frombuffer(mv, dtype="<u8", count=num_words, offset=off)
        off += num_words * 8
        symlen = np.frombuffer(
            mv, dtype=np.uint8, count=num_words, offset=off
        )
        off += num_words
        zrow = zcol = None
        crc_calc = zlib.crc32(symlen, zlib.crc32(words))
        if version == 1:  # legacy: crc covered only the symlen sidecar
            crc_calc = zlib.crc32(symlen)
        if zero_planes:
            nrow_b = (hdr.num_windows + 7) // 8
            ncol_b = (hdr.e + 7) // 8
            bitmaps = mv[off: off + nrow_b + ncol_b]
            zrow = _unpack_bitmap(bitmaps[:nrow_b], hdr.num_windows)
            zcol = _unpack_bitmap(bitmaps[nrow_b:], hdr.e)
            crc_calc = zlib.crc32(bitmaps, crc_calc)
        if crc_calc != crc:
            raise ContainerFormatError(
                "payload CRC mismatch — corrupt container",
                fault=FAULT_CRC_MISMATCH,
                offset=_OFF_CRC,
                index=index,
            )
        c = cls(
            words=words,
            symlen=symlen,
            num_symbols=hdr.num_symbols,
            num_windows=hdr.num_windows,
            signal_length=hdr.signal_length,
            n=hdr.n,
            e=hdr.e,
            l_max=hdr.l_max,
            domain_id=hdr.domain_id,
            predictor=predictor,
            predict_bands=predict_bands,
            zero_planes=zero_planes,
            zrow=zrow,
            zcol=zcol,
        )
        if c.max_symlen != hdr.max_symlen:
            raise ContainerFormatError(
                "max_symlen header mismatch — corrupt container",
                fault=FAULT_HEADER_MISMATCH,
                offset=_OFF_MAX_SYMLEN,
                index=index,
            )
        return c
