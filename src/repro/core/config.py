"""Codec configuration — the paper's Table 1 parameters.

Beyond Table 1, the config carries the **container-v3 coding stage**
(predictor + zero-plane suppression, ROADMAP item 3): an optional lossless
re-coding of the quantized levels before entropy coding.  ``predictor``/
``predict_bands``/``zero_planes`` default off, in which case the encoder
emits the classic v2 container byte for byte.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["CodecConfig", "DOMAIN_DEFAULTS", "PREDICTORS"]

# predictor name -> wire id (container v3 flag bits; order is frozen)
PREDICTORS = {"none": 0, "delta": 1, "linear2": 2}


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """FPTC per-signal-domain parameters (paper Table 1).

    Attributes:
      n:  DCT_SIZE — transform block size, range [4, 128].
      e:  ENCODED_COEFFS — retained low-frequency coefficients, [1, N].
      b1: HYBRID_BOUNDARY_1 — low/mid zone boundary, [0, E].
      b2: HYBRID_BOUNDARY_2 — mid/high zone boundary, [B1, E].
      mu: MU_COMPANDING — companding strength, [1, 500].
      alpha1: DEAD_RATIO_ZONE1 — zone-1 deadzone ratio, [0, 1].
      a0_percentile: ZONE_PERCENTILE — clip percentile for zone maxima,
        [90, 100].
      l_max: maximum Huffman codeword length (LUT is 2**l_max entries; the
        paper bounds it so the table stays cache-resident).
      scale_headroom: multiplier on calibrated zone maxima — clipping guard
        for low-stationarity domains (paper tunes A0 per-domain by
        stationarity; this is the explicit knob).
      predictor: container-v3 window predictor on the low-frequency bands —
        "none" (v2 behaviour), "delta" (residual vs the previous window's
        level), or "linear2" (residual vs the 2*prev - prev2 linear
        extrapolation).  Lossless re-coding of the quantized levels: the
        reconstruction is bit-identical to v2 at the same quant table.
      predict_bands: how many leading coefficient bands [0, predict_bands)
        the predictor applies to (the DC/low-frequency bands, where
        adjacent windows correlate).  0 iff predictor == "none".
      zero_planes: container-v3 zero-plane suppression — all-zero-bin
        window rows and coefficient columns of the coded level grid are
        dropped from the symbol stream and recorded in header bitmaps.
    """

    n: int = 32
    e: int = 16
    b1: int = 2
    b2: int = 16
    mu: float = 50.0
    alpha1: float = 0.004
    a0_percentile: float = 99.9
    l_max: int = 12
    scale_headroom: float = 1.0
    predictor: str = "none"
    predict_bands: int = 0
    zero_planes: bool = False

    def __post_init__(self):
        if not (4 <= self.n <= 128):
            raise ValueError(f"N={self.n} outside [4, 128]")
        if not (1 <= self.e <= self.n):
            raise ValueError(f"E={self.e} outside [1, N={self.n}]")
        if not (0 <= self.b1 <= self.e):
            raise ValueError(f"B1={self.b1} outside [0, E={self.e}]")
        if not (self.b1 <= self.b2 <= self.e):
            raise ValueError(f"B2={self.b2} outside [B1={self.b1}, E={self.e}]")
        if not (1.0 <= self.mu <= 500.0):
            raise ValueError(f"mu={self.mu} outside [1, 500]")
        if not (0.0 <= self.alpha1 <= 1.0):
            raise ValueError(f"alpha1={self.alpha1} outside [0, 1]")
        if not (90.0 <= self.a0_percentile <= 100.0):
            raise ValueError(f"percentile={self.a0_percentile} outside [90,100]")
        if not (1 <= self.l_max <= 16):
            raise ValueError(f"l_max={self.l_max} outside [1, 16]")
        if self.predictor not in PREDICTORS:
            raise ValueError(
                f"predictor={self.predictor!r} not in {sorted(PREDICTORS)}"
            )
        if self.predictor == "none":
            if self.predict_bands != 0:
                raise ValueError(
                    "predict_bands must be 0 when predictor='none'"
                )
        elif not (1 <= self.predict_bands <= self.e):
            raise ValueError(
                f"predict_bands={self.predict_bands} outside [1, E={self.e}]"
            )

    @property
    def coding(self) -> Tuple[int, int, bool]:
        """The v3 coding triple ``(pred_id, predict_bands, zero_planes)``.

        ``(0, 0, False)`` means "no v3 stage" — the v2 wire format.  This
        triple is part of every plan key: plans with different codings trace
        different bucket math and must never share a cache entry.
        """
        return (
            PREDICTORS[self.predictor], self.predict_bands, self.zero_planes
        )

    def replace(self, **kw) -> "CodecConfig":
        return dataclasses.replace(self, **kw)


# Typical per-domain operating points (paper §3.4: typical values, tuned per
# domain smoothness / sampling rate).  These seed calibration; the RD
# benchmark sweeps around them exactly as the paper sweeps N and E.
#
# The last two are *device-resident workload* domains, not archival signal
# domains (see repro.core.domains):
#   kv          — KV-cache timelines, windowed along the token axis per
#                 (head, dim) channel.  n == e (quantization-only) by
#                 default: spectral truncation only helps TRAINED models
#                 whose adjacent-token keys/values are smooth, and the
#                 fixed-rate cache path needs a predictable block size
#                 anyway.  Post-RMSNorm dynamic range is narrow, so a
#                 moderate mu + headroom covers outlier channels.
#   train_state — flattened parameter/optimizer/gradient shards.  Near-
#                 lossless operating point: full retention, heavy mu-law
#                 resolution, 100th-percentile scales (a clipped weight is
#                 a training bug, not a rate win).
DOMAIN_DEFAULTS = {
    "biomedical": CodecConfig(n=32, e=16, b1=4, b2=16, mu=50.0),
    "seismic": CodecConfig(
        n=32, e=32, b1=16, b2=32, mu=255.0, a0_percentile=99.99,
        scale_headroom=1.6,
    ),
    "power": CodecConfig(n=32, e=6, b1=2, b2=6, mu=50.0),
    "meteorological": CodecConfig(n=32, e=8, b1=2, b2=8, mu=50.0),
    "default": CodecConfig(),
    "kv": CodecConfig(
        n=16, e=16, b1=2, b2=16, mu=50.0, a0_percentile=99.9,
        scale_headroom=1.25,
    ),
    "train_state": CodecConfig(
        n=64, e=64, b1=64, b2=64, mu=255.0, a0_percentile=100.0,
        scale_headroom=1.05, l_max=12,
    ),
}
