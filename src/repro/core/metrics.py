"""Evaluation metrics (paper §5.1, Eqs. 4-5)."""
from __future__ import annotations

import numpy as np

__all__ = ["prd", "compression_ratio", "nrmse", "snr_db"]


def prd(original: np.ndarray, reconstructed: np.ndarray) -> float:
    """Percentage root-mean-square difference (Eq. 5)."""
    x = np.asarray(original, dtype=np.float64).ravel()
    xh = np.asarray(reconstructed, dtype=np.float64).ravel()
    if x.shape != xh.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {xh.shape}")
    denom = np.sum(x * x)
    if denom == 0:
        return 0.0 if np.allclose(x, xh) else float("inf")
    return float(100.0 * np.sqrt(np.sum((x - xh) ** 2) / denom))


def compression_ratio(original_bytes: int, compressed_bytes: int) -> float:
    """CR = S_orig / S_comp (Eq. 4)."""
    return original_bytes / max(compressed_bytes, 1)


def nrmse(original: np.ndarray, reconstructed: np.ndarray) -> float:
    """Normalized RMSE (range-normalized) — seismic literature metric."""
    x = np.asarray(original, dtype=np.float64).ravel()
    xh = np.asarray(reconstructed, dtype=np.float64).ravel()
    rng = x.max() - x.min()
    if rng == 0:
        return 0.0 if np.allclose(x, xh) else float("inf")
    return float(np.sqrt(np.mean((x - xh) ** 2)) / rng)


def snr_db(original: np.ndarray, reconstructed: np.ndarray) -> float:
    x = np.asarray(original, dtype=np.float64).ravel()
    e = x - np.asarray(reconstructed, dtype=np.float64).ravel()
    pe = np.sum(e * e)
    if pe == 0:
        return float("inf")
    return float(10.0 * np.log10(np.sum(x * x) / pe))
