"""Windowed DCT-II forward / DCT-III inverse transforms (paper §3.1, Eq. 1).

The paper's transform for a window of N samples:

    C[k] = (2/N) * sum_n x[n] * cos(pi/N * (n + 1/2) * k),   k = 0..N-1

with inverse

    x[n] = C[0]/2 + sum_{k>=1} C[k] * cos(pi/N * (n + 1/2) * k).

On TPU both directions are realized as matmuls against a precomputed basis so
they run on the MXU (the paper's GPU kernel evaluates cosines per sample; the
TPU-native formulation is a [windows, N] @ [N, E] contraction, so both
directions inherit MXU throughput). Bases are cached per (N, E, dtype).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

__all__ = [
    "dct_basis",
    "idct_basis",
    "forward_dct",
    "inverse_dct",
    "window_signal",
    "unwindow_signal",
]


@functools.lru_cache(maxsize=64)
def _dct_basis_np(n: int, e: int) -> np.ndarray:
    """Forward DCT-II basis, shape [N, E]: C = x @ basis."""
    if not (1 <= e <= n):
        raise ValueError(f"retained coeffs E={e} must satisfy 1 <= E <= N={n}")
    samples = np.arange(n, dtype=np.float64)[:, None]  # n index
    freqs = np.arange(e, dtype=np.float64)[None, :]  # k index
    basis = (2.0 / n) * np.cos(np.pi / n * (samples + 0.5) * freqs)
    return basis  # [N, E]


@functools.lru_cache(maxsize=64)
def _idct_basis_np(n: int, e: int) -> np.ndarray:
    """Inverse (DCT-III) basis, shape [E, N]: x = C @ basis.

    Truncated reconstruction: coefficients k >= E are treated as zero
    (spectral truncation, paper §3.1).
    """
    samples = np.arange(n, dtype=np.float64)[None, :]
    freqs = np.arange(e, dtype=np.float64)[:, None]
    basis = np.cos(np.pi / n * (samples + 0.5) * freqs)
    basis[0, :] *= 0.5  # DC term halved in the inverse
    return basis  # [E, N]


def dct_basis(n: int, e: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(_dct_basis_np(n, e), dtype=dtype)


def idct_basis(n: int, e: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(_idct_basis_np(n, e), dtype=dtype)


def window_signal(signal: jnp.ndarray, n: int) -> jnp.ndarray:
    """Partition a 1-D signal strip into non-overlapping windows [W, N].

    The tail is zero-padded to a whole window (decoder trims via sample count
    carried in the container header).
    """
    length = signal.shape[-1]
    num_windows = -(-length // n)
    pad = num_windows * n - length
    if pad:
        signal = jnp.pad(signal, [(0, 0)] * (signal.ndim - 1) + [(0, pad)])
    return signal.reshape(signal.shape[:-1] + (num_windows, n))


def unwindow_signal(windows: jnp.ndarray, length: int) -> jnp.ndarray:
    """Inverse of :func:`window_signal`: [..., W, N] -> [..., length]."""
    flat = windows.reshape(windows.shape[:-2] + (-1,))
    return flat[..., :length]


def forward_dct(windows: jnp.ndarray, e: int) -> jnp.ndarray:
    """[..., W, N] windows -> [..., W, E] retained DCT-II coefficients."""
    n = windows.shape[-1]
    basis = dct_basis(n, e, dtype=windows.dtype)
    return windows @ basis


def inverse_dct(coeffs: jnp.ndarray, n: int) -> jnp.ndarray:
    """[..., W, E] coefficients -> [..., W, N] reconstructed windows."""
    e = coeffs.shape[-1]
    basis = idct_basis(n, e, dtype=coeffs.dtype)
    return coeffs @ basis
