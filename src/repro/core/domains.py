"""Workload signal domains: KV-cache timelines and training state.

The paper calibrates per *signal domain* (biomedical, seismic, power,
meteorological).  Two serving/training workloads are just more signal
domains for the same transform → quantize → (optional) entropy-code
pipeline:

  * **kv** — a KV-cache block ``[B, T, H, D]`` is ``B * H * D`` independent
    time-axis channels; adjacent-token keys/values of trained models are
    smooth, so windowed DCT along the token axis concentrates energy in the
    low bins exactly like an archival strip.  The cache path runs
    *fixed-rate* (transform + table quantization, no entropy coding) so
    compressed blocks keep a static size and O(1) random access during
    decode.
  * **train_state** — parameter / optimizer / gradient tensors flatten into
    fixed-length 1-D shards; accumulators are smooth along the flattened
    axis, the same structure cuSZ+-class compressors exploit for scientific
    checkpoints.  Shards ride the full entropy-coded container path (they
    live on disk / the checkpoint wire, where variable size is fine).

Both calibrations are thin shims over :func:`repro.core.calibration.
calibrate`; they only own the domain-specific *flattening* of structured
tensors into the 1-D strips the calibrator samples windows from, plus the
reserved domain ids the container header carries.
"""
from __future__ import annotations

from typing import Any, Iterable, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.calibration import DomainTables, calibrate
from repro.core.config import CodecConfig, DOMAIN_DEFAULTS

__all__ = [
    "KV_DOMAIN_ID",
    "TRAIN_STATE_DOMAIN_ID",
    "kv_channel_strips",
    "calibrate_kv",
    "train_state_strip",
    "calibrate_train_state",
]

# Reserved domain ids for the workload domains.  0-4 are the archival
# domains (see tests/_synth.GOLDEN_DOMAINS), 5-7 stay free for archival
# growth; containers carry the id in the header so a decode with the wrong
# tables is rejected by validate_container_tables.
KV_DOMAIN_ID = 8
TRAIN_STATE_DOMAIN_ID = 9


def kv_channel_strips(kv: Any, n: int) -> np.ndarray:
    """Flatten a KV block ``[B, T, H, D]`` into per-channel time strips.

    Returns ``f32[B * H * D, T]`` — one row per (batch, head, dim) channel,
    samples ordered along the token axis (the axis the windowed DCT runs
    over).  ``T`` must divide the window size ``n`` so that concatenated
    rows never share a window.
    """
    kv = np.asarray(jax.device_get(kv), dtype=np.float32)
    if kv.ndim != 4:
        raise ValueError(
            f"KV block must be [B, T, H, D], got shape {kv.shape}"
        )
    t = kv.shape[1]
    if t % n:
        raise ValueError(
            f"KV time axis T={t} must be a multiple of the DCT window "
            f"n={n} (fixed-size blocks keep O(1) cache access)"
        )
    return np.moveaxis(kv, 1, -1).reshape(-1, t)


def calibrate_kv(
    kv_sample: Any,
    config: Optional[CodecConfig] = None,
    *,
    domain_id: int = KV_DOMAIN_ID,
    max_windows: Optional[int] = 65536,
    seed: int = 0,
) -> DomainTables:
    """Calibrate ``kv``-domain tables from a representative KV block.

    ``kv_sample`` is ``[B, T, H, D]`` (e.g. one layer's key or value cache
    after a representative prefill).  Every (batch, head, dim) channel
    contributes its token timeline to the calibration strip; windows are
    channel-aligned, so the per-bin scales and the symbol histogram see
    exactly the coefficient distribution the fixed-rate cache path will
    quantize.
    """
    config = config or DOMAIN_DEFAULTS["kv"]
    strips = kv_channel_strips(kv_sample, config.n)
    return calibrate(
        strips.reshape(-1), config,
        domain_id=domain_id, max_windows=max_windows, seed=seed,
    )


def train_state_strip(
    tree_or_leaves: Union[Any, Sequence[Any]],
    *,
    max_elems: int = 1 << 22,
    seed: int = 0,
) -> np.ndarray:
    """Flatten a pytree (or iterable) of float tensors into one 1-D strip.

    Large states are subsampled leaf-proportionally to ``max_elems`` with
    contiguous runs (the calibrator needs *windows*, so sampling keeps
    whole aligned spans rather than scattered elements).  Non-float leaves
    are skipped — they do not compress through FPTC.

    Each leaf is normalized to unit max-abs before it joins the strip:
    checkpoint leaves span orders of magnitude (params vs Adam ``v``), and
    the encode path (``serving.workloads.state_to_containers``) applies
    the same per-leaf normalization, so calibration must see the
    distribution the quantizer will actually face.
    """
    leaves: Iterable[Any]
    if isinstance(tree_or_leaves, (list, tuple)):
        leaves = tree_or_leaves
    else:
        leaves = jax.tree_util.tree_leaves(tree_or_leaves)
    flats = []
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind != "f" or arr.size == 0:
            continue
        flat = arr.astype(np.float32).ravel()
        amax = float(np.max(np.abs(flat)))
        if amax > 0.0:
            flat = flat / np.float32(amax)
        flats.append(flat)
    if not flats:
        raise ValueError("no float leaves to calibrate train_state on")
    total = sum(f.size for f in flats)
    if total > max_elems:
        rng = np.random.default_rng(seed)
        kept = []
        for f in flats:
            take = max(int(f.size / total * max_elems), 1)
            take = min(take, f.size)
            start = int(rng.integers(0, f.size - take + 1))
            kept.append(f[start:start + take])
        flats = kept
    return np.concatenate(flats)


def calibrate_train_state(
    tree_or_leaves: Union[Any, Sequence[Any]],
    config: Optional[CodecConfig] = None,
    *,
    domain_id: int = TRAIN_STATE_DOMAIN_ID,
    max_windows: Optional[int] = 65536,
    seed: int = 0,
) -> DomainTables:
    """Calibrate ``train_state``-domain tables from a representative state.

    One calibration serves a whole checkpoint: every float leaf contributes
    to the strip, and the resulting tables are serialized once per
    checkpoint (scale + histogram sidecar) instead of once per leaf.
    """
    config = config or DOMAIN_DEFAULTS["train_state"]
    strip = train_state_strip(tree_or_leaves, seed=seed)
    return calibrate(
        strip, config,
        domain_id=domain_id, max_windows=max_windows, seed=seed,
    )
