"""Length-limited canonical Huffman coding (paper §3.3).

Code lengths come from the Larmore–Hirschberg *package-merge* algorithm, which
solves length-limited minimum-redundancy coding in O(sigma * L_max) for an
alphabet of sigma symbols (sigma = 256 here: 1-byte post-quantization values).
Codes are then canonized: symbols sorted by (length, value), codewords
assigned in increasing numeric order per length.

This module is **offline/host-side** (numpy): it runs during per-domain
calibration (paper §3.4.2, Fig. 4(2)) and produces the small decode tables
consumed by the JAX/Pallas decoders:

  * ``first_code_shifted[l]`` — smallest L_max-bit-aligned prefix of length l
  * ``limit_shifted[l]``      — one past the largest prefix of length l
  * ``rank_offset[l]``        — rank of the first symbol with code length l
  * ``sorted_symbols[r]``     — symbol for canonical rank r

With these, decode needs **no 2^L_max LUT**: the code length of a prefix P is
``1 + sum_l [P >= limit_shifted[l]]`` (vectorized compares), and the symbol is
``sorted_symbols[rank_offset[len] + ((P - first_code_shifted[len]) >>
(L_max - len))]`` — on TPU the final 256-way lookup is a one-hot matmul.
A classic 2^L_max LUT is also built for the CPU fast path and
as a cross-check oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = [
    "HuffmanCodebook",
    "package_merge_lengths",
    "build_codebook",
    "kraft_sum",
]

ALPHABET = 256


def package_merge_lengths(freqs: np.ndarray, l_max: int) -> np.ndarray:
    """Optimal code lengths under max-length constraint via package-merge.

    Args:
      freqs: int64[ALPHABET] symbol frequencies; zero-frequency symbols get
        length 0 (no codeword).
      l_max: maximum codeword length.

    Returns:
      int32[ALPHABET] code lengths (0 for absent symbols).
    """
    freqs = np.asarray(freqs, dtype=np.int64)
    if freqs.ndim != 1:
        raise ValueError("freqs must be 1-D")
    active = np.nonzero(freqs > 0)[0]
    n = active.size
    lengths = np.zeros(freqs.shape[0], dtype=np.int32)
    if n == 0:
        return lengths
    if n == 1:
        lengths[active[0]] = 1
        return lengths
    if n > (1 << l_max):
        raise ValueError(f"{n} symbols cannot be coded with L_max={l_max}")

    # Package-merge: items are (weight, symbol-multiset as count vector over
    # active symbols). We track, per item, how many times each *original leaf*
    # appears, via index lists — classic implementation.
    base = [(int(freqs[s]), (i,)) for i, s in enumerate(active)]
    base.sort(key=lambda t: t[0])

    packages = list(base)
    for _ in range(l_max - 1):
        # package: pair up adjacent items
        merged = []
        for i in range(0, len(packages) - 1, 2):
            w = packages[i][0] + packages[i + 1][0]
            leaves = packages[i][1] + packages[i + 1][1]
            merged.append((w, leaves))
        # merge with the original leaves
        packages = sorted(base + merged, key=lambda t: t[0])

    # take the first 2n-2 items; each occurrence of leaf i adds 1 to its depth
    counts = np.zeros(n, dtype=np.int32)
    for w, leaves in packages[: 2 * n - 2]:
        for i in leaves:
            counts[i] += 1
    lengths[active] = counts
    return lengths


def kraft_sum(lengths: np.ndarray) -> float:
    """Kraft inequality sum; exactly 1.0 for a complete prefix code."""
    lens = np.asarray(lengths)
    lens = lens[lens > 0]
    return float(np.sum(2.0 ** (-lens.astype(np.float64))))


@dataclasses.dataclass(frozen=True)
class HuffmanCodebook:
    """Canonical length-limited codebook + decode tables (all host numpy)."""

    l_max: int
    lengths: np.ndarray  # int32[256] — 0 means absent
    codes: np.ndarray  # uint32[256] — canonical codeword (right-aligned)
    # --- decode tables (see module docstring) ---
    sorted_symbols: np.ndarray  # uint8[256], padded with 0 beyond num_active
    rank_offset: np.ndarray  # int32[l_max + 1]
    first_code_shifted: np.ndarray  # uint32[l_max + 1]
    limit_shifted: np.ndarray  # uint32[l_max + 1]
    lut_symbol: np.ndarray  # uint8[2**l_max]  (GPU-style LUT, CPU fast path)
    lut_length: np.ndarray  # uint8[2**l_max]

    @property
    def num_active(self) -> int:
        return int(np.sum(self.lengths > 0))

    def expected_bits(self, freqs: np.ndarray) -> float:
        freqs = np.asarray(freqs, dtype=np.float64)
        total = freqs.sum()
        if total == 0:
            return 0.0
        return float(np.sum(freqs * self.lengths) / total)

    def encode_lengths_of(self, symbols: np.ndarray) -> np.ndarray:
        return self.lengths[symbols]


def build_codebook(freqs: np.ndarray, l_max: int = 12) -> HuffmanCodebook:
    """Build the canonical length-limited codebook from a symbol histogram.

    Zero-frequency symbols receive no codeword: calibration (paper §3.4.2)
    applies Laplace smoothing upstream so every symbol that *can* occur at
    encode time has an entry.
    """
    if not (1 <= l_max <= 16):
        raise ValueError("l_max must be in [1, 16] (prefix must fit 16 bits)")
    lengths = package_merge_lengths(freqs, l_max)

    # canonical assignment: sort by (length, symbol); assign increasing codes
    order = np.lexsort((np.arange(ALPHABET), lengths))
    order = order[lengths[order] > 0]
    codes = np.zeros(ALPHABET, dtype=np.uint32)
    code = 0
    prev_len = 0
    for sym in order:
        l = int(lengths[sym])
        code <<= l - prev_len
        codes[sym] = code
        code += 1
        prev_len = l

    # decode tables
    counts = np.bincount(lengths[lengths > 0], minlength=l_max + 1)
    sorted_symbols = np.zeros(ALPHABET, dtype=np.uint8)
    sorted_symbols[: order.size] = order.astype(np.uint8)
    rank_offset = np.zeros(l_max + 1, dtype=np.int32)
    first_code = np.zeros(l_max + 1, dtype=np.uint32)
    first_code_shifted = np.zeros(l_max + 1, dtype=np.uint32)
    limit_shifted = np.zeros(l_max + 1, dtype=np.uint32)
    rank = 0
    code = 0
    prev_len = 0
    full = np.uint32((1 << l_max))
    for l in range(1, l_max + 1):
        code <<= l - prev_len
        prev_len = l
        rank_offset[l] = rank
        first_code[l] = code
        first_code_shifted[l] = code << (l_max - l)
        code += int(counts[l])
        rank += int(counts[l])
        limit_shifted[l] = min(code << (l_max - l), int(full))
    # lengths with zero count get degenerate [first, limit) ranges that are
    # empty but keep limit_shifted monotone — required by the arithmetic
    # decoder's "1 + sum(P >= limit)" length rule.

    # GPU-style LUT (cross-check + CPU fast decode)
    lut_symbol = np.zeros(1 << l_max, dtype=np.uint8)
    lut_length = np.zeros(1 << l_max, dtype=np.uint8)
    for sym in order:
        l = int(lengths[sym])
        prefix = int(codes[sym]) << (l_max - l)
        span = 1 << (l_max - l)
        lut_symbol[prefix : prefix + span] = sym
        lut_length[prefix : prefix + span] = l

    return HuffmanCodebook(
        l_max=l_max,
        lengths=lengths,
        codes=codes,
        sorted_symbols=sorted_symbols,
        rank_offset=rank_offset,
        first_code_shifted=first_code_shifted,
        limit_shifted=limit_shifted,
        lut_symbol=lut_symbol,
        lut_length=lut_length,
    )


def decode_prefix_arith(
    book: HuffmanCodebook, prefix: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Arithmetic canonical decode of L_max-bit prefixes (numpy oracle).

    Mirrors exactly what the Pallas kernel does: length via vectorized
    compares against ``limit_shifted``, rank arithmetic, then symbol lookup.
    """
    prefix = np.asarray(prefix, dtype=np.uint32)
    limits = book.limit_shifted[1:, None]  # [L, ...]
    ge = prefix[None, :] >= limits
    length = 1 + np.sum(ge, axis=0)
    length = np.minimum(length, book.l_max).astype(np.int32)
    fcs = book.first_code_shifted[length]
    rank = book.rank_offset[length] + (
        (prefix - fcs) >> (book.l_max - length)
    ).astype(np.int32)
    rank = np.clip(rank, 0, ALPHABET - 1)
    return book.sorted_symbols[rank], length
