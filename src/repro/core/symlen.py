"""SymLen bitstream format (paper §4.1, Algorithm 1) — pack + parallel unpack.

Codewords are greedily packed MSB-first into fixed 64-bit words; a codeword
never straddles a word boundary.  The *symlen* sidecar stores, per word, the
number of symbols it contains — making every word independently decodable
(the decoder stops after symlen[w] symbols and ignores padding bits).

On-wire format: little-endian uint64 words.  Inside JAX we represent each
word as a (hi, lo) pair of uint32 because TPU int64 is emulated;
``words_to_u32`` / ``u32_to_words`` convert losslessly.

Four implementations:
  * ``pack_symlen_np``      — faithful Algorithm 1, host numpy (the paper's
                              embedded sequential encoder).
  * ``pack_symlen_scan``    — the same algorithm as a ``lax.scan`` (jittable);
                              one scan step per symbol, <=1 word flush per
                              step.  A length-S serial chain: kept as the
                              single-stream reference/baseline.
  * ``pack_symlen_chunked`` — chunk-parallel packing: B scan-lite chunk
                              packs under ``vmap`` (each chunk starts at a
                              fresh word; the scan carries only the O(1)
                              bit-offset/word-index recurrence) stitched by
                              a prefix sum over per-chunk word counts + a
                              gather.  Because every SymLen word is
                              independently decodable, the output decodes
                              bit-exactly with the unchanged decoders, at a
                              cost of < 1 padding word per chunk of stream
                              size.
  * ``unpack_symlen``       — word-parallel decode in pure JAX: lane-per-word
                              slot loop + prefix-sum compaction.  The Pallas
                              kernel in ``repro.kernels.huffman_decode`` is
                              the TPU-tiled version of the same computation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.huffman import HuffmanCodebook

__all__ = [
    "PackedStream",
    "pack_symlen_np",
    "pack_symlen_scan",
    "pack_symlen_chunked",
    "pack_symlen_chunked_parts",
    "stitch_chunk_parts",
    "stitch_capacity",
    "chunk_words_bound",
    "unpack_symlen_np",
    "unpack_symlen",
    "compact_padded_scatter",
    "words_to_u32",
    "u32_to_words",
    "zero_plane_masks",
    "v3_expand_index",
]

WORD_BITS = 64


@dataclasses.dataclass
class PackedStream:
    """A SymLen-packed stream (host container; see core.container for I/O)."""

    words: np.ndarray  # uint64[W]
    symlen: np.ndarray  # int32[W]
    num_symbols: int

    @property
    def num_words(self) -> int:
        return int(self.words.shape[0])

    @property
    def max_symlen(self) -> int:
        return int(self.symlen.max()) if self.symlen.size else 0

    @property
    def payload_bytes(self) -> int:
        # words + symlen sidecar (uint8 is sufficient: symlen <= 64)
        return self.num_words * 8 + self.num_words


def words_to_u32(words: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """uint64[W] -> (hi uint32[W], lo uint32[W])."""
    w = np.asarray(words, dtype=np.uint64)
    hi = (w >> np.uint64(32)).astype(np.uint32)
    lo = (w & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return hi, lo


def u32_to_words(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    return (np.asarray(hi, np.uint64) << np.uint64(32)) | np.asarray(
        lo, np.uint64
    )


# ---------------------------------------------------------------------------
# Host reference encoder — Algorithm 1, line for line.
# ---------------------------------------------------------------------------
def pack_symlen_np(symbols: np.ndarray, book: HuffmanCodebook) -> PackedStream:
    symbols = np.asarray(symbols, dtype=np.uint8).ravel()
    codes = book.codes
    lens = book.lengths
    out_words = []
    out_symlen = []
    buffer = 0
    bit_size = 0
    count = 0
    for s in symbols:
        code = int(codes[s])
        code_len = int(lens[s])
        if code_len == 0:
            raise ValueError(f"symbol {s} has no codeword (histogram gap)")
        if bit_size + code_len > WORD_BITS:
            out_words.append(buffer)
            out_symlen.append(count)
            buffer = 0
            bit_size = 0
            count = 0
            # retry same symbol on the fresh word (always fits: len <= 64)
        shift = WORD_BITS - bit_size - code_len
        buffer |= code << shift
        bit_size += code_len
        count += 1
    if count > 0:
        out_words.append(buffer)
        out_symlen.append(count)
    return PackedStream(
        words=np.array(out_words, dtype=np.uint64),
        symlen=np.array(out_symlen, dtype=np.int32),
        num_symbols=int(symbols.size),
    )


# ---------------------------------------------------------------------------
# Device encoders — scan (1 step per symbol) and chunk-parallel.
# ---------------------------------------------------------------------------
def _precheck_symbols(symbols, lengths, num_symbols, valid=None) -> None:
    """Host-side guard against silent corruption: every symbol that occurs in
    the input must have a codeword (``lengths[sym] > 0``).

    A zero-length symbol would emit zero bits yet still increment the word's
    symlen count, so the stream *decodes* — to garbage.  ``pack_symlen_np``
    raises for this; the device packers must reject the same input.  Under
    jit/vmap the operands are tracers and the check is skipped — batched
    callers (``repro.serving.batch_encode``) enforce it with a device-side
    flag checked at drain time instead.
    """
    if any(
        isinstance(x, jax.core.Tracer)
        for x in (symbols, lengths, num_symbols, valid)
    ):
        return
    if valid is not None:
        syms = np.asarray(symbols).ravel()[np.asarray(valid).ravel()]
    else:
        syms = np.asarray(symbols).ravel()[: int(num_symbols)]
    if syms.size == 0:
        return
    lens = np.asarray(lengths).ravel()
    hist = np.bincount(syms.astype(np.int64), minlength=lens.size)
    gaps = np.nonzero((hist[: lens.size] > 0) & (lens == 0))[0]
    if gaps.size:
        raise ValueError(
            f"symbol {int(gaps[0])} has no codeword (histogram gap); "
            f"{gaps.size} distinct input symbol(s) are unencodable"
        )


def pack_symlen_scan(
    symbols: jnp.ndarray,
    codes: jnp.ndarray,  # uint32[256] (right-aligned codewords, len <= 32)
    lengths: jnp.ndarray,  # int32[256]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (hi uint32[W], lo uint32[W], symlen int32[W], num_words int32).

    The faithful Algorithm-1 device transcription — one scan step per
    symbol, carrying the output buffers — kept as the single-stream
    reference and the baseline the chunk-parallel packer is benchmarked
    against.  Output arrays are sized at the worst case (one word per
    symbol); the returned ``num_words`` gives the valid prefix. Codeword
    length is bounded by 32 (L_max <= 16 in practice) so a codeword touches
    at most both halves of the (hi, lo) pair.
    """
    n = symbols.shape[0]
    _precheck_symbols(symbols, lengths, n)
    symbols = symbols.astype(jnp.int32)

    def emit(code: jnp.ndarray, clen: jnp.ndarray, bit_size: jnp.ndarray):
        """Place right-aligned ``code`` of length clen at bit offset bit_size
        (MSB-first) inside a fresh 64-bit (hi, lo) pair."""
        shift = 64 - bit_size - clen  # in [0, 63]
        c = code.astype(jnp.uint32)
        # hi receives bits of code shifted by (shift - 32) when shift >= 32
        hi = jnp.where(
            shift >= 32,
            _shl32(c, shift - 32),
            _shr32(c, 32 - shift),
        )
        lo = jnp.where(shift >= 32, jnp.uint32(0), _shl32(c, shift))
        return hi, lo

    def step(carry, sym):
        w, count, bhi, blo, bit_size, out_hi, out_lo, out_sl = carry
        code = codes[sym]
        clen = lengths[sym]
        flush = bit_size + clen > WORD_BITS
        # flush current word
        out_hi = jnp.where(flush, out_hi.at[w].set(bhi), out_hi)
        out_lo = jnp.where(flush, out_lo.at[w].set(blo), out_lo)
        out_sl = jnp.where(flush, out_sl.at[w].set(count), out_sl)
        w = jnp.where(flush, w + 1, w)
        bhi = jnp.where(flush, jnp.uint32(0), bhi)
        blo = jnp.where(flush, jnp.uint32(0), blo)
        bit_size = jnp.where(flush, 0, bit_size)
        count = jnp.where(flush, 0, count)
        # append symbol
        add_hi, add_lo = emit(code, clen, bit_size)
        bhi = bhi | add_hi
        blo = blo | add_lo
        bit_size = bit_size + clen
        count = count + 1
        return (w, count, bhi, blo, bit_size, out_hi, out_lo, out_sl), None

    init = (
        jnp.int32(0),
        jnp.int32(0),
        jnp.uint32(0),
        jnp.uint32(0),
        jnp.int32(0),
        jnp.zeros((n,), jnp.uint32),
        jnp.zeros((n,), jnp.uint32),
        jnp.zeros((n,), jnp.int32),
    )
    (w, count, bhi, blo, _, out_hi, out_lo, out_sl), _ = jax.lax.scan(
        step, init, symbols
    )
    # final partial word
    has_tail = count > 0
    out_hi = jnp.where(has_tail, out_hi.at[w].set(bhi), out_hi)
    out_lo = jnp.where(has_tail, out_lo.at[w].set(blo), out_lo)
    out_sl = jnp.where(has_tail, out_sl.at[w].set(count), out_sl)
    num_words = w + has_tail.astype(jnp.int32)
    return out_hi, out_lo, out_sl, num_words


def _pack_chunk(
    symbols: jnp.ndarray,  # int32[M]
    valid: jnp.ndarray,  # bool[M] — padding slots pack to nothing
    codes: jnp.ndarray,  # uint32[256]
    lengths: jnp.ndarray,  # int32[256]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Greedy packing of one chunk, scan-lite and scatter-free (vmappable).

    Returns (hi uint32[M], lo uint32[M], symlen int32[M], num_words int32);
    the valid word prefix is ``num_words``.

    The (code, length) table lookup happens here; the packing math itself
    lives in :func:`_pack_chunk_emit` so the fused Pallas encode kernel
    (``repro.kernels.encode_fused``), which looks the tables up via the
    one-hot MXU idiom instead of a gather, runs the *same* emit code —
    that sharing is what makes the kernel path bit-identical by
    construction.
    """
    m = symbols.shape[0]
    if m == 0:
        z = jnp.zeros((0,), jnp.uint32)
        return z, z, jnp.zeros((0,), jnp.int32), jnp.int32(0)
    # masked slots emit a zero-length, zero-valued code: a no-op
    code = jnp.where(valid, codes[symbols], jnp.uint32(0))
    clen = jnp.where(valid, lengths[symbols], 0)
    return _pack_chunk_emit(code, clen, valid)


def _pack_chunk_emit(
    code: jnp.ndarray,  # uint32[M] right-aligned codewords (0 when masked)
    clen: jnp.ndarray,  # int32[M] codeword lengths (0 when masked)
    valid: jnp.ndarray,  # bool[M]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Greedy word materialization from per-symbol (code, length) pairs.

    The only truly sequential part of greedy packing is the (bit offset,
    word index) recurrence — an O(1) carry per symbol — so that is *all* the
    ``lax.scan`` computes (carrying the output buffers instead, as
    ``pack_symlen_scan`` does, costs an O(M) select per step and is
    quadratic).  Word materialization happens outside the scan with no
    scatter (CPU XLA scatters serialize): symbol bits within a word occupy
    disjoint slots, so each word is a *segment sum* of per-symbol shifted
    codes — and since ``word_idx`` is sorted, segment sums are differences
    of one cumulative sum at segment boundaries found by ``searchsorted``
    (uint32 overflow wraps; differences stay exact mod 2^32).
    """
    m = code.shape[0]

    def step(carry, cl):
        bit_size, w = carry
        flush = bit_size + cl > WORD_BITS
        w = w + flush.astype(jnp.int32)
        start = jnp.where(flush, 0, bit_size)
        return (start + cl, w), (w, start)

    _, (word_idx, start) = jax.lax.scan(
        step, (jnp.int32(0), jnp.int32(0)), clen
    )
    # place right-aligned `code` of length clen at bit offset `start`
    # (MSB-first) of its word: hi takes the bits when shift >= 32
    shift = WORD_BITS - start - clen  # in [0, 64]; 64 only for clen == 0
    add_hi = jnp.where(
        shift >= 32, _shl32(code, shift - 32), _shr32(code, 32 - shift)
    )
    add_lo = jnp.where(shift >= 32, jnp.uint32(0), _shl32(code, shift))
    zero_u = jnp.zeros((1,), jnp.uint32)
    zero_i = jnp.zeros((1,), jnp.int32)
    csum_hi = jnp.concatenate([zero_u, jnp.cumsum(add_hi)])
    csum_lo = jnp.concatenate([zero_u, jnp.cumsum(add_lo)])
    csum_sl = jnp.concatenate([zero_i, jnp.cumsum(valid.astype(jnp.int32))])
    # word w covers symbols [right[w-1], right[w]): word indices are
    # contiguous from 0, so one searchsorted gives both boundaries
    w_range = jnp.arange(m, dtype=jnp.int32)
    right = jnp.searchsorted(
        word_idx, w_range, side="right", method="scan_unrolled"
    ).astype(jnp.int32)
    left = jnp.concatenate([zero_i, right[:-1]])
    out_hi = csum_hi[right] - csum_hi[left]
    out_lo = csum_lo[right] - csum_lo[left]
    out_sl = csum_sl[right] - csum_sl[left]
    num_words = jnp.max(jnp.where(valid, word_idx + 1, 0))
    return out_hi, out_lo, out_sl, num_words


def pack_symlen_chunked(
    symbols: jnp.ndarray,
    codes: jnp.ndarray,  # uint32[256]
    lengths: jnp.ndarray,  # int32[256]
    *,
    chunk_size: int,
    num_symbols=None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunk-parallel SymLen packing (Algorithm 1, chunk-lifted).

    Splits the stream into ``B = ceil(S / chunk_size)`` fixed-size chunks,
    packs each greedily starting at a fresh 64-bit word — a ``vmap`` of B
    scan-lite chunk packs instead of one serial scan of length S — then
    stitches the per-chunk word runs into one dense stream via a prefix sum
    over per-chunk word counts + a gather.

    **Decoder compatibility.**  SymLen words are independently decodable (the
    sidecar says how many symbols each word holds; trailing pad bits are
    ignored), so *any* symbol→word assignment that preserves symbol order and
    respects the 64-bit capacity is a legal stream.  Starting a fresh word at
    each chunk boundary is therefore invisible to the unchanged serial /
    word-parallel / Pallas decoders: the output decodes bit-exactly.  Cost:
    each chunk boundary wastes at most the tail of one word, i.e. the stream
    grows by < 1 word per chunk vs the sequential packer (with
    ``chunk_size = S`` the output is bit-identical to ``pack_symlen_np``).

    Args:
      symbols: integer[S] symbol stream.
      codes / lengths: encode tables.
      chunk_size: symbols per chunk (static under jit).
      num_symbols: optional true symbol count (host int or device scalar) —
        symbols at index >= num_symbols are padding and pack to nothing.
        Defaults to S.  This is what lets the batched encoder stack
        shape-bucketed signals without corrupting their streams.

    Returns:
      (hi uint32[C], lo uint32[C], symlen int32[C], num_words int32) with
      capacity ``C = B * chunk_size``; the valid prefix is ``num_words``.
    """
    chunk_hi, chunk_lo, chunk_sl, wpc = pack_symlen_chunked_parts(
        symbols, codes, lengths, chunk_size=chunk_size,
        num_symbols=num_symbols,
    )
    num_chunks, _ = chunk_hi.shape
    return stitch_chunk_parts(
        chunk_hi, chunk_lo, chunk_sl, wpc,
        capacity=num_chunks * chunk_size,
    )


def chunk_words_bound(chunk_size: int, l_max: int) -> int:
    """Static upper bound on the words one chunk of ``chunk_size`` symbols
    can pack to — host-computable, so device-resident consumers of chunk
    parts (the transcode pipeline) can size stitched streams without a host
    sync on the true word counts.

    A word is flushed only when the next codeword (<= ``l_max`` bits) does
    not fit, so every flushed word carries more than ``64 - l_max`` bits and
    therefore at least ``floor(64 / l_max)`` symbols; only the chunk's last
    word may hold fewer (>= 1).  Hence
    ``words <= (chunk_size - 1) // floor(64 / l_max) + 1`` (and trivially
    ``words <= chunk_size``).
    """
    if chunk_size <= 0:
        return 0
    s_min = max(WORD_BITS // max(int(l_max), 1), 1)
    return min(int(chunk_size), (int(chunk_size) - 1) // s_min + 1)


# Stitched-stream capacities quantize to this grid so jit specializations of
# downstream decode stay O(log sizes) even when capacities are exact counts.
STITCH_CAPACITY_GRID = 256


def stitch_capacity(words: int, *, grid: int = STITCH_CAPACITY_GRID) -> int:
    """Round a stitched-stream word capacity up to the compile grid.

    ``words`` may be the static worst-case bound (``chunk_words_bound``
    summed over chunks) or — when the caller tolerates one pre-decode sync
    on ``words_per_chunk`` — the exact packed word count; the grid bounds
    the number of distinct static capacities (hence XLA specializations of
    the bucket decode) either way.  Deliberately NOT a power of two: the
    bound is already ~2-3x the true word count and decode slot work is
    linear in capacity, so p2 rounding on top would double it again.
    """
    return -(-max(int(words), 1) // grid) * grid


@functools.partial(jax.jit, static_argnames=("capacity",))
def stitch_chunk_parts(
    chunk_hi: jnp.ndarray,  # uint32[B, C]
    chunk_lo: jnp.ndarray,  # uint32[B, C]
    chunk_sl: jnp.ndarray,  # int32[B, C]
    words_per_chunk: jnp.ndarray,  # int32[B]
    *,
    capacity: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Device-side stitch: chunk parts -> one dense decoder-shaped stream.

    Chunk b's valid words (its row's first ``words_per_chunk[b]`` entries)
    land in the output run ``[cum[b-1], cum[b])`` — a pure gather (output
    position -> source chunk/slot), scatter-free, all on device.  Positions
    past the total word count are zero words with ``symlen == 0``, which
    every decoder treats as contributing no symbols — so the output is
    directly consumable as a (padded) concatenated bucket stream by
    ``unpack_symlen`` / the Pallas kernel / ``BatchDecoder.decode_streams``.

    ``capacity`` must be a static host-side bound on the total word count
    (exact counts are device-resident); :func:`chunk_words_bound` gives a
    safe per-chunk bound and :func:`stitch_capacity` the compile-grid
    rounding the serving executor's staging contract expects (its inputs
    may live on any shard's device — the stitch follows them, so per-shard
    streams never leave their device).  Multi-signal chunk parts
    ``[K, B, C]`` stitch to
    one concatenated multi-signal stream by reshaping to ``[K * B, C]`` —
    row order is signal order, so the segment structure the symlen sidecar
    induces matches the per-signal window metadata.

    Returns (hi uint32[capacity], lo uint32[capacity], symlen
    int32[capacity], num_words int32) — ``num_words`` (a device scalar; no
    sync) is the live prefix.
    """
    b = chunk_hi.shape[0]
    if b == 0 or capacity == 0:
        z = jnp.zeros((capacity,), jnp.uint32)
        return z, z, jnp.zeros((capacity,), jnp.int32), jnp.int32(0)
    wpc = words_per_chunk.astype(jnp.int32)
    cum = jnp.cumsum(wpc)  # inclusive prefix sum, int32[B]
    pos = jnp.arange(capacity, dtype=jnp.int32)
    src = jnp.minimum(
        jnp.searchsorted(cum, pos, side="right"), b - 1
    ).astype(jnp.int32)
    slot = jnp.minimum(pos - (cum[src] - wpc[src]), chunk_hi.shape[1] - 1)
    live = pos < cum[-1]
    return (
        jnp.where(live, chunk_hi[src, slot], jnp.uint32(0)),
        jnp.where(live, chunk_lo[src, slot], jnp.uint32(0)),
        jnp.where(live, chunk_sl[src, slot], 0),
        cum[-1],
    )


def pack_symlen_chunked_parts(
    symbols: jnp.ndarray,
    codes: jnp.ndarray,  # uint32[256]
    lengths: jnp.ndarray,  # int32[256]
    *,
    chunk_size: int,
    num_symbols=None,
    valid=None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The un-stitched form of :func:`pack_symlen_chunked`.

    Returns (hi uint32[B, chunk_size], lo uint32[B, chunk_size],
    symlen int32[B, chunk_size], words_per_chunk int32[B]): chunk b's valid
    words are its row's first ``words_per_chunk[b]`` entries, and the dense
    stream is their in-order concatenation.  The batched encode engine
    consumes this directly — draining chunk runs and concatenating on the
    host is cheaper than a device-side gather stitch, and the stream bytes
    are identical either way.

    ``valid`` (bool[S], mutually exclusive with ``num_symbols``) masks an
    arbitrary — not necessarily prefix — subset of slots: masked slots emit
    nothing, advance nothing, and are not counted in the symlen sidecar, so
    the packed stream equals the greedy pack of the *compacted* valid
    subsequence.  This is what makes container-v3 zero-plane suppression
    free at encode time: the suppressed grid cells are simply masked out.
    """
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    s = symbols.shape[0]
    num_chunks = max(-(-s // chunk_size), 1)
    cap = num_chunks * chunk_size
    if valid is not None:
        if num_symbols is not None:
            raise ValueError("pass num_symbols or valid, not both")
        _precheck_symbols(symbols, lengths, None, valid)
        valid = valid.astype(bool)
        if cap != s:
            valid = jnp.pad(valid, (0, cap - s))
    else:
        if num_symbols is None:
            num_symbols = s
        _precheck_symbols(symbols, lengths, num_symbols)
        nsym = jnp.asarray(num_symbols, jnp.int32)
        valid = jnp.arange(cap, dtype=jnp.int32) < nsym
    symbols = symbols.astype(jnp.int32)
    if cap != s:
        symbols = jnp.pad(symbols, (0, cap - s))
    return jax.vmap(_pack_chunk, in_axes=(0, 0, None, None))(
        symbols.reshape(num_chunks, chunk_size),
        valid.reshape(num_chunks, chunk_size),
        codes,
        lengths,
    )


# ---------------------------------------------------------------------------
# Container-v3 zero-plane stream layout (host-side reference).
#
# With zero-plane suppression, the coded symbol stream omits every grid cell
# (w, k) lying in an all-zero-bin window row (zrow[w]) or coefficient column
# (zcol[k]) of the coded level grid.  The two helpers below define the ONE
# canonical mapping between the dense coded stream and the flat [W, E] grid
# — the encoder's suppression mask and the decoder's expansion index are
# both derived from it, so encode and decode can never disagree about
# stream order (row-major over the surviving cells).
# ---------------------------------------------------------------------------
def zero_plane_masks(grid: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(zrow bool[W], zcol bool[E]) of a coded level grid ``[W, E]``.

    ``zrow[w]``: every band of window w coded to the zero bin 128.
    ``zcol[k]``: band k coded to 128 in every window (all-zero rows are
    themselves all-128, so including them cannot flip a column).
    A cell is suppressed iff its row OR column is a zero plane; the
    surviving cell count is rectangular: (W - nzrow) * (E - nzcol).
    """
    grid = np.asarray(grid)
    zrow = np.all(grid == 128, axis=1)
    zcol = np.all(grid == 128, axis=0)
    return zrow, zcol


def v3_expand_index(
    members,
    e: int,
    *,
    total_windows: int = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Expansion metadata for a (possibly concatenated) v3 coded stream.

    ``members`` is a sequence of ``(num_windows, zrow, zcol)`` per signal in
    stream order (``zrow``/``zcol`` may be None for no suppression);
    ``total_windows`` pads the grid to the decode bucket's rounded window
    count.  Returns:

      idx int32[total_windows * e] — for each flat grid cell, its position
        in the dense coded stream (concatenation of the members' coded
        symbols), or -1 where the cell is suppressed or bucket padding
        (those expand to the zero bin — see ``quantize.expand_coded_stream``).
      seg_start int32[total_windows] — the index of the first window of the
        cell's signal (its own index for padding windows, making each one a
        degenerate single-window segment that unpredicts to all-128), the
        segment structure ``quantize.unpredict_levels`` needs so prediction
        never crosses a signal boundary.
    """
    win_off = 0
    sym_off = 0
    nw_total = sum(int(m[0]) for m in members)
    if total_windows is None:
        total_windows = nw_total
    if total_windows < nw_total:
        raise ValueError(
            f"total_windows={total_windows} < member windows {nw_total}"
        )
    idx = np.full(total_windows * e, -1, dtype=np.int32)
    seg_start = np.arange(total_windows, dtype=np.int32)
    for num_windows, zrow, zcol in members:
        w = int(num_windows)
        mask = np.ones((w, e), dtype=bool)
        if zrow is not None:
            mask &= ~np.asarray(zrow, dtype=bool)[:, None]
        if zcol is not None:
            mask &= ~np.asarray(zcol, dtype=bool)[None, :]
        flat = mask.ravel()
        ncoded = int(np.count_nonzero(flat))
        local = np.cumsum(flat) - 1  # rank of each coded cell, row-major
        span = idx[win_off * e: win_off * e + w * e]
        span[flat] = (local[flat] + sym_off).astype(np.int32)
        seg_start[win_off: win_off + w] = win_off
        win_off += w
        sym_off += ncoded
    return idx, seg_start


def _shl32(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """uint32 left shift, defined 0 for s >= 32 or s < 0."""
    s32 = jnp.clip(s, 0, 31).astype(jnp.uint32)
    val = x << s32
    return jnp.where((s >= 32) | (s < 0), jnp.uint32(0), val)


def _shr32(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """uint32 logical right shift, defined 0 for s >= 32 or s < 0."""
    s32 = jnp.clip(s, 0, 31).astype(jnp.uint32)
    val = x >> s32
    return jnp.where((s >= 32) | (s < 0), jnp.uint32(0), val)


# ---------------------------------------------------------------------------
# Host reference decoder (bit-serial, LUT-based — the paper's GPU semantics).
# ---------------------------------------------------------------------------
def unpack_symlen_np(
    stream: PackedStream, book: HuffmanCodebook
) -> np.ndarray:
    out = np.empty(stream.num_symbols, dtype=np.uint8)
    pos = 0
    lmax = book.l_max
    mask = (1 << lmax) - 1
    for w, sl in zip(stream.words, stream.symlen):
        cur = int(w)
        consumed = 0
        for _ in range(int(sl)):
            window = (cur >> max(WORD_BITS - lmax, 0)) & mask
            # if fewer than lmax bits remain, low bits are zero padding —
            # prefix-free codes still decode correctly (paper §4.2.1)
            sym = book.lut_symbol[window]
            l = int(book.lut_length[window])
            cur = (cur << l) & ((1 << WORD_BITS) - 1)
            consumed += l
            out[pos] = sym
            pos += 1
        assert consumed <= WORD_BITS
    assert pos == stream.num_symbols
    return out


def compact_padded_scatter(
    padded: jnp.ndarray,  # [W, max_symlen] (any integer dtype)
    symlen: jnp.ndarray,  # int32[W]
    num_symbols: int,
) -> jnp.ndarray:
    """Compact a padded per-word symbol tile to a dense ``[num_symbols]``.

    Segment-aware scatter: one exclusive prefix-sum over the symlen sidecar
    gives every word its output offset, then all (word, slot) pairs scatter
    simultaneously — slot ``j`` of word ``w`` lands at ``offsets[w] + j`` when
    ``j < symlen[w]`` and is dropped otherwise.  This replaces the per-symbol
    ``searchsorted`` gather (O(T log W) index searches) with a single
    O(W * max_symlen) scatter, and — because the offsets are *segment* sums —
    it is oblivious to container boundaries: concatenated multi-container
    streams compact in the same dispatch (the paper's prefix-scan +
    cooperative-write stage, batch-lifted).

    Padding words (symlen == 0) and tail slots contribute nothing; output
    positions beyond the last real symbol stay zero.
    """
    w, max_symlen = padded.shape
    symlen = symlen.astype(jnp.int32)
    offsets = jnp.cumsum(symlen) - symlen  # exclusive prefix sum, int32[W]
    slot = jnp.arange(max_symlen, dtype=jnp.int32)
    idx = offsets[:, None] + slot[None, :]  # [W, max_symlen]
    valid = slot[None, :] < symlen[:, None]
    # invalid lanes scatter out of bounds and are dropped
    idx = jnp.where(valid, idx, num_symbols)
    out = jnp.zeros((num_symbols,), dtype=padded.dtype)
    return out.at[idx.ravel()].set(padded.ravel(), mode="drop")


# ---------------------------------------------------------------------------
# Word-parallel decoder — pure JAX (XLA); mirrors the Pallas kernel exactly.
# ---------------------------------------------------------------------------
def unpack_symlen(
    hi: jnp.ndarray,  # uint32[W]
    lo: jnp.ndarray,  # uint32[W]
    symlen: jnp.ndarray,  # int32[W]
    dec_limit: jnp.ndarray,  # uint32[L_max] = limit_shifted[1:]
    dec_first: jnp.ndarray,  # uint32[L_max + 1] = first_code_shifted
    dec_rank: jnp.ndarray,  # int32[L_max + 1]  = rank_offset
    dec_syms: jnp.ndarray,  # int32[256]        = sorted_symbols
    l_max: int,
    max_symlen: int,
    num_symbols: int,
) -> jnp.ndarray:
    """Decode all words in parallel and compact to a dense uint8[num_symbols].

    Per slot iteration (over ``max_symlen`` slots), ALL words decode one
    symbol simultaneously:
      1. prefix  = top L_max bits of the remaining buffer (lives in hi)
      2. length  = 1 + sum_l [prefix >= limit_shifted[l]]   (vector compares)
      3. rank    = rank_offset[len] + ((prefix - first_code_shifted[len])
                   >> (L_max - len))
      4. symbol  = sorted_symbols[rank]
      5. funnel-shift (hi, lo) left by length
    Compaction: :func:`compact_padded_scatter` — a segment-aware scatter
    driven by one exclusive prefix-sum of symlen (the XLA lift of the paper's
    prefix-scan + warp-cooperative write stage); works unchanged on
    concatenated multi-container streams.
    """

    def slot_step(carry, _):
        cur_hi, cur_lo = carry
        prefix = _shr32(cur_hi, 32 - l_max)  # uint32[W]
        ge = prefix[None, :] >= dec_limit[:, None]  # [L_max, W]
        length = 1 + jnp.sum(ge.astype(jnp.int32), axis=0)
        length = jnp.minimum(length, l_max)  # clamp garbage/padding prefixes
        fcs = dec_first[length]
        rank = dec_rank[length] + (
            _shr32(prefix - fcs, l_max - length)
        ).astype(jnp.int32)
        rank = jnp.clip(rank, 0, 255)
        sym = dec_syms[rank].astype(jnp.uint8)
        # funnel shift left by `length` (1 <= length <= l_max <= 16 < 32)
        new_hi = _shl32(cur_hi, length) | _shr32(cur_lo, 32 - length)
        new_lo = _shl32(cur_lo, length)
        return (new_hi, new_lo), sym

    (_, _), padded = jax.lax.scan(
        slot_step, (hi, lo), None, length=max_symlen
    )  # padded: uint8[max_symlen, W]
    return compact_padded_scatter(padded.T, symlen, num_symbols)
