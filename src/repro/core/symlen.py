"""SymLen bitstream format (paper §4.1, Algorithm 1) — pack + parallel unpack.

Codewords are greedily packed MSB-first into fixed 64-bit words; a codeword
never straddles a word boundary.  The *symlen* sidecar stores, per word, the
number of symbols it contains — making every word independently decodable
(the decoder stops after symlen[w] symbols and ignores padding bits).

On-wire format: little-endian uint64 words.  Inside JAX we represent each
word as a (hi, lo) pair of uint32 because TPU int64 is emulated (DESIGN.md
§2); ``words_to_u32`` / ``u32_to_words`` convert losslessly.

Three implementations:
  * ``pack_symlen_np``    — faithful Algorithm 1, host numpy (the paper's
                            embedded sequential encoder).
  * ``pack_symlen_scan``  — the same algorithm as a ``lax.scan`` (jittable);
                            one scan step per symbol, <=1 word flush per step.
  * ``unpack_symlen``     — word-parallel decode in pure JAX: lane-per-word
                            slot loop + prefix-sum compaction.  The Pallas
                            kernel in ``repro.kernels.huffman_decode`` is the
                            TPU-tiled version of the same computation.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.huffman import HuffmanCodebook

__all__ = [
    "PackedStream",
    "pack_symlen_np",
    "pack_symlen_scan",
    "unpack_symlen_np",
    "unpack_symlen",
    "compact_padded_scatter",
    "words_to_u32",
    "u32_to_words",
]

WORD_BITS = 64


@dataclasses.dataclass
class PackedStream:
    """A SymLen-packed stream (host container; see core.container for I/O)."""

    words: np.ndarray  # uint64[W]
    symlen: np.ndarray  # int32[W]
    num_symbols: int

    @property
    def num_words(self) -> int:
        return int(self.words.shape[0])

    @property
    def max_symlen(self) -> int:
        return int(self.symlen.max()) if self.symlen.size else 0

    @property
    def payload_bytes(self) -> int:
        # words + symlen sidecar (uint8 is sufficient: symlen <= 64)
        return self.num_words * 8 + self.num_words


def words_to_u32(words: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """uint64[W] -> (hi uint32[W], lo uint32[W])."""
    w = np.asarray(words, dtype=np.uint64)
    hi = (w >> np.uint64(32)).astype(np.uint32)
    lo = (w & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return hi, lo


def u32_to_words(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    return (np.asarray(hi, np.uint64) << np.uint64(32)) | np.asarray(
        lo, np.uint64
    )


# ---------------------------------------------------------------------------
# Host reference encoder — Algorithm 1, line for line.
# ---------------------------------------------------------------------------
def pack_symlen_np(symbols: np.ndarray, book: HuffmanCodebook) -> PackedStream:
    symbols = np.asarray(symbols, dtype=np.uint8).ravel()
    codes = book.codes
    lens = book.lengths
    out_words = []
    out_symlen = []
    buffer = 0
    bit_size = 0
    count = 0
    for s in symbols:
        code = int(codes[s])
        code_len = int(lens[s])
        if code_len == 0:
            raise ValueError(f"symbol {s} has no codeword (histogram gap)")
        if bit_size + code_len > WORD_BITS:
            out_words.append(buffer)
            out_symlen.append(count)
            buffer = 0
            bit_size = 0
            count = 0
            # retry same symbol on the fresh word (always fits: len <= 64)
        shift = WORD_BITS - bit_size - code_len
        buffer |= code << shift
        bit_size += code_len
        count += 1
    if count > 0:
        out_words.append(buffer)
        out_symlen.append(count)
    return PackedStream(
        words=np.array(out_words, dtype=np.uint64),
        symlen=np.array(out_symlen, dtype=np.int32),
        num_symbols=int(symbols.size),
    )


# ---------------------------------------------------------------------------
# Device encoder — identical semantics as a lax.scan (1 step per symbol).
# ---------------------------------------------------------------------------
def pack_symlen_scan(
    symbols: jnp.ndarray,
    codes: jnp.ndarray,  # uint32[256] (right-aligned codewords, len <= 32)
    lengths: jnp.ndarray,  # int32[256]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (hi uint32[W], lo uint32[W], symlen int32[W], num_words int32).

    Output arrays are sized at the worst case (one word per symbol); the
    returned ``num_words`` gives the valid prefix. Codeword length is bounded
    by 32 (L_max <= 16 in practice) so a codeword touches at most both halves
    of the (hi, lo) pair.
    """
    n = symbols.shape[0]
    symbols = symbols.astype(jnp.int32)

    def emit(code: jnp.ndarray, clen: jnp.ndarray, bit_size: jnp.ndarray):
        """Place right-aligned ``code`` of length clen at bit offset bit_size
        (MSB-first) inside a fresh 64-bit (hi, lo) pair."""
        shift = 64 - bit_size - clen  # in [0, 63]
        c = code.astype(jnp.uint32)
        # hi receives bits of code shifted by (shift - 32) when shift >= 32
        hi = jnp.where(
            shift >= 32,
            _shl32(c, shift - 32),
            _shr32(c, 32 - shift),
        )
        lo = jnp.where(shift >= 32, jnp.uint32(0), _shl32(c, shift))
        return hi, lo

    def step(carry, sym):
        w, count, bhi, blo, bit_size, out_hi, out_lo, out_sl = carry
        code = codes[sym]
        clen = lengths[sym]
        flush = bit_size + clen > WORD_BITS
        # flush current word
        out_hi = jnp.where(flush, out_hi.at[w].set(bhi), out_hi)
        out_lo = jnp.where(flush, out_lo.at[w].set(blo), out_lo)
        out_sl = jnp.where(flush, out_sl.at[w].set(count), out_sl)
        w = jnp.where(flush, w + 1, w)
        bhi = jnp.where(flush, jnp.uint32(0), bhi)
        blo = jnp.where(flush, jnp.uint32(0), blo)
        bit_size = jnp.where(flush, 0, bit_size)
        count = jnp.where(flush, 0, count)
        # append symbol
        add_hi, add_lo = emit(code, clen, bit_size)
        bhi = bhi | add_hi
        blo = blo | add_lo
        bit_size = bit_size + clen
        count = count + 1
        return (w, count, bhi, blo, bit_size, out_hi, out_lo, out_sl), None

    init = (
        jnp.int32(0),
        jnp.int32(0),
        jnp.uint32(0),
        jnp.uint32(0),
        jnp.int32(0),
        jnp.zeros((n,), jnp.uint32),
        jnp.zeros((n,), jnp.uint32),
        jnp.zeros((n,), jnp.int32),
    )
    (w, count, bhi, blo, _, out_hi, out_lo, out_sl), _ = jax.lax.scan(
        step, init, symbols
    )
    # final partial word
    has_tail = count > 0
    out_hi = jnp.where(has_tail, out_hi.at[w].set(bhi), out_hi)
    out_lo = jnp.where(has_tail, out_lo.at[w].set(blo), out_lo)
    out_sl = jnp.where(has_tail, out_sl.at[w].set(count), out_sl)
    num_words = w + has_tail.astype(jnp.int32)
    return out_hi, out_lo, out_sl, num_words


def _shl32(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """uint32 left shift, defined 0 for s >= 32 or s < 0."""
    s32 = jnp.clip(s, 0, 31).astype(jnp.uint32)
    val = x << s32
    return jnp.where((s >= 32) | (s < 0), jnp.uint32(0), val)


def _shr32(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """uint32 logical right shift, defined 0 for s >= 32 or s < 0."""
    s32 = jnp.clip(s, 0, 31).astype(jnp.uint32)
    val = x >> s32
    return jnp.where((s >= 32) | (s < 0), jnp.uint32(0), val)


# ---------------------------------------------------------------------------
# Host reference decoder (bit-serial, LUT-based — the paper's GPU semantics).
# ---------------------------------------------------------------------------
def unpack_symlen_np(
    stream: PackedStream, book: HuffmanCodebook
) -> np.ndarray:
    out = np.empty(stream.num_symbols, dtype=np.uint8)
    pos = 0
    lmax = book.l_max
    mask = (1 << lmax) - 1
    for w, sl in zip(stream.words, stream.symlen):
        cur = int(w)
        consumed = 0
        for _ in range(int(sl)):
            window = (cur >> max(WORD_BITS - lmax, 0)) & mask
            # if fewer than lmax bits remain, low bits are zero padding —
            # prefix-free codes still decode correctly (paper §4.2.1)
            sym = book.lut_symbol[window]
            l = int(book.lut_length[window])
            cur = (cur << l) & ((1 << WORD_BITS) - 1)
            consumed += l
            out[pos] = sym
            pos += 1
        assert consumed <= WORD_BITS
    assert pos == stream.num_symbols
    return out


def compact_padded_scatter(
    padded: jnp.ndarray,  # [W, max_symlen] (any integer dtype)
    symlen: jnp.ndarray,  # int32[W]
    num_symbols: int,
) -> jnp.ndarray:
    """Compact a padded per-word symbol tile to a dense ``[num_symbols]``.

    Segment-aware scatter: one exclusive prefix-sum over the symlen sidecar
    gives every word its output offset, then all (word, slot) pairs scatter
    simultaneously — slot ``j`` of word ``w`` lands at ``offsets[w] + j`` when
    ``j < symlen[w]`` and is dropped otherwise.  This replaces the per-symbol
    ``searchsorted`` gather (O(T log W) index searches) with a single
    O(W * max_symlen) scatter, and — because the offsets are *segment* sums —
    it is oblivious to container boundaries: concatenated multi-container
    streams compact in the same dispatch (the paper's prefix-scan +
    cooperative-write stage, batch-lifted).

    Padding words (symlen == 0) and tail slots contribute nothing; output
    positions beyond the last real symbol stay zero.
    """
    w, max_symlen = padded.shape
    symlen = symlen.astype(jnp.int32)
    offsets = jnp.cumsum(symlen) - symlen  # exclusive prefix sum, int32[W]
    slot = jnp.arange(max_symlen, dtype=jnp.int32)
    idx = offsets[:, None] + slot[None, :]  # [W, max_symlen]
    valid = slot[None, :] < symlen[:, None]
    # invalid lanes scatter out of bounds and are dropped
    idx = jnp.where(valid, idx, num_symbols)
    out = jnp.zeros((num_symbols,), dtype=padded.dtype)
    return out.at[idx.ravel()].set(padded.ravel(), mode="drop")


# ---------------------------------------------------------------------------
# Word-parallel decoder — pure JAX (XLA); mirrors the Pallas kernel exactly.
# ---------------------------------------------------------------------------
def unpack_symlen(
    hi: jnp.ndarray,  # uint32[W]
    lo: jnp.ndarray,  # uint32[W]
    symlen: jnp.ndarray,  # int32[W]
    dec_limit: jnp.ndarray,  # uint32[L_max] = limit_shifted[1:]
    dec_first: jnp.ndarray,  # uint32[L_max + 1] = first_code_shifted
    dec_rank: jnp.ndarray,  # int32[L_max + 1]  = rank_offset
    dec_syms: jnp.ndarray,  # int32[256]        = sorted_symbols
    l_max: int,
    max_symlen: int,
    num_symbols: int,
) -> jnp.ndarray:
    """Decode all words in parallel and compact to a dense uint8[num_symbols].

    Per slot iteration (over ``max_symlen`` slots), ALL words decode one
    symbol simultaneously:
      1. prefix  = top L_max bits of the remaining buffer (lives in hi)
      2. length  = 1 + sum_l [prefix >= limit_shifted[l]]   (vector compares)
      3. rank    = rank_offset[len] + ((prefix - first_code_shifted[len])
                   >> (L_max - len))
      4. symbol  = sorted_symbols[rank]
      5. funnel-shift (hi, lo) left by length
    Compaction: :func:`compact_padded_scatter` — a segment-aware scatter
    driven by one exclusive prefix-sum of symlen (the XLA lift of the paper's
    prefix-scan + warp-cooperative write stage); works unchanged on
    concatenated multi-container streams.
    """

    def slot_step(carry, _):
        cur_hi, cur_lo = carry
        prefix = _shr32(cur_hi, 32 - l_max)  # uint32[W]
        ge = prefix[None, :] >= dec_limit[:, None]  # [L_max, W]
        length = 1 + jnp.sum(ge.astype(jnp.int32), axis=0)
        length = jnp.minimum(length, l_max)  # clamp garbage/padding prefixes
        fcs = dec_first[length]
        rank = dec_rank[length] + (
            _shr32(prefix - fcs, l_max - length)
        ).astype(jnp.int32)
        rank = jnp.clip(rank, 0, 255)
        sym = dec_syms[rank].astype(jnp.uint8)
        # funnel shift left by `length` (1 <= length <= l_max <= 16 < 32)
        new_hi = _shl32(cur_hi, length) | _shr32(cur_lo, 32 - length)
        new_lo = _shl32(cur_lo, length)
        return (new_hi, new_lo), sym

    (_, _), padded = jax.lax.scan(
        slot_step, (hi, lo), None, length=max_symlen
    )  # padded: uint8[max_symlen, W]
    return compact_padded_scatter(padded.T, symlen, num_symbols)
