"""Hybrid three-zone quantization (paper §3.2, Eqs. 2-3).

The E retained DCT coefficient indices are partitioned into three contiguous
zones by boundaries B1, B2:

  zone 0  [0,  B1): mu-law companding — fine resolution near zero, coarse at
                    the extremes. q in [0,1] mapped to 8-bit levels with
                    positive -> 129..255, negative -> 0..127, zero -> 128.
  zone 1  [B1, B2): symmetric linear quantizer with a deadzone of width
                    d1 = alpha1 * A1 around zero (everything inside collapses
                    to the 128 bin).
  zone 2  [B2, E ): aggressive zeroing — every coefficient maps to bin 128.

Per-bin maxima A[k] are clipped percentiles over a representative calibration
set (paper §3.2.1); the whole mapping is table-driven so the encoder is a
single vectorized pass.  The "quantization table" of the paper (Fig. 4) is the
:class:`QuantTable` pytree below: zone id, per-bin scale, and the two scalars
(mu, alpha1).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QuantTable",
    "build_quant_table",
    "quantize",
    "dequantize",
    "predict_levels",
    "unpredict_levels",
    "expand_coded_stream",
]

_ZERO_BIN = 128.0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantTable:
    """Table-driven 3-zone quantizer parameters for one signal domain.

    Attributes:
      zone:  int32[E]  — zone id per retained coefficient index (0/1/2).
      scale: float32[E] — per-bin clipped-percentile maximum (A0 / A1).
      mu:    float32[] — companding strength (zone 0).
      alpha1: float32[] — deadzone ratio (zone 1).
    """

    zone: jnp.ndarray
    scale: jnp.ndarray
    mu: jnp.ndarray
    alpha1: jnp.ndarray

    def tree_flatten(self):
        return (self.zone, self.scale, self.mu, self.alpha1), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def num_coeffs(self) -> int:
        return self.zone.shape[0]


def build_quant_table(
    calib_coeffs: np.ndarray,
    *,
    b1: int,
    b2: int,
    mu: float,
    alpha1: float,
    percentile: float,
    scale_headroom: float = 1.0,
) -> QuantTable:
    """Build a :class:`QuantTable` from calibration coefficients [W, E].

    The per-bin scale is the ``percentile`` of |coeff| over calibration
    windows (paper: "clipped percentile ... rejecting outliers that would
    otherwise waste quantization levels on rare extremes").
    """
    calib_coeffs = np.asarray(calib_coeffs, dtype=np.float64)
    if calib_coeffs.ndim != 2:
        calib_coeffs = calib_coeffs.reshape(-1, calib_coeffs.shape[-1])
    e = calib_coeffs.shape[-1]
    if not (0 <= b1 <= b2 <= e):
        raise ValueError(f"need 0 <= B1({b1}) <= B2({b2}) <= E({e})")
    scale = np.percentile(np.abs(calib_coeffs), percentile, axis=0)
    # Headroom guards against clipping on non-stationary domains where the
    # deployed data's tails exceed the calibration percentile (paper §3.4.1:
    # A0 is set per-domain by stationarity; seismic needs the most slack).
    scale = np.maximum(scale * scale_headroom, 1e-12)
    zone = np.full((e,), 2, dtype=np.int32)
    zone[:b2] = 1
    zone[:b1] = 0
    return QuantTable(
        zone=jnp.asarray(zone),
        scale=jnp.asarray(scale, dtype=jnp.float32),
        mu=jnp.float32(mu),
        alpha1=jnp.float32(alpha1),
    )


def _mulaw_compress(c_abs: jnp.ndarray, a0: jnp.ndarray, mu: jnp.ndarray):
    """Eq. 2: q = ln(1 + mu*|c|/A0) / ln(1 + mu), |c| clipped to A0."""
    x = jnp.minimum(c_abs / a0, 1.0)
    return jnp.log1p(mu * x) / jnp.log1p(mu)


def _mulaw_expand(q: jnp.ndarray, a0: jnp.ndarray, mu: jnp.ndarray):
    return a0 * (jnp.expm1(q * jnp.log1p(mu)) / mu)


def quantize(coeffs: jnp.ndarray, table: QuantTable) -> jnp.ndarray:
    """Map float coefficients [..., E] to uint8 levels via the 3-zone table."""
    c = coeffs.astype(jnp.float32)
    a = table.scale
    mu = table.mu
    sign_pos = c > 0

    # --- zone 0: mu-law companding -------------------------------------
    q01 = _mulaw_compress(jnp.abs(c), a, mu)
    lvl0 = jnp.where(
        sign_pos,
        129.0 + jnp.round(q01 * 126.0),
        127.0 - jnp.round(q01 * 127.0),
    )
    # exact zeros land on the zero bin
    lvl0 = jnp.where(c == 0, _ZERO_BIN, lvl0)

    # --- zone 1: linear deadzone (Eq. 3) --------------------------------
    d1 = table.alpha1 * a
    denom = jnp.maximum(a - d1, 1e-12)
    c_clip = jnp.clip(c, -a, a)
    mag = jnp.abs(c_clip)
    lvl1_pos = 129.0 + jnp.floor((c_clip - d1) / denom * 126.0 + 0.5)
    lvl1_neg = 127.0 - jnp.floor((mag - d1) / denom * 127.0 + 0.5)
    lvl1 = jnp.where(
        c_clip > d1, lvl1_pos, jnp.where(c_clip < -d1, lvl1_neg, _ZERO_BIN)
    )

    # --- zone 2: aggressive zeroing -------------------------------------
    lvl2 = jnp.full_like(c, _ZERO_BIN)

    lvl = jnp.where(
        table.zone == 0, lvl0, jnp.where(table.zone == 1, lvl1, lvl2)
    )
    return jnp.clip(lvl, 0.0, 255.0).astype(jnp.uint8)


def dequantize(levels: jnp.ndarray, table: QuantTable) -> jnp.ndarray:
    """Inverse 3-zone mapping: uint8 levels [..., E] -> float32 coefficients.

    Uses the midpoint reconstruction of each quantization cell.
    """
    lvl = levels.astype(jnp.float32)
    a = table.scale
    mu = table.mu
    pos = lvl > _ZERO_BIN
    neg = lvl < _ZERO_BIN

    # zone 0 inverse mu-law
    q01 = jnp.where(pos, (lvl - 129.0) / 126.0, (127.0 - lvl) / 127.0)
    mag0 = _mulaw_expand(jnp.clip(q01, 0.0, 1.0), a, mu)
    c0 = jnp.where(pos, mag0, -mag0)
    c0 = jnp.where(lvl == _ZERO_BIN, 0.0, c0)

    # zone 1 inverse linear deadzone
    d1 = table.alpha1 * a
    span = a - d1
    mag1 = jnp.where(
        pos, d1 + (lvl - 129.0) / 126.0 * span, d1 + (127.0 - lvl) / 127.0 * span
    )
    c1 = jnp.where(pos, mag1, jnp.where(neg, -mag1, 0.0))

    c = jnp.where(table.zone == 0, c0, jnp.where(table.zone == 1, c1, 0.0))
    return c


# ---------------------------------------------------------------------------
# Container-v3 window prediction (ROADMAP item 3, cuSZ+-style delta coding).
#
# A lossless re-coding of the quantized levels BEFORE entropy coding: for
# the low-frequency bands k < predict_bands, the coded symbol is the mod-256
# residual of the level against a prediction from the previous window(s),
# with a virtual all-128 (zero-bin) history before the first window of each
# signal.  Smooth domains concentrate the residual histogram around 128,
# which the canonical Huffman stage then exploits.  This is the EXACT
# reference math: the XLA bucket arms, the Pallas megakernels (which trace
# these functions in-kernel) and the host codec all call these same
# functions, so fused == unfused stays bit-identical by construction.
#
# All arithmetic runs in uint32 mod 256 — safe because 256 divides 2**32,
# so uint32 wraparound never changes a value mod 256 (the linear2 inverse
# takes a double cumulative sum whose intermediates overflow u8/i32).
# ---------------------------------------------------------------------------
def predict_levels(
    levels: jnp.ndarray, pred_id: int, predict_bands: int
) -> jnp.ndarray:
    """Forward prediction: uint8 levels ``[..., W, E]`` -> coded grid.

    Columns ``k < predict_bands`` become mod-256 residuals against the
    predictor (``pred_id`` 1 = delta, 2 = linear2); the rest pass through.
    Purely row-local along the window axis (shift-with-128-fill), so it
    vmaps over batch rows with no segment bookkeeping: every leading-axis
    row is one signal.
    """
    if pred_id == 0 or predict_bands == 0:
        return levels
    l = levels.astype(jnp.uint32)
    zero = jnp.full_like(l[..., :1, :], 128)
    l1 = jnp.concatenate([zero, l[..., :-1, :]], axis=-2)  # prev window
    if pred_id == 1:
        pred = l1
    else:
        l2 = jnp.concatenate([zero, l1[..., :-1, :]], axis=-2)  # prev-prev
        pred = 2 * l1 - l2  # u32 wrap ok mod 256
    r = jnp.mod(l - pred + 128, 256)
    e = levels.shape[-1]
    band = jnp.arange(e, dtype=jnp.int32) < predict_bands
    return jnp.where(band, r, l).astype(jnp.uint8)


def _seg_cumsum(t: jnp.ndarray, seg_start: jnp.ndarray) -> jnp.ndarray:
    """Segmented inclusive cumsum along axis 0 of ``t`` [W, E] (uint32).

    ``seg_start[w]`` is the index of the first window of w's segment (self
    for single-window segments).  Implemented as a plain cumsum minus a
    gather of the exclusive cumsum at each segment start — no scan over
    segments, so it lowers to the same primitives inside and outside Pallas.
    """
    a = jnp.cumsum(t, axis=0, dtype=jnp.uint32)  # inclusive
    excl = a - t  # exclusive
    return a - excl[seg_start, :]


def unpredict_levels(
    grid: jnp.ndarray,
    seg_start: jnp.ndarray,
    pred_id: int,
    predict_bands: int,
) -> jnp.ndarray:
    """Inverse prediction: coded grid ``[W, E]`` (any uint dtype) -> levels.

    Exactly inverts :func:`predict_levels` over concatenated signals:
    ``seg_start`` marks each window's signal start so predictions never
    cross a signal boundary.  The delta inverse is one segmented cumsum of
    ``t = (r - 128) mod 256``; linear2 telescopes to a double segmented
    cumsum.  A window whose residuals are all ``t == 0`` (e.g. suppressed /
    padding windows expanded to 128) contributes the identity, which is why
    zero-plane expansion commutes with unprediction.
    """
    if pred_id == 0 or predict_bands == 0:
        return grid.astype(jnp.uint8)
    g = grid.astype(jnp.uint32)
    t = jnp.mod(g + 128, 256)  # (r - 128) mod 256
    cs = _seg_cumsum(t, seg_start)
    if pred_id == 2:
        cs = _seg_cumsum(cs, seg_start)
    lvl = jnp.mod(cs + 128, 256)
    e = grid.shape[-1]
    band = jnp.arange(e, dtype=jnp.int32) < predict_bands
    return jnp.where(band, lvl, g).astype(jnp.uint8)


def expand_coded_stream(
    dense: jnp.ndarray, idx: jnp.ndarray
) -> jnp.ndarray:
    """Zero-plane expansion: dense coded symbols -> flat residual grid.

    ``idx[p]`` is the position of flat grid cell ``p`` in the dense coded
    stream, or ``-1`` where the cell was suppressed (zero-plane) or is
    bucket padding — those cells expand to the zero bin 128.  ``idx`` is
    built host-side at staging time (:func:`repro.core.symlen.
    v3_expand_index`); the gather itself is shared by the XLA arm and the
    decode megakernel epilogue.
    """
    took = dense[jnp.clip(idx, 0, None)]
    return jnp.where(idx >= 0, took, jnp.asarray(128, dense.dtype))


def quant_grid(table: QuantTable) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All 256 reconstruction values per bin: [E, 256] (for LUT-style dequant).

    This is the dequantization table materialized — usable as a gather-free
    one-hot matmul operand by fused decode kernels, and by tests bounding
    the per-cell quantization error.  ``dequantize`` broadcasts over
    leading axes with the bin axis last, so the whole grid is one call on a
    [256, E] level matrix (the old per-bin ``vmap`` sliced the table with a
    traced index and could never actually trace).
    """
    levels = jnp.arange(256, dtype=jnp.uint8)  # [256]
    e = table.num_coeffs
    grid = dequantize(
        jnp.broadcast_to(levels[:, None], (256, e)), table
    )  # [256, E]: column k reconstructs every level under bin k
    return grid.T, levels
