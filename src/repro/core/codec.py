"""End-to-end FPTC codec (paper Fig. 3): transform → quantize → entropy code.

Two matched implementations:

  * **Host path** (`encode` / `decode`) — numpy + Algorithm-1 reference
    bitpacking.  This models the paper's embedded sequential encoder and
    serves as the oracle for everything else.
  * **Device path** (`encode_device` / `decode_device`) — jitted JAX.  The
    decoder is the word-parallel SymLen decode + fused dequant/iDCT pipeline
    (the paper's dual-fused GPU design, lifted to XLA; the Pallas kernels in
    ``repro.kernels`` are the hand-tiled TPU versions wired in via
    ``use_kernels=True``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dct, symlen
from repro.core.calibration import DeviceTables, DomainTables
from repro.core.container import Container
from repro.core.quantize import (
    dequantize,
    expand_coded_stream,
    predict_levels,
    quantize,
    unpredict_levels,
)

__all__ = [
    "encode",
    "decode",
    "encode_device",
    "decode_device",
    "transcode",
    "validate_container_tables",
]


def validate_container_tables(plan_key, tables: DomainTables) -> None:
    """Reject a container/tables pairing whose configs disagree.

    A container carries its encode-time (domain_id, n, e, l_max, coding) in
    the header; decoding it with a :class:`DomainTables` built for a
    different config either dies in an opaque shape error or — worse —
    decodes silently to garbage (coincident config, different book: two
    domains can share (n, e, l_max) yet quantize/code differently, so
    domain_id is part of the check; a v3 book is calibrated on *coded*
    residual symbols, so the coding triple is too).  Every decode path calls
    this before touching the stream.
    """
    cfg = tables.config
    want = (tables.domain_id, cfg.n, cfg.e, cfg.l_max, cfg.coding)
    if tuple(plan_key) != want:
        raise ValueError(
            f"container plan_key (domain_id, n, e, l_max, coding)="
            f"{tuple(plan_key)} does not match the supplied DomainTables "
            f"{want} — decoding with mismatched tables would produce garbage"
        )


# ---------------------------------------------------------------------------
# Host (reference / embedded-encoder) path
# ---------------------------------------------------------------------------
def encode(signal: np.ndarray, tables: DomainTables) -> Container:
    """Single-pass table-driven encode (paper §4.1, Fig. 5).

    With a v3 coding in the config, the quantized level grid is re-coded
    losslessly before entropy coding: prediction residuals on the low bands
    (``quantize.predict_levels``) and zero-plane suppression
    (``symlen.zero_plane_masks``); the container records both in its header.
    """
    cfg = tables.config
    pred_id, bands, zplanes = cfg.coding
    signal = np.asarray(signal, dtype=np.float32).ravel()
    length = signal.shape[0]
    windows = dct.window_signal(jnp.asarray(signal), cfg.n)
    coeffs = dct.forward_dct(windows, cfg.e)
    levels = quantize(coeffs, tables.quant)
    grid = np.asarray(predict_levels(levels, pred_id, bands))
    zrow = zcol = None
    if zplanes:
        zrow, zcol = symlen.zero_plane_masks(grid)
        coded = grid[~zrow, :][:, ~zcol].ravel()
    else:
        coded = grid.ravel()
    stream = symlen.pack_symlen_np(coded, tables.book)
    return Container(
        words=stream.words,
        symlen=stream.symlen.astype(np.uint8),
        num_symbols=stream.num_symbols,
        num_windows=int(windows.shape[0]),
        signal_length=length,
        n=cfg.n,
        e=cfg.e,
        l_max=cfg.l_max,
        domain_id=tables.domain_id,
        predictor=pred_id,
        predict_bands=bands,
        zero_planes=zplanes,
        zrow=zrow,
        zcol=zcol,
    )


def decode(container: Container, tables: DomainTables) -> np.ndarray:
    """Reference decode: serial Huffman LUT + dequant + inverse DCT."""
    validate_container_tables(container.plan_key, tables)
    stream = symlen.PackedStream(
        words=container.words,
        symlen=container.symlen.astype(np.int32),
        num_symbols=container.num_symbols,
    )
    syms = symlen.unpack_symlen_np(stream, tables.book)
    pred_id, bands, zplanes = container.coding
    nw, e = container.num_windows, container.e
    if container.coding == (0, 0, False):
        coeffs_q = jnp.asarray(syms.reshape(nw, e))
    else:
        idx, seg = symlen.v3_expand_index(
            [(nw, container.zrow, container.zcol)], e
        )
        if syms.size == 0:  # everything suppressed: the grid is all 128
            grid = np.full((nw, e), 128, dtype=np.int32)
        else:
            grid = np.asarray(
                expand_coded_stream(
                    jnp.asarray(syms, jnp.int32), jnp.asarray(idx)
                )
            ).reshape(nw, e)
        coeffs_q = unpredict_levels(
            jnp.asarray(grid, jnp.uint32), jnp.asarray(seg), pred_id, bands
        )
    coeffs = dequantize(coeffs_q, tables.quant)
    windows = dct.inverse_dct(coeffs, container.n)
    return np.asarray(dct.unwindow_signal(windows, container.signal_length))


# ---------------------------------------------------------------------------
# Device (jitted) path
# ---------------------------------------------------------------------------
# Legacy per-signal encode jit: length-S serial packing scan, one XLA
# specialization per signal length, and a blocking int(num_words) sync per
# container.  Kept ONLY as the baseline the batched encode engine is
# benchmarked against (bench_throughput) — production callers go through
# encode_device -> serving.batch_encode.
@functools.partial(jax.jit, static_argnames=("n", "e"))
def _encode_stages_device(
    signal: jnp.ndarray, tables: DeviceTables, n: int, e: int
):
    windows = dct.window_signal(signal, n)
    coeffs = dct.forward_dct(windows, e)
    syms = quantize(coeffs, tables.quant).ravel()
    hi, lo, sl, num_words = symlen.pack_symlen_scan(
        syms, tables.codes, tables.lengths
    )
    return hi, lo, sl, num_words, windows.shape[0]


def encode_device(
    signal: jnp.ndarray, tables: DomainTables
) -> Container:
    """Jitted encode, bit-identical to the host encoder.

    Batch-of-one wrapper over the bucketed batch engine
    (:mod:`repro.serving.batch_encode`) in exact packing mode: tables ride
    the persistent plan cache, shapes ride power-of-two buckets, and the
    only *output* sync is the batch drain (no per-container
    ``int(num_words)`` inside the jitted hot path).  Note the engine stages
    inputs through host buffers for bucket stacking, so a device-resident
    input array costs one device->host transfer here — ingest inputs are
    host arrays in the intended deployment.  Encode many signals at once —
    and get chunk-parallel packing — with
    :class:`repro.serving.batch_encode.BatchEncoder` directly.
    """
    from repro.serving.batch_encode import default_encoder

    return default_encoder().encode([signal], tables).to_host()[0]


# Legacy per-container jit: every shape-ish quantity is a static argname, so
# a heterogeneous archive retraces XLA per container.  Kept ONLY as the
# baseline the batched engine is benchmarked against (bench_throughput) —
# production callers go through decode_device -> serving.batch_decode.
@functools.partial(
    jax.jit,
    static_argnames=("l_max", "max_symlen", "num_symbols", "num_windows",
                     "n", "e", "signal_length", "use_kernels"),
)
def _decode_device(
    hi: jnp.ndarray,
    lo: jnp.ndarray,
    sl: jnp.ndarray,
    tables: DeviceTables,
    *,
    l_max: int,
    max_symlen: int,
    num_symbols: int,
    num_windows: int,
    n: int,
    e: int,
    signal_length: int,
    use_kernels: bool = False,
) -> jnp.ndarray:
    if use_kernels:
        # hand-tiled Pallas TPU kernels (interpret=True on CPU)
        from repro.kernels import ops as kops

        syms = kops.huffman_decode(
            hi, lo, sl, tables,
            l_max=l_max, max_symlen=max_symlen, num_symbols=num_symbols,
        )
        coeffs_q = syms.reshape(num_windows, e)
        windows = kops.idct_dequant(coeffs_q, tables.quant, n=n)
    else:
        syms = symlen.unpack_symlen(
            hi, lo, sl,
            tables.dec_limit, tables.dec_first, tables.dec_rank,
            tables.dec_syms,
            l_max=l_max, max_symlen=max_symlen, num_symbols=num_symbols,
        )
        coeffs_q = syms.reshape(num_windows, e)
        coeffs = dequantize(coeffs_q, tables.quant)
        windows = dct.inverse_dct(coeffs, n)
    return dct.unwindow_signal(windows, signal_length)


def decode_device(
    container: Container,
    tables: DomainTables,
    *,
    use_kernels: Optional[bool] = None,
) -> np.ndarray:
    """Word-parallel decode (the paper's dual-fused GPU pipeline on XLA/TPU).

    Batch-of-one wrapper over the bucketed batch engine
    (:mod:`repro.serving.batch_decode`): shape buckets bound recompilation,
    tables/bases ride the persistent plan cache.  ``use_kernels`` selects
    the fused Pallas megakernel path (``None`` defers to the process-wide
    ``FPTC_USE_KERNELS`` default; the kernel path is bit-identical to the
    XLA path).  Decode many containers at once with
    :class:`repro.serving.batch_decode.BatchDecoder` directly.
    """
    from repro.serving.batch_decode import default_decoder

    dec = default_decoder(use_kernels=use_kernels)
    return dec.decode([container], tables).to_host()[0]


def transcode(
    container: Container,
    src_tables: DomainTables,
    dst_tables: DomainTables,
) -> Container:
    """Re-encode one container under a new (domain, config), device-resident.

    Container-of-one wrapper over the transcode pipeline
    (:mod:`repro.serving.transcode`) in exact packing mode: decode and
    re-encode compose on device with no host round trip in between, and the
    output is byte-identical to ``decode_device``-to-host followed by
    ``encode_device`` under ``dst_tables``.  Transcode many containers at
    once — and get chunk-parallel packing — with
    :class:`repro.serving.transcode.Transcoder` directly.
    """
    from repro.serving.transcode import default_transcoder

    return default_transcoder().transcode_to_host(
        [container], src_tables, dst_tables
    )[0]


def roundtrip_metrics(
    signal: np.ndarray, tables: DomainTables
) -> Tuple[float, float]:
    """(CR, PRD) of a host-path roundtrip — used by RD benchmarks."""
    from repro.core.metrics import prd

    c = encode(signal, tables)
    rec = decode(c, tables)
    return c.compression_ratio, prd(signal, rec)
