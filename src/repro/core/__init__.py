"""FPTC core: the paper's contribution as composable JAX modules."""
from repro.core.config import CodecConfig, DOMAIN_DEFAULTS
from repro.core.container import Container
from repro.core.calibration import DomainTables, DeviceTables, calibrate
from repro.core.codec import (
    decode,
    decode_device,
    encode,
    encode_device,
    transcode,
)
from repro.core.domains import (
    KV_DOMAIN_ID,
    TRAIN_STATE_DOMAIN_ID,
    calibrate_kv,
    calibrate_train_state,
)
from repro.core.metrics import compression_ratio, prd

__all__ = [
    "KV_DOMAIN_ID",
    "TRAIN_STATE_DOMAIN_ID",
    "calibrate_kv",
    "calibrate_train_state",
    "CodecConfig",
    "DOMAIN_DEFAULTS",
    "Container",
    "DomainTables",
    "DeviceTables",
    "calibrate",
    "encode",
    "decode",
    "encode_device",
    "decode_device",
    "transcode",
    "compression_ratio",
    "prd",
]
