"""FPTC core: the paper's contribution as composable JAX modules."""
from repro.core.config import CodecConfig, DOMAIN_DEFAULTS
from repro.core.container import Container
from repro.core.calibration import DomainTables, DeviceTables, calibrate
from repro.core.codec import (
    decode,
    decode_device,
    encode,
    encode_device,
    transcode,
)
from repro.core.metrics import compression_ratio, prd

__all__ = [
    "CodecConfig",
    "DOMAIN_DEFAULTS",
    "Container",
    "DomainTables",
    "DeviceTables",
    "calibrate",
    "encode",
    "decode",
    "encode_device",
    "decode_device",
    "transcode",
    "compression_ratio",
    "prd",
]
