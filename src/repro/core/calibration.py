"""Offline per-domain calibration (paper §3.4, Fig. 4).

From representative domain data, precompute the two deployed structures:
  1. the quantization table (per-bin zone + clipped-percentile scales), and
  2. the length-limited canonical Huffman codebook.

Both are then shipped to encoders (embedded devices) and decoders (servers).
Laplace (+1) smoothing is applied to the symbol histogram so *every* uint8
symbol has a codeword — the codebook only approximates the optimal code for
unseen data anyway (paper §3.4.2: "an intrinsic property of Huffman"), and a
missing codeword would be a hard encode failure in deployment.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dct
from repro.core.config import CodecConfig
from repro.core.huffman import HuffmanCodebook, build_codebook
from repro.core.quantize import (
    QuantTable,
    build_quant_table,
    predict_levels,
    quantize,
)

__all__ = ["DomainTables", "DeviceTables", "calibrate"]


@dataclasses.dataclass(frozen=True)
class DomainTables:
    """Host-side calibrated structures for one signal domain."""

    config: CodecConfig
    quant: QuantTable
    book: HuffmanCodebook
    domain_id: int = 0
    hist: Optional[np.ndarray] = None  # smoothed symbol histogram (rebuilds
    # the codebook deterministically — serialized with ckpt compression)

    def device_tables(self) -> "DeviceTables":
        """Device-resident tables, uploaded **once** per DomainTables.

        Decoding an archive calls this per container; without memoization
        every call re-uploads ~1.5 KiB of codebook arrays host->device and
        defeats jit donation/caching of the table pytree.  The cache lives on
        the (frozen) instance, so repeated decodes — and the BatchDecoder
        plan cache — reuse the exact same device buffers.
        """
        cached = getattr(self, "_device_cache", None)
        if cached is None:
            b = self.book
            cached = DeviceTables(
                codes=jnp.asarray(b.codes, dtype=jnp.uint32),
                lengths=jnp.asarray(b.lengths, dtype=jnp.int32),
                dec_limit=jnp.asarray(b.limit_shifted[1:], dtype=jnp.uint32),
                dec_first=jnp.asarray(b.first_code_shifted, dtype=jnp.uint32),
                dec_rank=jnp.asarray(b.rank_offset, dtype=jnp.int32),
                dec_syms=jnp.asarray(b.sorted_symbols, dtype=jnp.int32),
                quant=self.quant,
            )
            object.__setattr__(self, "_device_cache", cached)
        return cached


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DeviceTables:
    """Device-resident tables: Huffman encode/decode + quantization."""

    codes: jnp.ndarray  # uint32[256]
    lengths: jnp.ndarray  # int32[256]
    dec_limit: jnp.ndarray  # uint32[L_max]
    dec_first: jnp.ndarray  # uint32[L_max + 1]
    dec_rank: jnp.ndarray  # int32[L_max + 1]
    dec_syms: jnp.ndarray  # int32[256]
    quant: QuantTable

    def tree_flatten(self):
        return (
            self.codes,
            self.lengths,
            self.dec_limit,
            self.dec_first,
            self.dec_rank,
            self.dec_syms,
            self.quant,
        ), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def calibrate(
    signal: np.ndarray,
    config: CodecConfig,
    *,
    domain_id: int = 0,
    max_windows: Optional[int] = 65536,
    seed: int = 0,
) -> DomainTables:
    """Calibrate quantization table + Huffman codebook on representative data.

    Args:
      signal: 1-D representative signal strip (float).
      config: codec parameters (Table 1).
      max_windows: subsample cap for calibration windows (randomly sampled —
        paper §3.2.1: "distributions of randomly sampled DCT windows are very
        similar").
      seed: subsampling RNG seed.
    """
    signal = np.asarray(signal, dtype=np.float32).ravel()
    windows = np.asarray(dct.window_signal(jnp.asarray(signal), config.n))
    if max_windows is not None and windows.shape[0] > max_windows:
        rng = np.random.default_rng(seed)
        idx = rng.choice(windows.shape[0], size=max_windows, replace=False)
        # sorted: scales and the v2 histogram are order-invariant, but v3
        # configs histogram PREDICTION RESIDUALS between sampled windows —
        # keeping the sample in signal order makes adjacent sampled windows
        # as close as the subsample allows, so the residual histogram the
        # codebook is built on tracks the one the encoder will produce
        idx.sort()
        windows = windows[idx]
    coeffs = np.asarray(dct.forward_dct(jnp.asarray(windows), config.e))

    quant = build_quant_table(
        coeffs,
        b1=config.b1,
        b2=config.b2,
        mu=config.mu,
        alpha1=config.alpha1,
        percentile=config.a0_percentile,
        scale_headroom=config.scale_headroom,
    )

    levels = np.asarray(quantize(jnp.asarray(coeffs), quant))
    pred_id, bands, zplanes = config.coding
    # v3 configs entropy-code the TRANSFORMED symbols (prediction residuals,
    # minus suppressed zero planes), so that is what the codebook must be
    # calibrated on — a book built on raw levels would assign long codes to
    # the residual mass at 128 and give back the ratio the predictor won.
    grid = np.asarray(
        predict_levels(jnp.asarray(levels), pred_id, bands)
    )
    if zplanes:
        from repro.core.symlen import zero_plane_masks

        zrow, zcol = zero_plane_masks(grid)
        symbols = grid[~zrow, :][:, ~zcol].ravel()
    else:
        symbols = grid.ravel()
    hist = np.bincount(symbols, minlength=256).astype(np.int64)
    hist += 1  # Laplace smoothing: every symbol must be encodable
    book = build_codebook(hist, l_max=config.l_max)
    return DomainTables(
        config=config, quant=quant, book=book, domain_id=domain_id, hist=hist
    )


def tables_from_hist(
    config: CodecConfig,
    scale: np.ndarray,
    hist: np.ndarray,
    *,
    domain_id: int = 0,
) -> DomainTables:
    """Rebuild DomainTables from serialized (scale, hist) — used by the
    checkpoint decompressor and any consumer of shipped codec structures."""
    import jax.numpy as _jnp

    e = scale.shape[0]
    zone = np.full((e,), 2, dtype=np.int32)
    zone[: config.b2] = 1
    zone[: config.b1] = 0
    quant = QuantTable(
        zone=_jnp.asarray(zone),
        scale=_jnp.asarray(scale, dtype=_jnp.float32),
        mu=_jnp.float32(config.mu),
        alpha1=_jnp.float32(config.alpha1),
    )
    book = build_codebook(np.asarray(hist, dtype=np.int64), l_max=config.l_max)
    return DomainTables(
        config=config, quant=quant, book=book, domain_id=domain_id,
        hist=np.asarray(hist),
    )
