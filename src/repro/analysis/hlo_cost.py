"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body ONCE — a
scan-over-layers model under-reports FLOPs by the layer count (verified
empirically: a 10-iteration scan of a matmul reports 1 matmul).  This module
re-derives roofline quantities from the optimized HLO text with loop trip
multiplication:

  * flops        — dot ops: 2 * |out| * |contracted|; reduces: |in|
  * hbm_bytes    — per top-level op: operand + output buffer sizes (fusions
                   count only their boundary buffers — internal traffic stays
                   in registers/VMEM, matching the fused-op HBM model)
  * collective_bytes — per collective op: wire bytes with standard factors
                   (all-reduce 2x ring, reduce-scatter/all-gather 1x,
                   all-to-all 1x, collective-permute 1x)

While-loop trip counts are read from the loop condition's compare-constant
(scan bounds are static in this codebase).  Conditionals take the max branch.
All quantities are per-device: the HLO module is the SPMD-partitioned
per-device program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "analyze_jaxpr", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
# type strings may contain /*index=N*/ comments (inside tuples), so match
# lazily up to the first "opcode(" word — metadata strings come later on the
# line and cannot match first.
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\("
)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_COND_BODY = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CONSTANT_S32 = re.compile(r"constant\((\d+)\)")

# ops whose operand/output buffers count as HBM traffic
_MEM_OPS = {
    "fusion", "dot", "convolution", "custom-call", "copy", "transpose",
    "concatenate", "pad", "reduce", "sort", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "broadcast", "convert", "slice",
    "reduce-window", "select-and-scatter", "reverse", "iota", "rng",
    "rng-bit-generator", "select", "compare", "add", "multiply", "subtract",
    "divide", "exponential", "tanh", "log", "clamp", "maximum", "minimum",
    "reshape", "cbrt", "rsqrt", "sqrt", "negate", "abs", "and", "or", "xor",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_COLLECTIVES = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
    "all-reduce-start": 2.0, "all-gather-start": 1.0,
    "collective-permute-start": 1.0,
}
_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "domain", "opt-barrier", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "async-start", "async-done", "async-update",
    "get-dimension-size", "outfeed", "infeed",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: Optional[Dict[str, float]] = None
    coll_f32_bytes: float = 0.0

    def __add__(self, o: "Cost") -> "Cost":
        merged = dict(self.coll_by_op or {})
        for k, v in (o.coll_by_op or {}).items():
            merged[k] = merged.get(k, 0.0) + v
        return Cost(
            self.flops + o.flops,
            self.hbm_bytes + o.hbm_bytes,
            self.coll_bytes + o.coll_bytes,
            merged,
            self.coll_f32_bytes + o.coll_f32_bytes,
        )

    def scaled(self, k: float) -> "Cost":
        return Cost(
            self.flops * k, self.hbm_bytes * k, self.coll_bytes * k,
            {n: v * k for n, v in (self.coll_by_op or {}).items()},
            self.coll_f32_bytes * k,
        )


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_op: Dict[str, float]
    num_whiles: int
    unknown_trip_whiles: int
    collective_f32_bytes: float = 0.0
    pallas_calls: int = 0  # jaxpr-level analysis only (analyze_jaxpr)

    @property
    def collective_bytes_tpu(self) -> float:
        """bf16-adjusted wire bytes: the CPU backend upcasts bf16 compute
        to f32 before SPMD partitioning, so activation collectives in this
        lowering are f32; TPU (native bf16) moves half.  True-f32 state
        (optimizer scalars, fp32 routers) is a negligible share of the f32
        volume here — every activation/param tensor in the model is bf16 by
        construction."""
        return self.collective_bytes - 0.5 * self.collective_f32_bytes


class _Parser:
    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._split(text)
        self._memo: Dict[str, Cost] = {}
        self.num_whiles = 0
        self.unknown_trips = 0

    def _split(self, text: str):
        cur = None
        buf: List[str] = []
        for line in text.splitlines():
            m = _COMP_HEADER.match(line)
            if m and line.rstrip().endswith("{"):
                if cur is not None:
                    self.computations[cur] = buf
                cur = m.group(2)
                buf = []
                if m.group(1):
                    self.entry = cur
            elif line.strip() == "}":
                if cur is not None:
                    self.computations[cur] = buf
                    cur = None
                    buf = []
            elif cur is not None:
                buf.append(line)
        if cur is not None:
            self.computations[cur] = buf

    # -- trip count from the while condition computation ------------------
    def _trip_count(self, cond_name: str) -> Optional[int]:
        lines = self.computations.get(cond_name, [])
        consts = []
        for ln in lines:
            consts += [int(x) for x in _CONSTANT_S32.findall(ln)]
            # the bound may live one fusion deeper
            cm = _CALLS.search(ln)
            if cm:
                for ln2 in self.computations.get(cm.group(1), []):
                    consts += [int(x) for x in _CONSTANT_S32.findall(ln2)]
        consts = [c for c in consts if c > 0]
        return max(consts) if consts else None

    def _internal_slice_bytes(self, comp_name: str) -> Optional[int]:
        """If the called computation slices a big buffer (scan-accumulator
        pattern), return the total sliced bytes; else None.

        dynamic-slice: the op's OUTPUT is the slice.  dynamic-update-slice:
        the UPDATE operand (2nd arg) is the slice; the buffer is aliased.
        """
        if not hasattr(self, "_slice_memo"):
            self._slice_memo = {}
        if comp_name in self._slice_memo:
            return self._slice_memo[comp_name]
        shapes: Dict[str, str] = {}
        total = 0
        found = False
        for ln in self.computations.get(comp_name, []):
            m = _OP_LINE.match(ln)
            if not m:
                continue
            name, type_str, opcode = m.groups()
            shapes[name] = type_str
            if opcode == "dynamic-slice":
                found = True
                total += _shape_bytes(type_str)
            elif opcode == "dynamic-update-slice":
                found = True
                ops = _OPERANDS.findall(ln[m.end():].split(", calls=")[0])
                if len(ops) >= 2 and ops[1] in shapes:
                    total += _shape_bytes(shapes[ops[1]])
                else:
                    total += _shape_bytes(type_str) // 64  # fallback guess
        out = total if found else None
        self._slice_memo[comp_name] = out
        return out

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Cost()  # cycle guard
        lines = self.computations.get(comp_name, [])
        shapes: Dict[str, str] = {}
        total = Cost(coll_by_op={})
        for ln in lines:
            m = _OP_LINE.match(ln)
            if not m:
                continue
            name, type_str, opcode = m.groups()
            shapes[name] = type_str
            out_bytes = _shape_bytes(type_str)

            if opcode == "while":
                cb = _COND_BODY.search(ln)
                if not cb:
                    continue
                cond, body = cb.groups()
                trip = self._trip_count(cond)
                self.num_whiles += 1
                if trip is None:
                    trip = 1
                    self.unknown_trips += 1
                inner = self.cost_of(body) + self.cost_of(cond)
                total = total + inner.scaled(trip)
                continue
            if opcode == "conditional":
                br = _BRANCHES.search(ln)
                if br:
                    branch_costs = [
                        self.cost_of(b.strip().lstrip("%"))
                        for b in br.group(1).split(",")
                    ]
                    if branch_costs:
                        best = max(branch_costs, key=lambda c: c.flops)
                        total = total + best
                continue
            if opcode == "call":
                cm = _CALLS.search(ln)
                if cm:
                    total = total + self.cost_of(cm.group(1))
                continue

            # operand bytes (definitions seen so far in this computation)
            tail = ln[m.end():]
            operand_bytes = 0
            for om in _OPERANDS.finditer(tail.split(", calls=")[0]):
                op_shape = shapes.get(om.group(1))
                if op_shape:
                    operand_bytes += _shape_bytes(op_shape)

            if opcode in _COLLECTIVES:
                factor = _COLLECTIVES[opcode]
                wire = factor * max(out_bytes, operand_bytes)
                key = opcode.replace("-start", "")
                total.coll_bytes += wire
                total.coll_by_op[key] = total.coll_by_op.get(key, 0.0) + wire
                # track fp32 collective volume: the CPU backend's float
                # normalization upcasts bf16 dots/elementwise to f32 BEFORE
                # partitioning, so activation collectives ride f32 wires in
                # this lowering; on TPU (native bf16) they are half.  The
                # roofline reports both raw and bf16-adjusted numbers.
                if _SHAPE.search(type_str) and _SHAPE.search(
                    type_str
                ).group(1) == "f32":
                    total.coll_f32_bytes += wire
                total.hbm_bytes += out_bytes + operand_bytes
                continue

            if opcode == "fusion":
                cm = _CALLS.search(ln)
                slice_bytes = None
                if cm:
                    called = cm.group(1)
                    inner = self.cost_of(called)
                    # fusions contribute their internal flops but only their
                    # boundary bytes
                    total.flops += inner.flops
                    slice_bytes = self._internal_slice_bytes(called)
                if slice_bytes is not None:
                    # scan-accumulator pattern: the fusion reads/writes a
                    # [T, ...] buffer through internal dynamic-(update-)
                    # slices; real HBM traffic is the slices (the buffer is
                    # aliased in place).  Operands are capped at the slice
                    # volume; the small (non-accumulator) operands are below
                    # the cap anyway.
                    cap = max(slice_bytes, 1)
                    capped = 0
                    for om in _OPERANDS.finditer(tail.split(", calls=")[0]):
                        op_shape = shapes.get(om.group(1))
                        if op_shape:
                            capped += min(_shape_bytes(op_shape), cap)
                    total.hbm_bytes += 2 * slice_bytes + capped
                else:
                    total.hbm_bytes += out_bytes + operand_bytes
                continue

            if opcode == "dot":
                lhs_m = _OPERANDS.search(tail)
                lhs_shape = shapes.get(lhs_m.group(1), "") if lhs_m else ""
                lhs_dims = _shape_dims(lhs_shape)
                cm = _CONTRACT.search(ln)
                contracted = 1
                if cm and lhs_dims:
                    for idx in cm.group(1).split(","):
                        if idx:
                            i = int(idx)
                            if i < len(lhs_dims):
                                contracted *= lhs_dims[i]
                out_elems = 1
                for d in _shape_dims(type_str):
                    out_elems *= d
                total.flops += 2.0 * out_elems * contracted
                total.hbm_bytes += out_bytes + operand_bytes
                continue

            if opcode in ("reduce", "reduce-window"):
                total.flops += operand_bytes / 2  # ~1 flop per elem (bf16≈2B)
                total.hbm_bytes += out_bytes + operand_bytes
                continue

            if opcode == "dynamic-update-slice":
                # in-place: traffic = update read + update write (the full
                # buffer is aliased, not copied) — without this, scans that
                # accumulate into a [T, ...] buffer over-count by xT
                upd = max(operand_bytes - out_bytes, 0)
                total.hbm_bytes += 2 * upd
                continue
            if opcode == "dynamic-slice":
                total.hbm_bytes += 2 * out_bytes  # slice read + write
                continue
            if opcode == "scatter":
                # in-place scatter(-add): the big operand is aliased; real
                # traffic = updates read + scattered writes (+ indices)
                upd = max(operand_bytes - out_bytes, 0)
                total.hbm_bytes += 2 * min(upd, out_bytes) + (
                    upd - min(upd, out_bytes)
                )
                continue
            if opcode == "gather":
                # random-access reads of ~output volume (+ indices)
                total.hbm_bytes += 2 * out_bytes
                continue

            if opcode in _MEM_OPS:
                total.hbm_bytes += out_bytes + operand_bytes
                continue
            # _SKIP_OPS and anything unrecognized: no cost
        self._memo[comp_name] = total
        return total


def analyze_hlo(text: str) -> HloCost:
    p = _Parser(text)
    entry = p.entry or (next(iter(p.computations)) if p.computations else "")
    cost = p.cost_of(entry) if entry else Cost(coll_by_op={})
    return HloCost(
        flops=cost.flops,
        hbm_bytes=cost.hbm_bytes,
        collective_bytes=cost.coll_bytes,
        collective_by_op=cost.coll_by_op or {},
        num_whiles=p.num_whiles,
        unknown_trip_whiles=p.unknown_trips,
        collective_f32_bytes=cost.coll_f32_bytes,
    )


# ---------------------------------------------------------------------------
# Jaxpr-level analysis: the pre-lowering twin of analyze_hlo.
#
# Post-megakernel, the serving kernel path is all ``pallas_call`` — an
# opaque primitive whose kernel body never reaches the HLO text this
# module parses (on TPU it lowers to a custom-call; in interpret mode to
# an XLA while-nest whose structure has nothing to do with the kernel's
# declared tiling).  analyze_jaxpr walks the *jaxpr* instead and
# attributes each pallas_call from what the kernel declares:
#
#   flops = (kernel body cost) x prod(grid)       — every grid step runs
#           the body once;
#   hbm   = prod(grid) x sum(BlockSpec block bytes) — the pallas block
#           pipeline moves each operand/output block HBM<->VMEM once per
#           step; the body's own memory ops are VMEM traffic and are NOT
#           counted (same boundary-bytes model as analyze_hlo's fusions).
#
# scan multiplies its body by the static trip count (jax lowers
# fori_loop with concrete bounds to scan, so the kernels' slot loops and
# the 256-level LUT select are trip-counted exactly); while bodies with
# unknown trips count once and are flagged, mirroring the HLO parser.
# Elementwise traffic is attributed as whole-jaxpr I/O, not per-op: a
# jaxpr is pre-fusion, so summing every add/mul's operands would count
# register traffic as HBM.
# ---------------------------------------------------------------------------
_REDUCE_PRIMS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "cumsum", "cummax", "cummin",
    "cumprod", "cumlogsumexp",
}


def _aval_bytes(aval) -> float:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0.0
    n = 1
    for d in shape:
        try:
            n *= int(d)
        except TypeError:  # dynamic dim: count as 1
            pass
    try:
        return float(n * dtype.itemsize)
    except AttributeError:
        return 0.0


def _aval_elems(aval) -> float:
    shape = getattr(aval, "shape", ())
    n = 1
    for d in shape:
        try:
            n *= int(d)
        except TypeError:
            pass
    return float(n)


class _JaxprState:
    def __init__(self):
        self.num_whiles = 0
        self.unknown_trips = 0
        self.pallas_calls = 0


def _unwrap_jaxpr(obj):
    """Accept Jaxpr, ClosedJaxpr, or anything carrying a .jaxpr."""
    inner = getattr(obj, "jaxpr", None)
    return obj if inner is None else _unwrap_jaxpr(inner)


def _pallas_block_bytes(grid_mapping, eqn) -> Tuple[float, float]:
    """(steps, per-step boundary bytes) of one pallas_call from its
    declared grid and BlockSpecs."""
    steps = 1.0
    for g in getattr(grid_mapping, "grid", ()) or ():
        try:
            steps *= max(int(g), 1)
        except TypeError:
            pass  # symbolic grid dim: count once
    per_step = 0.0
    for bm in getattr(grid_mapping, "block_mappings", ()) or ():
        block = getattr(bm, "block_shape", None)
        sd = getattr(bm, "array_shape_dtype", None)
        if block is None or sd is None:
            continue
        elems = 1
        for d in block:
            try:
                elems *= max(int(d), 1)
            except TypeError:
                pass  # squeezed/mapped dims contribute one row
        try:
            per_step += float(elems * sd.dtype.itemsize)
        except AttributeError:
            continue
    if per_step == 0.0:
        # no usable block mappings (e.g. an older pallas): fall back to
        # the call's operand + output avals, moved once
        per_step = sum(_aval_bytes(v.aval) for v in eqn.invars) + sum(
            _aval_bytes(v.aval) for v in eqn.outvars
        )
        steps = 1.0
    return steps, per_step


def _jaxpr_cost(jaxpr, state: _JaxprState) -> Cost:
    total = Cost(coll_by_op={})
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name

        if prim == "pallas_call":
            state.pallas_calls += 1
            gm = eqn.params.get("grid_mapping")
            body = eqn.params.get("jaxpr")
            steps, per_step = (
                _pallas_block_bytes(gm, eqn) if gm is not None
                else (1.0, sum(_aval_bytes(v.aval) for v in eqn.invars))
            )
            if body is not None:
                inner = _jaxpr_cost(_unwrap_jaxpr(body), state)
                total.flops += inner.flops * steps
            total.hbm_bytes += steps * per_step
            continue

        if prim == "scan":
            body = eqn.params.get("jaxpr")
            length = int(eqn.params.get("length", 1) or 1)
            state.num_whiles += 1
            if body is not None:
                inner = _jaxpr_cost(_unwrap_jaxpr(body), state)
                total = total + inner.scaled(length)
            continue

        if prim == "while":
            state.num_whiles += 1
            state.unknown_trips += 1  # trip is data-dependent in a jaxpr
            for key in ("body_jaxpr", "cond_jaxpr"):
                body = eqn.params.get(key)
                if body is not None:
                    total = total + _jaxpr_cost(_unwrap_jaxpr(body), state)
            continue

        if prim == "cond":
            branches = eqn.params.get("branches") or ()
            costs = [
                _jaxpr_cost(_unwrap_jaxpr(b), state) for b in branches
            ]
            if costs:
                total = total + max(costs, key=lambda c: c.flops)
            continue

        if prim in ("pjit", "closed_call", "core_call", "remat_call",
                    "checkpoint", "remat", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr"):
            body = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if body is not None:
                total = total + _jaxpr_cost(_unwrap_jaxpr(body), state)
            continue

        if prim == "dot_general":
            (lc, _), _ = eqn.params["dimension_numbers"]
            lhs = eqn.invars[0].aval
            contracted = 1
            for i in lc:
                contracted *= int(lhs.shape[i])
            out_elems = _aval_elems(eqn.outvars[0].aval)
            total.flops += 2.0 * out_elems * contracted
            total.hbm_bytes += sum(
                _aval_bytes(v.aval) for v in eqn.invars
            ) + _aval_bytes(eqn.outvars[0].aval)
            continue

        if prim in _REDUCE_PRIMS:
            total.flops += _aval_elems(eqn.invars[0].aval)
            total.hbm_bytes += sum(
                _aval_bytes(v.aval) for v in eqn.invars
            ) + sum(_aval_bytes(v.aval) for v in eqn.outvars)
            continue

        if prim in ("gather", "dynamic_slice"):
            total.hbm_bytes += 2.0 * _aval_bytes(eqn.outvars[0].aval)
            continue
        if prim in ("scatter", "scatter-add", "scatter_add",
                    "dynamic_update_slice"):
            upd = _aval_bytes(eqn.invars[-1].aval)
            total.hbm_bytes += 2.0 * upd
            continue
        # elementwise & everything else: no per-op cost (see module note)
    return total


def analyze_jaxpr(jaxpr_or_fn, *example_args, **example_kwargs) -> HloCost:
    """Cost-analyze a jaxpr — including ones containing ``pallas_call``.

    Accepts a ``Jaxpr``/``ClosedJaxpr`` (e.g. from ``jax.make_jaxpr``), or
    a callable plus example arguments, which is traced here.  Returns the
    same :class:`HloCost` as :func:`analyze_hlo`, with ``pallas_calls``
    counting the kernels attributed from their declared grid/block shapes.
    Collective fields are always zero (jaxprs here are pre-partitioning).
    """
    if callable(jaxpr_or_fn) and not hasattr(jaxpr_or_fn, "eqns"):
        import jax

        jaxpr = jax.make_jaxpr(jaxpr_or_fn)(
            *example_args, **example_kwargs
        )
    else:
        jaxpr = jaxpr_or_fn
    jaxpr = _unwrap_jaxpr(jaxpr)
    state = _JaxprState()
    cost = _jaxpr_cost(jaxpr, state)
    # whole-jaxpr I/O: entry operands in, results out (counted once; the
    # per-op extras above only cover ops with non-streaming access)
    io_bytes = sum(_aval_bytes(v.aval) for v in jaxpr.invars) + sum(
        _aval_bytes(v.aval) for v in jaxpr.outvars
    )
    return HloCost(
        flops=cost.flops,
        hbm_bytes=cost.hbm_bytes + io_bytes,
        collective_bytes=0.0,
        collective_by_op={},
        num_whiles=state.num_whiles,
        unknown_trip_whiles=state.unknown_trips,
        collective_f32_bytes=0.0,
        pallas_calls=state.pallas_calls,
    )
