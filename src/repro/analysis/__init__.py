from repro.analysis.hlo_cost import HloCost, analyze_hlo

__all__ = ["HloCost", "analyze_hlo"]
