from repro.analysis.hlo_cost import HloCost, analyze_hlo, analyze_jaxpr

__all__ = ["HloCost", "analyze_hlo", "analyze_jaxpr"]
