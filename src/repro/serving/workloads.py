"""Device-resident FPTC workloads: KV-cache and training-state compression.

The engines compress *signals*; this module adapts two structured tensor
workloads onto them:

  * :class:`KVCacheCodec` — a model's KV cache blocks, compressed in the
    engines' **fixed-rate** mode (``BatchEncoder.encode_fixed`` /
    ``BatchDecoder.decode_fixed``): windowed DCT along the token axis per
    (batch, head, dim) channel + calibrated table quantization, entropy
    coding OFF so every compressed block has a static size and cold cache
    reads stay O(1) during decode.  Levels live in HBM as uint8 — a 4x
    footprint cut vs bf16 at ``e == n`` (quantization only), more with
    spectral truncation on trained models.  Tables — and therefore engine
    plans (device tables + DCT bases, uploaded once) — are cached per
    (layer group, dtype); compress/decompress never bounce through the
    host.
  * train-state sharding (:func:`shard_state` / :func:`unshard_state` +
    :func:`state_to_containers` / :func:`state_from_containers`) — float
    tensors of a checkpoint/optimizer tree flatten into fixed-length 1-D
    shards that ride the full entropy-coded container path as one batched
    encode (shards bucket perfectly: every shard but a leaf's last has the
    same length).  ``distributed.checkpoint`` uses these for compressed
    checkpoints; the shards are ordinary FPTC containers, so any engine —
    including the transcoder and the serving front-end — can consume them.

Both workloads use calibrated :class:`~repro.core.calibration.DomainTables`
from :mod:`repro.core.domains` (``kv`` / ``train_state`` domains) — the
standalone DCT + ad-hoc quantizer math the seed's ``kv_compression`` and
gradient compressor carried is replaced by the shared core pipeline.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import DomainTables
from repro.core.config import CodecConfig, DOMAIN_DEFAULTS
from repro.core.container import Container
from repro.core.domains import (
    KV_DOMAIN_ID,
    TRAIN_STATE_DOMAIN_ID,
    calibrate_kv,
    calibrate_train_state,
)
from repro.serving.batch_decode import BatchDecoder
from repro.serving.batch_encode import DEFAULT_CHUNK_SIZE, BatchEncoder

__all__ = [
    "CompressedKV",
    "KVCacheCodec",
    "shard_state",
    "unshard_state",
    "state_to_containers",
    "state_from_containers",
    "DEFAULT_SHARD_LEN",
    "write_workloads_report",
]


# ---------------------------------------------------------------------------
# KV-cache workload.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CompressedKV:
    """One compressed KV block: device-resident uint8 levels, fixed size.

    ``levels`` is ``uint8[B, H, D, W, E]`` — per-channel token-axis DCT
    windows, table-quantized.  ``t`` is the original token count
    (``t == W * n``), ``dtype`` the cache dtype to restore on decompress.
    The compressed footprint is exactly ``levels.nbytes`` — no sidecar:
    the quantizer scales live in the calibrated tables, shipped once per
    (layer group, dtype), not per block.
    """

    levels: jnp.ndarray
    t: int
    dtype: Any

    @property
    def nbytes(self) -> int:
        return int(self.levels.size) * self.levels.dtype.itemsize

    def raw_nbytes(self) -> int:
        """Bytes of the uncompressed block in its original dtype."""
        b, h, d, w, _ = self.levels.shape
        return b * h * d * self.t * np.dtype(self.dtype).itemsize

    @property
    def ratio(self) -> float:
        """Measured compressed/raw byte ratio (actual array bytes)."""
        return self.nbytes / self.raw_nbytes()


class KVCacheCodec:
    """Fixed-rate KV-cache compression over the batched engines.

    Usage::

        codec = KVCacheCodec()
        codec.calibrate(sample_block, layer="attn")   # once, offline
        ckv = codec.compress(kv_block, layer="attn")  # uint8 levels in HBM
        kv  = codec.decompress(ckv, layer="attn")     # [B, T, H, D] again

    ``layer`` names a *table group* — calibration is per (layer group,
    dtype), so e.g. all attention layers of one model can share tables
    (keys and values usually want separate groups; their distributions
    differ).  Compress/decompress are device-resident end to end: the
    only host work is the Python dispatch, pinned by the transfer-guard
    test in ``tests/test_workloads.py``.
    """

    def __init__(
        self,
        *,
        config: Optional[CodecConfig] = None,
        use_kernels: Optional[bool] = None,
        encoder: Optional[BatchEncoder] = None,
        decoder: Optional[BatchDecoder] = None,
    ):
        self.config = config or DOMAIN_DEFAULTS["kv"]
        self.encoder = encoder or BatchEncoder(use_kernels=use_kernels)
        self.decoder = decoder or BatchDecoder(use_kernels=use_kernels)
        self._tables: Dict[Tuple[Any, str], DomainTables] = {}

    # -- tables ------------------------------------------------------------
    def _key(self, layer: Any, dtype) -> Tuple[Any, str]:
        return (layer, str(np.dtype(dtype)))

    def calibrate(
        self, kv_sample: Any, *, layer: Any = None,
        domain_id: int = KV_DOMAIN_ID,
    ) -> DomainTables:
        """Calibrate (and register) tables for one (layer group, dtype).

        ``kv_sample`` is a representative ``[B, T, H, D]`` block — e.g. the
        layer's cache after prefilling calibration prompts.
        """
        tables = calibrate_kv(
            kv_sample, self.config, domain_id=domain_id,
        )
        self._tables[self._key(layer, _dtype_of(kv_sample))] = tables
        return tables

    def set_tables(
        self, tables: DomainTables, *, layer: Any = None,
        dtype=jnp.bfloat16,
    ) -> None:
        """Register pre-calibrated tables (shipped structures) for a group."""
        self._tables[self._key(layer, dtype)] = tables

    def tables_for(self, *, layer: Any = None, dtype=jnp.bfloat16
                   ) -> DomainTables:
        key = self._key(layer, dtype)
        try:
            return self._tables[key]
        except KeyError:
            raise KeyError(
                f"no KV tables calibrated for (layer, dtype)={key} — call "
                "calibrate(sample_block, layer=...) or set_tables(...) first"
            ) from None

    # -- the hot path ------------------------------------------------------
    def compress(self, kv: jnp.ndarray, *, layer: Any = None
                 ) -> CompressedKV:
        """``[B, T, H, D]`` cache block -> fixed-size uint8 levels.

        One fused dispatch through :meth:`BatchEncoder.encode_fixed` (plus
        the channel transpose); ``T`` must be a multiple of the domain's
        window size.  The input stays wherever it lives — device arrays
        never visit the host.
        """
        if kv.ndim != 4:
            raise ValueError(f"KV block must be [B, T, H, D], got {kv.shape}")
        tables = self.tables_for(layer=layer, dtype=_dtype_of(kv))
        x = jnp.moveaxis(kv.astype(jnp.float32), 1, -1)  # [B, H, D, T]
        levels = self.encoder.encode_fixed(x, tables)
        return CompressedKV(levels=levels, t=int(kv.shape[1]),
                            dtype=_dtype_of(kv))

    def decompress(self, ckv: CompressedKV, *, layer: Any = None
                   ) -> jnp.ndarray:
        """Inverse of :meth:`compress` -> ``[B, T, H, D]`` in the original
        dtype (LUT dequantization + MXU iDCT, device-resident)."""
        tables = self.tables_for(layer=layer, dtype=ckv.dtype)
        x = self.decoder.decode_fixed(ckv.levels, tables, length=ckv.t)
        return jnp.moveaxis(x, -1, 1).astype(ckv.dtype)  # [B, T, H, D]


def _dtype_of(x: Any):
    return x.dtype if hasattr(x, "dtype") else jnp.float32


# ---------------------------------------------------------------------------
# Training-state workload.
# ---------------------------------------------------------------------------
DEFAULT_SHARD_LEN = 1 << 16  # 64Ki samples per shard: uniform buckets, and
# each shard's packing chunks parallelize inside one engine dispatch


def shard_state(
    arrays: Mapping[str, np.ndarray],
    *,
    shard_len: int = DEFAULT_SHARD_LEN,
    normalize: bool = False,
) -> Tuple[List[np.ndarray], List[dict]]:
    """Split named float tensors into fixed-length 1-D f32 shards.

    Returns ``(shards, manifest)``: shards in deterministic (key-sorted,
    then offset) order, and per-leaf manifest entries ``{key, shape,
    dtype, lengths}`` where ``lengths`` are the true sample counts of the
    leaf's shards (all ``shard_len`` except the tail).  The split is the
    serving-side analog of the bucket ladder: uniform shard lengths mean
    one encode bucket shape per checkpoint, so the batched encode compiles
    once and pads almost nothing.

    ``normalize=True`` scales each leaf to unit max-abs and records the
    scale in its manifest entry (``unshard_state`` undoes it).  The lossy
    container path uses this: one shared quantizer then serves leaves that
    span orders of magnitude (params vs Adam ``v``), instead of the
    smallest-scale leaves losing all their resolution to the largest.
    The default (``False``) keeps shard/unshard bit-exact.
    """
    if shard_len <= 0:
        raise ValueError(f"shard_len must be positive, got {shard_len}")
    shards: List[np.ndarray] = []
    manifest: List[dict] = []
    for key in sorted(arrays):
        arr = np.asarray(arrays[key])
        flat = arr.astype(np.float32).ravel()
        entry = {
            "key": key,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
        if normalize:
            amax = float(np.max(np.abs(flat))) if flat.size else 0.0
            scale = amax if amax > 0.0 else 1.0
            flat = flat / np.float32(scale)
            entry["scale"] = scale
        lengths = []
        for start in range(0, flat.size, shard_len):
            piece = flat[start:start + shard_len]
            shards.append(piece)
            lengths.append(int(piece.size))
        entry["lengths"] = lengths
        manifest.append(entry)
    return shards, manifest


def unshard_state(
    shards: Sequence[np.ndarray], manifest: Sequence[dict]
) -> Dict[str, np.ndarray]:
    """Reassemble :func:`shard_state` output (shards in manifest order)."""
    out: Dict[str, np.ndarray] = {}
    pos = 0
    for entry in manifest:
        n_shards = len(entry["lengths"])
        pieces = shards[pos:pos + n_shards]
        pos += n_shards
        for piece, want in zip(pieces, entry["lengths"]):
            if piece.shape[0] != want:
                raise ValueError(
                    f"shard of {entry['key']} has {piece.shape[0]} samples, "
                    f"manifest says {want}"
                )
        flat = np.concatenate([np.asarray(p, np.float32) for p in pieces]) \
            if pieces else np.empty(0, np.float32)
        if "scale" in entry:  # undo shard_state(normalize=True)
            flat = flat * np.float32(entry["scale"])
        out[entry["key"]] = flat.astype(np.dtype(entry["dtype"])).reshape(
            entry["shape"]
        )
    if pos != len(shards):
        raise ValueError(
            f"manifest covers {pos} shards, got {len(shards)}"
        )
    return out


def state_to_containers(
    arrays: Mapping[str, np.ndarray],
    tables: DomainTables,
    *,
    encoder: Optional[BatchEncoder] = None,
    shard_len: int = DEFAULT_SHARD_LEN,
) -> Tuple[List[Container], List[dict]]:
    """Encode a named-tensor state as FPTC containers, one batched encode.

    Every shard of every leaf goes through ONE :meth:`BatchEncoder.encode`
    call — uniform shard lengths land in the same bucket, so the whole
    checkpoint is a handful of fused dispatches with chunk-parallel
    packing, drained once at the end (the only host sync; the bytes are
    headed to disk anyway).  Leaves are normalized to unit max-abs before
    quantization (scales ride the manifest), matching the normalization
    :func:`repro.core.domains.train_state_strip` applies at calibration.
    """
    encoder = encoder or BatchEncoder(chunk_size=DEFAULT_CHUNK_SIZE)
    shards, manifest = shard_state(
        arrays, shard_len=shard_len, normalize=True
    )
    containers = (
        encoder.encode(shards, tables).to_host() if shards else []
    )
    return containers, manifest


def state_from_containers(
    containers: Sequence[Container],
    manifest: Sequence[dict],
    tables: DomainTables,
    *,
    decoder: Optional[BatchDecoder] = None,
) -> Dict[str, np.ndarray]:
    """Decode :func:`state_to_containers` output back into named tensors
    (one batched decode, one drain)."""
    decoder = decoder or BatchDecoder()
    shards = (
        decoder.decode(list(containers), tables).to_host()
        if containers else []
    )
    return unshard_state(shards, manifest)


# ---------------------------------------------------------------------------
# Workload benchmark reporting.
# ---------------------------------------------------------------------------
def write_workloads_report(
    section: str,
    payload: dict,
    path: Optional[str] = None,
) -> str:
    """Merge one workload's report into ``BENCH_workloads.json``.

    Each workload example owns a section (``"kv_cache"`` /
    ``"checkpoint"``); the file accumulates sections so CI uploads one
    artifact with bytes-saved / reconstruction-error / overhead-per-step
    for every domain.  Writes are atomic (temp file + rename).
    """
    if path is None:
        path = os.path.join(
            "benchmarks", "artifacts", "workloads", "BENCH_workloads.json"
        )
    os.makedirs(os.path.dirname(path), exist_ok=True)
    report = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                report = json.load(f)
        except (json.JSONDecodeError, OSError):
            report = {}
    report[section] = payload
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path
