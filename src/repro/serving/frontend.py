"""Always-on serving front-end: adaptive deadline micro-batching over the
pipelined engines.

Everything below this module is *offline*: callers hand
:class:`~repro.serving.batch_decode.BatchDecoder` /
:class:`~repro.serving.batch_encode.BatchEncoder` /
:class:`~repro.serving.transcode.Transcoder` a fully formed batch.  A
production archive service absorbs an **open-loop request stream** — it
must form its own batches under latency SLOs, because the engines' fused
bucket dispatches only amortize their overhead when buckets stay full
(the throughput argument of the paper's GPU decode path), while a request
that waits for a full bucket under light load would blow its deadline.

:class:`ServingFrontend` is that batch-forming layer:

  * **Per-(kind, plan) request queues.**  Requests partition by traffic
    kind (decode / encode / transcode) and by the (domain, config) plan
    key — exactly the grouping the engines bucket by, so every flushed
    micro-batch maps onto whole engine buckets with no cross-key padding.
  * **Deadline micro-batching.**  A queue dispatches when it *fills* to
    the active :class:`~repro.tuning.policy.BucketPolicy`'s largest
    bucket edge at or below ``max_batch`` (a full batch carries zero
    batch-dim padding under the engines' ladder), OR when its oldest
    request's deadline minus ``flush_slack_ms`` arrives — whichever is
    first.  Heavy load therefore serves full buckets (throughput);
    light load serves singleton buckets just-in-time (latency).
  * **Bounded queues with explicit load-shedding.**  Admission past
    ``max_queue_depth`` raises :class:`QueueFullError` (carrying the
    queue key, its depth and the bound) — the caller learns it was shed
    and can back off; nothing is ever silently dropped.  A request whose
    deadline already expired at admission raises
    :class:`DeadlineExpiredError` instead of being enqueued dead.
  * **Unified admission.**  All three traffic kinds feed one dispatcher
    and the engines' shared scheduling machinery; a mixed stream
    interleaves freely, and per-request responses are **byte-identical**
    to the offline engine path on the same inputs — micro-batching
    changes *when* buckets run, never bytes (every per-signal output is
    independent of which other requests share its bucket).

Threading model: admission (``submit_*``) is safe from any number of
threads and returns a :class:`concurrent.futures.Future`.  ONE dispatcher
thread owns batch formation and all engine calls — jit tracing and plan
lookups stay on a single thread, honoring the engines'
tracing-on-the-calling-thread contract — and hands device-resident
batches to a small drain pool, so the host-side ``to_host()`` stitch of
micro-batch k overlaps the dispatch of micro-batch k+1 (the request-level
twin of the engines' double-buffered staging).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import (
    Any,
    Callable,
    Dict,
    Hashable,
    List,
    Mapping,
    Optional,
    Tuple,
    Union,
)

import numpy as np

from repro.core.calibration import DomainTables
from repro.core.container import Container
from repro.serving.batch_decode import BatchDecoder
from repro.serving.batch_encode import BatchEncoder
from repro.serving.engine import DevicesArg
from repro.serving.transcode import Transcoder
from repro.tuning.policy import BucketPolicy, PolicyArg

__all__ = [
    "DEADLINE",
    "FILL",
    "FrontendClosedError",
    "FrontendConfig",
    "FrontendError",
    "FrontendStats",
    "DeadlineExpiredError",
    "QueueFullError",
    "ServingFrontend",
    "policy_fill_target",
]

TablesArg = Union[DomainTables, Mapping[int, DomainTables]]

# dispatch reasons (stats + tests key on these)
FILL = "fill"  # the queue reached the policy-edge fill target
DEADLINE = "deadline"  # the oldest request's deadline slack arrived
FORCED = "forced"  # an explicit flush() or the closing drain


# ---------------------------------------------------------------------------
# Typed front-end errors: load shedding is a *response*, never a silent drop.
# ---------------------------------------------------------------------------
class FrontendError(RuntimeError):
    """Base class for serving front-end rejections/failures."""


class QueueFullError(FrontendError):
    """Admission rejected: the request's queue is at its depth bound.

    Carries the shed decision's evidence — ``queue`` (the (kind, plan)
    key), ``depth`` (pending requests at rejection) and ``bound`` — so
    callers and load balancers can report and back off instead of
    guessing.  Raised at admission; the request was never enqueued.
    """

    def __init__(self, queue: Hashable, depth: int, bound: int):
        self.queue = queue
        self.depth = depth
        self.bound = bound
        super().__init__(
            f"queue {queue!r} is full ({depth} pending >= bound {bound}); "
            "request shed — back off and retry"
        )


class DeadlineExpiredError(FrontendError):
    """Admission rejected: the request's deadline had already expired.

    Enqueueing it could only produce a guaranteed-late response that
    still costs a bucket slot; rejecting at admission is the honest
    failure.  Raised before enqueue; the request was never admitted.
    """

    def __init__(self, queue: Hashable, late_s: float):
        self.queue = queue
        self.late_s = late_s
        super().__init__(
            f"deadline for queue {queue!r} expired {late_s * 1e3:.2f} ms "
            "before admission"
        )


class FrontendClosedError(FrontendError):
    """The front-end is closed: no new admissions (and, on a non-draining
    close, the fate of requests that were still queued)."""


# ---------------------------------------------------------------------------
# Config + stats.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Micro-batching knobs.  See the README knob table.

    ``max_batch`` bounds how many requests one flush takes; the effective
    *fill target* snaps DOWN to the engines' active bucket-policy edge
    (:func:`policy_fill_target`), so fill-triggered batches carry zero
    batch-dimension padding.  ``max_queue_depth`` is the per-queue
    admission bound (shedding past it); ``default_slo_ms`` the deadline
    assigned to requests that don't bring one; ``flush_slack_ms`` how far
    ahead of the oldest deadline a queue flushes (covers dispatch + drain
    latency); ``drain_workers`` sizes the pool that overlaps host drains
    with the next dispatch.
    """

    max_batch: int = 64
    max_queue_depth: int = 256
    default_slo_ms: float = 100.0
    flush_slack_ms: float = 5.0
    drain_workers: int = 1

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {self.max_queue_depth}"
            )
        if self.drain_workers < 1:
            raise ValueError(
                f"drain_workers must be >= 1, got {self.drain_workers}"
            )
        if self.flush_slack_ms < 0:
            raise ValueError(
                f"flush_slack_ms must be >= 0, got {self.flush_slack_ms}"
            )


def policy_fill_target(policy: BucketPolicy, max_batch: int) -> int:
    """The largest ``policy`` bucket edge <= ``max_batch`` — the fill
    count at which a queue dispatches.  Snapping to an edge means a
    fill-triggered micro-batch pads by zero rows under the engines'
    bucket ladder (``policy.round(target) == target``)."""
    t = max(int(max_batch), 1)
    while t > 1 and policy.round(t) != t:
        t -= 1
    return t


@dataclasses.dataclass
class FrontendStats:
    """Counters the dispatcher/drain threads maintain (read them via
    :meth:`ServingFrontend.stats_snapshot` for a coherent copy)."""

    admitted: int = 0
    completed: int = 0
    failed: int = 0  # admitted but engine/drain raised (futures carry it)
    shed: int = 0  # rejected QueueFullError
    rejected_expired: int = 0  # rejected DeadlineExpiredError
    batches: int = 0
    fill_dispatches: int = 0
    deadline_dispatches: int = 0
    forced_dispatches: int = 0  # explicit flush() + the closing drain
    deadline_misses: int = 0  # completed after their own deadline
    max_inflight: int = 0  # peak requests dispatched-but-not-completed
    max_depth: int = 0  # peak single-queue depth observed at admission
    batch_size_sum: int = 0

    @property
    def mean_batch_size(self) -> float:
        return self.batch_size_sum / self.batches if self.batches else 0.0


@dataclasses.dataclass
class _Pending:
    payload: Any
    future: Future
    deadline: float  # absolute, frontend clock
    admitted_at: float


# ---------------------------------------------------------------------------
# The front-end.
# ---------------------------------------------------------------------------
class ServingFrontend:
    """Long-lived request front-end over the batched serving engines.

    Usage::

        with ServingFrontend(tables) as fe:          # tables: DomainTables
            fut = fe.submit_decode(container)        #   or {domain_id: ...}
            signal = fut.result()                    # np.float32 samples

    ``tables`` routes every traffic kind: decode requests resolve their
    container's domain, encode requests the ``domain_id`` they carry, and
    transcode requests both their source container's domain and their
    ``dst_domain_id`` target.  Engine knobs (``pipeline`` / ``devices`` /
    ``policy`` / ``use_kernels`` / ``chunk_size``) construct the three
    engines unless explicit engines are passed; the transcoder shares the
    front-end's decoder and encoder, so all traffic kinds warm ONE set of
    plan caches.  ``clock`` is injectable for deterministic tests.

    The front-end starts its dispatcher on construction (it is
    *always-on*); ``close()`` — or leaving the context — drains every
    queue, completes every admitted future, and joins the threads.
    """

    def __init__(
        self,
        tables: TablesArg,
        *,
        config: Optional[FrontendConfig] = None,
        decoder: Optional[BatchDecoder] = None,
        encoder: Optional[BatchEncoder] = None,
        transcoder: Optional[Transcoder] = None,
        use_kernels: Optional[bool] = None,
        chunk_size: Optional[int] = None,
        pipeline: bool = True,
        devices: DevicesArg = "auto",
        policy: PolicyArg = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.config = config or FrontendConfig()
        self.tables: Mapping[int, DomainTables] = (
            {tables.domain_id: tables}
            if isinstance(tables, DomainTables) else dict(tables)
        )
        self.decoder = decoder or BatchDecoder(
            use_kernels=use_kernels, pipeline=pipeline, devices=devices,
            policy=policy,
        )
        self.encoder = encoder or BatchEncoder(
            use_kernels=use_kernels, pipeline=pipeline, devices=devices,
            policy=policy,
            **({} if chunk_size is None else {"chunk_size": chunk_size}),
        )
        # the transcoder RIDES the front-end's decoder/encoder: one set of
        # engines, one set of plan caches, one device placement for all
        # three traffic kinds
        self.transcoder = transcoder or Transcoder(
            decoder=self.decoder, encoder=self.encoder,
        )
        self._clock = clock
        self._fill = policy_fill_target(
            self.decoder.scheduler.policy, self.config.max_batch
        )
        self.stats = FrontendStats()

        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queues: "Dict[Hashable, deque[_Pending]]" = {}
        self._inflight = 0
        self._flush_all = False
        self._closed = False
        self._drain_pool = ThreadPoolExecutor(
            max_workers=self.config.drain_workers,
            thread_name_prefix="fptc-frontend-drain",
        )
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="fptc-frontend-dispatch",
            daemon=True,
        )
        self._dispatcher.start()

    # -- context management --------------------------------------------------
    def __enter__(self) -> "ServingFrontend":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(drain=exc_type is None)

    # -- introspection -------------------------------------------------------
    @property
    def fill_target(self) -> int:
        """Requests at which a queue dispatches on fill (the largest
        active-policy bucket edge <= ``config.max_batch``)."""
        return self._fill

    def inflight(self) -> int:
        """Requests dispatched to the engines but not yet completed."""
        with self._lock:
            return self._inflight

    def queue_depths(self) -> Dict[Hashable, int]:
        """Snapshot of per-queue pending counts (admitted, not yet taken
        by the dispatcher)."""
        with self._lock:
            return {k: len(q) for k, q in self._queues.items() if q}

    def stats_snapshot(self) -> FrontendStats:
        """A coherent copy of the counters (the live object mutates under
        the front-end's lock)."""
        with self._lock:
            return dataclasses.replace(self.stats)

    # -- admission -----------------------------------------------------------
    def _tables_for(self, domain_id: int) -> DomainTables:
        try:
            return self.tables[domain_id]
        except KeyError:
            raise KeyError(
                f"no DomainTables registered for domain_id={domain_id}"
            ) from None

    def submit_decode(
        self, container: Container, *, deadline_ms: Optional[float] = None
    ) -> "Future[np.ndarray]":
        """Admit one container for decoding; resolves to its float32
        signal.  Raises :class:`QueueFullError` /
        :class:`DeadlineExpiredError` / :class:`FrontendClosedError` at
        admission (typed, never silent)."""
        self._tables_for(container.domain_id)  # unroutable fails up front
        key = ("decode", container.plan_key)
        return self._admit(key, container, deadline_ms)

    def submit_encode(
        self,
        signal: np.ndarray,
        domain_id: Optional[int] = None,
        *,
        deadline_ms: Optional[float] = None,
    ) -> "Future[Container]":
        """Admit one signal for encoding; resolves to its
        :class:`Container`.  ``domain_id`` defaults to the single
        registered domain (ambiguous with several — pass it)."""
        if domain_id is None:
            if len(self.tables) != 1:
                raise ValueError(
                    "domain_id is required when the front-end serves "
                    f"{len(self.tables)} domains"
                )
            domain_id = next(iter(self.tables))
        tab = self._tables_for(domain_id)
        cfg = tab.config
        key = ("encode", (domain_id, cfg.n, cfg.e, cfg.l_max, cfg.coding))
        return self._admit(key, (signal, domain_id), deadline_ms)

    def submit_transcode(
        self,
        container: Container,
        dst_domain_id: int,
        *,
        deadline_ms: Optional[float] = None,
    ) -> "Future[Container]":
        """Admit one container for migration to ``dst_domain_id``'s
        tables; resolves to the re-encoded :class:`Container`."""
        self._tables_for(container.domain_id)
        self._tables_for(dst_domain_id)
        key = ("transcode", container.plan_key, dst_domain_id)
        return self._admit(key, (container, dst_domain_id), deadline_ms)

    def _admit(
        self, key: Hashable, payload: Any, deadline_ms: Optional[float]
    ) -> Future:
        now = self._clock()
        slo = (
            self.config.default_slo_ms if deadline_ms is None
            else float(deadline_ms)
        )
        deadline = now + slo / 1e3
        with self._cond:
            if self._closed:
                raise FrontendClosedError(
                    "front-end is closed; no new admissions"
                )
            if deadline <= now:
                self.stats.rejected_expired += 1
                raise DeadlineExpiredError(key, now - deadline)
            q = self._queues.get(key)
            if q is None:
                q = self._queues[key] = deque()
            depth = len(q)
            if depth >= self.config.max_queue_depth:
                self.stats.shed += 1
                raise QueueFullError(key, depth, self.config.max_queue_depth)
            fut: Future = Future()
            q.append(_Pending(payload, fut, deadline, now))
            self.stats.admitted += 1
            if depth + 1 > self.stats.max_depth:
                self.stats.max_depth = depth + 1
            self._cond.notify_all()
        return fut

    def flush(self) -> None:
        """Force-dispatch everything currently queued, regardless of fill
        or deadlines (a no-op on empty queues).  Returns immediately; wait
        on the submitted futures for completion."""
        with self._cond:
            self._flush_all = True
            self._cond.notify_all()

    # -- the dispatcher ------------------------------------------------------
    def _take_ready(
        self, now: float, force: bool
    ) -> List[Tuple[Hashable, List[_Pending], str]]:
        """Pop every dispatchable micro-batch (caller holds the lock).

        A queue dispatches its oldest ``fill_target`` requests while it
        holds at least that many (reason FILL); once the oldest remaining
        request's ``deadline - flush_slack`` has arrived, whatever is left
        dispatches as one partial batch (reason DEADLINE).  ``force``
        (explicit flush / closing drain) takes everything in
        ``max_batch``-bounded slices.
        """
        slack = self.config.flush_slack_ms / 1e3
        out: List[Tuple[Hashable, List[_Pending], str]] = []
        for key, q in self._queues.items():
            while len(q) >= self._fill:
                out.append((
                    key, [q.popleft() for _ in range(self._fill)], FILL,
                ))
            if q and (force or q[0].deadline - slack <= now):
                batch = []
                while q and len(batch) < self.config.max_batch:
                    batch.append(q.popleft())
                out.append((key, batch, FORCED if force else DEADLINE))
        return out

    def _next_wake(self, now: float) -> Optional[float]:
        """Seconds until the earliest queued deadline-minus-slack (None =
        sleep until notified)."""
        slack = self.config.flush_slack_ms / 1e3
        earliest = None
        for q in self._queues.values():
            if q:
                t = q[0].deadline - slack
                if earliest is None or t < earliest:
                    earliest = t
        if earliest is None:
            return None
        return max(earliest - now, 0.0)

    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                while True:
                    force = self._flush_all or self._closed
                    self._flush_all = False
                    batches = self._take_ready(self._clock(), force)
                    if batches:
                        self.stats.batches += len(batches)
                        self._inflight += sum(len(b) for _, b, _ in batches)
                        if self._inflight > self.stats.max_inflight:
                            self.stats.max_inflight = self._inflight
                        for _, members, reason in batches:
                            self.stats.batch_size_sum += len(members)
                            if reason == FILL:
                                self.stats.fill_dispatches += 1
                            elif reason == DEADLINE:
                                self.stats.deadline_dispatches += 1
                            else:
                                self.stats.forced_dispatches += 1
                        break
                    if self._closed:
                        return  # closed and every queue drained
                    self._cond.wait(timeout=self._next_wake(self._clock()))
            for key, members, _reason in batches:
                self._dispatch_batch(key, members)

    def _dispatch_batch(
        self, key: Hashable, members: List[_Pending]
    ) -> None:
        """Run one micro-batch through its engine (dispatcher thread: all
        jit tracing happens here) and hand the device-resident result to
        the drain pool."""
        kind = key[0]
        try:
            if kind == "decode":
                for r in members:
                    self.decoder.submit(r.payload)
                batch = self.decoder.flush(self.tables)
            elif kind == "encode":
                for r in members:
                    signal, domain_id = r.payload
                    self.encoder.submit(signal, domain_id)
                batch = self.encoder.flush(self.tables)
            else:  # transcode
                for r in members:
                    container, dst = r.payload
                    self.transcoder.submit(container, dst)
                batch = self.transcoder.flush(self.tables, self.tables)
        except BaseException as e:  # noqa: BLE001 — fate rides the futures
            self._finish(members, error=e)
            return
        self._drain_pool.submit(self._drain, batch, members)

    def _drain(self, batch: Any, members: List[_Pending]) -> None:
        """Drain worker: host-materialize one micro-batch and complete its
        futures (overlaps the dispatcher forming the next batch)."""
        try:
            results = batch.to_host()
        except BaseException as e:  # noqa: BLE001
            self._finish(members, error=e)
            return
        self._finish(members, results=results)

    def _finish(
        self,
        members: List[_Pending],
        *,
        results: Optional[List[Any]] = None,
        error: Optional[BaseException] = None,
    ) -> None:
        now = self._clock()
        done = failed = misses = 0
        for i, r in enumerate(members):
            try:
                if error is not None:
                    r.future.set_exception(error)
                    failed += 1
                else:
                    r.future.set_result(results[i])
                    done += 1
                    if now > r.deadline:
                        misses += 1
            except Exception:  # future already cancelled by the caller
                pass
        with self._cond:
            self._inflight -= len(members)
            self.stats.completed += done
            self.stats.failed += failed
            self.stats.deadline_misses += misses
            self._cond.notify_all()

    # -- shutdown ------------------------------------------------------------
    def close(self, *, drain: bool = True) -> None:
        """Stop the front-end.  ``drain=True`` (default) flushes and
        completes everything already admitted before returning;
        ``drain=False`` fails queued requests with
        :class:`FrontendClosedError` (their futures carry it — still
        never a silent drop)."""
        with self._cond:
            if self._closed:
                self._cond.notify_all()
            else:
                self._closed = True
                if not drain:
                    for q in self._queues.values():
                        while q:
                            r = q.popleft()
                            try:
                                r.future.set_exception(FrontendClosedError(
                                    "front-end closed before this request "
                                    "dispatched"
                                ))
                            except Exception:
                                pass
                            self.stats.failed += 1
                self._cond.notify_all()
        self._dispatcher.join()
        self._drain_pool.shutdown(wait=True)
