"""Always-on serving front-end: adaptive deadline micro-batching over the
pipelined engines.

Everything below this module is *offline*: callers hand
:class:`~repro.serving.batch_decode.BatchDecoder` /
:class:`~repro.serving.batch_encode.BatchEncoder` /
:class:`~repro.serving.transcode.Transcoder` a fully formed batch.  A
production archive service absorbs an **open-loop request stream** — it
must form its own batches under latency SLOs, because the engines' fused
bucket dispatches only amortize their overhead when buckets stay full
(the throughput argument of the paper's GPU decode path), while a request
that waits for a full bucket under light load would blow its deadline.

:class:`ServingFrontend` is that batch-forming layer:

  * **Per-(kind, plan) request queues.**  Requests partition by traffic
    kind (decode / encode / transcode) and by the (domain, config) plan
    key — exactly the grouping the engines bucket by, so every flushed
    micro-batch maps onto whole engine buckets with no cross-key padding.
  * **Deadline micro-batching.**  A queue dispatches when it *fills* to
    the active :class:`~repro.tuning.policy.BucketPolicy`'s largest
    bucket edge at or below ``max_batch`` (a full batch carries zero
    batch-dim padding under the engines' ladder), OR when its oldest
    request's deadline minus ``flush_slack_ms`` arrives — whichever is
    first.  Heavy load therefore serves full buckets (throughput);
    light load serves singleton buckets just-in-time (latency).
  * **Bounded queues with explicit load-shedding.**  Admission past
    ``max_queue_depth`` raises :class:`QueueFullError` (carrying the
    queue key, its depth and the bound) — the caller learns it was shed
    and can back off; nothing is ever silently dropped.  A request whose
    deadline already expired at admission raises
    :class:`DeadlineExpiredError` instead of being enqueued dead.
  * **Unified admission.**  All three traffic kinds feed one dispatcher
    and the engines' shared scheduling machinery; a mixed stream
    interleaves freely, and per-request responses are **byte-identical**
    to the offline engine path on the same inputs — micro-batching
    changes *when* buckets run, never bytes (every per-signal output is
    independent of which other requests share its bucket).
  * **Fault isolation.**  With ``config.quarantine`` (the default) a
    corrupt container poisons only its own request: the engines exclude
    it from its bucket and its future carries a typed
    :class:`~repro.serving.quarantine.PoisonedContainerError` while its
    batch-mates complete byte-identically.  Transient engine faults
    retry with bounded exponential backoff + jitter
    (:class:`RetryPolicy`; poisoned payloads are never re-run — their
    outcome is a result, not a dispatch fault).  An optional watchdog
    (``config.watchdog_timeout_ms``) bounds every engine call: a hung
    dispatch fails its in-flight requests with a typed
    :class:`DispatchFailedError`, a fresh dispatcher generation takes
    over, and the queues keep draining.  :meth:`health` reports the
    degraded/ok state plus shed-rate and quarantine counters.

Threading model: admission (``submit_*``) is safe from any number of
threads and returns a :class:`concurrent.futures.Future`.  ONE dispatcher
thread owns batch formation and all engine calls — jit tracing and plan
lookups stay on a single thread, honoring the engines'
tracing-on-the-calling-thread contract — and hands device-resident
batches to a small drain pool, so the host-side ``to_host()`` stitch of
micro-batch k overlaps the dispatch of micro-batch k+1 (the request-level
twin of the engines' double-buffered staging).  The watchdog replaces a
timed-out dispatcher with a new generation; the abandoned thread's
eventual result is discarded through a per-batch completion token, so a
request completes exactly once however the race resolves.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import (
    Any,
    Callable,
    Dict,
    Hashable,
    List,
    Mapping,
    Optional,
    Tuple,
    Union,
)

import numpy as np

from repro.core.calibration import DomainTables
from repro.core.container import Container
from repro.serving.batch_decode import BatchDecoder
from repro.serving.batch_encode import BatchEncoder
from repro.serving.engine import DevicesArg
from repro.serving.transcode import Transcoder
from repro.tuning.policy import BucketPolicy, PolicyArg

__all__ = [
    "DEADLINE",
    "FILL",
    "DispatchFailedError",
    "FrontendClosedError",
    "FrontendConfig",
    "FrontendError",
    "FrontendStats",
    "DeadlineExpiredError",
    "QueueFullError",
    "RetryPolicy",
    "ServingFrontend",
    "policy_fill_target",
]

TablesArg = Union[DomainTables, Mapping[int, DomainTables]]

# dispatch reasons (stats + tests key on these)
FILL = "fill"  # the queue reached the policy-edge fill target
DEADLINE = "deadline"  # the oldest request's deadline slack arrived
FORCED = "forced"  # an explicit flush() or the closing drain


# ---------------------------------------------------------------------------
# Typed front-end errors: load shedding is a *response*, never a silent drop.
# ---------------------------------------------------------------------------
class FrontendError(RuntimeError):
    """Base class for serving front-end rejections/failures."""


class QueueFullError(FrontendError):
    """Admission rejected: the request's queue is at its depth bound.

    Carries the shed decision's evidence — ``queue`` (the (kind, plan)
    key), ``depth`` (pending requests at rejection) and ``bound`` — so
    callers and load balancers can report and back off instead of
    guessing.  Raised at admission; the request was never enqueued.
    """

    def __init__(self, queue: Hashable, depth: int, bound: int):
        self.queue = queue
        self.depth = depth
        self.bound = bound
        super().__init__(
            f"queue {queue!r} is full ({depth} pending >= bound {bound}); "
            "request shed — back off and retry"
        )


class DeadlineExpiredError(FrontendError):
    """Admission rejected: the request's deadline had already expired.

    Enqueueing it could only produce a guaranteed-late response that
    still costs a bucket slot; rejecting at admission is the honest
    failure.  Raised before enqueue; the request was never admitted.
    """

    def __init__(self, queue: Hashable, late_s: float):
        self.queue = queue
        self.late_s = late_s
        super().__init__(
            f"deadline for queue {queue!r} expired {late_s * 1e3:.2f} ms "
            "before admission"
        )


class FrontendClosedError(FrontendError):
    """The front-end is closed: no new admissions (and, on a non-draining
    close, the fate of requests that were still queued)."""


class DispatchFailedError(FrontendError):
    """A micro-batch's engine dispatch failed for good.

    The typed per-request outcome for a hung engine call the watchdog cut
    loose, or a transient fault that exhausted its :class:`RetryPolicy`
    budget (``__cause__`` carries the final attempt's exception).  The
    request itself may be perfectly valid — resubmitting it is safe and
    is exactly what the retry budget already did; this error says the
    *serving machinery* gave up, as opposed to a
    :class:`~repro.serving.quarantine.PoisonedContainerError`, which says
    the *payload* is bad.
    """

    def __init__(self, queue: Hashable, message: str):
        self.queue = queue
        super().__init__(
            f"dispatch for queue {queue!r} failed: {message}"
        )


# ---------------------------------------------------------------------------
# Config + stats.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff + jitter for transient
    dispatch faults.

    A failed micro-batch's members requeue (at the head — retries never
    jump the FIFO order of their queue) at most ``max_retries`` times
    each, waiting ``base_backoff_ms * 2**attempt`` (capped at
    ``max_backoff_ms``) scaled down by up to ``jitter`` fraction at
    random — the standard thundering-herd spreader.  Only *transient*
    faults retry: :meth:`retryable` rejects deterministic errors
    (``ValueError`` / ``KeyError`` / ``TypeError`` /
    ``NotImplementedError``), every typed front-end error, and — the
    contract the quarantine depends on — poisoned payloads, which never
    reach retry at all because quarantine delivers them as per-request
    *results*, not dispatch faults.  ``max_retries=0`` disables retry.
    """

    max_retries: int = 2
    base_backoff_ms: float = 10.0
    max_backoff_ms: float = 1000.0
    jitter: float = 0.5

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}"
            )
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")

    def backoff_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based), in seconds."""
        base = min(
            self.base_backoff_ms * (2.0 ** max(attempt - 1, 0)),
            self.max_backoff_ms,
        ) / 1e3
        return base * (1.0 - self.jitter * random.random())

    def retryable(self, exc: BaseException) -> bool:
        """Whether a dispatch fault is worth re-running the batch for."""
        from repro.serving.quarantine import PoisonedContainerError

        if isinstance(exc, (PoisonedContainerError, FrontendError)):
            return False
        if isinstance(
            exc, (ValueError, KeyError, TypeError, NotImplementedError)
        ):
            return False  # deterministic: identical inputs fail identically
        return isinstance(exc, Exception)


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Micro-batching knobs.  See the README knob table.

    ``max_batch`` bounds how many requests one flush takes; the effective
    *fill target* snaps DOWN to the engines' active bucket-policy edge
    (:func:`policy_fill_target`), so fill-triggered batches carry zero
    batch-dimension padding.  ``max_queue_depth`` is the per-queue
    admission bound (shedding past it); ``default_slo_ms`` the deadline
    assigned to requests that don't bring one; ``flush_slack_ms`` how far
    ahead of the oldest deadline a queue flushes (covers dispatch + drain
    latency); ``drain_workers`` sizes the pool that overlaps host drains
    with the next dispatch.

    Fault-isolation knobs: ``quarantine`` turns corrupt containers into
    per-request typed errors instead of batch failures (the serving
    default — flip off to get the offline engines' raise-on-first-fault
    contract); ``retry`` is the transient-fault :class:`RetryPolicy`;
    ``watchdog_timeout_ms`` > 0 arms the dispatcher watchdog (an engine
    call exceeding it fails its batch with :class:`DispatchFailedError`
    and a fresh dispatcher takes over), polled every
    ``watchdog_poll_ms``; ``degraded_window_s`` is how long a fault event
    keeps :meth:`ServingFrontend.health` reporting ``degraded``.
    """

    max_batch: int = 64
    max_queue_depth: int = 256
    default_slo_ms: float = 100.0
    flush_slack_ms: float = 5.0
    drain_workers: int = 1
    quarantine: bool = True
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    watchdog_timeout_ms: float = 0.0  # 0 = watchdog disabled
    watchdog_poll_ms: float = 50.0
    degraded_window_s: float = 30.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {self.max_queue_depth}"
            )
        if self.drain_workers < 1:
            raise ValueError(
                f"drain_workers must be >= 1, got {self.drain_workers}"
            )
        if self.flush_slack_ms < 0:
            raise ValueError(
                f"flush_slack_ms must be >= 0, got {self.flush_slack_ms}"
            )
        if self.watchdog_timeout_ms < 0:
            raise ValueError(
                "watchdog_timeout_ms must be >= 0 (0 disables), got "
                f"{self.watchdog_timeout_ms}"
            )
        if self.watchdog_poll_ms <= 0:
            raise ValueError(
                f"watchdog_poll_ms must be > 0, got {self.watchdog_poll_ms}"
            )


def policy_fill_target(policy: BucketPolicy, max_batch: int) -> int:
    """The largest ``policy`` bucket edge <= ``max_batch`` — the fill
    count at which a queue dispatches.  Snapping to an edge means a
    fill-triggered micro-batch pads by zero rows under the engines'
    bucket ladder (``policy.round(target) == target``)."""
    t = max(int(max_batch), 1)
    while t > 1 and policy.round(t) != t:
        t -= 1
    return t


@dataclasses.dataclass
class FrontendStats:
    """Counters the dispatcher/drain threads maintain (read them via
    :meth:`ServingFrontend.stats_snapshot` for a coherent copy)."""

    admitted: int = 0
    completed: int = 0
    failed: int = 0  # admitted but engine/drain raised (futures carry it)
    shed: int = 0  # rejected QueueFullError
    rejected_expired: int = 0  # rejected DeadlineExpiredError
    batches: int = 0
    fill_dispatches: int = 0
    deadline_dispatches: int = 0
    forced_dispatches: int = 0  # explicit flush() + the closing drain
    deadline_misses: int = 0  # completed after their own deadline
    max_inflight: int = 0  # peak requests dispatched-but-not-completed
    max_depth: int = 0  # peak single-queue depth observed at admission
    batch_size_sum: int = 0
    quarantined: int = 0  # requests whose future carries a poison outcome
    retries: int = 0  # member re-dispatches after a transient fault
    retry_successes: int = 0  # completed on a retry attempt
    dispatch_failures: int = 0  # members failed with DispatchFailedError
    watchdog_restarts: int = 0  # hung dispatches the watchdog cut loose
    dispatcher_restarts: int = 0  # dispatcher-loop crash recoveries

    @property
    def mean_batch_size(self) -> float:
        return self.batch_size_sum / self.batches if self.batches else 0.0


@dataclasses.dataclass
class _Pending:
    payload: Any
    future: Future
    deadline: float  # absolute, frontend clock
    admitted_at: float
    attempts: int = 0  # dispatch attempts already failed transiently
    not_before: float = 0.0  # retry backoff: not dispatchable before this


# ---------------------------------------------------------------------------
# The front-end.
# ---------------------------------------------------------------------------
class ServingFrontend:
    """Long-lived request front-end over the batched serving engines.

    Usage::

        with ServingFrontend(tables) as fe:          # tables: DomainTables
            fut = fe.submit_decode(container)        #   or {domain_id: ...}
            signal = fut.result()                    # np.float32 samples

    ``tables`` routes every traffic kind: decode requests resolve their
    container's domain, encode requests the ``domain_id`` they carry, and
    transcode requests both their source container's domain and their
    ``dst_domain_id`` target.  Engine knobs (``pipeline`` / ``devices`` /
    ``policy`` / ``use_kernels`` / ``chunk_size``) construct the three
    engines unless explicit engines are passed; the transcoder shares the
    front-end's decoder and encoder, so all traffic kinds warm ONE set of
    plan caches.  ``clock`` is injectable for deterministic tests.

    ``fault_injector`` (an object with ``on_dispatch(key, members)``,
    e.g. :class:`repro.testing.faults.DispatcherFaultInjector`) is called
    inside the watchdog-covered window at the top of every batch dispatch
    — the chaos harness's hook for raising, delaying or hanging engine
    calls; ``None`` (the default) costs nothing.

    The front-end starts its dispatcher on construction (it is
    *always-on*); ``close()`` — or leaving the context — drains every
    queue, completes every admitted future, and joins the threads.
    """

    def __init__(
        self,
        tables: TablesArg,
        *,
        config: Optional[FrontendConfig] = None,
        decoder: Optional[BatchDecoder] = None,
        encoder: Optional[BatchEncoder] = None,
        transcoder: Optional[Transcoder] = None,
        use_kernels: Optional[bool] = None,
        chunk_size: Optional[int] = None,
        pipeline: bool = True,
        devices: DevicesArg = "auto",
        policy: PolicyArg = None,
        clock: Callable[[], float] = time.monotonic,
        fault_injector: Optional[Any] = None,
    ):
        self.config = config or FrontendConfig()
        self.tables: Mapping[int, DomainTables] = (
            {tables.domain_id: tables}
            if isinstance(tables, DomainTables) else dict(tables)
        )
        self.decoder = decoder or BatchDecoder(
            use_kernels=use_kernels, pipeline=pipeline, devices=devices,
            policy=policy,
        )
        self.encoder = encoder or BatchEncoder(
            use_kernels=use_kernels, pipeline=pipeline, devices=devices,
            policy=policy,
            **({} if chunk_size is None else {"chunk_size": chunk_size}),
        )
        # the transcoder RIDES the front-end's decoder/encoder: one set of
        # engines, one set of plan caches, one device placement for all
        # three traffic kinds
        self.transcoder = transcoder or Transcoder(
            decoder=self.decoder, encoder=self.encoder,
        )
        self._clock = clock
        self.fault_injector = fault_injector
        self._fill = policy_fill_target(
            self.decoder.scheduler.policy, self.config.max_batch
        )
        self.stats = FrontendStats()

        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queues: "Dict[Hashable, deque[_Pending]]" = {}
        self._inflight = 0
        self._flush_all = False
        self._closed = False
        # fault-isolation state (all under self._lock):
        self._gen = 0  # dispatcher generation; watchdog bumps to restart
        self._watch: Optional[Dict[str, Any]] = None  # in-flight dispatch
        # batches taken from the queues but not yet dispatched, shared so
        # a watchdog restart can hand them to the replacement generation
        # instead of leaving them captive in the stuck thread's locals
        self._undispatched: List[Tuple[Hashable, List["_Pending"], str]] = []
        self._undispatched_gen = 0  # generation owning _undispatched
        self._scrub_pending = False  # abandoned dispatch may have leaked
        # submits into the engines' buffers; next dispatch discards them
        self._events: "deque[Tuple[float, str]]" = deque(maxlen=64)
        self._drain_pool = ThreadPoolExecutor(
            max_workers=self.config.drain_workers,
            thread_name_prefix="fptc-frontend-drain",
        )
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, args=(0,),
            name="fptc-frontend-dispatch", daemon=True,
        )
        self._dispatcher.start()
        self._wd_stop = threading.Event()
        self._watchdog: Optional[threading.Thread] = None
        if self.config.watchdog_timeout_ms > 0:
            self._watchdog = threading.Thread(
                target=self._watchdog_loop, name="fptc-frontend-watchdog",
                daemon=True,
            )
            self._watchdog.start()

    # -- context management --------------------------------------------------
    def __enter__(self) -> "ServingFrontend":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(drain=exc_type is None)

    # -- introspection -------------------------------------------------------
    @property
    def fill_target(self) -> int:
        """Requests at which a queue dispatches on fill (the largest
        active-policy bucket edge <= ``config.max_batch``)."""
        return self._fill

    def inflight(self) -> int:
        """Requests dispatched to the engines but not yet completed."""
        with self._lock:
            return self._inflight

    def queue_depths(self) -> Dict[Hashable, int]:
        """Snapshot of per-queue pending counts (admitted, not yet taken
        by the dispatcher)."""
        with self._lock:
            return {k: len(q) for k, q in self._queues.items() if q}

    def stats_snapshot(self) -> FrontendStats:
        """A coherent copy of the counters (the live object mutates under
        the front-end's lock)."""
        with self._lock:
            return dataclasses.replace(self.stats)

    def health(self) -> Dict[str, Any]:
        """The ``/healthz`` contract: liveness + degraded-state evidence.

        ``status`` is ``"ok"``, ``"degraded"`` (a watchdog restart,
        dispatcher crash or dispatch failure happened within
        ``config.degraded_window_s`` — the frontend still serves, a load
        balancer should prefer healthier replicas) or ``"closed"``.
        ``shed_rate`` is sheds / admission attempts over the frontend's
        lifetime; ``events`` lists the recent fault descriptions backing
        a degraded verdict.
        """
        now = self._clock()
        window = self.config.degraded_window_s
        with self._lock:
            recent = [
                {"age_s": round(now - t, 3), "event": msg}
                for t, msg in self._events
                if now - t <= window
            ]
            attempts = self.stats.admitted + self.stats.shed
            status = "closed" if self._closed else (
                "degraded" if recent else "ok"
            )
            return {
                "status": status,
                "degraded": bool(recent),
                "events": recent,
                "shed_rate": self.stats.shed / attempts if attempts else 0.0,
                "quarantined": self.stats.quarantined,
                "retries": self.stats.retries,
                "retry_successes": self.stats.retry_successes,
                "dispatch_failures": self.stats.dispatch_failures,
                "watchdog_restarts": self.stats.watchdog_restarts,
                "dispatcher_restarts": self.stats.dispatcher_restarts,
                "inflight": self._inflight,
                "queued": sum(len(q) for q in self._queues.values()),
            }

    def _health_event(self, message: str) -> None:
        """Record a degraded-state event (caller holds the lock)."""
        self._events.append((self._clock(), message))

    # -- admission -----------------------------------------------------------
    def _tables_for(self, domain_id: int) -> DomainTables:
        try:
            return self.tables[domain_id]
        except KeyError:
            raise KeyError(
                f"no DomainTables registered for domain_id={domain_id}"
            ) from None

    def _route_container(self, container: Any) -> Tuple[Any, tuple]:
        """Resolve (payload, plan_key) for a decode/transcode admission.

        Raw bytes are admitted as-is under quarantine — routing reads the
        header via :meth:`Container.peek` (O(1), no CRC) and the full
        parse + validation happens at dispatch, where a corrupt payload
        poisons only its own request.  An unparseable *header* still
        fails here, at admission, with the typed
        :class:`~repro.core.container.ContainerFormatError` — same
        contract as :class:`QueueFullError`: typed, immediate, never
        enqueued.  Without quarantine, bytes parse fully at admission.
        """
        if isinstance(container, Container):
            return container, container.plan_key
        if self.config.quarantine:
            hdr = Container.peek(container)
            return container, hdr.plan_key
        parsed = Container.from_bytes(container)
        return parsed, parsed.plan_key

    def submit_decode(
        self,
        container: Union[Container, bytes, bytearray, memoryview],
        *,
        deadline_ms: Optional[float] = None,
    ) -> "Future[np.ndarray]":
        """Admit one container (parsed, or raw wire bytes) for decoding;
        resolves to its float32 signal.  Raises :class:`QueueFullError` /
        :class:`DeadlineExpiredError` / :class:`FrontendClosedError` at
        admission (typed, never silent).  Under ``config.quarantine`` a
        corrupt payload resolves the future to a typed
        :class:`~repro.serving.quarantine.PoisonedContainerError` instead
        of failing its batch-mates."""
        payload, plan_key = self._route_container(container)
        self._tables_for(plan_key[0])  # unroutable fails up front
        key = ("decode", plan_key)
        return self._admit(key, payload, deadline_ms)

    def submit_encode(
        self,
        signal: np.ndarray,
        domain_id: Optional[int] = None,
        *,
        deadline_ms: Optional[float] = None,
    ) -> "Future[Container]":
        """Admit one signal for encoding; resolves to its
        :class:`Container`.  ``domain_id`` defaults to the single
        registered domain (ambiguous with several — pass it)."""
        if domain_id is None:
            if len(self.tables) != 1:
                raise ValueError(
                    "domain_id is required when the front-end serves "
                    f"{len(self.tables)} domains"
                )
            domain_id = next(iter(self.tables))
        tab = self._tables_for(domain_id)
        cfg = tab.config
        key = ("encode", (domain_id, cfg.n, cfg.e, cfg.l_max, cfg.coding))
        return self._admit(key, (signal, domain_id), deadline_ms)

    def submit_transcode(
        self,
        container: Union[Container, bytes, bytearray, memoryview],
        dst_domain_id: int,
        *,
        deadline_ms: Optional[float] = None,
    ) -> "Future[Container]":
        """Admit one container (parsed, or raw wire bytes) for migration
        to ``dst_domain_id``'s tables; resolves to the re-encoded
        :class:`Container`."""
        payload, plan_key = self._route_container(container)
        self._tables_for(plan_key[0])
        self._tables_for(dst_domain_id)
        key = ("transcode", plan_key, dst_domain_id)
        return self._admit(key, (payload, dst_domain_id), deadline_ms)

    def _admit(
        self, key: Hashable, payload: Any, deadline_ms: Optional[float]
    ) -> Future:
        now = self._clock()
        slo = (
            self.config.default_slo_ms if deadline_ms is None
            else float(deadline_ms)
        )
        deadline = now + slo / 1e3
        with self._cond:
            if self._closed:
                raise FrontendClosedError(
                    "front-end is closed; no new admissions"
                )
            if deadline <= now:
                self.stats.rejected_expired += 1
                raise DeadlineExpiredError(key, now - deadline)
            q = self._queues.get(key)
            if q is None:
                q = self._queues[key] = deque()
            depth = len(q)
            if depth >= self.config.max_queue_depth:
                self.stats.shed += 1
                self._health_event(f"request shed (queue {key!r} full)")
                raise QueueFullError(key, depth, self.config.max_queue_depth)
            fut: Future = Future()
            q.append(_Pending(payload, fut, deadline, now))
            self.stats.admitted += 1
            if depth + 1 > self.stats.max_depth:
                self.stats.max_depth = depth + 1
            self._cond.notify_all()
        return fut

    def flush(self) -> None:
        """Force-dispatch everything currently queued, regardless of fill
        or deadlines (a no-op on empty queues).  Returns immediately; wait
        on the submitted futures for completion."""
        with self._cond:
            self._flush_all = True
            self._cond.notify_all()

    # -- the dispatcher ------------------------------------------------------
    def _take_ready(
        self, now: float, force: bool
    ) -> List[Tuple[Hashable, List[_Pending], str]]:
        """Pop every dispatchable micro-batch (caller holds the lock).

        A queue dispatches its oldest ``fill_target`` requests while it
        holds at least that many (reason FILL); once the oldest remaining
        request's ``deadline - flush_slack`` has arrived, whatever is left
        dispatches as one partial batch (reason DEADLINE).  ``force``
        (explicit flush / closing drain) takes everything in
        ``max_batch``-bounded slices — including members still inside a
        retry backoff, so close() never waits one out.  A queue whose head
        is backing off is otherwise skipped whole: retries requeue at the
        head, and dispatching past them would reorder the FIFO.
        """
        slack = self.config.flush_slack_ms / 1e3
        out: List[Tuple[Hashable, List[_Pending], str]] = []
        for key, q in self._queues.items():
            if q and not force and q[0].not_before > now:
                continue  # head is in retry backoff — don't reorder past it
            while len(q) >= self._fill:
                out.append((
                    key, [q.popleft() for _ in range(self._fill)], FILL,
                ))
            retry_due = bool(q) and q[0].attempts > 0 and (
                q[0].not_before <= now
            )  # a retried head redispatches the moment its backoff ends:
            # it was already taken by a fill/deadline/flush trigger once
            if q and (force or retry_due or q[0].deadline - slack <= now):
                batch = []
                while q and len(batch) < self.config.max_batch:
                    batch.append(q.popleft())
                out.append((key, batch, FORCED if force else DEADLINE))
        return out

    def _next_wake(self, now: float) -> Optional[float]:
        """Seconds until the earliest queued dispatch condition (None =
        sleep until notified): deadline-minus-slack, pushed back to the
        head's retry backoff expiry where one is pending."""
        slack = self.config.flush_slack_ms / 1e3
        earliest = None
        for q in self._queues.values():
            if q:
                if len(q) >= self._fill or q[0].attempts > 0:
                    t = q[0].not_before  # dispatch the moment backoff ends
                else:
                    t = max(q[0].deadline - slack, q[0].not_before)
                if earliest is None or t < earliest:
                    earliest = t
        if earliest is None:
            return None
        return max(earliest - now, 0.0)

    def _dispatch_loop(self, my_gen: int) -> None:
        while True:
            try:
                if self._dispatch_once(my_gen):
                    return
            except BaseException as e:  # noqa: BLE001 — keep draining
                # _dispatch_batch contains engine faults; anything landing
                # here is a dispatcher-loop bug.  Log it as a degraded
                # event and keep the loop alive — queues must keep
                # draining (futures of an affected batch were already
                # failed by _dispatch_batch's own handler).
                with self._cond:
                    if self._closed or self._gen != my_gen:
                        return
                    self.stats.dispatcher_restarts += 1
                    self._health_event(
                        f"dispatcher loop crashed and restarted: {e!r}"
                    )

    def _dispatch_once(self, my_gen: int) -> bool:
        """One batch-formation round.  Returns True when this dispatcher
        generation should exit (front-end closed+drained, or the watchdog
        superseded it)."""
        with self._cond:
            while True:
                if self._gen != my_gen:
                    return True  # superseded by a watchdog restart
                force = self._flush_all or self._closed
                self._flush_all = False
                batches = self._take_ready(self._clock(), force)
                if batches:
                    self.stats.batches += len(batches)
                    self._inflight += sum(len(b) for _, b, _ in batches)
                    if self._inflight > self.stats.max_inflight:
                        self.stats.max_inflight = self._inflight
                    for _, members, reason in batches:
                        self.stats.batch_size_sum += len(members)
                        if reason == FILL:
                            self.stats.fill_dispatches += 1
                        elif reason == DEADLINE:
                            self.stats.deadline_dispatches += 1
                        else:
                            self.stats.forced_dispatches += 1
                    break
                if self._closed:
                    return True  # closed and every queue drained
                self._cond.wait(timeout=self._next_wake(self._clock()))
            self._undispatched = list(batches)
            self._undispatched_gen = my_gen
        while True:
            with self._cond:
                if self._gen != my_gen:
                    # superseded mid-list: hand any still-untaken batches
                    # back to their queues (front, order preserved) for
                    # the new generation — never drop a request.  A
                    # watchdog restart usually already requeued them (and
                    # the replacement generation may own the list by now);
                    # this covers a supersede landing between batches.
                    if self._undispatched_gen == my_gen:
                        self._requeue_undispatched_locked()
                    return True
                if not self._undispatched:
                    return False
                key, members, _reason = self._undispatched.pop(0)
            self._dispatch_batch(key, members)

    def _requeue_undispatched_locked(self) -> None:
        """Return taken-but-undispatched batches to their queues (front,
        order preserved).  Caller holds ``self._cond``."""
        for k2, m2, _ in reversed(self._undispatched):
            q = self._queues.setdefault(k2, deque())
            for r in reversed(m2):
                q.appendleft(r)
            self._inflight -= len(m2)
        if self._undispatched:
            # the requeued requests were already due for dispatch (a
            # fill/deadline/flush trigger took them once); re-arm the
            # flush so the next round takes them again instead of
            # sleeping out their deadlines
            self._flush_all = True
        self._undispatched = []
        self._cond.notify_all()

    def _claim(self, token: Dict[str, bool]) -> bool:
        """Atomically claim a batch's completion token.  Exactly one of
        {dispatcher success path, dispatcher failure path, watchdog
        timeout} wins; the losers discard their outcome — this is what
        makes a watchdog-abandoned engine call's eventual return
        harmless."""
        with self._cond:
            if token["done"]:
                return False
            token["done"] = True
            return True

    def _dispatch_batch(
        self, key: Hashable, members: List[_Pending]
    ) -> None:
        """Run one micro-batch through its engine (dispatcher thread: all
        jit tracing happens here) and hand the device-resident result to
        the drain pool.  The whole engine call sits inside a watchdog
        window with a per-batch completion token."""
        kind = key[0]
        quarantine = self.config.quarantine
        token: Dict[str, bool] = {"done": False}
        watch = {
            "token": token, "key": key, "members": members,
            "t0": self._clock(),
        }
        with self._lock:
            if self._scrub_pending:
                # an abandoned dispatch may have submitted members into the
                # engines' buffers without flushing; a stale leftover would
                # splice alien requests into this batch
                self.decoder._pending.take()
                self.encoder._pending.take()
                self.transcoder._pending.take()
                self._scrub_pending = False
            self._watch = watch
        try:
            if self.fault_injector is not None:
                self.fault_injector.on_dispatch(key, members)
            if kind == "decode":
                for r in members:
                    self.decoder.submit(r.payload)
                batch = self.decoder.flush(
                    self.tables, quarantine=quarantine
                )
            elif kind == "encode":
                for r in members:
                    signal, domain_id = r.payload
                    self.encoder.submit(signal, domain_id)
                batch = self.encoder.flush(self.tables, quarantine=quarantine)
            else:  # transcode
                for r in members:
                    container, dst = r.payload
                    self.transcoder.submit(container, dst)
                batch = self.transcoder.flush(
                    self.tables, self.tables, quarantine=quarantine
                )
        except BaseException as e:  # noqa: BLE001 — fate rides the futures
            with self._lock:
                if self._watch is watch:
                    self._watch = None
            self._fail_or_retry(key, members, e, token)
            return
        with self._lock:
            if self._watch is watch:
                self._watch = None
        if not self._claim(token):
            return  # watchdog already failed these members; drop the result
        self._drain_pool.submit(self._drain, key, batch, members)

    def _fail_or_retry(
        self,
        key: Hashable,
        members: List[_Pending],
        error: BaseException,
        token: Optional[Dict[str, bool]] = None,
    ) -> None:
        """Resolve a failed dispatch/drain: requeue transiently-failed
        members that still have retry budget (head of their queue, with
        backoff), fail the rest on their futures."""
        if token is not None and not self._claim(token):
            return  # the watchdog already resolved this batch
        policy = self.config.retry
        with self._lock:
            closed = self._closed
        retry: List[_Pending] = []
        fail: List[_Pending] = []
        if policy.max_retries > 0 and not closed and policy.retryable(error):
            for r in members:
                (retry if r.attempts < policy.max_retries else fail).append(r)
        else:
            fail = list(members)
        if fail:
            if policy.retryable(error):
                # transient fault out of budget: typed give-up, original
                # fault chained
                final: BaseException = DispatchFailedError(
                    key,
                    f"transient fault persisted through "
                    f"{policy.max_retries} retries: {error!r}",
                )
                final.__cause__ = error
            else:
                final = error
            with self._cond:
                if isinstance(final, DispatchFailedError):
                    self.stats.dispatch_failures += len(fail)
                self._health_event(
                    f"dispatch failed for {len(fail)} request(s) on queue "
                    f"{key!r}: {final!r}"
                )
            self._finish(fail, error=final)
        if retry:
            now = self._clock()
            with self._cond:
                q = self._queues.setdefault(key, deque())
                for r in reversed(retry):
                    r.attempts += 1
                    r.not_before = now + policy.backoff_s(r.attempts)
                    q.appendleft(r)
                self._inflight -= len(retry)
                self.stats.retries += len(retry)
                self._cond.notify_all()

    def _drain(
        self, key: Hashable, batch: Any, members: List[_Pending]
    ) -> None:
        """Drain worker: host-materialize one micro-batch and complete its
        futures (overlaps the dispatcher forming the next batch)."""
        try:
            results = batch.to_host()
        except BaseException as e:  # noqa: BLE001
            self._fail_or_retry(key, members, e)
            return
        self._finish(members, results=results)

    def _finish(
        self,
        members: List[_Pending],
        *,
        results: Optional[List[Any]] = None,
        error: Optional[BaseException] = None,
    ) -> None:
        now = self._clock()
        done = failed = misses = poisoned = retry_ok = 0
        for i, r in enumerate(members):
            try:
                if error is not None:
                    r.future.set_exception(error)
                    failed += 1
                elif isinstance(results[i], BaseException):
                    # a quarantined member's typed per-request outcome —
                    # its batch-mates' results are untouched
                    r.future.set_exception(results[i])
                    failed += 1
                    poisoned += 1
                else:
                    r.future.set_result(results[i])
                    done += 1
                    if r.attempts > 0:
                        retry_ok += 1
                    if now > r.deadline:
                        misses += 1
            except Exception:  # future already cancelled by the caller
                pass
        with self._cond:
            self._inflight -= len(members)
            self.stats.completed += done
            self.stats.failed += failed
            self.stats.quarantined += poisoned
            self.stats.retry_successes += retry_ok
            self.stats.deadline_misses += misses
            self._cond.notify_all()

    # -- the watchdog --------------------------------------------------------
    def _watchdog_loop(self) -> None:
        """Bound every engine call: a dispatch older than
        ``watchdog_timeout_ms`` fails its members with a typed
        :class:`DispatchFailedError` and a fresh dispatcher generation
        takes over the queues.  The abandoned thread keeps running its
        stuck call as a daemon; the completion token makes whatever it
        eventually produces inert."""
        timeout = self.config.watchdog_timeout_ms / 1e3
        poll = self.config.watchdog_poll_ms / 1e3
        while not self._wd_stop.wait(poll):
            with self._lock:
                watch = self._watch
            if watch is None:
                continue
            elapsed = self._clock() - watch["t0"]
            if elapsed <= timeout:
                continue
            if not self._claim(watch["token"]):
                continue  # the dispatch completed while we were deciding
            members = watch["members"]
            key = watch["key"]
            err = DispatchFailedError(
                key,
                f"engine call exceeded the watchdog timeout "
                f"({elapsed * 1e3:.0f} ms > "
                f"{self.config.watchdog_timeout_ms:.0f} ms); dispatcher "
                "restarted",
            )
            with self._cond:
                self._gen += 1
                new_gen = self._gen
                self._scrub_pending = True
                if self._watch is watch:
                    self._watch = None
                # free the batches the stuck thread had taken but not yet
                # dispatched: the replacement generation drains them now
                # instead of waiting for the stuck call to return
                self._requeue_undispatched_locked()
                self.stats.watchdog_restarts += 1
                self.stats.dispatch_failures += len(members)
                self._health_event(
                    f"watchdog cut a hung dispatch on queue {key!r} "
                    f"({len(members)} request(s) failed)"
                )
            # watchdog-timeout faults are NOT retried: the payload just
            # demonstrated it can wedge an engine call, and re-running it
            # would wedge the replacement dispatcher too
            self._finish(members, error=err)
            replacement = threading.Thread(
                target=self._dispatch_loop, args=(new_gen,),
                name=f"fptc-frontend-dispatch-g{new_gen}", daemon=True,
            )
            with self._lock:
                self._dispatcher = replacement
            replacement.start()

    # -- shutdown ------------------------------------------------------------
    def close(self, *, drain: bool = True) -> None:
        """Stop the front-end.  ``drain=True`` (default) flushes and
        completes everything already admitted before returning;
        ``drain=False`` fails queued requests with
        :class:`FrontendClosedError` (their futures carry it — still
        never a silent drop)."""
        with self._cond:
            if self._closed:
                self._cond.notify_all()
            else:
                self._closed = True
                if not drain:
                    for q in self._queues.values():
                        while q:
                            r = q.popleft()
                            try:
                                r.future.set_exception(FrontendClosedError(
                                    "front-end closed before this request "
                                    "dispatched"
                                ))
                            except Exception:
                                pass
                            self.stats.failed += 1
                self._cond.notify_all()
        # join whichever dispatcher generation is current — the watchdog
        # may replace a hung dispatcher while we wait, in which case the
        # replacement (not the stuck daemon) owns the closing drain
        while True:
            with self._lock:
                t = self._dispatcher
            t.join(timeout=0.2)
            with self._lock:
                current = self._dispatcher
            if current is not t:
                continue  # superseded mid-join; wait on the replacement
            if not t.is_alive():
                break
        self._wd_stop.set()
        if self._watchdog is not None:
            self._watchdog.join()
        self._drain_pool.shutdown(wait=True)
