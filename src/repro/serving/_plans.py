"""Shared LRU plan cache for the batched serving engines.

The engines memoize device-resident per-(domain, config) state — decode
plans (tables + iDCT basis), encode plans (tables + gap flag), and
transcode plans (a decode/encode plan pair) — keyed by (tables identity,
plan_key, shard device).  Keying by ``id(tables)`` is safe only because
each plan keeps its source :class:`DomainTables` alive (the ``source``
field, or the sub-plans' sources for a :class:`TranscodePlan`), so an id
can never be reused while its cache entry exists.

Shard-aware keys: with multi-device sharding each shard needs its own
device-resident copy of the tables/bases, so the device a plan was built
for is part of the cache key and the factory receives it
(``factory(tables, key, device)``); ``device=None`` is the single-shard
default placement and behaves exactly like the pre-sharding cache.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable, Tuple, TypeVar

Plan = TypeVar("Plan")
PlanKey = Tuple[int, int, int, int]  # (domain_id, n, e, l_max)


@dataclasses.dataclass(frozen=True)
class TranscodePlan:
    """Device-resident state for one (source, target) transcode pairing.

    Pairs the source's :class:`~repro.serving.batch_decode.DecodePlan` and
    the target's :class:`~repro.serving.batch_encode.EncodePlan` under one
    cache key, so a transcode route (archive migration between two
    configs) resolves both halves — device tables, iDCT basis, gap flag —
    in one LRU lookup, and the pairing's lifetime is managed as a unit.
    The sub-plans come from (and stay shared with) the underlying
    decoder's/encoder's own caches, so a Transcoder never duplicates
    device buffers the engines already hold.
    """

    decode: object  # DecodePlan for the source (domain, config)
    encode: object  # EncodePlan for the target (domain, config)
    src_key: PlanKey
    dst_key: PlanKey


class PlanCache:
    """Tiny LRU over plans built by an engine-supplied factory.

    ``tables`` may be a single object or a tuple of objects (the transcode
    pairing); identity keying covers every element.

    ``get`` is thread-safe (a lock around lookup/insert, with the factory
    running OUTSIDE it so hits never stall behind a concurrent build):
    the engines *prefetch* plans from the :class:`~repro.serving.engine.
    PipelineExecutor`'s staging worker — the per-device table/basis
    ``device_put`` of bucket k+1's plan overlaps bucket k's dispatch
    instead of the first dispatch on each shard paying it — so the cache
    is hit from both the worker and the dispatching caller thread.  Plan
    factories only build device arrays (transfers, no jit tracing), which
    keeps the worker inside its transfers-only contract.
    """

    def __init__(self, factory: Callable[..., Plan], maxsize: int = 32):
        self._factory = factory
        self.maxsize = maxsize
        self._plans: "OrderedDict[tuple, Plan]" = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def get(self, tables, key, device: Any = None) -> Plan:
        ident = (
            tuple(id(t) for t in tables)
            if isinstance(tables, tuple) else id(tables)
        )
        cache_key = (ident, key, device)
        with self._lock:
            plan = self._plans.get(cache_key)
            if plan is not None:
                self._plans.move_to_end(cache_key)
                self.hits += 1
                return plan
            self.misses += 1
        # build OUTSIDE the lock: the factory runs device transfers, and a
        # dispatch-thread cache HIT must not stall behind the staging
        # worker's build (that stall is what plan prefetch removes).  Two
        # threads racing the same miss build twice; first insert wins and
        # the duplicate's buffers are dropped — harmless, bytes unaffected.
        plan = self._factory(tables, key, device)
        with self._lock:
            existing = self._plans.get(cache_key)
            if existing is not None:
                self._plans.move_to_end(cache_key)
                return existing
            self._plans[cache_key] = plan
            while len(self._plans) > self.maxsize:
                self._plans.popitem(last=False)
            return plan

    def __len__(self) -> int:
        return len(self._plans)
