"""Shared LRU plan cache for the batched serving engines.

The engines memoize device-resident per-(domain, config) state — decode
plans (tables + iDCT basis), encode plans (tables + gap flag), and
transcode plans (a decode/encode plan pair) — keyed by (tables identity,
plan_key, shard device).  Keying by ``id(tables)`` is safe only because
each plan keeps its source :class:`DomainTables` alive (the ``source``
field, or the sub-plans' sources for a :class:`TranscodePlan`), so an id
can never be reused while its cache entry exists.

Shard-aware keys: with multi-device sharding each shard needs its own
device-resident copy of the tables/bases, so the device a plan was built
for is part of the cache key and the factory receives it
(``factory(tables, key, device)``); ``device=None`` is the single-shard
default placement and behaves exactly like the pre-sharding cache.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable, Tuple, TypeVar

Plan = TypeVar("Plan")
# (domain_id, n, e, l_max, coding) — coding is the container-v3 triple
# (pred_id, predict_bands, zero_planes), (0, 0, False) for v1/v2 streams.
# Plans with different codings trace different bucket math (the coding is a
# static argument of the fused/XLA bucket functions), so it must split the
# cache exactly like the shape parameters do.
PlanKey = Tuple[int, int, int, int, Tuple[int, int, bool]]

TRIVIAL_CODING = (0, 0, False)


def normalize_plan_key(key) -> PlanKey:
    """Accept legacy 4-tuple (domain_id, n, e, l_max) keys by appending the
    trivial coding; 5-tuples pass through.  Keeps pre-v3 callers (and
    archived key literals in tests/benchmarks) valid."""
    key = tuple(key)
    if len(key) == 4:
        return key + (TRIVIAL_CODING,)
    if len(key) != 5:
        raise ValueError(f"malformed plan key {key!r}")
    return key[:4] + (tuple(key[4]),)


@dataclasses.dataclass(frozen=True)
class TranscodePlan:
    """Device-resident state for one (source, target) transcode pairing.

    Pairs the source's :class:`~repro.serving.batch_decode.DecodePlan` and
    the target's :class:`~repro.serving.batch_encode.EncodePlan` under one
    cache key, so a transcode route (archive migration between two
    configs) resolves both halves — device tables, iDCT basis, gap flag —
    in one LRU lookup, and the pairing's lifetime is managed as a unit.
    The sub-plans come from (and stay shared with) the underlying
    decoder's/encoder's own caches, so a Transcoder never duplicates
    device buffers the engines already hold.
    """

    decode: object  # DecodePlan for the source (domain, config)
    encode: object  # EncodePlan for the target (domain, config)
    src_key: PlanKey
    dst_key: PlanKey


class PlanCache:
    """Tiny LRU over plans built by an engine-supplied factory.

    ``tables`` may be a single object or a tuple of objects (the transcode
    pairing); identity keying covers every element.

    ``get`` is thread-safe and **single-flight per key**: the factory runs
    OUTSIDE the cache lock (so a hit never stalls behind a concurrent
    build of a *different* key), but concurrent misses on the SAME key
    coalesce — the first caller builds, later callers wait on that build
    and share its plan.  The engines *prefetch* plans from the
    :class:`~repro.serving.engine.PipelineExecutor`'s staging worker, and
    a serving front-end may warm plans from several admission threads at
    once; without coalescing, every racer would ``device_put`` its own
    copy of the tables/bases and all but one set of device buffers would
    be built just to be dropped.  Plan factories only build device arrays
    (transfers, no jit tracing), which keeps the worker inside its
    transfers-only contract.  A failed build clears its in-flight marker
    and re-raises; coalesced waiters then retry the build themselves (the
    failure may have been the leader's alone).
    """

    def __init__(self, factory: Callable[..., Plan], maxsize: int = 32):
        self._factory = factory
        self.maxsize = maxsize
        self._plans: "OrderedDict[tuple, Plan]" = OrderedDict()
        self._building: dict = {}  # cache_key -> threading.Event
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.coalesced = 0  # gets served by waiting on another thread's build

    def get(self, tables, key, device: Any = None) -> Plan:
        ident = (
            tuple(id(t) for t in tables)
            if isinstance(tables, tuple) else id(tables)
        )
        cache_key = (ident, key, device)
        waited = False
        while True:
            with self._lock:
                plan = self._plans.get(cache_key)
                if plan is not None:
                    self._plans.move_to_end(cache_key)
                    if not waited:  # a coalesced get counts once, as coalesced
                        self.hits += 1
                    return plan
                done = self._building.get(cache_key)
                if done is None:
                    # we are the build leader for this key
                    done = self._building[cache_key] = threading.Event()
                    self.misses += 1
                    break
                # same-key build in flight: wait for it, then re-check
                if not waited:
                    self.coalesced += 1
            waited = True
            done.wait()
        try:
            plan = self._factory(tables, key, device)
        except BaseException:
            with self._lock:
                self._building.pop(cache_key, None)
            done.set()  # wake waiters; they retry and surface their own error
            raise
        with self._lock:
            self._plans[cache_key] = plan
            self._building.pop(cache_key, None)
            while len(self._plans) > self.maxsize:
                self._plans.popitem(last=False)
        done.set()
        return plan

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)
