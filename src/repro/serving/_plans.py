"""Shared LRU plan cache for the batched serving engines.

Both engines memoize device-resident per-(domain, config) state — decode
plans (tables + iDCT basis) and encode plans (tables + gap flag) — keyed by
(tables identity, plan_key).  Keying by ``id(tables)`` is safe only because
each plan keeps its source :class:`DomainTables` alive (the ``source``
field), so an id can never be reused while its cache entry exists.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Tuple, TypeVar

Plan = TypeVar("Plan")
PlanKey = Tuple[int, int, int, int]  # (domain_id, n, e, l_max)


class PlanCache:
    """Tiny LRU over plans built by an engine-supplied factory."""

    def __init__(self, factory: Callable[..., Plan], maxsize: int = 32):
        self._factory = factory
        self.maxsize = maxsize
        self._plans: "OrderedDict[Tuple[int, PlanKey], Plan]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, tables, key: PlanKey) -> Plan:
        cache_key = (id(tables), key)
        plan = self._plans.get(cache_key)
        if plan is not None:
            self._plans.move_to_end(cache_key)
            self.hits += 1
            return plan
        self.misses += 1
        plan = self._factory(tables, key)
        self._plans[cache_key] = plan
        while len(self._plans) > self.maxsize:
            self._plans.popitem(last=False)
        return plan

    def __len__(self) -> int:
        return len(self._plans)
