"""Shared serving-engine layer: bucket scheduling, pipelined execution,
multi-device sharding.

PR 1-3 grew three engines (batched decode, batched encode, device-resident
transcode) that each re-implemented the same machinery: group work items by
(domain, config) key, pad shapes to power-of-two buckets, loop bucket ->
host stage -> h2d upload -> fused jit dispatch, then drain once.  This
module owns that machinery so the engines are thin *stage definitions*:

  * :class:`BucketScheduler` — grouping (first-appearance key order),
    power-of-two / symlen-slot bucket rounding, and shard assignment: with
    more than one visible device, each key group's members split into
    contiguous per-device shards (per-signal streams are independent, so
    sharding the batch axis is embarrassingly parallel — no collectives,
    just per-shard placement).
  * :class:`PipelineExecutor` — runs per-bucket work as stage(upload) ->
    stage(dispatch) with double buffering: a single staging worker runs
    host staging + h2d upload of bucket k+1 while the main thread
    dispatches bucket k (XLA dispatch is async, so device compute of
    bucket k overlaps both).  ``fetch_to_host`` is the drain-side twin: it
    starts every bucket's d2h copy before materializing any of them, so
    drains overlap each other and any still-running dispatch.
  * :class:`GatherStage` — the device-staging contract: an encode bucket's
    signal matrix materializes *inside* the bucket's fused dispatch as a
    batched ``dynamic_slice`` gather out of decoded window tensors
    (optionally donating the source buffer on its last use).

Pipelining and sharding change *when* and *where* buckets run — never what
bytes they produce: bucket padding is invisible to decoded samples and
per-row packing, dispatch order is deterministic, and the synchronous
single-device path is the degenerate case (one shard, no prefetch).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import ThreadPoolExecutor
from typing import (
    Any,
    Callable,
    Dict,
    Hashable,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import jax
import numpy as np

from repro.tuning.policy import BucketPolicy, PolicyArg

__all__ = [
    "MAX_SYMLEN_CAP",
    "p2",
    "symlen_bucket",
    "serving_devices",
    "default_use_kernels",
    "putter",
    "Bucket",
    "BucketScheduler",
    "PipelineExecutor",
    "ExecutorStats",
    "GatherStage",
    "SubmitBuffer",
    "fetch_to_host",
    "fetch_to_host_stitched",
]

MAX_SYMLEN_CAP = 64  # a 64-bit word holds at most 64 one-bit codes

DevicesArg = Union[None, str, Sequence[Any]]


def p2(x: int) -> int:
    """Next power of two (>= 1) — the bucket rounding."""
    return 1 << max(int(x) - 1, 0).bit_length()


def symlen_bucket(x: int) -> int:
    """Round the slot-loop trip count up to a multiple of 8 (cap 64).

    The decode cost is linear in this number, so power-of-two rounding would
    waste up to 2x slot iterations (e.g. 33 -> 64); multiples of 8 bound the
    waste at <8 slots while keeping specializations to at most 8 variants.
    """
    return min(-(-max(int(x), 1) // 8) * 8, MAX_SYMLEN_CAP)


def default_use_kernels() -> bool:
    """Process-wide default for the engines' ``use_kernels`` stage toggle.

    Engines constructed with ``use_kernels=None`` resolve it here, so one
    environment variable flips every default-constructed engine (and the
    ``codec.*_device`` batch-of-one wrappers) onto the fused Pallas kernel
    path — how the ``kernels-interpret`` CI leg re-runs the whole
    engine/conformance/property surface against the kernels:

        FPTC_USE_KERNELS=1 pytest ...

    The kernel path is bit-identical to the XLA path by construction, so
    the toggle changes which device programs run — never bytes.
    """
    return os.environ.get("FPTC_USE_KERNELS", "").strip().lower() in (
        "1", "true", "yes", "on"
    )


def serving_devices(devices: DevicesArg = "auto") -> Tuple[Any, ...]:
    """Resolve a devices argument to the tuple the scheduler shards over.

    ``None`` — single-shard, default placement (arrays stay uncommitted;
    exactly the pre-sharding engine behavior).  ``"auto"`` — one shard per
    visible local device when there is more than one, else the single-shard
    default; shard 0 keeps *default* placement (None) so small/batch-of-one
    work stays uncommitted and honors ``jax.default_device`` instead of
    silently occupying device 0, while shards 1..n-1 commit to the
    remaining local devices.  An explicit sequence pins every shard to
    those devices (arrays are committed to them).
    """
    if devices is None:
        return (None,)
    if devices == "auto":
        local = jax.local_devices()
        return (None, *local[1:]) if len(local) > 1 else (None,)
    devs = tuple(devices)
    if not devs:
        raise ValueError("devices must be None, 'auto', or a non-empty "
                         "sequence of jax devices")
    return devs


def putter(device: Any) -> Callable[[Any], Any]:
    """The engines' one placement idiom: uncommitted default-device upload
    when ``device`` is None (the single-shard behavior), committed
    ``jax.device_put`` onto the shard's device otherwise."""
    if device is None:
        import jax.numpy as jnp

        return jnp.asarray
    return lambda x: jax.device_put(x, device)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One schedulable unit of engine work: the members of one key group
    assigned to one shard.  ``items`` are caller-side indices in input
    order; ``device`` is None for default placement (single-shard mode)."""

    key: Hashable
    shard: int
    device: Any
    items: Tuple[int, ...]


def member_positions(buckets: Sequence[Bucket], count: int) -> List[int]:
    """Per original index, its position in the buckets' flattened member
    order — what restores caller order after a bucket-ordered drain."""
    pos = [0] * count
    i = 0
    for b in buckets:
        for item in b.items:
            pos[item] = i
            i += 1
    return pos


class BucketScheduler:
    """Owns grouping, shard assignment and bucket rounding for the engines.

    Grouping preserves first-appearance key order with members in input
    order inside each group — the contract every engine (and the caller
    order restoration built on :func:`member_positions`) relies on.  With
    ``num_shards > 1`` each group's members additionally split into
    contiguous per-device shards, so one fused dispatch per (key, shard)
    runs on its own device and the per-shard results stay device-resident
    until the single drain.

    ``policy`` picks the bucket-edge ladder every traced axis rounds with
    (:meth:`round`): a :class:`~repro.tuning.policy.BucketPolicy`, a name
    (``"p2"`` / ``"half-octave"`` / ``"cost-balanced"``), or None for the
    ``FPTC_BUCKET_POLICY`` env default (``p2`` — the historical rounding).
    Policies trade padding waste against jit-specialization count and
    never change produced bytes.
    """

    def __init__(self, devices: DevicesArg = "auto",
                 policy: PolicyArg = None):
        self.devices = serving_devices(devices)
        self.policy = BucketPolicy.of(policy)

    def round(self, x: int) -> int:
        """Bucket-edge rounding for a traced axis under this scheduler's
        policy (the old hard-coded ``p2(x)`` when policy is ``p2``)."""
        return self.policy.round(max(int(x), 1))

    @property
    def num_shards(self) -> int:
        return len(self.devices)

    def device_of(self, shard: int) -> Any:
        return self.devices[shard]

    @staticmethod
    def group_by(keys: Sequence[Hashable]) -> Tuple[
        List[Hashable], Dict[Hashable, List[int]]
    ]:
        """Group indices by key: (first-appearance key order, key->indices
        in input order) — the one grouping loop all engines share."""
        order: List[Hashable] = []
        groups: "OrderedDict[Hashable, List[int]]" = OrderedDict()
        for i, key in enumerate(keys):
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(i)
        return order, groups

    def buckets(
        self,
        keys: Sequence[Hashable],
        shard_ids: Optional[Sequence[int]] = None,
        shard_devices: Optional[Dict[int, Any]] = None,
        item_costs: Optional[Sequence[float]] = None,
    ) -> List[Bucket]:
        """Schedule items into (key, shard) buckets.

        Without ``shard_ids``, each key group's members split into
        ``min(len(group), num_shards)`` contiguous per-device shards
        placed on this scheduler's devices, with the starting shard
        rotating across groups — an archive of many small (domain,
        config) groups still spreads over every device instead of piling
        onto shard 0.  The split is equal-count unless ``item_costs``
        gives a predicted cost per item (one float per key, any units —
        e.g. :meth:`repro.tuning.cost_model.CostModel.signal_decode_cost`),
        in which case each group partitions contiguously at
        cost-balanced boundaries instead: mixed archives where one
        signal decodes 100x slower than another stop making every other
        device wait on the heavy shard.  Splits stay contiguous either
        way, so member order (and hence bytes) never changes.
        With ``shard_ids`` (one per item — a
        *pinning*, e.g. the transcode pipeline keeping a signal's
        re-encode on the device that decoded it), members partition by
        their given shard instead, ascending shard order, relative order
        preserved; ``shard_devices`` then maps those shard ids to devices
        (required whenever the pinned ids come from a different scheduler
        — the data's placement wins over this scheduler's own device
        tuple).
        """
        order, groups = self.group_by(keys)
        out: List[Bucket] = []
        next_shard = 0  # rotating start keeps small groups off shard 0
        for key in order:
            idxs = groups[key]
            if shard_ids is None:
                if item_costs is not None and self.num_shards > 1:
                    parts = _split_balanced(
                        idxs, [float(item_costs[i]) for i in idxs],
                        self.num_shards,
                    )
                else:
                    parts = _split_contiguous(idxs, self.num_shards)
                shards = [
                    (next_shard + j) % self.num_shards
                    for j in range(len(parts))
                ]
                next_shard = (next_shard + len(parts)) % self.num_shards
            else:
                by_shard: "OrderedDict[int, List[int]]" = OrderedDict()
                for i in idxs:
                    by_shard.setdefault(int(shard_ids[i]), []).append(i)
                shards = sorted(by_shard)
                parts = [by_shard[s] for s in shards]
            for shard, part in zip(shards, parts):
                if shard_devices is not None:
                    device = shard_devices[shard]
                elif shard < len(self.devices):
                    device = self.devices[shard]
                else:
                    raise ValueError(
                        f"pinned shard id {shard} has no device: this "
                        f"scheduler holds {self.num_shards} shard(s) — "
                        "pass shard_devices when shard_ids come from "
                        "another scheduler"
                    )
                out.append(Bucket(
                    key=key,
                    shard=shard,
                    device=device,
                    items=tuple(part),
                ))
        return out


def _split_contiguous(items: List[int], num_shards: int) -> List[List[int]]:
    k = min(len(items), max(num_shards, 1))
    if k <= 1:
        return [list(items)]
    q, r = divmod(len(items), k)
    out, off = [], 0
    for s in range(k):
        size = q + (1 if s < r else 0)
        out.append(items[off:off + size])
        off += size
    return out


def _split_balanced(
    items: List[int], costs: List[float], num_shards: int
) -> List[List[int]]:
    """Contiguous partition of ``items`` into <= ``num_shards`` parts with
    near-equal predicted cost: greedily close part ``s`` once its running
    cost reaches the ideal boundary ``total * (s+1) / k``.  Equal costs
    give the same +-1 size balance as the equal-count split (remainder
    items may land on different parts); contiguity keeps member (and
    byte) order identical to the unweighted path."""
    k = min(len(items), max(num_shards, 1))
    total = sum(costs)
    if k <= 1 or not (total > 0.0):
        return _split_contiguous(items, num_shards)
    out: List[List[int]] = []
    part: List[int] = []
    acc = 0.0
    s = 0
    for j, (item, cost) in enumerate(zip(items, costs)):
        part.append(item)
        acc += cost
        remaining_items = len(items) - (j + 1)
        remaining_parts = k - (s + 1)
        if remaining_parts <= 0:
            continue
        # close this part at its ideal cost boundary, or when the leftover
        # items are only just enough to make every remaining part non-empty
        if acc >= total * (s + 1) / k or remaining_items <= remaining_parts:
            out.append(part)
            part = []
            s += 1
    if part:
        out.append(part)
    return out


# ---------------------------------------------------------------------------
# The staging contract for device-resident encode staging.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class GatherStage:
    """Stage an encode bucket by gathering rows INSIDE the fused dispatch.

    ``flat`` is a flattened device tensor of decoded samples carrying
    enough trailing zeros that every ``dynamic_slice`` of the bucket width
    stays in bounds; row ``r`` of the bucket covers samples
    ``[starts[r], starts[r] + lens[r])`` and is exact-zero beyond
    ``lens[r]``.  ``donate`` marks the bucket as ``flat``'s last consumer,
    letting XLA reuse the buffer for the bucket's outputs (ignored on
    backends without donation support, e.g. CPU).
    """

    flat: Any  # f32[T + width] device array
    starts: Any  # int32[K]
    lens: Any  # int32[K]
    donate: bool = False


# ---------------------------------------------------------------------------
# The incremental submission surface shared by the engines.
# ---------------------------------------------------------------------------
class SubmitBuffer:
    """Thread-safe pending-work buffer behind the engines' ``submit`` /
    ``flush`` surface.

    The batch engines historically assumed batch-at-once staging: callers
    hand ``decode``/``encode``/``transcode`` a fully formed sequence.  A
    serving front-end forms batches *incrementally* — requests trickle in
    from admission threads, and the batch only exists when the
    micro-batcher decides to flush.  ``submit`` appends one work item (any
    thread) and returns its index in flush order; ``take`` atomically
    claims everything pending (the flushing thread's move).  The buffer
    carries items only — deadlines, shedding and queue bounds are the
    front-end's admission policy (:mod:`repro.serving.frontend`), not the
    engines'.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._items: List[Any] = []

    def submit(self, item: Any) -> int:
        """Append one pending item; returns its index in the next flush."""
        with self._lock:
            self._items.append(item)
            return len(self._items) - 1

    def take(self) -> List[Any]:
        """Atomically claim (and clear) everything pending, in order."""
        with self._lock:
            items, self._items = self._items, []
            return items

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)


# ---------------------------------------------------------------------------
# The pipelined executor.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ExecutorStats:
    runs: int = 0
    buckets: int = 0
    pipelined_buckets: int = 0  # buckets whose upload ran on the worker
    upload_s: float = 0.0  # host staging + h2d time (worker or inline)
    dispatch_s: float = 0.0  # main-thread dispatch time (async: excludes
    # device compute that overlaps later stages)
    max_inflight: int = 0  # peak buckets simultaneously staged/dispatching


class PipelineExecutor:
    """Runs bucket work as stage(upload) -> stage(dispatch), double-buffered.

    Work items are opaque to the executor (engines pass
    :class:`Bucket`\\ s, ``decode_streams`` passes its stream groups):
    ``upload(item)`` does the host staging and h2d transfer for one
    bucket; ``dispatch(item, staged)`` launches its fused device work.
    With ``pipeline=True`` and more than one bucket, a single staging
    worker keeps up to ``prefetch`` uploads in flight ahead of the main
    thread's dispatches — host staging and h2d upload of bucket k+1
    overlap device compute of bucket k (dispatch itself is async, so d2h
    drains issued later overlap the remaining dispatches too).  Dispatch
    order is always bucket order and every bucket sees exactly the same
    staged inputs, so the pipelined path is byte-identical to the serial
    one by construction.

    The worker thread performs transfers but never traces: jit tracing,
    plan-cache access and dispatch stay on the calling thread.
    """

    def __init__(self, *, pipeline: bool = True, prefetch: int = 2):
        if prefetch < 1:
            raise ValueError(f"prefetch must be >= 1, got {prefetch}")
        self.pipeline = pipeline
        self.prefetch = prefetch
        self.stats = ExecutorStats()
        self._pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.Lock()
        self._inflight = 0

    @property
    def inflight(self) -> int:
        """Buckets currently staged or dispatching (in-flight accounting
        for the serving front-end's load reporting; 0 between runs)."""
        with self._lock:
            return self._inflight

    def _inflight_add(self, delta: int) -> None:
        with self._lock:
            self._inflight += delta
            if self._inflight > self.stats.max_inflight:
                self.stats.max_inflight = self._inflight

    def _worker(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="fptc-stage"
                )
        return self._pool

    def run(
        self,
        work: Sequence[Any],
        upload: Callable[[Any], Any],
        dispatch: Callable[[Any, Any], Any],
    ) -> List[Any]:
        n = len(work)
        self.stats.runs += 1
        self.stats.buckets += n
        if n == 0:
            return []

        def timed_upload(b: Any) -> Any:
            t0 = time.perf_counter()
            try:
                return upload(b)
            finally:
                self.stats.upload_s += time.perf_counter() - t0

        def timed_dispatch(b: Any, staged: Any) -> Any:
            t0 = time.perf_counter()
            try:
                return dispatch(b, staged)
            finally:
                self.stats.dispatch_s += time.perf_counter() - t0
                self._inflight_add(-1)

        if not self.pipeline or n == 1:
            out = []
            for b in work:
                self._inflight_add(1)
                try:
                    staged = timed_upload(b)
                except BaseException:
                    self._inflight_add(-1)
                    raise
                out.append(timed_dispatch(b, staged))
            return out

        pool = self._worker()
        results: List[Any] = [None] * n
        pending: "deque[Tuple[int, Any, Any]]" = deque()

        def pop_dispatch() -> None:
            j, bj, fut = pending.popleft()
            try:
                staged = fut.result()
            except BaseException:
                self._inflight_add(-1)
                raise
            results[j] = timed_dispatch(bj, staged)

        try:
            for i, b in enumerate(work):
                self._inflight_add(1)
                pending.append((i, b, pool.submit(timed_upload, b)))
                self.stats.pipelined_buckets += 1
                if len(pending) > self.prefetch:
                    pop_dispatch()
            while pending:
                pop_dispatch()
        finally:
            # on error, drain leftover staging futures so their (harmless)
            # transfers don't outlive the arrays they close over.
            # cancel() is a no-op on an already-RUNNING future — the
            # staging worker must be JOINED, not abandoned, or its
            # in-flight upload (possibly holding donated buffers) outlives
            # this call and the next run() races it on the 1-thread pool
            while pending:
                _, _, fut = pending.popleft()
                if not fut.cancel():
                    try:
                        fut.result()
                    except BaseException:
                        pass  # the primary exception is already in flight
                self._inflight_add(-1)
        return results


def fetch_to_host(arrays: Sequence[Any]) -> List[np.ndarray]:
    """Drain device arrays: start EVERY d2h copy before materializing any.

    ``np.asarray`` per array serializes transfer-and-wait; issuing all
    ``copy_to_host_async`` first lets the copies overlap each other and any
    still-executing dispatches — the drain-side half of the double buffer.
    """
    for a in arrays:
        start = getattr(a, "copy_to_host_async", None)
        if start is not None:
            start()
    return [np.asarray(a) for a in arrays]


def fetch_to_host_stitched(
    bucket_arrays: Sequence[Sequence[Any]],
    stitch: Callable[[int, List[np.ndarray]], Any],
) -> List[Any]:
    """Drain per-bucket device arrays and overlap the host-side stitch.

    The drain-side double buffer, extended into the numpy post-processing:
    every bucket's d2h copies start up front (as :func:`fetch_to_host`),
    then the main thread materializes bucket ``k+1``'s arrays while a
    single worker runs ``stitch(k, host_arrays)`` — so the per-signal
    chunk-run concatenation of bucket ``k`` happens while bucket ``k+1``'s
    copies land, instead of serializing all transfers before the first
    stitch.  Results come back in bucket order; a stitch exception
    propagates to the caller (remaining stitches are abandoned with the
    pool).
    """
    for arrays in bucket_arrays:
        for a in arrays:
            start = getattr(a, "copy_to_host_async", None)
            if start is not None:
                start()
    if not bucket_arrays:
        return []
    if len(bucket_arrays) == 1:
        return [stitch(0, [np.asarray(a) for a in bucket_arrays[0]])]
    with ThreadPoolExecutor(
        max_workers=1, thread_name_prefix="fptc-stitch"
    ) as pool:
        futures = []
        for b, arrays in enumerate(bucket_arrays):
            host = [np.asarray(a) for a in arrays]  # waits on bucket b only
            futures.append(pool.submit(stitch, b, host))
        return [f.result() for f in futures]
