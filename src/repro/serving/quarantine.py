"""Per-request poison quarantine: the serving fault taxonomy.

FPTC's asymmetry puts the server on the receiving end of containers
produced by flaky low-power encoders over lossy links.  Offline, a corrupt
blob raising out of ``decode()`` is the right call — the caller owns the
batch.  In serving, one poisoned container must never take down the
co-bucketed requests that happened to share its micro-batch: the engines'
``quarantine=True`` mode excludes the poisoned signal from its bucket (the
rest of the batch completes **byte-identically** to a clean run — per-signal
streams are independent, so exclusion changes padding only) and the drain
returns a typed per-signal outcome instead of raising batch-wide.

This module owns that outcome type (:class:`PoisonedContainerError`), the
fault-class vocabulary (wire-format faults re-exported from
:mod:`repro.core.container`, plus the engine-level classes below), and the
deep validation pass that runs at staging:

  * wire-format parse — :meth:`Container.from_bytes` (magic / version /
    reserved flags / truncation / CRC / max_symlen), all typed with byte
    offsets;
  * **header consistency** — the common header is NOT covered by the CRC
    (the payload checksum must not change when only metadata is rewritten),
    so CRC-blind header flips are caught structurally: ``num_windows`` must
    equal ``ceil(signal_length / n)``, ``num_symbols`` must match the
    window grid (minus zero-plane suppression for v3);
  * **sidecar consistency** — ``sum(symlen) == num_symbols`` ties the
    CRC-covered sidecar to the CRC-blind header count;
  * **plan routing** — unknown ``domain_id`` or a container/tables config
    mismatch (``core.codec.validate_container_tables``).

The device-side histogram-gap flag (an encode-time fault) rides the same
taxonomy: ``EncodedBatch`` drains demote it from batch-fatal to per-signal
under ``quarantine=True``.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

from repro.core.calibration import DomainTables
from repro.core.codec import validate_container_tables
from repro.core.container import (
    FAULT_BAD_MAGIC,
    FAULT_BAD_VERSION,
    FAULT_CRC_MISMATCH,
    FAULT_HEADER_MISMATCH,
    FAULT_RESERVED_FLAGS,
    FAULT_TRUNCATED,
    Container,
    ContainerFormatError,
)

__all__ = [
    "PoisonedContainerError",
    "FAULT_BAD_MAGIC",
    "FAULT_BAD_VERSION",
    "FAULT_CRC_MISMATCH",
    "FAULT_HEADER_MISMATCH",
    "FAULT_RESERVED_FLAGS",
    "FAULT_TRUNCATED",
    "FAULT_SIDECAR_MISMATCH",
    "FAULT_PLAN_MISMATCH",
    "FAULT_UNROUTABLE",
    "FAULT_HISTOGRAM_GAP",
    "FAULT_UNKNOWN",
    "classify_fault",
    "validate_container",
    "validate_or_poison",
]

# Engine-level fault classes (wire-format classes come from core.container).
FAULT_SIDECAR_MISMATCH = "sidecar-mismatch"
FAULT_PLAN_MISMATCH = "plan-mismatch"
FAULT_UNROUTABLE = "unroutable"
FAULT_HISTOGRAM_GAP = "histogram-gap"
FAULT_UNKNOWN = "unknown"


class PoisonedContainerError(Exception):
    """One signal's typed per-request outcome after quarantine.

    Carries the quarantine record the serving layer logs and returns:
    ``index`` (the signal's position in its submitted batch), ``fault``
    (one of the ``FAULT_*`` classes) and ``offset`` (the byte offset of
    the offending field, where the wire-format parse knows one).  Raised
    per-signal — never batch-wide — by the engines' ``quarantine=True``
    drains and delivered through the frontend's per-request futures.
    """

    def __init__(
        self,
        message: str,
        *,
        index: Optional[int] = None,
        fault: str = FAULT_UNKNOWN,
        offset: Optional[int] = None,
    ):
        super().__init__(message)
        self.index = index
        self.fault = fault
        self.offset = offset

    def __str__(self) -> str:
        where = []
        if self.index is not None:
            where.append(f"container[{self.index}]")
        if self.offset is not None:
            where.append(f"byte offset {self.offset}")
        loc = f" ({', '.join(where)})" if where else ""
        return f"[{self.fault}] {self.args[0]}{loc}"

    @classmethod
    def wrap(
        cls, exc: BaseException, index: Optional[int] = None
    ) -> "PoisonedContainerError":
        """Build the per-request outcome from a validation exception,
        preserving its fault class / offset when it carries one."""
        if isinstance(exc, PoisonedContainerError):
            if exc.index is None and index is not None:
                exc.index = index
            return exc
        fault = classify_fault(exc)
        offset = getattr(exc, "offset", None)
        if index is None:
            index = getattr(exc, "index", None)
        # ContainerFormatError decorates __str__ with the same fault/index/
        # offset this class renders; use its bare message to avoid printing
        # the quarantine record twice
        if isinstance(exc, ContainerFormatError) and exc.args:
            message = str(exc.args[0])
        else:
            message = str(exc)
        err = cls(message, index=index, fault=fault, offset=offset)
        err.__cause__ = exc
        return err


def classify_fault(exc: BaseException) -> str:
    """Map a validation exception onto the fault-class vocabulary."""
    fault = getattr(exc, "fault", None)
    if fault is not None:
        return fault
    if isinstance(exc, KeyError):
        return FAULT_UNROUTABLE
    if isinstance(exc, ValueError):
        msg = str(exc)
        if "plan_key" in msg or "does not match" in msg:
            return FAULT_PLAN_MISMATCH
        if "histogram gap" in msg or "no codeword" in msg:
            return FAULT_HISTOGRAM_GAP
    return FAULT_UNKNOWN


def _lookup_tables(container: Container, tables) -> DomainTables:
    if isinstance(tables, DomainTables):
        return tables
    try:
        return tables[container.domain_id]
    except KeyError:
        raise PoisonedContainerError(
            f"no DomainTables registered for "
            f"domain_id={container.domain_id}",
            fault=FAULT_UNROUTABLE,
        ) from None


def validate_container(
    container: Container,
    tables: Union[DomainTables, dict, None] = None,
    *,
    index: Optional[int] = None,
) -> None:
    """Deep (engine-level) validation of an already-parsed container.

    ``from_bytes`` catches everything the CRC covers; the CRC deliberately
    does NOT cover the header, so this pass ties the header's CRC-blind
    counts to each other and to the CRC-covered sidecar, then checks the
    container/tables pairing.  Raises :class:`PoisonedContainerError`.
    """

    def _poison(message: str, fault: str) -> None:
        raise PoisonedContainerError(message, index=index, fault=fault)

    n, e = container.n, container.e
    if n <= 0 or e <= 0 or e > n:
        _poison(
            f"header config (n={n}, e={e}) is not a valid window shape",
            FAULT_HEADER_MISMATCH,
        )
    want_windows = -(-container.signal_length // n)
    if container.num_windows != want_windows:
        _poison(
            f"header num_windows={container.num_windows} does not cover "
            f"signal_length={container.signal_length} at n={n} "
            f"(want {want_windows})",
            FAULT_HEADER_MISMATCH,
        )
    if container.zero_planes:
        kept_rows = container.num_windows - int(container.zrow.sum())
        kept_cols = e - int(container.zcol.sum())
        want_symbols = kept_rows * kept_cols
    else:
        want_symbols = container.num_windows * e
    if container.num_symbols != want_symbols:
        _poison(
            f"header num_symbols={container.num_symbols} does not match "
            f"the window grid (want {want_symbols})",
            FAULT_HEADER_MISMATCH,
        )
    if int(container.symlen.sum()) != container.num_symbols:
        _poison(
            f"symlen sidecar sums to {int(container.symlen.sum())} "
            f"symbols but the header promises {container.num_symbols}",
            FAULT_SIDECAR_MISMATCH,
        )
    if tables is not None:
        tab = _lookup_tables(container, tables)
        try:
            validate_container_tables(container.plan_key, tab)
        except ValueError as exc:
            raise PoisonedContainerError(
                str(exc), index=index, fault=FAULT_PLAN_MISMATCH
            ) from exc


def validate_or_poison(
    item, index: int, tables=None
) -> Tuple[Optional[Container], Optional[PoisonedContainerError]]:
    """The quarantine staging pre-pass for one batch slot.

    ``item`` is raw bytes (any bytes-like) or an already-parsed
    :class:`Container`.  Returns ``(container, None)`` when it survives the
    full wire-format + deep validation against ``tables``, else
    ``(None, error)`` with the typed per-request outcome — never raises.
    """
    try:
        if isinstance(item, Container):
            container = item
        else:
            container = Container.from_bytes(item, index=index)
        validate_container(container, tables, index=index)
        return container, None
    except Exception as exc:  # noqa: BLE001 — every fault becomes typed
        return None, PoisonedContainerError.wrap(exc, index)
