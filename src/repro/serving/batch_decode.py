"""Batched bucketed decode engine: one fused dispatch for N containers.

The paper's asymmetry argument is about *server-side batch* decompression
throughput, but a per-container ``decode_device`` loop pays three taxes the
GPU codecs it compares against (GPU-Huffman, cuSZ+) never do:

  1. **recompilation** — seven container-specific static argnames mean XLA
     retraces for nearly every container in a heterogeneous archive;
  2. **table re-upload** — codebook + quant tables travel host->device per
     call;
  3. **host sync** — ``np.asarray`` blocks on every container.

This module removes all three:

  * **Shape bucketing.**  A batch's streams are concatenated and padded to
    power-of-two word/window/symlen-slot counts, so jit specializations are
    O(log sizes) instead of O(containers).  The formerly-static per-container
    quantities (word offsets, symbol counts, signal lengths) are either
    device arrays (the symlen sidecar drives all offsets) or host-side slice
    metadata — never trace constants.
  * **Concatenated-stream decode.**  SymLen words decode independently, so a
    whole batch is one word axis: the Pallas grid (or the XLA lane loop)
    sweeps every container in one dispatch, and compaction is a
    segment-aware scatter over one exclusive prefix-sum of the concatenated
    symlen sidecar (``core.symlen.compact_padded_scatter``) — container
    boundaries fall out of the segment sums for free.
  * **Persistent decode plans.**  Device tables and the iDCT basis upload
    once per (domain, config, shard device) into an LRU :class:`DecodePlan`
    cache; decoded samples stay on device inside a :class:`DecodedBatch`
    until an explicit ``.to_host()`` drains them.

Scheduling, double-buffered pipelining and multi-device sharding live in
the shared :mod:`repro.serving.engine` layer: host staging + h2d upload of
bucket k+1 overlap device compute of bucket k, and with several visible
devices each (domain, config) group's containers split into per-device
shards (streams are per-signal independent, so sharding is embarrassingly
parallel).  Neither changes the produced bytes — padding is invisible to
decoded samples and dispatch order is deterministic.

``core.codec.decode_device`` is a batch-of-one wrapper over this engine, so
every existing caller rides the same path.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import (
    Any,
    Callable,
    Dict,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dct, symlen
from repro.core.calibration import DeviceTables, DomainTables
from repro.core.codec import validate_container_tables
from repro.core.container import Container
from repro.core.quantize import (
    expand_coded_stream,
    quant_grid,
    unpredict_levels,
)
from repro.serving._plans import (
    TRIVIAL_CODING,
    PlanCache,
    normalize_plan_key,
)
from repro.serving.engine import (
    BucketScheduler,
    DevicesArg,
    PipelineExecutor,
    SubmitBuffer,
    default_use_kernels,
    fetch_to_host,
    member_positions,
    p2,
    putter,
    symlen_bucket,
)
from repro.tuning import autotune as _autotune
from repro.tuning.cost_model import CostModel, default_cost_model
from repro.tuning.policy import PolicyArg

__all__ = [
    "BatchDecoder",
    "DecodedBatch",
    "DecodePlan",
    "StreamGroup",
    "streams_from_containers",
    "default_decoder",
    "bucket_cache_size",
]

TablesArg = Union[DomainTables, Mapping[int, DomainTables]]


# ---------------------------------------------------------------------------
# Decode plans: per-(domain, config, shard) device state, uploaded once.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """Device-resident decode state for one (domain, config) on one shard.

    Holds the Huffman/quant tables and the iDCT basis as device arrays plus
    the statics that specialize the fused decode.  Everything here is
    batch-size independent: one plan serves every bucket shape on its
    device (``device=None`` is the single-shard default placement).
    """

    tables: DeviceTables
    basis: jnp.ndarray  # f32[E, N]
    lut: jnp.ndarray  # f32[E, 256] — quant_grid reconstruction LUT
    n: int
    e: int
    l_max: int
    domain_id: int
    device: object
    source: DomainTables  # host tables (kept so cache keys stay alive)
    # container-v3 coding triple (pred_id, predict_bands, zero_planes);
    # TRIVIAL_CODING decodes the classic v1/v2 stream
    coding: Tuple[int, int, bool] = TRIVIAL_CODING


def _build_decode_plan(tables: DomainTables, key, device) -> DecodePlan:
    domain_id, n, e, l_max, coding = normalize_plan_key(key)
    dev_tables = tables.device_tables()
    basis = dct.idct_basis(n, e)
    # the 256-level reconstruction LUT (quant_grid): dequantization becomes
    # an exact selection instead of per-symbol transcendentals, and —
    # because the fused Pallas kernel and the XLA path select from the SAME
    # materialized values — the two paths' float outputs are bit-identical
    lut, _ = quant_grid(tables.quant)
    if device is not None:
        dev_tables = jax.device_put(dev_tables, device)
        basis = jax.device_put(basis, device)
        lut = jax.device_put(lut, device)
    return DecodePlan(
        tables=dev_tables,
        basis=basis,
        lut=lut,
        n=n,
        e=e,
        l_max=l_max,
        domain_id=domain_id,
        device=device,
        source=tables,
        coding=coding,
    )


# ---------------------------------------------------------------------------
# The fused bucket decode — ONE jit specialization per bucket shape.
# ---------------------------------------------------------------------------
def _decode_bucket_math(
    hi: jnp.ndarray,  # uint32[Wp]   (concatenated + zero-padded words)
    lo: jnp.ndarray,  # uint32[Wp]
    sl: jnp.ndarray,  # int32[Wp]    (0 on padding words)
    tables: DeviceTables,
    lut: jnp.ndarray,  # f32[E, 256] quant_grid reconstruction LUT
    basis: jnp.ndarray,  # f32[E, N]
    v3: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    *,
    l_max: int,
    max_symlen: int,
    num_windows: int,  # bucketed (power-of-two) window count
    n: int,
    e: int,
    use_kernels: bool,
    coding: Tuple[int, int, bool] = TRIVIAL_CODING,
    tuning_epoch: int = 0,
) -> jnp.ndarray:
    """Decode one concatenated bucket to windows f32[num_windows, N].

    Statics are *bucket shape only* — every per-container quantity rides in
    the device arrays (the symlen sidecar induces all word/symbol offsets via
    prefix sums) or stays host-side slice metadata.  Padding words carry
    symlen == 0 and therefore scatter no symbols; padding windows decode to
    don't-care rows that the host slicing never reads.

    Both arms dequantize by exact selection from the plan's materialized
    256-level LUT (``quant_grid``): faster than per-symbol transcendentals,
    and — since the fused kernel selects from the SAME values — it is what
    makes ``use_kernels=True`` bit-identical to this XLA arm.  With
    ``use_kernels=True`` the whole bucket lowers to exactly ONE
    ``pallas_call`` (the decode megakernel, ``kernels/decode_fused.py``) —
    no intermediate ``[max_symlen, W]`` tile, no separate compaction or
    iDCT program.

    ``tuning_epoch`` is a pure retrace key: the kernel path resolves its
    Pallas block sizes from the tuning cache *at trace time*
    (``ops.decode_bucket_fused`` -> ``tuned_blocks``), so without it a
    bucket shape traced before ``tune()`` stored a better entry would keep
    its stale specialization forever.  Engines pass the cache epoch
    (bumped on every store) when ``use_kernels`` — the XLA arm always
    passes 0, since it has no tunables to invalidate.

    A non-trivial ``coding`` (container v3) inserts the inverse of the
    encoder's lossless pre-entropy stage between symbol unpack and LUT
    dequantization.  ``v3`` carries the host-precomputed expansion index
    ``idx int32[num_windows * e]`` (each cell's position in the dense coded
    stream, -1 = zero-plane-suppressed or bucket padding, expanding to the
    zero bin 128) and per-window segment starts ``seg int32[num_windows]``
    (the owning signal's first window; self for padding windows, whose
    degenerate segments unpredict back to 128).  ``num_symbols`` stays the
    ``num_windows * e`` capacity bound — ``idx`` never references a
    position at or beyond the bucket's true coded-symbol total, so the
    garbage tail is never read.  Exact inverse math:
    ``quantize.expand_coded_stream`` / ``unpredict_levels`` — the same
    reference functions the host decoder and the fused kernel epilogue
    call, which is what keeps all three bit-identical.
    """
    del tuning_epoch  # participates in the jit cache key only
    num_symbols = num_windows * e
    if use_kernels:
        from repro.kernels import ops as kops

        return kops.decode_bucket_fused(
            hi, lo, sl, tables, lut, basis, v3,
            l_max=l_max, max_symlen=max_symlen, num_windows=num_windows,
            n=n, e=e, coding=coding,
        )
    syms = symlen.unpack_symlen(
        hi, lo, sl,
        tables.dec_limit, tables.dec_first, tables.dec_rank, tables.dec_syms,
        l_max=l_max, max_symlen=max_symlen, num_symbols=num_symbols,
    )
    if coding == TRIVIAL_CODING:
        levels = syms.reshape(num_windows, e).astype(jnp.int32)
    else:
        idx, seg = v3
        pred_id, bands, _ = coding
        grid = expand_coded_stream(syms, idx).reshape(num_windows, e)
        levels = unpredict_levels(
            grid.astype(jnp.uint32), seg, pred_id, bands
        ).astype(jnp.int32)
    coeffs = lut[jnp.arange(e, dtype=jnp.int32)[None, :], levels]
    return coeffs @ basis


_decode_bucket = functools.partial(
    jax.jit,
    static_argnames=(
        "l_max", "max_symlen", "num_windows", "n", "e", "use_kernels",
        "coding", "tuning_epoch",
    ),
)(_decode_bucket_math)


def bucket_cache_size() -> Optional[int]:
    """Number of live XLA specializations of the fused bucket decode
    (None if this JAX version doesn't expose the jit cache)."""
    try:
        return _decode_bucket._cache_size()
    except AttributeError:  # pragma: no cover - older/newer jax
        return None


# ---------------------------------------------------------------------------
# Fixed-rate (entropy-off) mode: LUT dequantization + inverse DCT only.
# The decode half of BatchEncoder.encode_fixed — the KV-cache workload's
# O(1)-access path.  Levels arrive as a device-resident uint8 tensor (no
# container, no symlen sidecar) and samples come back device-resident.
# Dequantization selects from the plan's materialized quant_grid LUT, so
# fixed-rate samples are bit-identical to what the container path would
# reconstruct from the same levels.
# ---------------------------------------------------------------------------
def _decode_fixed_math(
    levels: jnp.ndarray,  # uint8[..., W, E]
    lut: jnp.ndarray,  # f32[E, 256]
    basis: jnp.ndarray,  # f32[E, N]
    *,
    e: int,
) -> jnp.ndarray:
    idx = levels.astype(jnp.int32)
    coeffs = lut[jnp.arange(e, dtype=jnp.int32), idx]
    windows = coeffs @ basis  # [..., W, N]
    return windows.reshape(windows.shape[:-2] + (-1,))


_decode_fixed = functools.partial(
    jax.jit, static_argnames=("e",)
)(_decode_fixed_math)


def _decode_fixed_kernels_math(
    levels, tables, basis, *, n, e, tuning_epoch=0
):
    # the staged Pallas dequant+iDCT tile; it dequantizes in-kernel (not
    # from the LUT), so floats agree with the XLA arm to ~1e-5 — the
    # fixed-rate byte contract lives on the ENCODE side (levels), where the
    # exact-parity arm is bit-identical
    del tuning_epoch
    from repro.kernels import ops as kops

    flat = levels.reshape(-1, e).astype(jnp.int32)
    windows = kops.idct_dequant(flat, tables.quant, n=n, basis=basis)
    return windows.reshape(levels.shape[:-2] + (-1,))


_decode_fixed_kernels = functools.partial(
    jax.jit, static_argnames=("n", "e", "tuning_epoch")
)(_decode_fixed_kernels_math)


# ---------------------------------------------------------------------------
# Decoded batches: outputs stay on device until explicitly drained.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Slice:
    """Where container i's samples live: rows [win_off, win_off + nw) of
    group ``group``'s window tensor, first ``signal_length`` samples."""

    group: int
    win_off: int
    num_windows: int
    signal_length: int


class DecodedBatch:
    """Result of :meth:`BatchDecoder.decode` — device-resident windows.

    ``to_host()`` performs the only host sync: every bucket's d2h copy is
    started before any is materialized (so shard drains overlap), then
    numpy slicing back to per-container signals (input order preserved).

    A quarantined decode (``BatchDecoder.decode(..., quarantine=True)``)
    carries a ``poisoned`` record per excluded signal: its slice is None,
    ``to_host()`` returns the typed
    :class:`~repro.serving.quarantine.PoisonedContainerError` at that
    position, and ``device_signal(i)`` raises it.
    """

    def __init__(
        self,
        groups: List[jnp.ndarray],
        slices: List[Optional[_Slice]],
        *,
        poisoned: Optional[Dict[int, Exception]] = None,
    ):
        self._groups = groups  # per group: f32[num_windows_p, N] on device
        self._slices = slices
        self._poisoned: Dict[int, Exception] = dict(poisoned or {})

    def __len__(self) -> int:
        return len(self._slices)

    @property
    def device_windows(self) -> List[jnp.ndarray]:
        """The raw per-bucket window tensors (device arrays)."""
        return list(self._groups)

    def device_signal(self, i: int) -> jnp.ndarray:
        """Container i's reconstructed signal as a device array (lazy).
        Raises the typed per-request error for a quarantined signal."""
        s = self._slices[i]
        if s is None:
            raise self._poisoned[i]
        rows = self._groups[s.group][s.win_off:s.win_off + s.num_windows]
        return rows.reshape(-1)[: s.signal_length]

    def block_until_ready(self) -> "DecodedBatch":
        for g in self._groups:
            g.block_until_ready()
        return self

    def to_host(self) -> List[Any]:
        """Drain the batch: one device->host transfer per bucket, all
        copies in flight before the first materializes.  Quarantined
        positions hold their typed per-request error instead of samples —
        a poisoned signal never raises batch-wide here."""
        host = fetch_to_host(self._groups)
        out: List[Any] = []
        for i, s in enumerate(self._slices):
            if s is None:
                out.append(self._poisoned[i])
                continue
            rows = host[s.group][s.win_off:s.win_off + s.num_windows]
            out.append(rows.reshape(-1)[: s.signal_length].copy())
        return out


# ---------------------------------------------------------------------------
# Pre-concatenated device streams: the engine's input contract, exposed.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StreamGroup:
    """One (domain, config) group's concatenated SymLen stream, ready for a
    fused bucket decode — the representation :meth:`BatchDecoder.decode`
    builds internally from host containers, made public so device-resident
    producers (the transcode pipeline's ``symlen.stitch_chunk_parts``
    output) can feed the decoder WITHOUT materializing containers or
    touching the host.

    ``hi``/``lo``/``symlen`` are device (or host) word arrays of one shared
    length; trailing padding words must carry ``symlen == 0`` (they then
    contribute no symbols).  ``members`` lists each signal's
    ``(num_windows, signal_length)`` in stream order — the word->symbol
    prefix sums recover everything else.  ``max_symlen`` is a host-side
    bound on the per-word symbol count (<= 64); exact is best (fewest slot
    iterations) but any safe bound decodes correctly.  ``device``/``shard``
    place the group's fused dispatch (None = default single-shard
    placement); ``live_words`` is the host-known true word count when the
    producer has it (container staging does; device-resident stitches
    don't) — it feeds the padding-occupancy stats only.

    v3 groups (plan key with a non-trivial coding triple) additionally
    carry the host-precomputed coded-stream expansion: ``v3_idx``
    ``int32[num_windows_bucketed * e]`` (dense-stream position per grid
    cell, -1 = suppressed/padding) and ``v3_seg``
    ``int32[num_windows_bucketed]`` (per-window segment start for the
    unpredictor), both built by ``symlen.v3_expand_index`` at the
    *scheduler-rounded* window count so the arrays are bucket-shaped (no
    per-batch retrace).
    """

    plan_key: tuple  # (domain_id, n, e, l_max, coding)
    hi: jnp.ndarray  # uint32[Wp]
    lo: jnp.ndarray  # uint32[Wp]
    symlen: jnp.ndarray  # int32[Wp]
    max_symlen: int
    members: Sequence[Tuple[int, int]]  # (num_windows, signal_length)
    device: object = None
    shard: int = 0
    live_words: Optional[int] = None
    v3_idx: Optional[jnp.ndarray] = None  # int32[NWp * e]
    v3_seg: Optional[jnp.ndarray] = None  # int32[NWp]

    @property
    def total_windows(self) -> int:
        return sum(nw for nw, _ in self.members)


def _stage_container_group(
    members: Sequence[Container],
    key,
    device,
    shard: int,
    rounder: Callable[[int], int] = p2,
) -> StreamGroup:
    """Host-stage one bucket: concatenate member streams into bucket-edge
    padded word arrays (``rounder`` — the scheduler policy's ``round``;
    power-of-two by default) and upload them (to ``device`` when
    sharded).  For a v3 plan key the coded-stream expansion index/segment
    arrays are built here too, at the rounded window count the dispatch
    will use (padding windows expand to the zero bin and unpredict to
    themselves)."""
    total_words = sum(c.num_words for c in members)
    wp = rounder(max(total_words, 1))
    hi = np.zeros(wp, dtype=np.uint32)
    lo = np.zeros(wp, dtype=np.uint32)
    sl = np.zeros(wp, dtype=np.int32)
    woff = 0
    for c in members:
        chi, clo = c.words_u32()
        hi[woff:woff + c.num_words] = chi
        lo[woff:woff + c.num_words] = clo
        sl[woff:woff + c.num_words] = c.symlen
        woff += c.num_words
    put = putter(device)
    key = normalize_plan_key(key)
    v3_idx = v3_seg = None
    if key[4] != TRIVIAL_CODING:
        e = key[2]
        nwp = rounder(max(sum(c.num_windows for c in members), 1))
        idx, seg = symlen.v3_expand_index(
            [(c.num_windows, c.zrow, c.zcol) for c in members],
            e, total_windows=nwp,
        )
        v3_idx = put(idx)
        v3_seg = put(seg)
    return StreamGroup(
        plan_key=key,
        hi=put(hi),
        lo=put(lo),
        symlen=put(sl),
        max_symlen=max((c.max_symlen for c in members), default=0),
        members=[(c.num_windows, c.signal_length) for c in members],
        device=device,
        shard=shard,
        live_words=total_words,
        v3_idx=v3_idx,
        v3_seg=v3_seg,
    )


def streams_from_containers(
    containers: Sequence[Container],
    policy: PolicyArg = None,
) -> Tuple[List[StreamGroup], List[int]]:
    """Group host containers by plan_key and concatenate their streams
    (single-shard, default placement — the eager public form of the
    staging :meth:`BatchDecoder.decode` pipelines lazily).  ``policy``
    picks the word-padding ladder (None = ``FPTC_BUCKET_POLICY``).

    Returns the :class:`StreamGroup` list (group order = first appearance;
    members in input order within a group) plus, per input container, its
    member position in the groups' flattened order — what
    :meth:`BatchDecoder.decode` uses to restore caller order after
    :meth:`BatchDecoder.decode_streams`.
    """
    containers = list(containers)
    scheduler = BucketScheduler(devices=None, policy=policy)
    buckets = scheduler.buckets([c.plan_key for c in containers])
    groups = [
        _stage_container_group(
            [containers[i] for i in b.items], b.key, b.device, b.shard,
            scheduler.round,
        )
        for b in buckets
    ]
    return groups, member_positions(buckets, len(containers))


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BatchDecoderStats:
    batches: int = 0
    containers: int = 0
    dispatches: int = 0  # fused bucket launches
    plan_hits: int = 0
    plan_misses: int = 0
    quarantined: int = 0  # signals poisoned out of quarantine=True batches
    # per-dispatch padding/occupancy records (bounded history) — feeds the
    # bench JSON's bucket-waste report and the half-octave bucket-policy
    # decision (ROADMAP)
    bucket_pad: "deque[dict]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=1024)
    )


class BatchDecoder:
    """Decodes many containers in a bounded number of fused dispatches.

    Usage::

        dec = BatchDecoder()
        batch = dec.decode(containers, tables)   # tables: DomainTables, or
                                                 # {domain_id: DomainTables}
        signals = batch.to_host()                # one sync, input order

    Containers are grouped by :attr:`Container.plan_key` (domain, config);
    each group's streams are concatenated word-wise and padded to the
    ``policy`` ladder's bucket edges (``p2`` by default /
    ``FPTC_BUCKET_POLICY``), then decoded by one :func:`_decode_bucket`
    launch.  A mixed archive of hundreds of containers therefore costs
    #distinct-plan-keys x #shards dispatches and O(density * log sizes)
    compilations, total.  ``pipeline`` double-buffers host
    staging/upload against device compute; ``devices`` controls sharding
    (``"auto"`` = all visible local devices, ``None`` = single default
    device), with the per-device split cost-balanced over
    ``cost_model``'s per-container decode-cost prediction — policy,
    pipelining and sharding all change scheduling only, never bytes.
    """

    def __init__(
        self,
        *,
        use_kernels: Optional[bool] = None,
        plan_cache_size: int = 32,
        pipeline: bool = True,
        devices: DevicesArg = "auto",
        prefetch: int = 2,
        policy: PolicyArg = None,
        cost_model: Optional[CostModel] = None,
    ):
        # None defers to the process-wide FPTC_USE_KERNELS default — the
        # kernels-interpret CI leg flips every engine onto the fused path
        if use_kernels is None:
            use_kernels = default_use_kernels()
        self.use_kernels = use_kernels
        self._plans = PlanCache(_build_decode_plan, plan_cache_size)
        self.scheduler = BucketScheduler(devices=devices, policy=policy)
        self.executor = PipelineExecutor(pipeline=pipeline, prefetch=prefetch)
        self.cost_model = (
            cost_model if cost_model is not None else default_cost_model()
        )
        self.stats = BatchDecoderStats()
        self._pending = SubmitBuffer()

    # -- incremental submission (the front-end's surface) -------------------
    def submit(self, container: Container) -> int:
        """Queue one container for the next :meth:`flush` (thread-safe).

        The incremental half of the batch-at-once :meth:`decode`: a serving
        front-end admits containers one at a time as requests arrive, then
        flushes them as ONE fused-bucket batch when its micro-batcher
        decides.  Returns the container's index in flush order — batch
        formation changes *when* the bucket dispatches, never the bytes any
        member decodes to.
        """
        return self._pending.submit(container)

    @property
    def pending(self) -> int:
        """Containers submitted since the last flush."""
        return len(self._pending)

    def flush(
        self, tables: TablesArg, *, quarantine: bool = False
    ) -> DecodedBatch:
        """Decode everything submitted since the last flush as one batch
        (submission order).  An empty flush is a no-op empty batch."""
        return self.decode(self._pending.take(), tables, quarantine=quarantine)

    # -- plan management ---------------------------------------------------
    def _tables_for(self, key, tables: TablesArg) -> DomainTables:
        if isinstance(tables, DomainTables):
            return tables
        domain_id = key[0]
        try:
            return tables[domain_id]
        except KeyError:
            raise KeyError(
                f"no DomainTables registered for domain_id={domain_id}"
            ) from None

    def _plan_for_key(self, key, tables: TablesArg, device=None) -> DecodePlan:
        key = normalize_plan_key(key)
        tab = self._tables_for(key, tables)
        validate_container_tables(key, tab)
        return self._plans.get(tab, key, device)

    def plan_for(
        self, container: Container, tables: TablesArg
    ) -> DecodePlan:
        return self._plan_for_key(container.plan_key, tables)

    # -- fixed-rate (entropy-off) decode -----------------------------------
    def decode_fixed(
        self,
        levels: jnp.ndarray,
        tables: DomainTables,
        *,
        length: Optional[int] = None,
        dtype=jnp.float32,
    ) -> jnp.ndarray:
        """Inverse of :meth:`BatchEncoder.encode_fixed`:
        ``uint8[..., W, E]`` levels -> ``[..., T]`` samples (``T = W * n``,
        trimmed to ``length`` when given).

        Dequantization is an exact selection from the plan's 256-level
        ``quant_grid`` LUT — the same values the container decode path
        reconstructs — followed by the MXU iDCT.  Everything stays device-
        resident; tables/basis/LUT ride the persistent :class:`DecodePlan`
        cache, so repeated cold-block reads pay zero re-uploads.
        """
        cfg = tables.config
        key = (tables.domain_id, cfg.n, cfg.e, cfg.l_max, cfg.coding)
        plan = self._plan_for_key(key, tables)
        n, e = plan.n, plan.e
        if levels.shape[-1] != e:
            raise ValueError(
                f"levels last axis {levels.shape[-1]} != domain E={e}"
            )
        if self.use_kernels:
            x = _decode_fixed_kernels(
                levels, plan.tables, plan.basis, n=n, e=e,
                tuning_epoch=_autotune.epoch(),
            )
        else:
            x = _decode_fixed(levels, plan.lut, plan.basis, e=e)
        self.stats.dispatches += 1
        if length is not None:
            x = x[..., :length]
        return x.astype(dtype)

    # -- the batched decode ------------------------------------------------
    def decode(
        self,
        containers: Sequence[Any],
        tables: TablesArg,
        *,
        quarantine: bool = False,
    ) -> DecodedBatch:
        """Decode a (possibly mixed-domain, mixed-length) batch of containers.

        Returns a :class:`DecodedBatch`; nothing is synced to host here.

        ``quarantine=True`` is the serving contract: items may be raw bytes
        or parsed :class:`Container` objects, each is wire-format + deep
        validated against ``tables`` before staging, and a poisoned item is
        excluded from its bucket instead of raising batch-wide — the clean
        subset decodes byte-identically to a clean batch and the poisoned
        slot's :class:`~repro.serving.quarantine.PoisonedContainerError`
        rides the returned batch.  Without quarantine every item must be a
        :class:`Container` and any fault raises (the offline contract).
        """
        containers = list(containers)
        self.stats.batches += 1
        self.stats.containers += len(containers)

        poisoned: Dict[int, Exception] = {}
        clean_pos = list(range(len(containers)))
        if quarantine:
            from repro.serving.quarantine import validate_or_poison

            clean_pos, clean = [], []
            for i, item in enumerate(containers):
                c, err = validate_or_poison(item, i, tables)
                if err is not None:
                    poisoned[i] = err
                else:
                    clean_pos.append(i)
                    clean.append(c)
            total = len(containers)
            self.stats.quarantined += len(poisoned)
            containers = clean

        if not containers:
            slices: List[Optional[_Slice]] = (
                [None] * total if quarantine else []
            )
            return DecodedBatch([], slices, poisoned=poisoned)

        if isinstance(tables, DomainTables):
            # a single DomainTables means "decode everything with these" —
            # only coherent for a single-domain batch (otherwise some
            # containers would silently decode with the wrong tables, or die
            # in an opaque shape error when configs differ)
            domains = {c.domain_id for c in containers}
            if len(domains) > 1:
                raise ValueError(
                    f"mixed-domain batch (domain_ids={sorted(domains)}) "
                    "needs a {domain_id: DomainTables} mapping, not a "
                    "single DomainTables"
                )

        # with several shards, split each group at cost-balanced (not
        # equal-count) boundaries over the model's per-container decode
        # cost — container metadata carries everything the model needs
        item_costs = None
        if self.scheduler.num_shards > 1:
            item_costs = [
                self.cost_model.signal_decode_cost(
                    c.num_words, c.num_windows,
                    e=c.e, n=c.n, max_symlen=symlen_bucket(c.max_symlen),
                )
                for c in containers
            ]
        buckets = self.scheduler.buckets(
            [c.plan_key for c in containers], item_costs=item_costs
        )
        member_pos = member_positions(buckets, len(containers))
        # staging stays lazy: the executor's worker runs the host concat +
        # h2d upload of bucket k+1 while bucket k's decode dispatches
        lazy = [
            functools.partial(
                _stage_container_group,
                [containers[i] for i in b.items], b.key, b.device, b.shard,
                self.scheduler.round,
            )
            for b in buckets
        ]
        batch = self.decode_streams(lazy, tables)
        # decode_streams orders slices by (group, member); restore the
        # caller's container order
        slices = [batch._slices[member_pos[i]] for i in range(len(containers))]
        if quarantine:
            full: List[Optional[_Slice]] = [None] * total
            for j, i in enumerate(clean_pos):
                full[i] = slices[j]
            slices = full
        return DecodedBatch(batch._groups, slices, poisoned=poisoned)

    def decode_streams(
        self,
        groups: Sequence[Union[StreamGroup, Callable[[], StreamGroup]]],
        tables: TablesArg,
    ) -> DecodedBatch:
        """Decode pre-concatenated (device- or host-resident) bucket streams.

        This is :meth:`decode` minus the container unpacking/concatenation:
        each :class:`StreamGroup` is one fused dispatch, nothing is synced
        to host, and device-array inputs stay on device end to end — the
        entry point the transcode pipeline uses to feed an
        ``EncodedBatch``'s stitched chunk parts straight back through the
        decoder.  A group may also be a zero-argument callable producing
        its :class:`StreamGroup` — the executor's staging contract, letting
        the host concat + upload of later groups overlap earlier groups'
        decode.  The returned batch's signals are ordered group by group,
        following each group's ``members`` order.
        """
        groups = list(groups)

        def upload(g) -> StreamGroup:
            grp = g() if callable(g) else g
            put = putter(grp.device)
            # shard-aware plan prefetch: build/upload this bucket's decode
            # plan (tables + basis + LUT device_put) from the staging
            # worker, so the first dispatch on each shard doesn't pay it —
            # PlanCache.get is thread-safe and the factory only transfers
            self._plan_for_key(tuple(grp.plan_key), tables, grp.device)
            return dataclasses.replace(
                grp, hi=put(grp.hi), lo=put(grp.lo), symlen=put(grp.symlen),
                v3_idx=(
                    put(grp.v3_idx) if grp.v3_idx is not None else None
                ),
                v3_seg=(
                    put(grp.v3_seg) if grp.v3_seg is not None else None
                ),
            )

        def dispatch(g, grp: StreamGroup) -> Tuple[jnp.ndarray,
                                                   StreamGroup]:
            plan = self._plan_for_key(
                tuple(grp.plan_key), tables, grp.device
            )
            wp = int(grp.hi.shape[0])
            num_windows = self.scheduler.round(max(grp.total_windows, 1))
            if plan.coding != TRIVIAL_CODING:
                if grp.v3_idx is None or grp.v3_seg is None:
                    raise ValueError(
                        "v3-coded StreamGroup is missing its "
                        "v3_idx/v3_seg expansion arrays (build them with "
                        "symlen.v3_expand_index at the scheduler-rounded "
                        "window count)"
                    )
                v3 = (grp.v3_idx, grp.v3_seg)
            else:
                v3 = None
            windows = _decode_bucket(
                grp.hi,
                grp.lo,
                grp.symlen,
                plan.tables,
                plan.lut,
                plan.basis,
                v3,
                l_max=plan.l_max,
                max_symlen=symlen_bucket(grp.max_symlen),
                num_windows=num_windows,
                n=plan.n,
                e=plan.e,
                use_kernels=self.use_kernels,
                coding=plan.coding,
                # retrace when the tuning cache learns better block sizes
                # (kernel path only — the XLA arm has no tunables)
                tuning_epoch=(
                    _autotune.epoch() if self.use_kernels else 0
                ),
            )
            self.stats.dispatches += 1
            self.stats.bucket_pad.append({
                "plan_key": tuple(grp.plan_key),
                "shard": grp.shard,
                "policy": self.scheduler.policy.name,
                "words": grp.live_words,
                "words_padded": wp,
                "windows": grp.total_windows,
                "windows_padded": num_windows,
            })
            return windows, grp

        results = self.executor.run(groups, upload, dispatch)

        out_groups: List[jnp.ndarray] = []
        slices: List[_Slice] = []
        for g, (windows, grp) in enumerate(results):
            win_off = 0
            for num_windows, signal_length in grp.members:
                slices.append(_Slice(
                    group=g,
                    win_off=win_off,
                    num_windows=num_windows,
                    signal_length=signal_length,
                ))
                win_off += num_windows
            out_groups.append(windows)

        self.stats.plan_hits = self._plans.hits
        self.stats.plan_misses = self._plans.misses
        return DecodedBatch(out_groups, slices)

    def decode_to_host(
        self, containers: Sequence[Container], tables: TablesArg
    ) -> List[np.ndarray]:
        """Convenience: decode + drain in one call."""
        return self.decode(containers, tables).to_host()


# ---------------------------------------------------------------------------
# Process-wide default decoders (codec.decode_device rides these).
# ---------------------------------------------------------------------------
_DEFAULTS: Dict[bool, BatchDecoder] = {}


def default_decoder(use_kernels: Optional[bool] = None) -> BatchDecoder:
    if use_kernels is None:
        use_kernels = default_use_kernels()
    dec = _DEFAULTS.get(use_kernels)
    if dec is None:
        dec = _DEFAULTS[use_kernels] = BatchDecoder(use_kernels=use_kernels)
    return dec
