from repro.serving.batch_decode import (
    BatchDecoder,
    DecodedBatch,
    DecodePlan,
    default_decoder,
)
from repro.serving.batch_encode import (
    BatchEncoder,
    EncodedBatch,
    EncodePlan,
    default_encoder,
)
from repro.serving.kv_compression import (
    KVCompressionConfig,
    compress_kv_block,
    decompress_kv_block,
)

__all__ = [
    "BatchDecoder",
    "DecodedBatch",
    "DecodePlan",
    "default_decoder",
    "BatchEncoder",
    "EncodedBatch",
    "EncodePlan",
    "default_encoder",
    "KVCompressionConfig",
    "compress_kv_block",
    "decompress_kv_block",
]
