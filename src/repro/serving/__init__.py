from repro.serving.kv_compression import (
    KVCompressionConfig,
    compress_kv_block,
    decompress_kv_block,
)

__all__ = [
    "KVCompressionConfig",
    "compress_kv_block",
    "decompress_kv_block",
]
