from repro.serving.batch_decode import (
    BatchDecoder,
    DecodedBatch,
    DecodePlan,
    StreamGroup,
    default_decoder,
    streams_from_containers,
)
from repro.serving.batch_encode import (
    BatchEncoder,
    EncodedBatch,
    EncodedBucketParts,
    EncodePlan,
    default_encoder,
)
from repro.serving.engine import (
    BucketScheduler,
    GatherStage,
    PipelineExecutor,
    SubmitBuffer,
    serving_devices,
)
from repro.serving.frontend import (
    DeadlineExpiredError,
    FrontendClosedError,
    FrontendConfig,
    FrontendError,
    FrontendStats,
    QueueFullError,
    ServingFrontend,
    policy_fill_target,
)
from repro.serving.kv_compression import (
    KVCompressionConfig,
    compress_kv_block,
    decompress_kv_block,
)
from repro.serving.transcode import (
    Transcoder,
    TranscodePlan,
    default_transcoder,
)
from repro.tuning.policy import (
    BucketPolicy,
    COST_BALANCED,
    HALF_OCTAVE,
    P2,
)

__all__ = [
    "BatchDecoder",
    "DecodedBatch",
    "DecodePlan",
    "StreamGroup",
    "default_decoder",
    "streams_from_containers",
    "BatchEncoder",
    "EncodedBatch",
    "EncodedBucketParts",
    "EncodePlan",
    "default_encoder",
    "Transcoder",
    "TranscodePlan",
    "default_transcoder",
    "BucketScheduler",
    "BucketPolicy",
    "P2",
    "HALF_OCTAVE",
    "COST_BALANCED",
    "GatherStage",
    "PipelineExecutor",
    "SubmitBuffer",
    "serving_devices",
    "ServingFrontend",
    "FrontendConfig",
    "FrontendStats",
    "FrontendError",
    "QueueFullError",
    "DeadlineExpiredError",
    "FrontendClosedError",
    "policy_fill_target",
    "KVCompressionConfig",
    "compress_kv_block",
    "decompress_kv_block",
]
