"""Device-resident transcode pipeline: decode -> re-encode, no host round trip.

FPTC's asymmetric design puts batch *re-compression* on the server: archives
are routinely migrated between configs — tighter quantization for cold
storage, a new window size ``n`` or coefficient count ``e`` after a domain
recalibration.  Composing the two serving engines through host containers
pays one device->host drain per decoded signal, a host re-stack, and one
host->device re-upload per encode bucket, all in the middle of the hot loop.

:class:`Transcoder` removes the round trip by making the engines' internal
stream representations a shared, device-resident contract:

  * **Source streams.**  A host archive (``Container`` list) stages through
    the decoder's own lazy bucket staging (the executor overlaps each
    bucket's concat+upload with the previous bucket's decode); a
    device-resident :class:`~repro.serving.batch_encode.EncodedBatch` feeds
    its un-stitched chunk parts through ``core.symlen.stitch_chunk_parts``
    — a device-side gather that lays the per-chunk word runs into
    decoder-shaped concatenated bucket streams (capacity sized by the
    host-computable :func:`~repro.core.symlen.chunk_words_bound`, so no
    sync on the true word counts; opt-in ``exact_capacity=True`` trades
    ONE pre-decode sync on the true counts for ~2x less decode slot work
    on chunk-heavy sources).
  * **Decode.**  :meth:`BatchDecoder.decode_streams` — the same fused
    bucket dispatches ``decode()`` uses, minus the container unpacking.
  * **Re-stage on device, fused.**  Each target encode bucket's stacked
    signal matrix is a batched ``dynamic_slice`` gather out of the decoded
    window tensors that runs *inside* the bucket's fused encode dispatch
    (the :class:`~repro.serving.engine.GatherStage` staging contract — one
    jit per bucket, the flat source buffer donated on its last use); row
    layout, zero padding and chunk-size selection are the encoder's own
    (:meth:`BatchEncoder.encode_staged`), which is what makes the output
    **byte-identical** to draining the decoded signals to host and
    re-encoding them.
  * **Sharding.**  With several devices, a signal re-encodes on the shard
    that decoded it (``shard_ids`` pins the encode buckets), so the whole
    decode -> gather -> re-encode chain stays on one device per shard and
    the shards run embarrassingly parallel.
  * **One drain.**  The result is a normal :class:`EncodedBatch`; nothing
    syncs until its ``to_host()``.  Between decode and re-encode there are
    zero device->host transfers (the conformance suite pins this with a
    ``jax.transfer_guard``).

``core.codec.transcode`` is a container-of-one wrapper over this engine in
exact packing mode, mirroring ``encode_device`` / ``decode_device``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import symlen
from repro.core.calibration import DomainTables
from repro.core.container import Container
from repro.serving._plans import PlanCache, TranscodePlan
from repro.serving.batch_decode import (
    BatchDecoder,
    StreamGroup,
    _stage_container_group,
)
from repro.serving.batch_encode import (
    DEFAULT_CHUNK_SIZE,
    BatchEncoder,
    EncodedBatch,
)
from repro.serving.engine import (
    DevicesArg,
    GatherStage,
    SubmitBuffer,
    member_positions,
    putter,
)
from repro.tuning.policy import PolicyArg

__all__ = ["Transcoder", "TranscodePlan", "default_transcoder"]

TablesArg = Union[DomainTables, Dict[int, DomainTables]]
Source = Union[Sequence[Container], EncodedBatch]


def _signal_words_bound(
    num_symbols: int, chunk_size: int, l_max: int
) -> int:
    """Host-side bound on one signal's packed word count under chunking."""
    full, rem = divmod(int(num_symbols), int(chunk_size))
    return full * symlen.chunk_words_bound(chunk_size, l_max) + (
        symlen.chunk_words_bound(rem, l_max)
    )


@dataclasses.dataclass
class TranscoderStats:
    batches: int = 0
    signals: int = 0
    stitches: int = 0  # device-side chunk-part stitch dispatches
    capacity_syncs: int = 0  # exact_capacity pre-decode word-count syncs
    plan_hits: int = 0
    plan_misses: int = 0
    quarantined: int = 0  # signals poisoned out of quarantine=True batches


class Transcoder:
    """Re-encodes batches under a new (domain, config) without leaving the
    device.

    Usage::

        tc = Transcoder()                       # chunked (fast) packing
        batch = tc.transcode(containers, src_tables, dst_tables)
        migrated = batch.to_host()              # the ONLY host sync

    ``source`` is either a container archive (one upload, zero syncs) or a
    device-resident :class:`EncodedBatch` fresh off a
    :class:`BatchEncoder` — in which case its chunk parts are stitched
    into decoder streams on device and the batch is *consumed* (a later
    ``to_host()`` on it raises; drain the transcode result instead).
    Output signal order is source order.  ``dst_domain_ids`` routes each
    signal's target tables when ``dst_tables`` is a mapping; it defaults
    to the source domain ids (re-windowing / re-quantizing within the
    same domain id).  ``pipeline``/``devices`` are the shared engine-layer
    knobs; ``exact_capacity=True`` opts into one pre-decode sync on the
    true stitched word counts (EncodedBatch sources only) to shrink
    decode slot work for chunk-heavy streams — none of them change the
    produced bytes.
    """

    def __init__(
        self,
        *,
        chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
        use_kernels: Optional[bool] = None,
        decoder: Optional[BatchDecoder] = None,
        encoder: Optional[BatchEncoder] = None,
        plan_cache_size: int = 32,
        pipeline: bool = True,
        devices: DevicesArg = "auto",
        prefetch: int = 2,
        exact_capacity: bool = False,
        policy: PolicyArg = None,
    ):
        # use_kernels threads through BOTH stage definitions: the decode
        # megakernel and the fused encode tile (None = FPTC_USE_KERNELS
        # env default; bytes are identical either way)
        self.decoder = decoder or BatchDecoder(
            use_kernels=use_kernels, pipeline=pipeline, devices=devices,
            prefetch=prefetch, policy=policy,
        )
        self.encoder = encoder or BatchEncoder(
            chunk_size=chunk_size, use_kernels=use_kernels,
            pipeline=pipeline, devices=devices, prefetch=prefetch,
            policy=policy,
        )
        if self.decoder.scheduler.devices != self.encoder.scheduler.devices:
            raise ValueError(
                "decoder and encoder must shard over the same devices — a "
                "signal re-encodes on the shard that decoded it (got "
                f"{self.decoder.scheduler.devices} vs "
                f"{self.encoder.scheduler.devices})"
            )
        if self.decoder.scheduler.policy != self.encoder.scheduler.policy:
            # max_width (and the flat gather pad) are sized by the ENCODE
            # bucket ladder; mixing ladders across the two halves is legal
            # arithmetic but a silent perf/compile-count trap — refuse
            raise ValueError(
                "decoder and encoder must use the same bucket policy (got "
                f"{self.decoder.scheduler.policy.name!r} vs "
                f"{self.encoder.scheduler.policy.name!r})"
            )
        self.exact_capacity = exact_capacity
        self._plans = PlanCache(self._build_plan, plan_cache_size)
        self.stats = TranscoderStats()
        self._pending = SubmitBuffer()

    # -- incremental submission (the front-end's surface) -------------------
    def submit(
        self, container: Container, dst_domain_id: Optional[int] = None
    ) -> int:
        """Queue one container for the next :meth:`flush` (thread-safe).

        The incremental half of the batch-at-once :meth:`transcode` — see
        :meth:`~repro.serving.batch_decode.BatchDecoder.submit`.
        ``dst_domain_id`` routes the re-encode tables when the flush passes
        a mapping (None = keep the source domain id).
        """
        return self._pending.submit((container, dst_domain_id))

    @property
    def pending(self) -> int:
        """Containers submitted since the last flush."""
        return len(self._pending)

    def flush(
        self,
        src_tables: TablesArg,
        dst_tables: TablesArg,
        *,
        quarantine: bool = False,
    ) -> EncodedBatch:
        """Transcode everything submitted since the last flush as one batch
        (submission order).  An empty flush is a no-op empty batch."""
        items = self._pending.take()
        containers = [c for c, _ in items]
        if all(d is None for _, d in items):
            dst_ids = None  # transcode()'s own per-tables-type defaulting
        else:
            # fill unrouted members exactly like transcode()'s None default
            # would: the single tables' own id, or the source domain id
            # under a mapping
            single = (
                dst_tables if isinstance(dst_tables, DomainTables) else None
            )

            def _src_domain(c) -> int:
                if isinstance(c, Container):
                    return c.domain_id
                try:  # quarantine admits raw bytes; route off the header
                    return Container.peek(c).domain_id
                except Exception:
                    return 0  # unparseable: poisoned before routing matters

            dst_ids = [
                d if d is not None
                else (single.domain_id if single is not None
                      else _src_domain(c))
                for c, d in items
            ]
        return self.transcode(
            containers, src_tables, dst_tables, dst_domain_ids=dst_ids,
            quarantine=quarantine,
        )

    @property
    def scheduler(self):
        """The shard scheduler both halves of the pipeline follow."""
        return self.decoder.scheduler

    # -- plan pairing ------------------------------------------------------
    def _build_plan(self, tables, key, device) -> TranscodePlan:
        (src_tab, dst_tab), (src_key, dst_key) = tables, key
        return TranscodePlan(
            decode=self.decoder._plans.get(src_tab, src_key, device),
            encode=self.encoder.plan_for(dst_tab, device),
            src_key=src_key,
            dst_key=dst_key,
        )

    def plan_for(
        self, src_tables: DomainTables, dst_tables: DomainTables, device=None
    ) -> TranscodePlan:
        src_cfg, dst_cfg = src_tables.config, dst_tables.config
        src_key = (
            src_tables.domain_id, src_cfg.n, src_cfg.e, src_cfg.l_max,
            src_cfg.coding,
        )
        dst_key = (
            dst_tables.domain_id, dst_cfg.n, dst_cfg.e, dst_cfg.l_max,
            dst_cfg.coding,
        )
        return self._plans.get(
            (src_tables, dst_tables), (src_key, dst_key), device
        )

    # -- source normalization ----------------------------------------------
    def _streams_from_encoded(
        self, batch: EncodedBatch, src_tables: TablesArg
    ) -> Tuple[List[StreamGroup], List[int], List[Tuple[int, int]],
               List[tuple], List[int]]:
        """Stitch an EncodedBatch's chunk parts into decoder streams,
        entirely on device (each shard's parts stitch on their own
        device).  Returns (groups, per-signal member position, per-signal
        (length, src plan key) in source order, pending gap flags,
        per-signal shard ids).  Does NOT consume the batch — transcode()
        marks it consumed only once the whole pipeline is committed, so a
        failed transcode (bad routing, missing tables) leaves the source
        drainable."""
        parts = batch.device_parts()
        for p in parts:
            key = tuple(p.plan_key)
            if len(key) == 5 and tuple(key[4]) != (0, 0, False):
                # a v3-coded SOURCE stream needs its per-signal ncoded /
                # zero-plane bitmaps on host to build the decode expansion
                # (symlen.v3_expand_index) — a sync this zero-transfer path
                # refuses by contract.  Drain the batch and feed the host
                # containers instead (the container path decodes v3 fine);
                # v2 -> v3 *upgrades* (v3 on the TARGET) are unaffected.
                raise NotImplementedError(
                    "device-resident transcode from a v3-coded EncodedBatch "
                    f"source (coding={tuple(key[4])}) is not supported — "
                    "drain it with to_host() and transcode the containers, "
                    "or keep the source coding trivial"
                )
        slices = batch.signal_slices()
        # signals per bucket, in row order (== stream symbol order)
        per_bucket: List[List] = [[] for _ in parts]
        for s in slices:
            per_bucket[s.bucket].append(s)
        for rows in per_bucket:
            rows.sort(key=lambda s: s.row)

        # merge source buckets sharing (plan_key, shard) into one decode
        # group, mirroring the container path's grouping — same
        # fused-dispatch count and window bucket as the drained-container
        # round trip, with every shard's stream staying on its device
        key_order, by_key = self.scheduler.group_by(
            [(p.plan_key, p.shard) for p in parts]
        )

        # exact_capacity: ONE batched pre-decode sync on the true per-chunk
        # word counts, so the stitched streams are sized by what was packed
        # instead of the l_max worst case (~2-3x looser); decode work is
        # linear in capacity, bytes are identical either way
        wpc_host = None
        if self.exact_capacity:
            wpc_host = jax.device_get([p.words_per_chunk for p in parts])
            self.stats.capacity_syncs += 1

        groups: List[StreamGroup] = []
        member_pos_by_sig: Dict[Tuple[int, int], int] = {}
        pos = 0
        for key, shard in key_order:
            l_max = key[3]
            seg_hi, seg_lo, seg_sl = [], [], []
            members: List[Tuple[int, int]] = []
            tab = self.decoder._tables_for(key, src_tables)
            lengths = np.asarray(tab.book.lengths)
            nonzero = lengths[lengths > 0]
            min_len = int(nonzero.min()) if nonzero.size else 1
            max_sl = min(symlen.WORD_BITS // max(min_len, 1),
                         symlen.WORD_BITS)
            device = None
            for b in by_key[(key, shard)]:
                p = parts[b]
                device = p.device
                if wpc_host is not None:
                    cap = int(np.sum(wpc_host[b]))
                else:
                    cap = sum(
                        _signal_words_bound(
                            s.num_windows * s.e, p.chunk_size, l_max
                        )
                        for s in per_bucket[b]
                    )
                c = p.chunk_size
                shi, slo, ssl, _ = symlen.stitch_chunk_parts(
                    p.hi.reshape(-1, c),
                    p.lo.reshape(-1, c),
                    p.symlen.reshape(-1, c),
                    p.words_per_chunk.reshape(-1),
                    capacity=symlen.stitch_capacity(cap),
                )
                self.stats.stitches += 1
                seg_hi.append(shi)
                seg_lo.append(slo)
                seg_sl.append(ssl)
                for s in per_bucket[b]:
                    members.append((s.num_windows, s.signal_length))
                    member_pos_by_sig[(s.bucket, s.row)] = pos
                    pos += 1
            groups.append(StreamGroup(
                plan_key=key,
                hi=seg_hi[0] if len(seg_hi) == 1 else jnp.concatenate(seg_hi),
                lo=seg_lo[0] if len(seg_lo) == 1 else jnp.concatenate(seg_lo),
                symlen=(
                    seg_sl[0] if len(seg_sl) == 1 else jnp.concatenate(seg_sl)
                ),
                max_symlen=max_sl,
                members=members,
                device=device,
                shard=shard,
            ))

        member_pos = [
            member_pos_by_sig[(s.bucket, s.row)] for s in slices
        ]
        meta = [
            (s.signal_length, (s.domain_id, s.n, s.e, s.l_max))
            for s in slices
        ]
        shard_ids = [parts[s.bucket].shard for s in slices]
        # inherit the source's own pending flags too: a chained transcode
        # must not launder an upstream histogram-gap batch into a clean
        # drain
        flags = list(batch._pending_flags) + [
            (p.plan_key, p.unencodable) for p in parts
        ]
        return groups, member_pos, meta, flags, shard_ids

    # -- the transcode -----------------------------------------------------
    def transcode(
        self,
        source: Source,
        src_tables: TablesArg,
        dst_tables: TablesArg,
        *,
        dst_domain_ids: Optional[Sequence[int]] = None,
        quarantine: bool = False,
    ) -> EncodedBatch:
        """Decode ``source`` under ``src_tables`` and re-encode under
        ``dst_tables``, device-resident end to end.

        Returns an :class:`EncodedBatch` (source order); nothing is synced
        to host here — drain it once with ``to_host()``.

        ``quarantine=True`` (container sources): items may be raw bytes or
        :class:`Container` objects; each is validated against
        ``src_tables`` at staging and a poisoned item is excluded from its
        bucket instead of raising batch-wide — its typed error rides the
        returned batch's drain.  EncodedBatch sources are device-resident
        output of our own engines (no wire format to corrupt), so only the
        per-signal histogram-gap demotion applies to them.
        """
        src_batch: Optional[EncodedBatch] = None
        poisoned: Dict[int, Exception] = {}
        clean_pos: List[int] = []
        total = 0
        if isinstance(source, EncodedBatch):
            src_batch = source
            groups, member_pos, meta, flags, shard_ids = (
                self._streams_from_encoded(source, src_tables)
            )
            # placement follows the DATA: the source batch's shard ids may
            # come from a different scheduler (e.g. a sharded encoder
            # feeding a single-device transcoder), so its parts' devices —
            # not this scheduler's tuple — decide where each shard runs
            shard_devices = {g.shard: g.device for g in groups}
        else:
            containers = list(source)
            total = len(containers)
            clean_pos = list(range(total))
            if quarantine:
                from repro.serving.quarantine import validate_or_poison

                clean_pos, clean = [], []
                for i, item in enumerate(containers):
                    c, err = validate_or_poison(item, i, src_tables)
                    if err is not None:
                        poisoned[i] = err
                    else:
                        clean_pos.append(i)
                        clean.append(c)
                self.stats.quarantined += len(poisoned)
                containers = clean
                if dst_domain_ids is not None:
                    dst_domain_ids = [dst_domain_ids[i] for i in clean_pos]
                if not containers:
                    self.stats.batches += 1
                    return EncodedBatch(
                        [], [None] * total, (),
                        poisoned=poisoned, quarantine=True,
                    )
            buckets = self.scheduler.buckets(
                [c.plan_key for c in containers]
            )
            member_pos = member_positions(buckets, len(containers))
            # lazy staging: the decode executor's worker concatenates and
            # uploads bucket k+1 while bucket k decodes
            groups = [
                functools.partial(
                    _stage_container_group,
                    [containers[i] for i in b.items],
                    b.key, b.device, b.shard,
                    self.decoder.scheduler.round,
                )
                for b in buckets
            ]
            meta = [(c.signal_length, c.plan_key) for c in containers]
            flags = []
            shard_ids = [0] * len(containers)
            shard_devices = {}
            for b in buckets:
                shard_devices[b.shard] = b.device
                for i in b.items:
                    shard_ids[i] = b.shard
        self.stats.batches += 1
        self.stats.signals += len(meta)

        lengths = [length for length, _ in meta]
        if dst_domain_ids is None and not isinstance(
            dst_tables, DomainTables
        ):
            dst_domain_ids = [key[0] for _, key in meta]

        # resolve the (source, target) plan pairings up front: device
        # tables/bases upload through the shared caches before dispatch.
        # max_width (the widest dst encode bucket) sizes the one-time zero
        # pad that keeps every fused gather's dynamic_slice in bounds.
        dst_doms = (
            [dst_tables.domain_id] * len(meta)
            if isinstance(dst_tables, DomainTables) else list(dst_domain_ids)
        )
        max_width = 1
        for (length, src_key), dst_dom, shard in zip(
            meta, dst_doms, shard_ids
        ):
            src_tab = self.decoder._tables_for(src_key, src_tables)
            dst_tab = self.encoder._tables_for(dst_dom, dst_tables)
            self.plan_for(src_tab, dst_tab, shard_devices[shard])
            n_dst = dst_tab.config.n
            # the ENCODER's bucket rounding, exactly: the fused gathers
            # dynamic_slice `wp * n` samples per row, and dynamic_slice
            # CLAMPS out-of-range starts — an undersized pad would silently
            # shift tail rows' windows instead of erroring
            max_width = max(
                max_width,
                self.encoder.scheduler.round(
                    max(-(-length // n_dst), 1)
                ) * n_dst,
            )
        self.stats.plan_hits = self._plans.hits
        self.stats.plan_misses = self._plans.misses

        decoded = self.decoder.decode_streams(groups, src_tables)
        group_shards = [
            g.shard if isinstance(g, StreamGroup) else None for g in groups
        ]
        if None in group_shards:
            # lazy container staging: shard rides the scheduler buckets
            group_shards = [b.shard for b in buckets]

        # flatten each shard's decoded window tensors once (zero-padded by
        # the widest bucket so every gather slice stays in bounds, then up
        # to a bucket-edge length: the flat tensor is an operand of the
        # fused gather+encode jit, so an unbucketed data-dependent length
        # would recompile the whole DCT+quant+pack per distinct archive
        # size — policy rounding keeps those specializations O(density *
        # log sizes) like every other traced shape in the engines);
        # per-signal sample runs are contiguous, so encode staging is one
        # batched dynamic_slice fused into each bucket's encode dispatch
        tensors = decoded.device_windows
        starts = np.zeros((len(meta),), dtype=np.int64)
        flats: Dict[int, jnp.ndarray] = {}
        remaining: Dict[int, int] = {}
        if tensors:
            bases = np.zeros((len(tensors),), dtype=np.int64)
            for shard in sorted(set(group_shards)):
                gidx = [g for g, s in enumerate(group_shards) if s == shard]
                off = 0
                for g in gidx:
                    bases[g] = off
                    off += tensors[g].size
                if off + max_width > np.iinfo(np.int32).max:
                    # gather starts ride int32 (jax default x32): a flat
                    # tensor past 2^31 samples would wrap offsets negative
                    # and re-encode the wrong samples SILENTLY — refuse
                    raise ValueError(
                        f"shard {shard}'s decoded windows span "
                        f"{off + max_width} samples, past the int32 gather "
                        "range — transcode the archive in smaller batches"
                    )
                pad = putter(shard_devices[shard])(np.zeros(
                    (self.scheduler.round(off + max_width) - off,),
                    np.float32,
                ))
                flats[shard] = jnp.concatenate(
                    [tensors[g].reshape(-1) for g in gidx] + [pad]
                )
                remaining[shard] = 0
            widths = [w.shape[1] for w in tensors]
            for i in range(len(meta)):
                s = decoded._slices[member_pos[i]]
                starts[i] = bases[s.group] + s.win_off * widths[s.group]
                remaining[shard_ids[i]] += 1

        def stage(idxs, kp: int, wp: int, n: int, device) -> GatherStage:
            shard = shard_ids[idxs[0]]  # bucket rows share one shard (pinned)
            st = np.zeros((kp,), dtype=np.int32)
            ln = np.zeros((kp,), dtype=np.int32)
            for row, i in enumerate(idxs):
                st[row] = starts[i]
                ln[row] = lengths[i]
            put = putter(device)
            remaining[shard] -= len(idxs)
            return GatherStage(
                flat=flats[shard],
                starts=put(st),
                lens=put(ln),
                # last bucket gathering from this shard's decoded windows:
                # donate the flat buffer into the fused encode
                donate=remaining[shard] == 0,
            )

        out = self.encoder.encode_staged(
            lengths, dst_tables,
            domain_ids=dst_domain_ids,
            stage=stage,
            pending_flags=flags,
            shard_ids=shard_ids,
            shard_devices=shard_devices,
            quarantine=quarantine,
        )
        if quarantine and src_batch is None and total:
            # restore source positions: poisoned slots hold their typed
            # error, clean slots keep their (unchanged) bucket/row slices
            full = [None] * total
            for j, i in enumerate(clean_pos):
                full[i] = out._slices[j]
            out = EncodedBatch(
                out._buckets, full, out._pending_flags,
                poisoned=poisoned, quarantine=True,
            )
        if src_batch is not None:
            # commit point: the source's buffers now back the transcode
            # result; mark it consumed only NOW, so any earlier failure
            # (bad routing, missing tables) left it drainable
            src_batch._mark_consumed(
                "its device buffers were donated to a Transcoder — drain "
                "the transcode result instead"
            )
        return out

    def transcode_to_host(
        self,
        source: Source,
        src_tables: TablesArg,
        dst_tables: TablesArg,
        *,
        dst_domain_ids: Optional[Sequence[int]] = None,
    ) -> List[Container]:
        """Convenience: transcode + single drain in one call."""
        return self.transcode(
            source, src_tables, dst_tables, dst_domain_ids=dst_domain_ids
        ).to_host()


# ---------------------------------------------------------------------------
# Process-wide default transcoders (codec.transcode rides the exact one).
# ---------------------------------------------------------------------------
_DEFAULTS: Dict[Optional[int], Transcoder] = {}


def default_transcoder(chunk_size: Optional[int] = None) -> Transcoder:
    """Shared transcoder per chunk size.  ``None`` (the default) is *exact*
    packing mode — what ``core.codec.transcode`` rides; pass
    ``DEFAULT_CHUNK_SIZE`` (or any chunk) for chunk-parallel packing.
    Same process-lifetime plan-cache trade as ``default_encoder``."""
    tc = _DEFAULTS.get(chunk_size)
    if tc is None:
        tc = _DEFAULTS[chunk_size] = Transcoder(chunk_size=chunk_size)
    return tc
