"""Device-resident transcode pipeline: decode -> re-encode, no host round trip.

FPTC's asymmetric design puts batch *re-compression* on the server: archives
are routinely migrated between configs — tighter quantization for cold
storage, a new window size ``n`` or coefficient count ``e`` after a domain
recalibration.  Composing the two serving engines through host containers
pays one device->host drain per decoded signal, a host re-stack, and one
host->device re-upload per encode bucket, all in the middle of the hot loop.

:class:`Transcoder` removes the round trip by making the engines' internal
stream representations a shared, device-resident contract:

  * **Source streams.**  A host archive (``Container`` list) uploads once
    via the decoder's own :func:`~repro.serving.batch_decode.
    streams_from_containers`; a device-resident
    :class:`~repro.serving.batch_encode.EncodedBatch` feeds its un-stitched
    chunk parts through ``core.symlen.stitch_chunk_parts`` — a device-side
    gather that lays the per-chunk word runs into decoder-shaped
    concatenated bucket streams (capacity sized by the host-computable
    :func:`~repro.core.symlen.chunk_words_bound`, so no sync on the true
    word counts).
  * **Decode.**  :meth:`BatchDecoder.decode_streams` — the same fused
    bucket dispatches ``decode()`` uses, minus the container unpacking.
  * **Re-stage on device.**  Each target encode bucket's stacked signal
    matrix is one jitted gather out of the decoded window tensors
    (:func:`_gather_rows`); row layout, zero padding and chunk-size
    selection are the encoder's own (:meth:`BatchEncoder.encode_staged`),
    which is what makes the output **byte-identical** to draining the
    decoded signals to host and re-encoding them.
  * **One drain.**  The result is a normal :class:`EncodedBatch`; nothing
    syncs until its ``to_host()``.  Between decode and re-encode there are
    zero device->host transfers (the conformance suite pins this with a
    ``jax.transfer_guard``).

``core.codec.transcode`` is a container-of-one wrapper over this engine in
exact packing mode, mirroring ``encode_device`` / ``decode_device``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import symlen
from repro.core.calibration import DomainTables
from repro.core.container import Container
from repro.serving._plans import PlanCache, TranscodePlan
from repro.serving.batch_decode import (
    BatchDecoder,
    StreamGroup,
    _p2,
    streams_from_containers,
)
from repro.serving.batch_encode import (
    DEFAULT_CHUNK_SIZE,
    BatchEncoder,
    EncodedBatch,
)

__all__ = ["Transcoder", "TranscodePlan", "default_transcoder"]

TablesArg = Union[DomainTables, Dict[int, DomainTables]]
Source = Union[Sequence[Container], EncodedBatch]


@functools.partial(jax.jit, static_argnames=("width",))
def _gather_rows(
    flat: jnp.ndarray,  # f32[T + 1] (flattened decoded windows)
    starts: jnp.ndarray,  # int32[K] first-sample flat offset per row
    lens: jnp.ndarray,  # int32[K] true sample count per row
    *,
    width: int,
) -> jnp.ndarray:
    """Stage one encode bucket's signal matrix ``f32[K, width]`` on device.

    Row ``r`` gathers samples ``[starts[r], starts[r] + lens[r])`` of the
    flattened window tensors and is exact-zero beyond ``lens[r]`` — the
    same layout ``BatchEncoder.encode`` stages host-side (a decoded
    signal's own window padding is *re-decoded* data, not zeros, so the
    mask is what keeps device staging bit-identical to the host path).

    ``flat`` must already carry >= ``width`` trailing zeros past the last
    real start (transcode() pads ONCE by the widest bucket) so every slice
    stays in bounds — dynamic_slice clamps out-of-range starts, which
    would silently shift a tail row's window otherwise.  Every row is one
    contiguous sample run, so the cheap lowering is a batched
    dynamic_slice (row-wise block copy) + tail mask — NOT a per-element
    gather, which costs ~2x the fused encode itself on CPU.
    """
    pos = jnp.arange(width, dtype=jnp.int32)

    def row(start, length):
        x = jax.lax.dynamic_slice(flat, (start,), (width,))
        return jnp.where(pos < length, x, jnp.zeros((), flat.dtype))

    return jax.vmap(row)(starts, lens)


def _signal_words_bound(
    num_symbols: int, chunk_size: int, l_max: int
) -> int:
    """Host-side bound on one signal's packed word count under chunking."""
    full, rem = divmod(int(num_symbols), int(chunk_size))
    return full * symlen.chunk_words_bound(chunk_size, l_max) + (
        symlen.chunk_words_bound(rem, l_max)
    )


@dataclasses.dataclass
class TranscoderStats:
    batches: int = 0
    signals: int = 0
    stitches: int = 0  # device-side chunk-part stitch dispatches
    plan_hits: int = 0
    plan_misses: int = 0


class Transcoder:
    """Re-encodes batches under a new (domain, config) without leaving the
    device.

    Usage::

        tc = Transcoder()                       # chunked (fast) packing
        batch = tc.transcode(containers, src_tables, dst_tables)
        migrated = batch.to_host()              # the ONLY host sync

    ``source`` is either a container archive (one upload, zero syncs) or a
    device-resident :class:`EncodedBatch` fresh off a
    :class:`BatchEncoder` — in which case its chunk parts are stitched
    into decoder streams on device and the batch is *consumed* (a later
    ``to_host()`` on it raises; drain the transcode result instead).
    Output signal order is source order.  ``dst_domain_ids`` routes each
    signal's target tables when ``dst_tables`` is a mapping; it defaults
    to the source domain ids (re-windowing / re-quantizing within the
    same domain id).
    """

    def __init__(
        self,
        *,
        chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
        use_kernels: bool = False,
        decoder: Optional[BatchDecoder] = None,
        encoder: Optional[BatchEncoder] = None,
        plan_cache_size: int = 32,
    ):
        self.decoder = decoder or BatchDecoder(use_kernels=use_kernels)
        self.encoder = encoder or BatchEncoder(chunk_size=chunk_size)
        self._plans = PlanCache(self._build_plan, plan_cache_size)
        self.stats = TranscoderStats()

    # -- plan pairing ------------------------------------------------------
    def _build_plan(self, tables, key) -> TranscodePlan:
        (src_tab, dst_tab), (src_key, dst_key) = tables, key
        return TranscodePlan(
            decode=self.decoder._plans.get(src_tab, src_key),
            encode=self.encoder.plan_for(dst_tab),
            src_key=src_key,
            dst_key=dst_key,
        )

    def plan_for(
        self, src_tables: DomainTables, dst_tables: DomainTables
    ) -> TranscodePlan:
        src_cfg, dst_cfg = src_tables.config, dst_tables.config
        src_key = (src_tables.domain_id, src_cfg.n, src_cfg.e, src_cfg.l_max)
        dst_key = (dst_tables.domain_id, dst_cfg.n, dst_cfg.e, dst_cfg.l_max)
        return self._plans.get((src_tables, dst_tables), (src_key, dst_key))

    # -- source normalization ----------------------------------------------
    def _streams_from_encoded(
        self, batch: EncodedBatch, src_tables: TablesArg
    ) -> Tuple[List[StreamGroup], List[int], List[Tuple[int, int]],
               List[tuple]]:
        """Stitch an EncodedBatch's chunk parts into decoder streams,
        entirely on device.  Returns (groups, per-signal member position,
        per-signal (length, src plan key) in source order, pending gap
        flags).  Does NOT consume the batch — transcode() marks it consumed
        only once the whole pipeline is committed, so a failed transcode
        (bad routing, missing tables) leaves the source drainable."""
        parts = batch.device_parts()
        slices = batch.signal_slices()
        # signals per bucket, in row order (== stream symbol order)
        per_bucket: List[List] = [[] for _ in parts]
        for s in slices:
            per_bucket[s.bucket].append(s)
        for rows in per_bucket:
            rows.sort(key=lambda s: s.row)

        # merge buckets sharing a plan_key into one decode group, mirroring
        # streams_from_containers' grouping (same fused-dispatch count and
        # window bucket as the drained-container round trip)
        key_order: List[Tuple[int, int, int, int]] = []
        by_key: Dict[Tuple[int, int, int, int], List[int]] = {}
        for b, p in enumerate(parts):
            if p.plan_key not in by_key:
                by_key[p.plan_key] = []
                key_order.append(p.plan_key)
            by_key[p.plan_key].append(b)

        groups: List[StreamGroup] = []
        member_pos_by_sig: Dict[Tuple[int, int], int] = {}
        pos = 0
        for key in key_order:
            l_max = key[3]
            seg_hi, seg_lo, seg_sl = [], [], []
            members: List[Tuple[int, int]] = []
            tab = self.decoder._tables_for(key, src_tables)
            lengths = np.asarray(tab.book.lengths)
            nonzero = lengths[lengths > 0]
            min_len = int(nonzero.min()) if nonzero.size else 1
            max_sl = min(symlen.WORD_BITS // max(min_len, 1),
                         symlen.WORD_BITS)
            for b in by_key[key]:
                p = parts[b]
                cap = sum(
                    _signal_words_bound(
                        s.num_windows * s.e, p.chunk_size, l_max
                    )
                    for s in per_bucket[b]
                )
                c = p.chunk_size
                # round capacity to a coarse grid (not a power of two:
                # the bound is already ~2-3x the true word count, and
                # decode slot work is linear in capacity — p2 rounding on
                # top would double it again)
                cap = -(-max(cap, 1) // 256) * 256
                shi, slo, ssl, _ = symlen.stitch_chunk_parts(
                    p.hi.reshape(-1, c),
                    p.lo.reshape(-1, c),
                    p.symlen.reshape(-1, c),
                    p.words_per_chunk.reshape(-1),
                    capacity=cap,
                )
                self.stats.stitches += 1
                seg_hi.append(shi)
                seg_lo.append(slo)
                seg_sl.append(ssl)
                for s in per_bucket[b]:
                    members.append((s.num_windows, s.signal_length))
                    member_pos_by_sig[(s.bucket, s.row)] = pos
                    pos += 1
            groups.append(StreamGroup(
                plan_key=key,
                hi=seg_hi[0] if len(seg_hi) == 1 else jnp.concatenate(seg_hi),
                lo=seg_lo[0] if len(seg_lo) == 1 else jnp.concatenate(seg_lo),
                symlen=(
                    seg_sl[0] if len(seg_sl) == 1 else jnp.concatenate(seg_sl)
                ),
                max_symlen=max_sl,
                members=members,
            ))

        member_pos = [
            member_pos_by_sig[(s.bucket, s.row)] for s in slices
        ]
        meta = [
            (s.signal_length, (s.domain_id, s.n, s.e, s.l_max))
            for s in slices
        ]
        # inherit the source's own pending flags too: a chained transcode
        # must not launder an upstream histogram-gap batch into a clean
        # drain
        flags = list(batch._pending_flags) + [
            (p.plan_key, p.unencodable) for p in parts
        ]
        return groups, member_pos, meta, flags

    # -- the transcode -----------------------------------------------------
    def transcode(
        self,
        source: Source,
        src_tables: TablesArg,
        dst_tables: TablesArg,
        *,
        dst_domain_ids: Optional[Sequence[int]] = None,
    ) -> EncodedBatch:
        """Decode ``source`` under ``src_tables`` and re-encode under
        ``dst_tables``, device-resident end to end.

        Returns an :class:`EncodedBatch` (source order); nothing is synced
        to host here — drain it once with ``to_host()``.
        """
        src_batch: Optional[EncodedBatch] = None
        if isinstance(source, EncodedBatch):
            src_batch = source
            groups, member_pos, meta, flags = self._streams_from_encoded(
                source, src_tables
            )
        else:
            containers = list(source)
            groups, member_pos = streams_from_containers(containers)
            meta = [(c.signal_length, c.plan_key) for c in containers]
            flags = []
        self.stats.batches += 1
        self.stats.signals += len(meta)

        lengths = [length for length, _ in meta]
        if dst_domain_ids is None and not isinstance(
            dst_tables, DomainTables
        ):
            dst_domain_ids = [key[0] for _, key in meta]

        # resolve the (source, target) plan pairings up front: device
        # tables/bases upload through the shared caches before dispatch.
        # max_width (the widest dst encode bucket) sizes the one-time zero
        # pad that keeps every _gather_rows dynamic_slice in bounds.
        dst_doms = (
            [dst_tables.domain_id] * len(meta)
            if isinstance(dst_tables, DomainTables) else list(dst_domain_ids)
        )
        max_width = 1
        for (length, src_key), dst_dom in zip(meta, dst_doms):
            src_tab = self.decoder._tables_for(src_key, src_tables)
            dst_tab = self.encoder._tables_for(dst_dom, dst_tables)
            self.plan_for(src_tab, dst_tab)
            n_dst = dst_tab.config.n
            max_width = max(
                max_width, _p2(max(-(-length // n_dst), 1)) * n_dst
            )
        self.stats.plan_hits = self._plans.hits
        self.stats.plan_misses = self._plans.misses

        decoded = self.decoder.decode_streams(groups, src_tables)

        # flatten the decoded window tensors once (padded once, by the
        # widest bucket); per-signal sample runs are contiguous, so encode
        # staging is one batched dynamic_slice per bucket
        tensors = decoded.device_windows
        starts = np.zeros((len(meta),), dtype=np.int64)
        if tensors:
            flat = jnp.concatenate(
                [w.reshape(-1) for w in tensors]
                + [jnp.zeros((max_width,), tensors[0].dtype)]
            )
            bases = np.concatenate(
                [[0], np.cumsum([w.size for w in tensors])]
            ).astype(np.int64)
            widths = [w.shape[1] for w in tensors]
            for i in range(len(meta)):
                s = decoded._slices[member_pos[i]]
                starts[i] = bases[s.group] + s.win_off * widths[s.group]

        def stage(idxs: List[int], kp: int, wp: int, n: int) -> jnp.ndarray:
            st = np.zeros((kp,), dtype=np.int32)
            ln = np.zeros((kp,), dtype=np.int32)
            for row, i in enumerate(idxs):
                st[row] = starts[i]
                ln[row] = lengths[i]
            return _gather_rows(
                flat, jnp.asarray(st), jnp.asarray(ln), width=wp * n
            )

        out = self.encoder.encode_staged(
            lengths, dst_tables,
            domain_ids=dst_domain_ids,
            stage=stage,
            pending_flags=flags,
        )
        if src_batch is not None:
            # commit point: the source's buffers now back the transcode
            # result; mark it consumed only NOW, so any earlier failure
            # (bad routing, missing tables) left it drainable
            src_batch._mark_consumed(
                "its device buffers were donated to a Transcoder — drain "
                "the transcode result instead"
            )
        return out

    def transcode_to_host(
        self,
        source: Source,
        src_tables: TablesArg,
        dst_tables: TablesArg,
        *,
        dst_domain_ids: Optional[Sequence[int]] = None,
    ) -> List[Container]:
        """Convenience: transcode + single drain in one call."""
        return self.transcode(
            source, src_tables, dst_tables, dst_domain_ids=dst_domain_ids
        ).to_host()


# ---------------------------------------------------------------------------
# Process-wide default transcoders (codec.transcode rides the exact one).
# ---------------------------------------------------------------------------
_DEFAULTS: Dict[Optional[int], Transcoder] = {}


def default_transcoder(chunk_size: Optional[int] = None) -> Transcoder:
    """Shared transcoder per chunk size.  ``None`` (the default) is *exact*
    packing mode — what ``core.codec.transcode`` rides; pass
    ``DEFAULT_CHUNK_SIZE`` (or any chunk) for chunk-parallel packing.
    Same process-lifetime plan-cache trade as ``default_encoder``."""
    tc = _DEFAULTS.get(chunk_size)
    if tc is None:
        tc = _DEFAULTS[chunk_size] = Transcoder(chunk_size=chunk_size)
    return tc
