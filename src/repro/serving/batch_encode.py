"""Batched bucketed encode engine: one fused dispatch per shape bucket.

PR 1 made decode archive-scale; this is the encode-side mirror, built for
server-side ingest/transcoding and re-encode benchmarks (the paper's
*embedded* encoder stays ``core.codec.encode`` — sequential by design).
A per-signal ``encode_device`` loop pays the same three taxes the decode
engine removed, plus one of its own:

  1. **serial packing** — ``symlen.pack_symlen_scan`` is one ``lax.scan``
     step per symbol, a length-S dependency chain that no amount of batching
     hides;
  2. **recompilation** — per-signal jit retraces for every distinct length;
  3. **table re-upload + host sync** — tables travel per call and
     ``int(num_words)`` blocks on every container.

This module removes all four:

  * **Chunk-parallel packing.**  ``symlen.pack_symlen_chunked_parts`` packs
    B fixed-size chunks concurrently (vmap of scan-lite chunk packs — the
    scan carries only the O(1) bit-offset recurrence; words materialize as
    cumsum differences at searchsorted segment boundaries, scatter-free).
    The SymLen format makes the chunked output decoder-compatible bit for
    bit (each word is independently decodable), at < 1 padding word per
    chunk of stream growth.
  * **Shape bucketing.**  Signals are grouped by (domain, config) and padded
    into power-of-two window/batch buckets, so jit specializations are
    O(log sizes).  Per-signal symbol counts ride a device array into the
    packer's validity mask — never trace constants.
  * **Persistent encode plans.**  Device tables upload once per
    (domain, config, shard device) into an LRU :class:`EncodePlan` cache.
  * **Device-resident results.**  Encoded streams stay on device inside an
    :class:`EncodedBatch` until an explicit ``.to_host()`` drain — one sync
    per bucket, where the zero-length-codeword flag is also checked (the
    device-side arm of the ``pack_symlen_np`` histogram-gap guard).

Scheduling, pipelining and sharding ride the shared
:mod:`repro.serving.engine` layer: bucket k+1's host stacking + upload
overlap bucket k's fused DCT+quant+pack, and with several devices each
bucket's batch axis splits into per-device shards (rows pack
independently, so per-signal bytes never depend on which shard packed
them).  Device-resident staging uses the :class:`~repro.serving.engine.
GatherStage` contract — the gather then happens *inside* the bucket's
fused dispatch (one jit per bucket, optionally donating the source
buffer on its last use).

``core.codec.encode_device`` is a batch-of-one wrapper over this engine in
*exact* mode (``chunk_size=None`` — one chunk per signal), which keeps its
output bit-identical to the host encoder.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import (
    Any,
    Dict,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dct, symlen
from repro.core.calibration import DeviceTables, DomainTables
from repro.core.container import Container
from repro.core.quantize import predict_levels, quantize
from repro.serving._plans import (
    TRIVIAL_CODING,
    PlanCache,
    normalize_plan_key,
)
from repro.serving.engine import (
    Bucket,
    BucketScheduler,
    DevicesArg,
    GatherStage,
    PipelineExecutor,
    SubmitBuffer,
    default_use_kernels,
    fetch_to_host_stitched,
    putter,
)
from repro.tuning import autotune as _autotune
from repro.tuning.cost_model import CostModel, default_cost_model
from repro.tuning.policy import PolicyArg

__all__ = [
    "BatchEncoder",
    "EncodedBatch",
    "EncodedBucketParts",
    "EncodePlan",
    "default_encoder",
    "DEFAULT_CHUNK_SIZE",
]

TablesArg = Union[DomainTables, Mapping[int, DomainTables]]

# Symbols per packing chunk.  Words per chunk ~= chunk * avg_bits / 64, so at
# ~4 bits/symbol a 1024-symbol chunk spans ~64 words and the <1-word-per-chunk
# padding bound costs < ~1.6% stream growth, while the packing scan shrinks
# from length S to length 1024 with S/1024 parallel lanes per signal.
DEFAULT_CHUNK_SIZE = 1024


# ---------------------------------------------------------------------------
# Encode plans: per-(domain, config, shard) device state, uploaded once.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EncodePlan:
    """Device-resident encode state for one (domain, config) on one shard.

    Everything here is batch-size independent: one plan serves every bucket
    shape on its device.  ``has_gaps`` records (host-side, at plan build)
    whether the Huffman book has zero-length entries — only then does the
    fused encode pay for the device-side unencodable-symbol check.
    """

    tables: DeviceTables
    basis: jnp.ndarray  # f32[N, E] dct basis — the fused kernel's operand
    n: int
    e: int
    l_max: int
    domain_id: int
    has_gaps: bool
    device: object
    source: DomainTables  # host tables (kept so cache keys stay alive)
    # container-v3 coding triple (pred_id, predict_bands, zero_planes);
    # TRIVIAL_CODING selects the classic v2 stream byte-for-byte
    coding: Tuple[int, int, bool] = TRIVIAL_CODING


def _build_encode_plan(tables: DomainTables, key, device) -> EncodePlan:
    domain_id, n, e, l_max, coding = normalize_plan_key(key)
    dev_tables = tables.device_tables()
    basis = dct.dct_basis(n, e)
    if device is not None:
        dev_tables = jax.device_put(dev_tables, device)
        basis = jax.device_put(basis, device)
    return EncodePlan(
        tables=dev_tables,
        basis=basis,
        n=n,
        e=e,
        l_max=l_max,
        domain_id=domain_id,
        has_gaps=bool(np.any(np.asarray(tables.book.lengths) == 0)),
        device=device,
        source=tables,
        coding=coding,
    )


# ---------------------------------------------------------------------------
# The fused bucket encode — ONE jit specialization per bucket shape.
# ---------------------------------------------------------------------------
def _encode_bucket_math(
    signals: jnp.ndarray,  # f32[K, Wp * n] (zero-padded signals)
    counts: jnp.ndarray,  # int32[K] true symbol count per signal
    tables: DeviceTables,
    *,
    n: int,
    e: int,
    chunk_size: int,
    check_gaps: bool,
    coding: Tuple[int, int, bool] = TRIVIAL_CODING,
):
    """DCT + quantize + chunk-parallel pack for one shape bucket.

    Statics are *bucket shape only*; per-signal true lengths ride in
    ``counts`` and become the packer's validity mask, so zero-padded windows
    contribute no symbols to any stream.  Returns the per-signal *chunk
    parts* (hi/lo/symlen ``[K, B, chunk_size]`` + words-per-chunk
    ``[K, B]``) — the drain concatenates chunk runs on the host, which is
    cheaper than a device-side stitch and byte-identical — plus the
    PER-ROW unencodable-symbol flags ``bool[K]`` (const False unless the
    book has histogram gaps; padding rows have no valid symbols and stay
    False).  Per-row rather than batch-wide is what lets the serving
    quarantine demote a histogram gap from batch-fatal to a per-signal
    outcome at drain.

    A non-trivial ``coding`` (container v3) inserts the lossless pre-entropy
    stage between quantize and pack: windowed prediction re-codes the low
    bands as mod-256 residuals (``quantize.predict_levels`` — row-local, so
    it vmaps over the batch with no cross-signal state), and zero-plane
    suppression masks all-128 window rows / coefficient columns out of the
    packer's validity mask (the masked chunk packer emits nothing for them,
    so the stream equals a greedy pack of the compacted symbols).  The v3
    return adds per-signal coded-symbol counts and — under zero planes —
    the row/column bitmaps: ``(hi, lo, sl, wpc, bad, ncoded, zrow, zcol)``.
    """
    windows = dct.window_signal(signals, n)  # [K, Wp, n]
    coeffs = dct.forward_dct(windows, e)  # [K, Wp, e]
    syms = quantize(coeffs, tables.quant)  # uint8[K, Wp, e]
    k = signals.shape[0]
    if coding == TRIVIAL_CODING:
        syms = syms.reshape(k, -1).astype(jnp.int32)  # [K, Sp]
        if check_gaps:
            valid = (
                jnp.arange(syms.shape[1], dtype=jnp.int32)[None, :]
                < counts[:, None]
            )
            bad = jnp.any((tables.lengths[syms] == 0) & valid, axis=1)
        else:
            bad = jnp.zeros((k,), jnp.bool_)
        hi, lo, sl, wpc = jax.vmap(
            lambda s, c: symlen.pack_symlen_chunked_parts(
                s,
                tables.codes,
                tables.lengths,
                chunk_size=chunk_size,
                num_symbols=c,
            )
        )(syms, counts)
        return hi, lo, sl, wpc, bad
    pred_id, bands, zplanes = coding
    grid = predict_levels(syms, pred_id, bands)  # uint8[K, Wp, e]
    flat = grid.reshape(k, -1).astype(jnp.int32)  # [K, Sp]
    # true-window mask: batch/window padding quantizes to 128 but its
    # *residuals* need not be 128, so every v3 mask is gated on it
    win_valid = (
        jnp.arange(grid.shape[1], dtype=jnp.int32)[None, :]
        < (counts // e)[:, None]
    )  # bool[K, Wp]
    if zplanes:
        is_zero = grid == jnp.uint8(128)
        # zrow over all rows (padding rows are garbage but the drain slices
        # mask[:num_windows]); zcol over VALID rows only, matching the host
        # encoder's grid which has exactly num_windows rows
        zrow = jnp.all(is_zero, axis=2)  # bool[K, Wp]
        zcol = jnp.all(is_zero | ~win_valid[:, :, None], axis=1)  # [K, e]
        valid = (win_valid & ~zrow)[:, :, None] & ~zcol[:, None, :]
        valid = valid.reshape(k, -1)
        ncoded = jnp.sum(valid, axis=1, dtype=jnp.int32)
    else:
        zrow = zcol = None
        valid = jnp.broadcast_to(win_valid[:, :, None], grid.shape)
        valid = valid.reshape(k, -1)
        ncoded = counts
    if check_gaps:
        bad = jnp.any((tables.lengths[flat] == 0) & valid, axis=1)
    else:
        bad = jnp.zeros((k,), jnp.bool_)
    hi, lo, sl, wpc = jax.vmap(
        lambda s, v: symlen.pack_symlen_chunked_parts(
            s,
            tables.codes,
            tables.lengths,
            chunk_size=chunk_size,
            valid=v,
        )
    )(flat, valid)
    return hi, lo, sl, wpc, bad, ncoded, zrow, zcol


_encode_bucket = functools.partial(
    jax.jit,
    static_argnames=("n", "e", "chunk_size", "check_gaps", "coding"),
)(_encode_bucket_math)


def _gather_rows_math(
    flat: jnp.ndarray,  # f32[T + width] (flattened decoded windows)
    starts: jnp.ndarray,  # int32[K] first-sample flat offset per row
    lens: jnp.ndarray,  # int32[K] true sample count per row
    width: int,
) -> jnp.ndarray:
    """Stage one encode bucket's signal matrix ``f32[K, width]`` on device.

    Row ``r`` gathers samples ``[starts[r], starts[r] + lens[r])`` of the
    flattened window tensors and is exact-zero beyond ``lens[r]`` — the
    same layout ``BatchEncoder.encode`` stages host-side (a decoded
    signal's own window padding is *re-decoded* data, not zeros, so the
    mask is what keeps device staging bit-identical to the host path).

    ``flat`` must already carry >= ``width`` trailing zeros past the last
    real start (the transcode pipeline pads ONCE by the widest bucket) so
    every slice stays in bounds — dynamic_slice clamps out-of-range starts,
    which would silently shift a tail row's window otherwise.  Every row is
    one contiguous sample run, so the cheap lowering is a batched
    dynamic_slice (row-wise block copy) + tail mask — NOT a per-element
    gather, which costs ~2x the fused encode itself on CPU.
    """
    pos = jnp.arange(width, dtype=jnp.int32)

    def row(start, length):
        x = jax.lax.dynamic_slice(flat, (start,), (width,))
        return jnp.where(pos < length, x, jnp.zeros((), flat.dtype))

    return jax.vmap(row)(starts, lens)


def _encode_bucket_gather_math(
    flat: jnp.ndarray,
    starts: jnp.ndarray,
    lens: jnp.ndarray,
    counts: jnp.ndarray,
    tables: DeviceTables,
    *,
    width: int,
    n: int,
    e: int,
    chunk_size: int,
    check_gaps: bool,
    coding: Tuple[int, int, bool] = TRIVIAL_CODING,
):
    """Device staging fused INTO the bucket encode: gather + DCT + quantize
    + pack in one jit per bucket (the former separate ``_gather_rows``
    dispatch is gone — its output never materializes in HBM between two
    launches)."""
    x = _gather_rows_math(flat, starts, lens, width)
    return _encode_bucket_math(
        x, counts, tables, n=n, e=e, chunk_size=chunk_size,
        check_gaps=check_gaps, coding=coding,
    )


_GATHER_STATICS = ("width", "n", "e", "chunk_size", "check_gaps", "coding")
_encode_bucket_gather = functools.partial(
    jax.jit, static_argnames=_GATHER_STATICS
)(_encode_bucket_gather_math)
# the last bucket to read a GatherStage's flat tensor may donate it, letting
# XLA reuse the decoded-window buffer for the pack outputs (no-op on CPU,
# where donation is unsupported — callers gate on the device platform)
_encode_bucket_gather_donate = functools.partial(
    jax.jit, static_argnames=_GATHER_STATICS, donate_argnums=(0,)
)(_encode_bucket_gather_math)


def _donation_supported(device) -> bool:
    platform = device.platform if device is not None else jax.default_backend()
    return platform in ("gpu", "tpu")


# ---------------------------------------------------------------------------
# The kernel-path twins: the fused Pallas encode tile instead of the XLA
# DCT+quant+pack — bit-identical output (pinned by the golden/conformance
# suites), one pallas_call per bucket.
# ---------------------------------------------------------------------------
def _encode_bucket_kernels_math(
    signals, counts, tables, basis, *, n, e, chunk_size, check_gaps,
    coding=TRIVIAL_CODING, tuning_epoch=0,
):
    # tuning_epoch is a pure retrace key (see batch_decode._decode_bucket):
    # the kernel resolves its rows-per-step block from the tuning cache at
    # trace time, so a cache store must invalidate old specializations
    del tuning_epoch
    from repro.kernels import ops as kops

    return kops.encode_bucket_fused(
        signals, counts, tables, basis,
        n=n, e=e, chunk_size=chunk_size, check_gaps=check_gaps,
        coding=coding,
    )


_encode_bucket_kernels = functools.partial(
    jax.jit,
    static_argnames=(
        "n", "e", "chunk_size", "check_gaps", "coding", "tuning_epoch"
    ),
)(_encode_bucket_kernels_math)


def _encode_bucket_gather_kernels_math(
    flat, starts, lens, counts, tables, basis,
    *, width, n, e, chunk_size, check_gaps, coding=TRIVIAL_CODING,
    tuning_epoch=0,
):
    """GatherStage staging for the kernel path: the row gather stays an XLA
    ``dynamic_slice`` batch fused into the same jit as the pallas_call (the
    gather feeds straight into the kernel's operand; no HBM round trip of a
    separately-dispatched signal matrix)."""
    x = _gather_rows_math(flat, starts, lens, width)
    return _encode_bucket_kernels_math(
        x, counts, tables, basis,
        n=n, e=e, chunk_size=chunk_size, check_gaps=check_gaps,
        coding=coding, tuning_epoch=tuning_epoch,
    )


_GATHER_KERNEL_STATICS = _GATHER_STATICS + ("tuning_epoch",)
_encode_bucket_gather_kernels = functools.partial(
    jax.jit, static_argnames=_GATHER_KERNEL_STATICS
)(_encode_bucket_gather_kernels_math)
_encode_bucket_gather_kernels_donate = functools.partial(
    jax.jit, static_argnames=_GATHER_KERNEL_STATICS, donate_argnums=(0,)
)(_encode_bucket_gather_kernels_math)


# ---------------------------------------------------------------------------
# Fixed-rate (entropy-off) mode: transform + table quantization only.
#
# The KV-cache workload keeps compressed blocks *fixed-size* so cold cache
# reads stay O(1) during decode — entropy coding would make block size
# data-dependent, and its rate win on narrow post-RMSNorm coefficient
# distributions is small anyway.  The fixed-rate path is the front half of
# the container pipeline (same window/DCT/quantize code, same calibrated
# tables riding the same EncodePlan cache) with the packer cut off: levels
# come back as a device-resident uint8 tensor whose shape is a pure
# function of the input shape.  Everything stays on device — no host
# staging, no drain; the caller owns the levels array.
# ---------------------------------------------------------------------------
def _encode_fixed_math(
    x: jnp.ndarray,  # f32[..., T] channel strips, T % n == 0
    tables: DeviceTables,
    *,
    n: int,
    e: int,
) -> jnp.ndarray:
    w = x.shape[-1] // n
    windows = x.reshape(x.shape[:-1] + (w, n))
    coeffs = dct.forward_dct(windows, e)
    return quantize(coeffs, tables.quant)  # uint8[..., W, e]


_encode_fixed = functools.partial(
    jax.jit, static_argnames=("n", "e")
)(_encode_fixed_math)


def _encode_fixed_kernels_math(
    x, tables, basis, *, n, e, tuning_epoch=0
):
    # the Pallas DCT+quant tile with the exact-parity quantization arm:
    # levels are BIT-identical to the XLA arm (pinned in test_workloads),
    # so the kernels toggle changes which programs run — never bytes
    del tuning_epoch
    from repro.kernels import ops as kops

    w = x.shape[-1] // n
    windows = x.reshape(-1, n)
    levels = kops.dct_quant(windows, tables.quant, e=e, basis=basis,
                            exact=True)
    return levels.astype(jnp.uint8).reshape(x.shape[:-1] + (w, e))


_encode_fixed_kernels = functools.partial(
    jax.jit, static_argnames=("n", "e", "tuning_epoch")
)(_encode_fixed_kernels_math)


# ---------------------------------------------------------------------------
# Encoded batches: streams stay on device until explicitly drained.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Slice:
    """Where signal i's stream lives: row ``row`` of bucket ``bucket``'s
    output arrays, plus the host-side container header fields."""

    bucket: int
    row: int
    num_windows: int
    signal_length: int
    n: int
    e: int
    l_max: int
    domain_id: int
    coding: Tuple[int, int, bool] = TRIVIAL_CODING


@dataclasses.dataclass(frozen=True)
class EncodedBucketParts:
    """One bucket's device-resident encode output, un-stitched.

    ``hi``/``lo``/``symlen`` are the per-chunk word runs
    ``[K, num_chunks, chunk_size]`` and ``words_per_chunk`` ``[K,
    num_chunks]`` — exactly what :func:`repro.core.symlen.
    pack_symlen_chunked_parts` produces per signal, batched over the
    bucket's ``K`` rows (rows past the real signals are batch padding and
    pack zero words).  ``unencodable`` is the bucket's device-side
    histogram-gap flag — per ROW (``bool[K]``; padding rows stay False) so
    a drain can demote the fault to a per-signal outcome — checked at
    drain.  ``shard``/``device`` record the
    scheduler placement (device None = default single-shard).  This is the
    shared stream contract between the encode engine and device-resident
    consumers (the transcode pipeline stitches these straight into decoder
    bucket streams via ``symlen.stitch_chunk_parts`` — no host round
    trip, each shard staying on its own device).

    Buckets encoded under a non-trivial coding (container v3) additionally
    carry per-signal coded-symbol counts ``ncoded`` and — when zero-plane
    suppression is on — the device-resident ``zrow``/``zcol`` masks; for
    trivial (v2) buckets all three stay ``None`` and the drain syncs
    exactly the arrays it always did.
    """

    plan_key: tuple  # (domain_id, n, e, l_max, coding)
    hi: jnp.ndarray  # uint32[K, B, C]
    lo: jnp.ndarray  # uint32[K, B, C]
    symlen: jnp.ndarray  # int32[K, B, C]
    words_per_chunk: jnp.ndarray  # int32[K, B]
    unencodable: jnp.ndarray  # bool[K]
    shard: int = 0
    device: object = None
    ncoded: Optional[jnp.ndarray] = None  # int32[K] (v3 only)
    zrow: Optional[jnp.ndarray] = None  # bool[K, Wp] (v3 zero planes)
    zcol: Optional[jnp.ndarray] = None  # bool[K, e] (v3 zero planes)

    @property
    def chunk_size(self) -> int:
        return int(self.hi.shape[2])

    @property
    def num_chunks(self) -> int:
        return int(self.hi.shape[1])

    def words_per_signal(self) -> jnp.ndarray:
        """Per-row word extents int32[K] — a device array (no sync)."""
        return jnp.sum(self.words_per_chunk, axis=1)


class EncodedBatch:
    """Result of :meth:`BatchEncoder.encode` — device-resident streams.

    ``to_host()`` performs the only host sync: every bucket's d2h copies
    start before any materializes (shard drains overlap), a histogram-gap
    check runs first (the device-side arm of the pack precheck), then
    numpy slicing into per-signal :class:`Container`\\ s (input order
    preserved).

    A batch drains **once**.  A second ``to_host()`` — or any drain after
    the device buffers were handed to a :class:`~repro.serving.transcode.
    Transcoder` — raises instead of silently re-syncing (the buffers may by
    then be donated or re-encoded under a different config, so a quiet
    second drain is a stale-data hazard).  Device-resident consumers read
    :meth:`device_parts` / :meth:`signal_slices` instead of draining.
    """

    def __init__(
        self,
        buckets: List[EncodedBucketParts],
        slices: List[Optional[_Slice]],
        pending_flags: Sequence[Tuple[tuple, jnp.ndarray]] = (),
        *,
        poisoned: Optional[Dict[int, Exception]] = None,
        quarantine: bool = False,
    ):
        self._buckets = buckets
        self._slices = slices
        # histogram-gap flags inherited from upstream device stages (a
        # transcode's source batch): checked at drain like our own
        self._pending_flags = list(pending_flags)
        # quarantine records: signals excluded before encoding (slice is
        # None at their index); the drain returns their typed error
        self._poisoned: Dict[int, Exception] = dict(poisoned or {})
        # quarantine drains demote a device-side histogram-gap flag from a
        # batch-fatal ValueError to a per-signal PoisonedContainerError
        self._quarantine = bool(quarantine)
        self._consumed: Optional[str] = None

    def __len__(self) -> int:
        return len(self._slices)

    def device_parts(self) -> List[EncodedBucketParts]:
        """The per-bucket chunk parts as device arrays — no host sync."""
        self._check_live("read device parts of")
        return list(self._buckets)

    def signal_slices(self) -> List[_Slice]:
        """Per-signal (input order) location + header metadata: which
        bucket/row holds signal i's chunk parts, plus the container header
        fields (num_windows, signal_length, n, e, l_max, domain_id)."""
        return list(self._slices)

    def block_until_ready(self) -> "EncodedBatch":
        for p in self._buckets:
            p.words_per_chunk.block_until_ready()
        return self

    def _check_live(self, verb: str) -> None:
        if self._consumed is not None:
            raise RuntimeError(
                f"cannot {verb} this EncodedBatch: {self._consumed}"
            )

    def _mark_consumed(self, reason: str) -> None:
        self._check_live("consume")
        self._consumed = reason

    def to_host(self) -> List[Any]:
        """Drain the batch into containers: one sync per bucket (all d2h
        copies in flight together), then a host-side stitch of each
        signal's chunk word-runs (chunk b of signal k contributes its
        row's first ``wpc[k, b]`` words).  The stitch is double-buffered
        (:func:`repro.serving.engine.fetch_to_host_stitched`): a worker
        concatenates bucket k's numpy chunk runs while bucket k+1's d2h
        copies land.

        A quarantined batch returns a :class:`~repro.serving.quarantine.
        PoisonedContainerError` at each poisoned signal's position instead
        of a :class:`Container` — never a batch-wide raise for per-signal
        faults.  Without quarantine, a device-side histogram-gap flag stays
        batch-fatal (the offline contract)."""
        self._check_live("drain")

        def _gap_error(key):
            # leave the batch live: a failed drain returned nothing, so
            # a retry must re-raise this error, not a bogus
            # "already drained" message
            return ValueError(
                f"encode batch for plan_key "
                f"(domain_id, n, e, l_max, coding)="
                f"{key} produced symbol(s) with no codeword (histogram "
                "gap in the Huffman book) — the stream would decode to "
                "garbage; recalibrate with Laplace smoothing or a "
                "complete codebook"
            )

        # upstream flags (a transcode's source batch) have no row->signal
        # mapping here, so they stay batch-fatal even under quarantine
        for key, flag in self._pending_flags:
            if bool(np.any(np.asarray(flag))):
                raise _gap_error(key)
        bucket_bad = [np.asarray(p.unencodable) for p in self._buckets]
        poisoned: Dict[int, Exception] = dict(self._poisoned)
        if self._quarantine:
            # demote the device-side gap flag to per-signal outcomes: the
            # flagged row's stream is garbage, but every other row packed
            # independently and drains byte-identically to a clean run
            from repro.serving.quarantine import (
                FAULT_HISTOGRAM_GAP,
                PoisonedContainerError,
            )

            for i, s in enumerate(self._slices):
                if s is None or i in poisoned:
                    continue
                if bool(bucket_bad[s.bucket][s.row]):
                    poisoned[i] = PoisonedContainerError(
                        "signal quantizes to symbol(s) with no codeword "
                        "(histogram gap in the Huffman book) under "
                        f"plan_key (domain_id, n, e, l_max, coding)="
                        f"{self._buckets[s.bucket].plan_key} — "
                        "recalibrate with Laplace smoothing or a complete "
                        "codebook",
                        index=i,
                        fault=FAULT_HISTOGRAM_GAP,
                    )
        else:
            for p, bad in zip(self._buckets, bucket_bad):
                if bool(np.any(bad)):
                    raise _gap_error(p.plan_key)

        per_bucket: List[List[Tuple[int, _Slice]]] = [
            [] for _ in self._buckets
        ]
        for i, s in enumerate(self._slices):
            if s is None or i in poisoned:
                continue
            per_bucket[s.bucket].append((i, s))

        def stitch_bucket(b: int, host: List[np.ndarray]):
            hi, lo, sl, wpc = host[:4]
            # v3 buckets drain (ncoded[, zrow, zcol]) after the stream parts
            ncoded = host[4] if len(host) > 4 else None
            zrow = host[5] if len(host) > 5 else None
            zcol = host[6] if len(host) > 6 else None
            stitched = []
            for i, s in per_bucket[b]:
                runs = [
                    (hi[s.row, c, :w], lo[s.row, c, :w], sl[s.row, c, :w])
                    for c, w in enumerate(wpc[s.row])
                    if w
                ]
                if runs:
                    hi_cat = np.concatenate([r[0] for r in runs])
                    lo_cat = np.concatenate([r[1] for r in runs])
                    sl_cat = np.concatenate([r[2] for r in runs])
                else:
                    hi_cat = lo_cat = np.empty(0, np.uint32)
                    sl_cat = np.empty(0, np.int32)
                pred_id, bands, zplanes = s.coding
                num_symbols = (
                    s.num_windows * s.e if ncoded is None
                    else int(ncoded[s.row])
                )
                stitched.append((i, Container(
                    words=symlen.u32_to_words(hi_cat, lo_cat),
                    symlen=sl_cat.astype(np.uint8),
                    num_symbols=num_symbols,
                    num_windows=s.num_windows,
                    signal_length=s.signal_length,
                    n=s.n,
                    e=s.e,
                    l_max=s.l_max,
                    domain_id=s.domain_id,
                    predictor=pred_id,
                    predict_bands=bands,
                    zero_planes=zplanes,
                    zrow=(
                        zrow[s.row, : s.num_windows].copy()
                        if zplanes else None
                    ),
                    zcol=zcol[s.row].copy() if zplanes else None,
                )))
            return stitched

        def drain_arrays(p: EncodedBucketParts):
            arrs = (p.hi, p.lo, p.symlen, p.words_per_chunk)
            if p.ncoded is not None:
                arrs += (p.ncoded,)
            if p.zrow is not None:
                arrs += (p.zrow, p.zcol)
            return arrs

        results = fetch_to_host_stitched(
            [drain_arrays(p) for p in self._buckets],
            stitch_bucket,
        )
        self._consumed = (
            "it was already drained by to_host() — hold on to the returned "
            "containers instead of draining twice"
        )
        out: List[Any] = [None] * len(self._slices)
        for i, err in poisoned.items():
            out[i] = err
        for stitched in results:
            for i, c in stitched:
                out[i] = c
        return out


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BatchEncoderStats:
    batches: int = 0
    signals: int = 0
    dispatches: int = 0  # fused bucket launches
    plan_hits: int = 0
    plan_misses: int = 0
    # per-dispatch padding/occupancy records (bounded history) — the
    # encode-side twin of BatchDecoderStats.bucket_pad
    bucket_pad: "deque[dict]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=1024)
    )


class BatchEncoder:
    """Encodes many signals in a bounded number of fused dispatches.

    Usage::

        enc = BatchEncoder()                      # chunked (fast) packing
        batch = enc.encode(signals, tables)       # tables: DomainTables, or
                                                  # {domain_id: DomainTables}
                                                  # + domain_ids=[...]
        containers = batch.to_host()              # one sync per bucket

    Signals are grouped by (domain, config) and sub-bucketed by power-of-two
    window and batch counts; each bucket is one fused dispatch.
    ``chunk_size=None`` selects *exact* mode (one packing chunk per
    signal): bit-identical output to ``core.codec.encode`` at the price of a
    length-S packing scan — that is what ``encode_device`` uses.
    ``pipeline``/``devices``/``prefetch`` are the shared engine-layer knobs
    (see :mod:`repro.serving.engine`): double-buffered staging and
    per-device bucket shards, neither of which changes output bytes.
    """

    def __init__(
        self,
        *,
        chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
        use_kernels: Optional[bool] = None,
        plan_cache_size: int = 32,
        pipeline: bool = True,
        devices: DevicesArg = "auto",
        prefetch: int = 2,
        policy: PolicyArg = None,
        cost_model: Optional[CostModel] = None,
    ):
        if chunk_size is not None and chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        self.chunk_size = chunk_size
        # None defers to the process-wide FPTC_USE_KERNELS default; the
        # fused Pallas tile is bit-identical to the XLA path, so the toggle
        # changes which device programs run — never bytes
        if use_kernels is None:
            use_kernels = default_use_kernels()
        self.use_kernels = use_kernels
        self._plans = PlanCache(_build_encode_plan, plan_cache_size)
        self.scheduler = BucketScheduler(devices=devices, policy=policy)
        self.executor = PipelineExecutor(pipeline=pipeline, prefetch=prefetch)
        self.cost_model = (
            cost_model if cost_model is not None else default_cost_model()
        )
        self.stats = BatchEncoderStats()
        self._pending = SubmitBuffer()

    # -- incremental submission (the front-end's surface) -------------------
    def submit(
        self, signal: np.ndarray, domain_id: Optional[int] = None
    ) -> int:
        """Queue one signal for the next :meth:`flush` (thread-safe).

        The incremental half of the batch-at-once :meth:`encode` — see
        :meth:`BatchDecoder.submit`.  ``domain_id`` routes the signal's
        tables when the flush passes a mapping; row bytes never depend on
        which other signals share the flush.
        """
        return self._pending.submit((signal, domain_id))

    @property
    def pending(self) -> int:
        """Signals submitted since the last flush."""
        return len(self._pending)

    def flush(
        self, tables: TablesArg, *, quarantine: bool = False
    ) -> EncodedBatch:
        """Encode everything submitted since the last flush as one batch
        (submission order).  An empty flush is a no-op empty batch."""
        items = self._pending.take()
        signals = [s for s, _ in items]
        doms = [d for _, d in items]
        if all(d is None for d in doms):
            domain_ids = None
        elif any(d is None for d in doms):
            if not isinstance(tables, DomainTables):
                raise ValueError(
                    "flush with a {domain_id: DomainTables} mapping needs "
                    "every submit() to carry a domain_id"
                )
            domain_ids = [
                tables.domain_id if d is None else d for d in doms
            ]
        else:
            domain_ids = doms
        return self.encode(
            signals, tables, domain_ids=domain_ids, quarantine=quarantine
        )

    # -- plan management ---------------------------------------------------
    def _tables_for(self, domain_id: int, tables: TablesArg) -> DomainTables:
        if isinstance(tables, DomainTables):
            return tables
        try:
            return tables[domain_id]
        except KeyError:
            raise KeyError(
                f"no DomainTables registered for domain_id={domain_id}"
            ) from None

    def plan_for(self, tables: DomainTables, device=None) -> EncodePlan:
        cfg = tables.config
        key = (tables.domain_id, cfg.n, cfg.e, cfg.l_max, cfg.coding)
        return self._plans.get(tables, key, device)

    # -- fixed-rate (entropy-off) encode -----------------------------------
    def encode_fixed(
        self, x: jnp.ndarray, tables: DomainTables
    ) -> jnp.ndarray:
        """Transform + quantize only: ``f32[..., T]`` -> ``uint8[..., W, E]``.

        The KV-cache workload's O(1)-access mode: compressed size is a pure
        function of input shape (``E/N`` levels per sample, no sidecar), the
        calibrated tables ride the same :class:`EncodePlan` cache as the
        container path, and the result is a device-resident array — no host
        staging on the way in, no drain on the way out.  ``T`` (the last
        axis) must be a multiple of the domain's window size ``n``; leading
        axes are free (a KV block arrives as ``[B, H, D, T]`` channels).
        Decode with :meth:`BatchDecoder.decode_fixed`.

        The ``use_kernels`` toggle selects the Pallas DCT+quant tile in its
        exact-parity arm — levels are bit-identical either way.
        """
        plan = self.plan_for(tables)
        n, e = plan.n, plan.e
        if x.shape[-1] % n:
            raise ValueError(
                f"fixed-rate encode needs the time axis ({x.shape[-1]}) to "
                f"be a multiple of the window size n={n} — pad the block "
                "(fixed-size blocks are the point of this mode)"
            )
        x = jnp.asarray(x, jnp.float32)
        if self.use_kernels:
            levels = _encode_fixed_kernels(
                x, plan.tables, plan.basis, n=n, e=e,
                tuning_epoch=_autotune.epoch(),
            )
        else:
            levels = _encode_fixed(x, plan.tables, n=n, e=e)
        self.stats.dispatches += 1
        return levels

    # -- the batched encode ------------------------------------------------
    def encode(
        self,
        signals: Sequence[np.ndarray],
        tables: TablesArg,
        *,
        domain_ids: Optional[Sequence[int]] = None,
        quarantine: bool = False,
    ) -> EncodedBatch:
        """Encode a (possibly mixed-domain, mixed-length) batch of signals.

        ``domain_ids`` assigns each signal its domain when ``tables`` is a
        mapping; with a single :class:`DomainTables` every signal uses it.
        Returns an :class:`EncodedBatch`; nothing is synced to host here.
        ``quarantine=True`` demotes the device-side histogram-gap flag from
        batch-fatal to a typed per-signal outcome at drain.
        """
        signals = [np.asarray(s, dtype=np.float32).ravel() for s in signals]

        def stage(idxs, kp: int, wp: int, n: int, device) -> np.ndarray:
            x = np.zeros((kp, wp * n), dtype=np.float32)
            for row, i in enumerate(idxs):
                x[row, : signals[i].shape[0]] = signals[i]
            return x

        return self.encode_staged(
            [int(s.shape[0]) for s in signals], tables,
            domain_ids=domain_ids, stage=stage, quarantine=quarantine,
        )

    def encode_staged(
        self,
        lengths: Sequence[int],
        tables: TablesArg,
        *,
        stage,
        domain_ids: Optional[Sequence[int]] = None,
        pending_flags: Sequence[tuple] = (),
        shard_ids: Optional[Sequence[int]] = None,
        shard_devices: Optional[Dict[int, object]] = None,
        quarantine: bool = False,
    ) -> EncodedBatch:
        """The bucketing/dispatch core of :meth:`encode`, with the signal
        *staging* pluggable.

        ``stage(idxs, kp, wp, n, device)`` must produce the bucket's
        stacked signal matrix ``f32[kp, wp * n]`` — row ``r`` holds signal
        ``idxs[r]``'s samples followed by exact zeros, rows past
        ``len(idxs)`` all-zero — as a host/device array, **or** a
        :class:`~repro.serving.engine.GatherStage` describing the rows as
        slices of a device-resident flat tensor, in which case the gather
        happens *inside* the bucket's fused dispatch (the transcode
        pipeline's path).  Under pipelining the stage callback runs on the
        executor's staging worker, one bucket ahead of dispatch.
        Everything else — grouping, padding, chunk-size selection, shard
        assignment (``shard_ids`` pins signals to shards, with
        ``shard_devices`` mapping foreign shard ids to their devices when
        the pinning comes from another scheduler; default is a contiguous
        split per bucket), the fused dispatch, slice metadata — is this
        one code path, which is what makes device-staged encodes
        byte-identical to host-staged ones.
        """
        self.stats.batches += 1
        self.stats.signals += len(lengths)
        if not lengths:
            return EncodedBatch(
                [], [], pending_flags, quarantine=quarantine
            )
        if domain_ids is None:
            if not isinstance(tables, DomainTables):
                raise ValueError(
                    "domain_ids is required when tables is a "
                    "{domain_id: DomainTables} mapping"
                )
            domain_ids = [tables.domain_id] * len(lengths)
        if len(domain_ids) != len(lengths):
            raise ValueError(
                f"domain_ids has {len(domain_ids)} entries for "
                f"{len(lengths)} signals"
            )

        # group by ((domain, config), windows bucket), shard-split — one
        # fused dispatch per (group, shard); batch dim padded to a bucket
        # edge in the upload stage.  The window bucket follows the
        # scheduler's policy ladder, so a denser policy both shrinks row
        # padding AND splits fewer-window signals away from wide ones.
        keys = []
        per_tab: Dict[tuple, DomainTables] = {}
        all_windows: List[int] = []
        for length, dom in zip(lengths, domain_ids):
            tab = self._tables_for(dom, tables)
            cfg = tab.config
            num_windows = -(-length // cfg.n)
            all_windows.append(num_windows)
            key = (
                (dom, cfg.n, cfg.e, cfg.l_max, cfg.coding),
                self.scheduler.round(max(num_windows, 1)),
            )
            keys.append(key)
            per_tab.setdefault(key, tab)
        # cost-balanced shard split over predicted per-signal encode cost
        # (only worth computing when there is more than one shard and the
        # scheduler actually splits — pinned shard_ids bypass the split)
        item_costs = None
        if self.scheduler.num_shards > 1 and shard_ids is None:
            item_costs = [
                self.cost_model.signal_encode_cost(
                    w, e=key[0][2], n=key[0][1]
                )
                for w, key in zip(all_windows, keys)
            ]
        buckets = self.scheduler.buckets(
            keys, shard_ids=shard_ids, shard_devices=shard_devices,
            item_costs=item_costs,
        )

        slices: List[Optional[_Slice]] = [None] * len(lengths)
        for b, bucket in enumerate(buckets):
            plan_key, wp = bucket.key
            _, n, e, l_max, coding = plan_key
            for row, i in enumerate(bucket.items):
                slices[i] = _Slice(
                    bucket=b,
                    row=row,
                    num_windows=-(-lengths[i] // n),
                    signal_length=int(lengths[i]),
                    n=n,
                    e=e,
                    l_max=l_max,
                    domain_id=plan_key[0],
                    coding=coding,
                )

        def upload(bucket: Bucket):
            plan_key, wp = bucket.key
            _, n, e, _, _ = plan_key
            idxs = list(bucket.items)
            # pad batch dim to a bucket edge; pad rows pack 0 symbols
            kp = self.scheduler.round(len(idxs))
            counts = np.zeros((kp,), dtype=np.int32)
            for row, i in enumerate(idxs):
                counts[row] = -(-lengths[i] // n) * e
            put = putter(bucket.device)
            # shard-aware plan prefetch: the staging worker pays this
            # bucket's table/basis device_put, not its first dispatch
            self._plans.get(per_tab[bucket.key], plan_key, bucket.device)
            x = stage(idxs, kp, wp, n, bucket.device)
            if not isinstance(x, GatherStage):
                # place host AND device stage results: a stage returning an
                # uncommitted jnp array must still land on the bucket's
                # shard, or the fused jit would see operands on two devices
                x = put(x)
            return x, put(counts)

        def dispatch(bucket: Bucket, staged):
            x, counts = staged
            plan_key, wp = bucket.key
            plan = self._plans.get(
                per_tab[bucket.key], plan_key, bucket.device
            )
            n, e = plan.n, plan.e
            coding = plan_key[4]
            sp = wp * e
            chunk = sp if self.chunk_size is None else min(self.chunk_size, sp)
            if isinstance(x, GatherStage):
                donate = x.donate and _donation_supported(bucket.device)
                if self.use_kernels:
                    fused = (
                        _encode_bucket_gather_kernels_donate
                        if donate else _encode_bucket_gather_kernels
                    )
                    out = fused(
                        x.flat, x.starts, x.lens, counts, plan.tables,
                        plan.basis, width=wp * n, n=n, e=e,
                        chunk_size=chunk, check_gaps=plan.has_gaps,
                        coding=coding, tuning_epoch=_autotune.epoch(),
                    )
                else:
                    fused = (
                        _encode_bucket_gather_donate
                        if donate else _encode_bucket_gather
                    )
                    out = fused(
                        x.flat, x.starts, x.lens, counts, plan.tables,
                        width=wp * n, n=n, e=e, chunk_size=chunk,
                        check_gaps=plan.has_gaps, coding=coding,
                    )
                kp = int(x.starts.shape[0])
            elif self.use_kernels:
                out = _encode_bucket_kernels(
                    x, counts, plan.tables, plan.basis,
                    n=n, e=e, chunk_size=chunk, check_gaps=plan.has_gaps,
                    coding=coding, tuning_epoch=_autotune.epoch(),
                )
                kp = int(x.shape[0])
            else:
                out = _encode_bucket(
                    x, counts, plan.tables,
                    n=n, e=e, chunk_size=chunk, check_gaps=plan.has_gaps,
                    coding=coding,
                )
                kp = int(x.shape[0])
            if coding == TRIVIAL_CODING:
                hi, lo, sl, wpc, bad = out
                ncoded = zrow = zcol = None
            else:
                hi, lo, sl, wpc, bad, ncoded, zrow, zcol = out
            self.stats.dispatches += 1
            self.stats.bucket_pad.append({
                "plan_key": plan_key,
                "shard": bucket.shard,
                "policy": self.scheduler.policy.name,
                "rows": len(bucket.items),
                "rows_padded": kp,
                "windows": sum(
                    -(-lengths[i] // n) for i in bucket.items
                ),
                "windows_padded": wp * kp,
            })
            return EncodedBucketParts(
                plan_key=plan_key, hi=hi, lo=lo, symlen=sl,
                words_per_chunk=wpc, unencodable=bad,
                shard=bucket.shard, device=bucket.device,
                ncoded=ncoded, zrow=zrow, zcol=zcol,
            )

        out_buckets = self.executor.run(buckets, upload, dispatch)
        self.stats.plan_hits = self._plans.hits
        self.stats.plan_misses = self._plans.misses
        return EncodedBatch(
            out_buckets, slices, pending_flags, quarantine=quarantine
        )

    def encode_to_host(
        self,
        signals: Sequence[np.ndarray],
        tables: TablesArg,
        *,
        domain_ids: Optional[Sequence[int]] = None,
    ) -> List[Container]:
        """Convenience: encode + drain in one call."""
        return self.encode(
            signals, tables, domain_ids=domain_ids
        ).to_host()


# ---------------------------------------------------------------------------
# Process-wide default encoders (codec.encode_device rides the exact one).
# ---------------------------------------------------------------------------
_DEFAULTS: Dict[Tuple[Optional[int], bool], BatchEncoder] = {}


def default_encoder(chunk_size: Optional[int] = None) -> BatchEncoder:
    """Shared encoder per (chunk size, resolved use_kernels).  ``None``
    chunk size (the default) is *exact* mode — bit-identical to the host
    encoder — which is what ``core.codec.encode_device`` rides; pass
    ``DEFAULT_CHUNK_SIZE`` (or any chunk) for the fast chunk-parallel
    packer.  The kernel toggle resolves from ``FPTC_USE_KERNELS`` *per
    call* (mirroring ``batch_decode.default_decoder``), so flipping the
    env mid-process switches which cached engine serves — bytes are
    identical either way.

    Being process-global, its plan cache keeps up to ``plan_cache_size``
    (32) recently-used DomainTables — and their device buffers — alive for
    the process lifetime (same trade as ``batch_decode.default_decoder``);
    callers churning many ephemeral table sets should hold their own
    :class:`BatchEncoder` and drop it when done."""
    use_kernels = default_use_kernels()
    key = (chunk_size, use_kernels)
    enc = _DEFAULTS.get(key)
    if enc is None:
        enc = _DEFAULTS[key] = BatchEncoder(
            chunk_size=chunk_size, use_kernels=use_kernels
        )
    return enc
