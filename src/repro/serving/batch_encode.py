"""Batched bucketed encode engine: one fused dispatch per shape bucket.

PR 1 made decode archive-scale; this is the encode-side mirror, built for
server-side ingest/transcoding and re-encode benchmarks (the paper's
*embedded* encoder stays ``core.codec.encode`` — sequential by design).
A per-signal ``encode_device`` loop pays the same three taxes the decode
engine removed, plus one of its own:

  1. **serial packing** — ``symlen.pack_symlen_scan`` is one ``lax.scan``
     step per symbol, a length-S dependency chain that no amount of batching
     hides;
  2. **recompilation** — per-signal jit retraces for every distinct length;
  3. **table re-upload + host sync** — tables travel per call and
     ``int(num_words)`` blocks on every container.

This module removes all four:

  * **Chunk-parallel packing.**  ``symlen.pack_symlen_chunked_parts`` packs
    B fixed-size chunks concurrently (vmap of scan-lite chunk packs — the
    scan carries only the O(1) bit-offset recurrence; words materialize as
    cumsum differences at searchsorted segment boundaries, scatter-free).
    The SymLen format makes the chunked output decoder-compatible bit for
    bit (each word is independently decodable), at < 1 padding word per
    chunk of stream growth.
  * **Shape bucketing.**  Signals are grouped by (domain, config) and padded
    into power-of-two window/batch buckets, so jit specializations are
    O(log sizes).  Per-signal symbol counts ride a device array into the
    packer's validity mask — never trace constants.
  * **Persistent encode plans.**  Device tables upload once per
    (domain, config) into an LRU :class:`EncodePlan` cache.
  * **Device-resident results.**  Encoded streams stay on device inside an
    :class:`EncodedBatch` until an explicit ``.to_host()`` drain — one sync
    per bucket, where the zero-length-codeword flag is also checked (the
    device-side arm of the ``pack_symlen_np`` histogram-gap guard).

``core.codec.encode_device`` is a batch-of-one wrapper over this engine in
*exact* mode (``chunk_size=None`` — one chunk per signal), which keeps its
output bit-identical to the host encoder.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dct, symlen
from repro.core.calibration import DeviceTables, DomainTables
from repro.core.container import Container
from repro.core.quantize import quantize
from repro.serving._plans import PlanCache
from repro.serving.batch_decode import _p2

__all__ = [
    "BatchEncoder",
    "EncodedBatch",
    "EncodedBucketParts",
    "EncodePlan",
    "default_encoder",
    "DEFAULT_CHUNK_SIZE",
]

TablesArg = Union[DomainTables, Mapping[int, DomainTables]]

# Symbols per packing chunk.  Words per chunk ~= chunk * avg_bits / 64, so at
# ~4 bits/symbol a 1024-symbol chunk spans ~64 words and the <1-word-per-chunk
# padding bound costs < ~1.6% stream growth, while the packing scan shrinks
# from length S to length 1024 with S/1024 parallel lanes per signal.
DEFAULT_CHUNK_SIZE = 1024


# ---------------------------------------------------------------------------
# Encode plans: per-(domain, config) device state, uploaded once.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EncodePlan:
    """Device-resident encode state for one (domain, config).

    Everything here is batch-size independent: one plan serves every bucket
    shape.  ``has_gaps`` records (host-side, at plan build) whether the
    Huffman book has zero-length entries — only then does the fused encode
    pay for the device-side unencodable-symbol check.
    """

    tables: DeviceTables
    n: int
    e: int
    l_max: int
    domain_id: int
    has_gaps: bool
    source: DomainTables  # host tables (kept so cache keys stay alive)


def _build_encode_plan(
    tables: DomainTables, key: Tuple[int, int, int, int]
) -> EncodePlan:
    domain_id, n, e, l_max = key
    return EncodePlan(
        tables=tables.device_tables(),
        n=n,
        e=e,
        l_max=l_max,
        domain_id=domain_id,
        has_gaps=bool(np.any(np.asarray(tables.book.lengths) == 0)),
        source=tables,
    )


# ---------------------------------------------------------------------------
# The fused bucket encode — ONE jit specialization per bucket shape.
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("n", "e", "chunk_size", "check_gaps")
)
def _encode_bucket(
    signals: jnp.ndarray,  # f32[K, Wp * n] (zero-padded signals)
    counts: jnp.ndarray,  # int32[K] true symbol count per signal
    tables: DeviceTables,
    *,
    n: int,
    e: int,
    chunk_size: int,
    check_gaps: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """DCT + quantize + chunk-parallel pack for one shape bucket.

    Statics are *bucket shape only*; per-signal true lengths ride in
    ``counts`` and become the packer's validity mask, so zero-padded windows
    contribute no symbols to any stream.  Returns the per-signal *chunk
    parts* (hi/lo/symlen ``[K, B, chunk_size]`` + words-per-chunk
    ``[K, B]``) — the drain concatenates chunk runs on the host, which is
    cheaper than a device-side stitch and byte-identical — plus the
    batch-wide unencodable-symbol flag (const False unless the book has
    histogram gaps).
    """
    windows = dct.window_signal(signals, n)  # [K, Wp, n]
    coeffs = dct.forward_dct(windows, e)  # [K, Wp, e]
    syms = quantize(coeffs, tables.quant)  # uint8[K, Wp, e]
    k = signals.shape[0]
    syms = syms.reshape(k, -1).astype(jnp.int32)  # [K, Sp]
    if check_gaps:
        valid = (
            jnp.arange(syms.shape[1], dtype=jnp.int32)[None, :]
            < counts[:, None]
        )
        bad = jnp.any((tables.lengths[syms] == 0) & valid)
    else:
        bad = jnp.zeros((), jnp.bool_)
    hi, lo, sl, wpc = jax.vmap(
        lambda s, c: symlen.pack_symlen_chunked_parts(
            s,
            tables.codes,
            tables.lengths,
            chunk_size=chunk_size,
            num_symbols=c,
        )
    )(syms, counts)
    return hi, lo, sl, wpc, bad


# ---------------------------------------------------------------------------
# Encoded batches: streams stay on device until explicitly drained.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Slice:
    """Where signal i's stream lives: row ``row`` of bucket ``bucket``'s
    output arrays, plus the host-side container header fields."""

    bucket: int
    row: int
    num_windows: int
    signal_length: int
    n: int
    e: int
    l_max: int
    domain_id: int


@dataclasses.dataclass(frozen=True)
class EncodedBucketParts:
    """One bucket's device-resident encode output, un-stitched.

    ``hi``/``lo``/``symlen`` are the per-chunk word runs
    ``[K, num_chunks, chunk_size]`` and ``words_per_chunk`` ``[K,
    num_chunks]`` — exactly what :func:`repro.core.symlen.
    pack_symlen_chunked_parts` produces per signal, batched over the
    bucket's ``K`` rows (rows past the real signals are batch padding and
    pack zero words).  ``unencodable`` is the bucket's device-side
    histogram-gap flag, checked at drain.  This is the shared stream
    contract between the encode engine and device-resident consumers (the
    transcode pipeline stitches these straight into decoder bucket
    streams via ``symlen.stitch_chunk_parts`` — no host round trip).
    """

    plan_key: Tuple[int, int, int, int]  # (domain_id, n, e, l_max)
    hi: jnp.ndarray  # uint32[K, B, C]
    lo: jnp.ndarray  # uint32[K, B, C]
    symlen: jnp.ndarray  # int32[K, B, C]
    words_per_chunk: jnp.ndarray  # int32[K, B]
    unencodable: jnp.ndarray  # bool[]

    @property
    def chunk_size(self) -> int:
        return int(self.hi.shape[2])

    @property
    def num_chunks(self) -> int:
        return int(self.hi.shape[1])

    def words_per_signal(self) -> jnp.ndarray:
        """Per-row word extents int32[K] — a device array (no sync)."""
        return jnp.sum(self.words_per_chunk, axis=1)


class EncodedBatch:
    """Result of :meth:`BatchEncoder.encode` — device-resident streams.

    ``to_host()`` performs the only host sync: one drain per bucket, a
    histogram-gap check (the device-side arm of the pack precheck), then
    numpy slicing into per-signal :class:`Container`\\ s (input order
    preserved).

    A batch drains **once**.  A second ``to_host()`` — or any drain after
    the device buffers were handed to a :class:`~repro.serving.transcode.
    Transcoder` — raises instead of silently re-syncing (the buffers may by
    then be donated or re-encoded under a different config, so a quiet
    second drain is a stale-data hazard).  Device-resident consumers read
    :meth:`device_parts` / :meth:`signal_slices` instead of draining.
    """

    def __init__(
        self,
        buckets: List[tuple],
        slices: List[_Slice],
        pending_flags: Sequence[Tuple[Tuple[int, int, int, int],
                                      jnp.ndarray]] = (),
    ):
        # per bucket: (plan_key, hi, lo, sl, wpc, bad) device arrays with
        # hi/lo/sl shaped [K, num_chunks, chunk_size], wpc [K, num_chunks]
        self._buckets = buckets
        self._slices = slices
        # histogram-gap flags inherited from upstream device stages (a
        # transcode's source batch): checked at drain like our own
        self._pending_flags = list(pending_flags)
        self._consumed: Optional[str] = None

    def __len__(self) -> int:
        return len(self._slices)

    def device_parts(self) -> List[EncodedBucketParts]:
        """The per-bucket chunk parts as device arrays — no host sync."""
        self._check_live("read device parts of")
        return [
            EncodedBucketParts(
                plan_key=key, hi=hi, lo=lo, symlen=sl,
                words_per_chunk=wpc, unencodable=bad,
            )
            for key, hi, lo, sl, wpc, bad in self._buckets
        ]

    def signal_slices(self) -> List[_Slice]:
        """Per-signal (input order) location + header metadata: which
        bucket/row holds signal i's chunk parts, plus the container header
        fields (num_windows, signal_length, n, e, l_max, domain_id)."""
        return list(self._slices)

    def block_until_ready(self) -> "EncodedBatch":
        for _, hi, lo, sl, wpc, bad in self._buckets:
            wpc.block_until_ready()
        return self

    def _check_live(self, verb: str) -> None:
        if self._consumed is not None:
            raise RuntimeError(
                f"cannot {verb} this EncodedBatch: {self._consumed}"
            )

    def _mark_consumed(self, reason: str) -> None:
        self._check_live("consume")
        self._consumed = reason

    def to_host(self) -> List[Container]:
        """Drain the batch into containers: one sync per bucket, then a
        host-side stitch of each signal's chunk word-runs (chunk b of
        signal k contributes its row's first ``wpc[k, b]`` words)."""
        self._check_live("drain")
        host = []
        for key, hi, lo, sl, wpc, bad in (
            [(k, None, None, None, None, b) for k, b in self._pending_flags]
            + self._buckets
        ):
            if bool(bad):
                # leave the batch live: a failed drain returned nothing, so
                # a retry must re-raise this error, not a bogus
                # "already drained" message
                raise ValueError(
                    f"encode batch for plan_key (domain_id, n, e, l_max)="
                    f"{key} produced symbol(s) with no codeword (histogram "
                    "gap in the Huffman book) — the stream would decode to "
                    "garbage; recalibrate with Laplace smoothing or a "
                    "complete codebook"
                )
            if hi is None:  # a pending upstream flag, nothing to drain
                continue
            host.append(
                (np.asarray(hi), np.asarray(lo), np.asarray(sl),
                 np.asarray(wpc))
            )
        self._consumed = (
            "it was already drained by to_host() — hold on to the returned "
            "containers instead of draining twice"
        )
        out = []
        for s in self._slices:
            hi, lo, sl, wpc = host[s.bucket]
            runs = [
                (hi[s.row, b, :w], lo[s.row, b, :w], sl[s.row, b, :w])
                for b, w in enumerate(wpc[s.row])
                if w
            ]
            if runs:
                hi_cat = np.concatenate([r[0] for r in runs])
                lo_cat = np.concatenate([r[1] for r in runs])
                sl_cat = np.concatenate([r[2] for r in runs])
            else:
                hi_cat = lo_cat = np.empty(0, np.uint32)
                sl_cat = np.empty(0, np.int32)
            out.append(
                Container(
                    words=symlen.u32_to_words(hi_cat, lo_cat),
                    symlen=sl_cat.astype(np.uint8),
                    num_symbols=s.num_windows * s.e,
                    num_windows=s.num_windows,
                    signal_length=s.signal_length,
                    n=s.n,
                    e=s.e,
                    l_max=s.l_max,
                    domain_id=s.domain_id,
                )
            )
        return out


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BatchEncoderStats:
    batches: int = 0
    signals: int = 0
    dispatches: int = 0  # fused bucket launches
    plan_hits: int = 0
    plan_misses: int = 0


class BatchEncoder:
    """Encodes many signals in a bounded number of fused dispatches.

    Usage::

        enc = BatchEncoder()                      # chunked (fast) packing
        batch = enc.encode(signals, tables)       # tables: DomainTables, or
                                                  # {domain_id: DomainTables}
                                                  # + domain_ids=[...]
        containers = batch.to_host()              # one sync per bucket

    Signals are grouped by (domain, config) and sub-bucketed by power-of-two
    window and batch counts; each bucket is one :func:`_encode_bucket`
    launch.  ``chunk_size=None`` selects *exact* mode (one packing chunk per
    signal): bit-identical output to ``core.codec.encode`` at the price of a
    length-S packing scan — that is what ``encode_device`` uses.
    """

    def __init__(
        self,
        *,
        chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
        plan_cache_size: int = 32,
    ):
        if chunk_size is not None and chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        self.chunk_size = chunk_size
        self._plans = PlanCache(_build_encode_plan, plan_cache_size)
        self.stats = BatchEncoderStats()

    # -- plan management ---------------------------------------------------
    def _tables_for(self, domain_id: int, tables: TablesArg) -> DomainTables:
        if isinstance(tables, DomainTables):
            return tables
        try:
            return tables[domain_id]
        except KeyError:
            raise KeyError(
                f"no DomainTables registered for domain_id={domain_id}"
            ) from None

    def plan_for(self, tables: DomainTables) -> EncodePlan:
        cfg = tables.config
        key = (tables.domain_id, cfg.n, cfg.e, cfg.l_max)
        return self._plans.get(tables, key)

    # -- the batched encode ------------------------------------------------
    def encode(
        self,
        signals: Sequence[np.ndarray],
        tables: TablesArg,
        *,
        domain_ids: Optional[Sequence[int]] = None,
    ) -> EncodedBatch:
        """Encode a (possibly mixed-domain, mixed-length) batch of signals.

        ``domain_ids`` assigns each signal its domain when ``tables`` is a
        mapping; with a single :class:`DomainTables` every signal uses it.
        Returns an :class:`EncodedBatch`; nothing is synced to host here.
        """
        signals = [np.asarray(s, dtype=np.float32).ravel() for s in signals]

        def stage(idxs: List[int], kp: int, wp: int, n: int) -> jnp.ndarray:
            x = np.zeros((kp, wp * n), dtype=np.float32)
            for row, i in enumerate(idxs):
                x[row, : signals[i].shape[0]] = signals[i]
            return jnp.asarray(x)

        return self.encode_staged(
            [int(s.shape[0]) for s in signals], tables,
            domain_ids=domain_ids, stage=stage,
        )

    def encode_staged(
        self,
        lengths: Sequence[int],
        tables: TablesArg,
        *,
        stage,
        domain_ids: Optional[Sequence[int]] = None,
        pending_flags: Sequence[tuple] = (),
    ) -> EncodedBatch:
        """The bucketing/dispatch core of :meth:`encode`, with the signal
        *staging* pluggable.

        ``stage(idxs, kp, wp, n)`` must return the bucket's stacked signal
        matrix ``f32[kp, wp * n]`` — row ``r`` holds signal ``idxs[r]``'s
        samples followed by exact zeros, rows past ``len(idxs)`` all-zero —
        as either a host array (the :meth:`encode` path) or a device array
        (the transcode pipeline, which gathers rows from decoded windows
        without leaving the device).  Everything else — grouping, padding,
        chunk-size selection, the fused dispatch, slice metadata — is this
        one code path, which is what makes device-staged encodes
        byte-identical to host-staged ones.
        """
        self.stats.batches += 1
        self.stats.signals += len(lengths)
        if not lengths:
            return EncodedBatch([], [], pending_flags)
        if domain_ids is None:
            if not isinstance(tables, DomainTables):
                raise ValueError(
                    "domain_ids is required when tables is a "
                    "{domain_id: DomainTables} mapping"
                )
            domain_ids = [tables.domain_id] * len(lengths)
        if len(domain_ids) != len(lengths):
            raise ValueError(
                f"domain_ids has {len(domain_ids)} entries for "
                f"{len(lengths)} signals"
            )

        # group by ((domain, config), windows bucket) — one fused dispatch
        # per group; batch dim padded to a power of two below
        bucket_order: List[Tuple[Tuple[int, int, int, int], int]] = []
        buckets: Dict[Tuple[Tuple[int, int, int, int], int], List[int]] = {}
        per_tab: Dict[Tuple[Tuple[int, int, int, int], int], DomainTables] = {}
        for i, (length, dom) in enumerate(zip(lengths, domain_ids)):
            tab = self._tables_for(dom, tables)
            cfg = tab.config
            num_windows = -(-length // cfg.n)
            key = (
                (dom, cfg.n, cfg.e, cfg.l_max),
                _p2(max(num_windows, 1)),
            )
            if key not in buckets:
                buckets[key] = []
                bucket_order.append(key)
                per_tab[key] = tab
            buckets[key].append(i)

        out_buckets: List[tuple] = []
        slices: List[Optional[_Slice]] = [None] * len(lengths)
        for b, key in enumerate(bucket_order):
            (plan_key, wp), idxs = key, buckets[key]
            plan = self._plans.get(per_tab[key], plan_key)
            n, e = plan.n, plan.e
            kp = _p2(len(idxs))  # pad batch dim; pad rows pack 0 symbols
            counts = np.zeros((kp,), dtype=np.int32)
            for row, i in enumerate(idxs):
                num_windows = -(-lengths[i] // n)
                counts[row] = num_windows * e
                slices[i] = _Slice(
                    bucket=b,
                    row=row,
                    num_windows=num_windows,
                    signal_length=int(lengths[i]),
                    n=n,
                    e=e,
                    l_max=plan.l_max,
                    domain_id=plan.domain_id,
                )
            x = stage(idxs, kp, wp, n)
            sp = wp * e
            chunk = sp if self.chunk_size is None else min(self.chunk_size, sp)
            hi, lo, sl, nw, bad = _encode_bucket(
                x if isinstance(x, jnp.ndarray) else jnp.asarray(x),
                jnp.asarray(counts),
                plan.tables,
                n=n,
                e=e,
                chunk_size=chunk,
                check_gaps=plan.has_gaps,
            )
            out_buckets.append((plan_key, hi, lo, sl, nw, bad))
            self.stats.dispatches += 1

        self.stats.plan_hits = self._plans.hits
        self.stats.plan_misses = self._plans.misses
        return EncodedBatch(out_buckets, slices, pending_flags)

    def encode_to_host(
        self,
        signals: Sequence[np.ndarray],
        tables: TablesArg,
        *,
        domain_ids: Optional[Sequence[int]] = None,
    ) -> List[Container]:
        """Convenience: encode + drain in one call."""
        return self.encode(signals, tables, domain_ids=domain_ids).to_host()


# ---------------------------------------------------------------------------
# Process-wide default encoders (codec.encode_device rides the exact one).
# ---------------------------------------------------------------------------
_DEFAULTS: Dict[Optional[int], BatchEncoder] = {}


def default_encoder(chunk_size: Optional[int] = None) -> BatchEncoder:
    """Shared encoder per chunk size.  ``None`` (the default) is *exact*
    mode — bit-identical to the host encoder — which is what
    ``core.codec.encode_device`` rides; pass ``DEFAULT_CHUNK_SIZE`` (or any
    chunk) for the fast chunk-parallel packer.

    Being process-global, its plan cache keeps up to ``plan_cache_size``
    (32) recently-used DomainTables — and their device buffers — alive for
    the process lifetime (same trade as ``batch_decode.default_decoder``);
    callers churning many ephemeral table sets should hold their own
    :class:`BatchEncoder` and drop it when done."""
    enc = _DEFAULTS.get(chunk_size)
    if enc is None:
        enc = _DEFAULTS[chunk_size] = BatchEncoder(chunk_size=chunk_size)
    return enc
