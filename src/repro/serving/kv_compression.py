"""FPTC KV-cache compression for long-context serving (DESIGN.md §3.3).

Cold KV blocks are DCT-transformed along the *time* axis in windows of N
tokens, 3-zone quantized to uint8, and kept compressed in HBM; blocks are
dequantized + inverse-transformed on access.  This trades ~4x (+truncation)
cache memory for a small reconstruction error in attention — the same
asymmetric trade the paper makes for archival signals, applied to the KV
timeline (keys/values of adjacent tokens are smooth for trained models).

Entropy coding is intentionally NOT applied here: cache blocks must stay
fixed-size for O(1) random access during decode (recorded in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import dct as dctlib

__all__ = ["KVCompressionConfig", "compress_kv_block", "decompress_kv_block"]


@dataclasses.dataclass(frozen=True)
class KVCompressionConfig:
    n: int = 16  # DCT window along the token axis
    e: int = 8  # retained coefficients
    # simple symmetric linear quantizer per (head, dim) channel — the KV
    # analog of the paper's zone-1; mu-law zone-0 adds little for KV because
    # the coefficient dynamic range per channel is narrow post-RMSNorm.

    @property
    def ratio(self) -> float:
        """Compressed bytes / raw bf16 bytes."""
        return (self.e / self.n) * (1 / 2) + 4.0 / (self.n * 2 * 128)


def compress_kv_block(
    kv: jnp.ndarray, cfg: KVCompressionConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """kv: [B, T, H, D] with T divisible by cfg.n.

    Returns (levels uint8 [B, T//N*E, H, D], scale f32 [B, T//N, H, D]).
    """
    b, t, h, d = kv.shape
    w = t // cfg.n
    x = kv.astype(jnp.float32).reshape(b, w, cfg.n, h, d)
    x = jnp.moveaxis(x, 2, -1)  # [B, W, H, D, N]
    coeffs = x @ dctlib.dct_basis(cfg.n, cfg.e)  # [B, W, H, D, E]
    scale = jnp.max(jnp.abs(coeffs), axis=-1, keepdims=True) + 1e-8
    q = jnp.clip(jnp.round(coeffs / scale * 127.0) + 128.0, 0, 255).astype(
        jnp.uint8
    )
    return q, scale[..., 0]


def decompress_kv_block(
    levels: jnp.ndarray, scale: jnp.ndarray, cfg: KVCompressionConfig,
    dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Inverse of :func:`compress_kv_block` -> [B, T, H, D]."""
    b, w, h, d, e = levels.shape
    coeffs = (levels.astype(jnp.float32) - 128.0) / 127.0 * scale[..., None]
    x = coeffs @ dctlib.idct_basis(cfg.n, e)  # [B, W, H, D, N]
    x = jnp.moveaxis(x, -1, 2)  # [B, W, N, H, D]
    return x.reshape(b, w * cfg.n, h, d).astype(dtype)
