"""Legacy standalone KV-cache compression (deprecated shim).

.. deprecated::
    Use :class:`repro.serving.workloads.KVCacheCodec` instead.  The codec
    routes KV blocks through the batched engines' fixed-rate mode with
    *calibrated* domain tables (3-zone quantization, fused kernels under
    ``use_kernels``, plans cached per layer group) — this module's ad-hoc
    per-window max-abs quantizer predates the engine stack and survives
    only so existing callers keep working for one release.

Design notes that remain true on the new path (and are load-bearing):
cold KV blocks are DCT-transformed along the *time* axis in windows of N
tokens and quantized to uint8; entropy coding is intentionally NOT applied
so cache blocks stay fixed-size for O(1) random access during decode.
Keys/values of adjacent tokens are smooth for trained models, so the
asymmetric transform-side cost buys a ~4x (+truncation) HBM cut for a
small attention reconstruction error.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import dct as dctlib

__all__ = ["KVCompressionConfig", "compress_kv_block", "decompress_kv_block"]


def _warn_deprecated(name: str) -> None:
    warnings.warn(
        f"repro.serving.kv_compression.{name} is deprecated; use "
        "repro.serving.workloads.KVCacheCodec (calibrated tables + the "
        "batched engines' fixed-rate mode) instead",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclasses.dataclass(frozen=True)
class KVCompressionConfig:
    n: int = 16  # DCT window along the token axis
    e: int = 8  # retained coefficients
    # simple symmetric linear quantizer per (head, dim) channel — the KV
    # analog of the paper's zone-1; mu-law zone-0 adds little for KV because
    # the coefficient dynamic range per channel is narrow post-RMSNorm.

    @property
    def ratio(self) -> float:
        """Compressed bytes / raw bf16 bytes.

        Per channel, each N-token window stores E uint8 levels plus one f32
        scale against N bf16 samples: ``E/(2N) + 4/(2N)``.  (The scale
        overhead is per *channel*, independent of head_dim — an earlier
        version wrongly divided it by a hard-coded head_dim of 128.)

        Prefer :attr:`repro.serving.workloads.CompressedKV.ratio`, which is
        measured from the actual array bytes of a round trip.
        """
        return (self.e / self.n) * (1 / 2) + 4.0 / (self.n * 2)


def compress_kv_block(
    kv: jnp.ndarray, cfg: KVCompressionConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """kv: [B, T, H, D] with T divisible by cfg.n.

    Returns ``(levels uint8 [B, W, H, D, E], scale f32 [B, W, H, D])``
    where ``W = T // N`` — one window of E levels and one scale per
    (batch, window, head, dim) channel.

    The uint8 mapping is symmetric: quantized values are clipped to
    [-127, 127] *before* the +128 bias, so level 128 is exactly 0.0 and
    every stored level decodes back into [-1, 1] of the window scale.
    (The earlier mapping clipped after biasing, so level 0 decoded to
    -128/127 — outside the encoder's own range.)

    .. deprecated:: use :class:`repro.serving.workloads.KVCacheCodec`.
    """
    _warn_deprecated("compress_kv_block")
    b, t, h, d = kv.shape
    w = t // cfg.n
    x = kv.astype(jnp.float32).reshape(b, w, cfg.n, h, d)
    x = jnp.moveaxis(x, 2, -1)  # [B, W, H, D, N]
    coeffs = x @ dctlib.dct_basis(cfg.n, cfg.e)  # [B, W, H, D, E]
    scale = jnp.max(jnp.abs(coeffs), axis=-1, keepdims=True) + 1e-8
    q = (
        jnp.clip(jnp.round(coeffs / scale * 127.0), -127, 127) + 128.0
    ).astype(jnp.uint8)
    return q, scale[..., 0]


def decompress_kv_block(
    levels: jnp.ndarray, scale: jnp.ndarray, cfg: KVCompressionConfig,
    dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Inverse of :func:`compress_kv_block` -> [B, T, H, D].

    .. deprecated:: use :class:`repro.serving.workloads.KVCacheCodec`.
    """
    _warn_deprecated("decompress_kv_block")
    b, w, h, d, e = levels.shape
    coeffs = (levels.astype(jnp.float32) - 128.0) / 127.0 * scale[..., None]
    x = coeffs @ dctlib.idct_basis(cfg.n, e)  # [B, W, H, D, N]
    x = jnp.moveaxis(x, -1, 2)  # [B, W, N, H, D]
    return x.reshape(b, w * cfg.n, h, d).astype(dtype)
