"""Synthetic open-loop traffic for the serving front-end.

An archive service's load is not a batch: requests arrive on their own
clock (open loop — arrivals don't wait for completions, so queueing
delay is *visible* instead of self-throttled away), sizes are heavy-
tailed (a few long recordings dominate bytes while short probes dominate
counts), and the stream mixes the four signal domains and all three
traffic kinds.  This module synthesizes exactly that stream,
deterministically:

  * **Poisson arrivals** — exponential inter-arrival gaps at the offered
    rate (the standard open-loop arrival model).
  * **Heavy-tailed sizes** — log-normal window counts, clipped to a
    ceiling; ``fixed_windows`` pins one size for shape-warm smoke runs.
  * **Four domains** — one representative dataset per paper domain
    (biomedical / seismic / power / meteorological), each with its own
    calibrated :class:`DomainTables`.
  * **Mixed kinds** — decode / encode / transcode drawn per-request from
    a configurable mix; decode and transcode payload containers are
    pre-encoded offline (byte-identical to what the front-end's encode
    path would produce) so replay measures *serving*, not setup.

:func:`replay` drives a :class:`~repro.serving.frontend.ServingFrontend`
with a generated stream and reports per-request latency percentiles,
achieved goodput, and shed/expired counts — the measurement
``benchmarks/bench_serving.py`` sweeps against offered load.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.calibration import DomainTables, calibrate
from repro.core.config import DOMAIN_DEFAULTS
from repro.core.container import Container
from repro.data.signals import make_signal
from repro.serving.batch_encode import BatchEncoder
from repro.serving.frontend import (
    DeadlineExpiredError,
    QueueFullError,
    ServingFrontend,
)

__all__ = [
    "DOMAIN_DATASETS",
    "Request",
    "ReplayReport",
    "TrafficConfig",
    "build_domain_tables",
    "generate",
    "replay",
]

# one representative dataset per paper domain, in domain_id order
DOMAIN_DATASETS: Tuple[Tuple[str, str], ...] = (
    ("biomedical", "mitbih"),
    ("seismic", "seismic"),
    ("power", "load_power"),
    ("meteorological", "temperature"),
)

# synthesis floors: the seismic generator convolves with a 255-tap Ricker
# wavelet, so its signals can't be shorter than that
_MIN_SAMPLES = {"seismic": 255}


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Knobs for one synthetic stream.

    ``rate`` is the offered load in requests/second (Poisson);
    ``duration_s`` how long arrivals keep coming.  ``mix`` weights the
    traffic kinds (normalized internally).  Sizes are log-normal in
    *windows*: ``median_windows`` the distribution median and ``sigma``
    the log-space shape (bigger = heavier tail), clipped to
    ``max_windows``; ``fixed_windows`` overrides the distribution with
    one constant size (deterministic shapes — smoke/CI runs).
    ``domains`` restricts which domain_ids generate traffic (None =
    all).  Everything derives from ``seed``.
    """

    rate: float = 100.0
    duration_s: float = 1.0
    mix: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {
            "decode": 0.6, "encode": 0.3, "transcode": 0.1,
        }
    )
    median_windows: int = 16
    sigma: float = 0.75
    max_windows: int = 256
    fixed_windows: Optional[int] = None
    domains: Optional[Tuple[int, ...]] = None
    seed: int = 0

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if not self.mix or any(w < 0 for w in self.mix.values()):
            raise ValueError(f"mix weights must be >= 0, got {self.mix}")
        unknown = set(self.mix) - {"decode", "encode", "transcode"}
        if unknown:
            raise ValueError(f"unknown traffic kinds in mix: {sorted(unknown)}")


@dataclasses.dataclass(frozen=True)
class Request:
    """One synthetic request: ``arrival`` is seconds from stream start;
    the payload is ``signal`` (encode) or ``container``
    (decode/transcode); transcode also carries ``dst_domain_id``."""

    arrival: float
    kind: str
    domain_id: int
    dataset: str
    num_windows: int
    signal: Optional[np.ndarray] = None
    container: Optional[Container] = None
    dst_domain_id: Optional[int] = None


def build_domain_tables(
    calib_len: int = 65536, seed: int = 1000
) -> Dict[int, DomainTables]:
    """Calibrate one :class:`DomainTables` per paper domain
    (domain_id = position in :data:`DOMAIN_DATASETS`)."""
    tables: Dict[int, DomainTables] = {}
    for domain_id, (domain, dataset) in enumerate(DOMAIN_DATASETS):
        tables[domain_id] = calibrate(
            make_signal(dataset, calib_len, seed=seed + domain_id),
            DOMAIN_DEFAULTS[domain],
            domain_id=domain_id,
        )
    return tables


def generate(
    cfg: TrafficConfig, tables: Mapping[int, DomainTables]
) -> List[Request]:
    """Synthesize one open-loop stream (deterministic in ``cfg.seed``).

    Decode/transcode payload containers are pre-encoded here with an
    offline (sync, single-device) encoder so that replay exercises only
    the serving path.  Transcode targets are drawn uniformly from the
    *other* registered domains.
    """
    rng = np.random.default_rng(cfg.seed)
    domain_ids = sorted(
        cfg.domains if cfg.domains is not None else tables.keys()
    )
    if not domain_ids:
        raise ValueError("no domains to generate traffic for")
    kinds = sorted(cfg.mix)
    weights = np.array([cfg.mix[k] for k in kinds], dtype=np.float64)
    if weights.sum() <= 0:
        raise ValueError(f"mix weights sum to zero: {cfg.mix}")
    weights /= weights.sum()

    # arrivals: Poisson process at `rate` until `duration_s`
    arrivals: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / cfg.rate)
        if t >= cfg.duration_s:
            break
        arrivals.append(t)

    requests: List[Request] = []
    encode_jobs: List[Tuple[int, int]] = []  # (request index, domain_id)
    for i, arrival in enumerate(arrivals):
        kind = kinds[int(rng.choice(len(kinds), p=weights))]
        domain_id = int(domain_ids[int(rng.integers(len(domain_ids)))])
        dataset = DOMAIN_DATASETS[domain_id][1]
        if cfg.fixed_windows is not None:
            nw = int(cfg.fixed_windows)
        else:
            nw = int(np.clip(
                np.rint(cfg.median_windows * rng.lognormal(0.0, cfg.sigma)),
                1, cfg.max_windows,
            ))
        n = tables[domain_id].config.n
        nw = max(nw, -(-_MIN_SAMPLES.get(dataset, 1) // n))
        signal = make_signal(dataset, nw * n, seed=int(rng.integers(2**31)))
        dst = None
        if kind == "transcode" and len(domain_ids) > 1:
            others = [d for d in domain_ids if d != domain_id]
            dst = int(others[int(rng.integers(len(others)))])
        elif kind == "transcode":
            dst = domain_id  # single-domain stream: re-encode in place
        requests.append(Request(
            arrival=arrival, kind=kind, domain_id=domain_id,
            dataset=dataset, num_windows=nw,
            signal=signal if kind == "encode" else None,
            dst_domain_id=dst,
        ))
        if kind != "encode":
            encode_jobs.append((i, domain_id))

    # pre-encode decode/transcode payloads, batched per domain
    if encode_jobs:
        enc = BatchEncoder(pipeline=False, devices=None)
        by_domain: Dict[int, List[int]] = {}
        for i, d in encode_jobs:
            by_domain.setdefault(d, []).append(i)
        for d, idxs in by_domain.items():
            containers = enc.encode_to_host(
                [make_signal(
                    requests[i].dataset,
                    requests[i].num_windows * tables[d].config.n,
                    seed=cfg.seed + 7_000_000 + i,
                ) for i in idxs],
                tables[d],
            )
            for i, c in zip(idxs, containers):
                requests[i] = dataclasses.replace(requests[i], container=c)
    return requests


@dataclasses.dataclass
class ReplayReport:
    """Outcome of one open-loop replay against a front-end."""

    offered_rps: float
    achieved_rps: float  # completed / wall duration
    submitted: int
    completed: int
    shed: int
    rejected_expired: int
    failed: int
    latencies_ms: List[float]  # per completed request, arrival -> result
    wall_s: float

    def percentile(self, q: float) -> float:
        if not self.latencies_ms:
            return float("nan")
        return float(np.percentile(self.latencies_ms, q))

    @property
    def p50_ms(self) -> float:
        return self.percentile(50)

    @property
    def p99_ms(self) -> float:
        return self.percentile(99)

    def summary(self) -> Dict[str, float]:
        return {
            "offered_rps": self.offered_rps,
            "achieved_rps": self.achieved_rps,
            "submitted": self.submitted,
            "completed": self.completed,
            "shed": self.shed,
            "rejected_expired": self.rejected_expired,
            "failed": self.failed,
            "p50_ms": self.p50_ms,
            "p95_ms": self.percentile(95),
            "p99_ms": self.p99_ms,
            "wall_s": self.wall_s,
        }


def replay(
    frontend: ServingFrontend,
    requests: List[Request],
    *,
    deadline_ms: Optional[float] = None,
    time_scale: float = 1.0,
) -> ReplayReport:
    """Drive ``frontend`` with ``requests`` open-loop.

    Each request is submitted at ``arrival * time_scale`` seconds after
    the replay starts, whether or not earlier requests completed — so
    queueing shows up as latency (and, past the queue bounds, as shed),
    exactly like a service behind real clients.  Latency is measured
    from *scheduled arrival* to result materialization (sojourn time:
    submit lateness under overload counts against the server, not the
    clock).  Returns once every submitted request resolved.
    """
    lock = threading.Lock()
    latencies: List[float] = []
    failed = [0]
    shed = 0
    expired = 0
    start = time.monotonic()

    def on_done(arrival_abs: float):
        def cb(fut):
            end = time.monotonic()
            with lock:
                if fut.exception() is None:
                    latencies.append((end - arrival_abs) * 1e3)
                else:
                    failed[0] += 1
        return cb

    pending = []
    for r in requests:
        target = start + r.arrival * time_scale
        delay = target - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        try:
            if r.kind == "decode":
                fut = frontend.submit_decode(
                    r.container, deadline_ms=deadline_ms
                )
            elif r.kind == "encode":
                fut = frontend.submit_encode(
                    r.signal, r.domain_id, deadline_ms=deadline_ms
                )
            else:
                fut = frontend.submit_transcode(
                    r.container, r.dst_domain_id, deadline_ms=deadline_ms
                )
        except QueueFullError:
            shed += 1
            continue
        except DeadlineExpiredError:
            expired += 1
            continue
        fut.add_done_callback(on_done(target))
        pending.append(fut)

    frontend.flush()
    for fut in pending:
        try:
            fut.result()
        except Exception:
            pass  # counted by the done callback
    wall = time.monotonic() - start
    with lock:
        lat = list(latencies)
        nfail = failed[0]
    span = requests[-1].arrival * time_scale if requests else 0.0
    offered = len(requests) / span if span > 0 else 0.0
    return ReplayReport(
        offered_rps=offered,
        achieved_rps=len(lat) / wall if wall > 0 else 0.0,
        submitted=len(pending),
        completed=len(lat),
        shed=shed,
        rejected_expired=expired,
        failed=nfail,
        latencies_ms=lat,
        wall_s=wall,
    )
