"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function computes exactly what its kernel computes, using only jnp /
core-library code — no pallas_call.  Kernel tests sweep shapes/dtypes and
assert_allclose against these.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import dct as _dct
from repro.core import symlen as _symlen
from repro.core.quantize import QuantTable, dequantize, quantize

__all__ = ["huffman_decode_padded_ref", "idct_dequant_ref", "dct_quant_ref"]


def huffman_decode_padded_ref(
    hi, lo, dec_limit, dec_first, dec_rank, dec_syms, *, l_max, max_symlen
):
    """Padded per-word decode tile [W, max_symlen] — no compaction."""
    import jax

    def slot_step(carry, _):
        cur_hi, cur_lo = carry
        prefix = _symlen._shr32(cur_hi, 32 - l_max)
        ge = prefix[None, :] >= dec_limit[:, None]
        length = 1 + jnp.sum(ge.astype(jnp.int32), axis=0)
        length = jnp.minimum(length, l_max)
        fcs = dec_first[length]
        rank = dec_rank[length] + (
            _symlen._shr32(prefix - fcs, l_max - length)
        ).astype(jnp.int32)
        rank = jnp.clip(rank, 0, 255)
        sym = dec_syms[rank].astype(jnp.int32)
        new_hi = _symlen._shl32(cur_hi, length) | _symlen._shr32(
            cur_lo, 32 - length
        )
        new_lo = _symlen._shl32(cur_lo, length)
        return (new_hi, new_lo), sym

    (_, _), padded = jax.lax.scan(
        slot_step, (hi, lo), None, length=max_symlen
    )
    return padded.T  # [W, max_symlen]


def idct_dequant_ref(levels, quant_table: QuantTable, *, n: int):
    """[W, E] uint8/int32 levels -> [W, N] reconstructed samples."""
    coeffs = dequantize(levels.astype(jnp.uint8), quant_table)
    return _dct.inverse_dct(coeffs, n)


def dct_quant_ref(windows, quant_table: QuantTable, *, e: int):
    """[W, N] samples -> [W, E] int32 quantized levels."""
    coeffs = _dct.forward_dct(windows, e)
    return quantize(coeffs, quant_table).astype(jnp.int32)
