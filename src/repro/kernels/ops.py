"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the codec (``repro.core.codec`` with
``use_kernels=True``) and the serving/benchmark layers call.  On CPU they run
the kernels in interpret mode; on TPU set ``interpret=False`` (the default
flips automatically on TPU backends).

Since the megakernel PR the kernel surface is:

  * :func:`huffman_decode` — ONE dispatch: the fused dense kernel decodes
    and compacts in the same ``pallas_call`` (the symlen sidecar rides into
    the kernel; no ``[max_symlen, W]`` HBM tile).
  * :func:`decode_bucket_fused` — the full decode megakernel: Huffman +
    compaction + LUT dequant + iDCT in a single ``pallas_call``.
  * :func:`encode_bucket_fused` — the encode-side twin: DCT + quantize +
    one-hot codeword lookup + chunk-parallel SymLen pack in one
    ``pallas_call``, bit-identical to the XLA engine path.
  * :func:`idct_dequant` / :func:`dct_quant` — the staged per-stage tiles
    (kept as oracles and for the legacy per-container baseline).

Every wrapper guards the int32 offset range before dispatch: symbol/word
offsets inside the kernels are int32 (jax default x32), so a bucket whose
dense symbol stream would cross the 2^31-byte mark must raise loudly
instead of wrapping offsets negative and compacting the wrong positions
silently (the same guard discipline as the transcoder's flat-gather path).

The megakernel wrappers resolve their Pallas block sizes at TRACE time:
``block_*=None`` (the engines' calling convention) consults the
:mod:`repro.tuning.autotune` cache for this (backend, plan key, bucket
shape) and falls back to the built-in defaults when nothing is tuned.
Blocks change tiling only — never bytes — and the engines key their jits
on the tuning-cache epoch so a new entry forces a retrace.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dct as _dct
from repro.core.calibration import DeviceTables
from repro.core.quantize import QuantTable
from repro.kernels import dct_quant as _dq
from repro.kernels import decode_fused as _df
from repro.kernels import encode_fused as _ef
from repro.kernels import huffman_decode as _hd
from repro.kernels import idct_dequant as _idq
from repro.tuning.autotune import tuned_blocks as _tuned_blocks

__all__ = [
    "huffman_decode",
    "decode_bucket_fused",
    "encode_bucket_fused",
    "idct_dequant",
    "dct_quant",
    "check_i32_offsets",
    "on_tpu",
]

_I32_MAX = np.iinfo(np.int32).max
_TRIVIAL_CODING = (0, 0, False)


def _coding_key(coding) -> tuple:
    """Flatten a non-trivial container-v3 coding into tuning plan-key ints.

    Trivial codings contribute NOTHING so every pre-v3 tuned entry (keyed
    without coding) keeps matching v1/v2 traffic byte-for-byte."""
    coding = tuple(coding)
    if coding == _TRIVIAL_CODING:
        return ()
    return (int(coding[0]), int(coding[1]), int(bool(coding[2])))


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def check_i32_offsets(num_symbols: int, max_symlen: int) -> None:
    """Refuse a decode whose dense symbol offsets would overflow int32.

    The fused kernels' compaction offsets (and the output capacity, which
    over-allocates one ``max_symlen`` row for the final word's spill) are
    int32; a bucket past the 2^31-symbol (= 2^31-byte) mark would wrap
    offsets negative and scatter symbols to the WRONG positions silently.
    Mirrors the transcoder's flat-gather int32 guard.
    """
    if int(num_symbols) + int(max_symlen) > _I32_MAX:
        raise ValueError(
            f"decode bucket of {num_symbols} symbols (+{max_symlen} spill) "
            "exceeds the int32 offset range of the fused kernels — decode "
            "the archive in smaller batches"
        )


def _check_encode_i32(width: int, e: int, n: int) -> None:
    """Encode-side arm of the int32 guard: per-signal symbol capacity."""
    sp = (int(width) // int(n)) * int(e)
    if sp > _I32_MAX:
        raise ValueError(
            f"encode bucket rows of {sp} symbols exceed the int32 offset "
            "range of the fused pack kernel — encode in smaller windows"
        )


def huffman_decode(
    hi: jnp.ndarray,
    lo: jnp.ndarray,
    symlen: jnp.ndarray,
    tables: DeviceTables,
    *,
    l_max: int,
    max_symlen: int,
    num_symbols: int,
) -> jnp.ndarray:
    """SymLen decode + compaction: packed words -> dense uint8[num_symbols].

    ONE dispatch: the symlen sidecar rides into the kernel, a VMEM-resident
    exclusive prefix-scan assigns per-word output offsets, and the
    cooperative store compacts symbols inside the same ``pallas_call`` —
    container boundaries are invisible (the prefix sums are segment sums),
    so concatenated batch streams decode in this single dispatch with no
    ``[max_symlen, W]`` HBM tile.  ``core.symlen.compact_padded_scatter``
    (over the staged tile kernel) remains the interpret-mode oracle.
    """
    check_i32_offsets(num_symbols, max_symlen)
    dense = _hd.huffman_decode_dense(
        hi,
        lo,
        symlen,
        tables.dec_limit,
        tables.dec_first,
        tables.dec_rank,
        tables.dec_syms,
        l_max=l_max,
        max_symlen=max_symlen,
        num_symbols=num_symbols,
        interpret=_interp(),
    )
    return dense.astype(jnp.uint8)


def decode_bucket_fused(
    hi: jnp.ndarray,
    lo: jnp.ndarray,
    symlen: jnp.ndarray,
    tables: DeviceTables,
    lut: jnp.ndarray,  # f32[E, 256] quant_grid reconstruction LUT
    basis: jnp.ndarray,  # f32[E, N] idct basis
    v3=None,  # (idx, seg) expansion arrays for non-trivial codings
    *,
    l_max: int,
    max_symlen: int,
    num_windows: int,
    n: int,
    e: int,
    coding=_TRIVIAL_CODING,
    block_words: int = None,
    block_windows: int = None,
) -> jnp.ndarray:
    """The decode megakernel: packed bucket -> windows f32[num_windows, N]
    in exactly one ``pallas_call`` (Huffman + compaction + LUT dequant +
    iDCT; see :mod:`repro.kernels.decode_fused`).

    A non-trivial ``coding`` (container v3) adds the in-kernel expansion +
    un-prediction epilogue; ``v3`` must then carry the host-built
    ``(idx, seg)`` arrays from :func:`repro.core.symlen.v3_expand_index`.
    Still exactly one ``pallas_call``.

    ``block_words``/``block_windows`` default to the tuning cache's winner
    for this (backend, plan key, bucket shape) — or the kernel's built-in
    defaults when nothing is tuned.  Explicit values (the autotuner's own
    sweep path) bypass the consult."""
    check_i32_offsets(num_windows * e, max_symlen)
    coding = tuple(coding)
    if block_words is None or block_windows is None:
        tuned = _tuned_blocks(
            "decode",
            plan_key=(n, e, l_max, max_symlen) + _coding_key(coding),
            shape=(int(hi.shape[0]), int(num_windows)),
        )
        if block_words is None:
            block_words = tuned.get("block_words", _hd.BLOCK_WORDS)
        if block_windows is None:
            block_windows = tuned.get("block_windows", _df.BLOCK_WINDOWS)
    idx, seg = v3 if v3 is not None else (None, None)
    return _df.decode_fused(
        hi,
        lo,
        symlen,
        tables.dec_limit,
        tables.dec_first,
        tables.dec_rank,
        tables.dec_syms,
        lut,
        basis,
        idx,
        seg,
        l_max=l_max,
        max_symlen=max_symlen,
        num_windows=num_windows,
        n=n,
        e=e,
        coding=coding,
        block_words=int(block_words),
        block_windows=int(block_windows),
        interpret=_interp(),
    )


def encode_bucket_fused(
    signals: jnp.ndarray,  # f32[K, Wp * N]
    counts: jnp.ndarray,  # int32[K]
    tables: DeviceTables,
    basis: jnp.ndarray,  # f32[N, E] dct_basis
    *,
    n: int,
    e: int,
    chunk_size: int,
    check_gaps: bool,
    coding=_TRIVIAL_CODING,
    block_rows: int = None,
):
    """The encode megakernel: signal rows -> SymLen chunk parts in one
    ``pallas_call``, bit-identical to the XLA engine path (see
    :mod:`repro.kernels.encode_fused`).

    A non-trivial ``coding`` (container v3) turns on the in-kernel
    prediction + zero-plane prologue; the return grows the per-row
    ``ncoded``/``zrow``/``zcol`` outputs (see
    :func:`repro.kernels.encode_fused.encode_fused`).

    ``block_rows`` (signals per grid step) defaults to the tuning cache's
    winner for this (backend, plan key, bucket shape), falling back to 1;
    explicit values bypass the consult (the autotuner's sweep path)."""
    _check_encode_i32(signals.shape[1], e, n)
    coding = tuple(coding)
    if block_rows is None:
        tuned = _tuned_blocks(
            "encode",
            plan_key=(n, e, int(chunk_size)) + _coding_key(coding),
            shape=(int(signals.shape[0]), int(signals.shape[1])),
        )
        block_rows = tuned.get("block_rows", 1)
    return _ef.encode_fused(
        signals,
        counts,
        tables.codes,
        tables.lengths,
        tables.quant.zone,
        tables.quant.scale,
        tables.quant.mu,
        tables.quant.alpha1,
        basis,
        n=n,
        e=e,
        chunk_size=chunk_size,
        check_gaps=check_gaps,
        coding=coding,
        block_rows=int(block_rows),
        interpret=_interp(),
    )


def idct_dequant(
    levels: jnp.ndarray,
    quant: QuantTable,
    *,
    n: int,
    basis: jnp.ndarray = None,
) -> jnp.ndarray:
    """Fused dequant + inverse DCT: [W, E] levels -> [W, N] samples.

    ``basis`` lets callers with a persistent decode plan (serving.batch_decode)
    pass an already-device-resident iDCT basis instead of re-deriving it here.
    """
    e = levels.shape[-1]
    if basis is None:
        basis = _dct.idct_basis(n, e)
    return _idq.idct_dequant(
        levels,
        quant.zone,
        quant.scale,
        basis,
        quant.mu,
        quant.alpha1,
        n=n,
        interpret=_interp(),
    )


def dct_quant(
    windows: jnp.ndarray,
    quant: QuantTable,
    *,
    e: int,
    basis: jnp.ndarray = None,
    exact: bool = False,
) -> jnp.ndarray:
    """Fused forward DCT + quantize: [W, N] samples -> [W, E] levels.

    ``basis`` lets callers with a persistent encode plan (the serving
    engines) pass the already-device-resident DCT basis instead of
    re-deriving it here; ``exact=True`` selects the reference-parity
    quantization arm (bit-identical levels to ``core.quantize.quantize`` —
    what the fixed-rate workload path pins its byte-identity tests on).
    """
    n = windows.shape[-1]
    if basis is None:
        basis = _dct.dct_basis(n, e)
    return _dq.dct_quant(
        windows,
        quant.zone,
        quant.scale,
        basis,
        quant.mu,
        quant.alpha1,
        e=e,
        interpret=_interp(),
        exact=exact,
    )
