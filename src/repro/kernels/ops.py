"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the codec (``repro.core.codec`` with
``use_kernels=True``) and the serving/benchmark layers call.  On CPU they run
the kernels in interpret mode; on TPU set ``interpret=False`` (the default
flips automatically on TPU backends).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import dct as _dct
from repro.core import symlen as _symlen
from repro.core.calibration import DeviceTables
from repro.core.quantize import QuantTable
from repro.kernels import dct_quant as _dq
from repro.kernels import huffman_decode as _hd
from repro.kernels import idct_dequant as _idq

__all__ = ["huffman_decode", "idct_dequant", "dct_quant", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def huffman_decode(
    hi: jnp.ndarray,
    lo: jnp.ndarray,
    symlen: jnp.ndarray,
    tables: DeviceTables,
    *,
    l_max: int,
    max_symlen: int,
    num_symbols: int,
) -> jnp.ndarray:
    """SymLen decode + compaction: packed words -> dense uint8[num_symbols].

    Kernel stage: slot-major per-word tile, grid over word blocks — container
    boundaries are invisible to the kernel, so concatenated batch streams
    decode in one dispatch.  Compaction stage: segment-aware scatter driven
    by one exclusive prefix-sum of the symlen sidecar (core.symlen).
    """
    tile = _hd.huffman_decode_tile(
        hi,
        lo,
        tables.dec_limit,
        tables.dec_first,
        tables.dec_rank,
        tables.dec_syms,
        l_max=l_max,
        max_symlen=max_symlen,
        interpret=_interp(),
    )  # [max_symlen, W] int32
    return _symlen.compact_padded_scatter(
        tile.T, symlen, num_symbols
    ).astype(jnp.uint8)


def idct_dequant(
    levels: jnp.ndarray,
    quant: QuantTable,
    *,
    n: int,
    basis: jnp.ndarray = None,
) -> jnp.ndarray:
    """Fused dequant + inverse DCT: [W, E] levels -> [W, N] samples.

    ``basis`` lets callers with a persistent decode plan (serving.batch_decode)
    pass an already-device-resident iDCT basis instead of re-deriving it here.
    """
    e = levels.shape[-1]
    if basis is None:
        basis = _dct.idct_basis(n, e)
    return _idq.idct_dequant(
        levels,
        quant.zone,
        quant.scale,
        basis,
        quant.mu,
        quant.alpha1,
        n=n,
        interpret=_interp(),
    )


def dct_quant(
    windows: jnp.ndarray, quant: QuantTable, *, e: int
) -> jnp.ndarray:
    """Fused forward DCT + quantize: [W, N] samples -> [W, E] levels."""
    n = windows.shape[-1]
    return _dq.dct_quant(
        windows,
        quant.zone,
        quant.scale,
        _dct.dct_basis(n, e),
        quant.mu,
        quant.alpha1,
        e=e,
        interpret=_interp(),
    )
