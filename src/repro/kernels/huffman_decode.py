"""Pallas TPU kernel: SymLen word-parallel Huffman decode (paper §4.2.1).

GPU original: one CUDA thread per 64-bit word, serial LUT loop per thread,
warp-shuffle cooperative writes.  TPU adaptation:

  * one VPU **lane** per word — a block of ``BLOCK_WORDS`` words is decoded by
    looping over *symbol slots*; every iteration decodes one symbol for all
    words in the block simultaneously (branch-free, no divergence possible);
  * the 2^L_max shared-memory LUT is replaced by **arithmetic canonical
    decoding**: length = 1 + #(prefix >= limit_shifted[l]) via vectorized
    compares, then rank arithmetic; the final 256-way symbol lookup is a
    **one-hot matmul** against the symbol table (gather-via-one-hot — the MXU
    idiom for small-table lookups);
  * 64-bit words are processed as (hi, lo) uint32 pairs with funnel shifts
    (TPU int64 is emulated; uint32 is native VPU width);
  * the warp-cooperative coalesced write stage comes in two forms.  The
    *staged* kernel (:func:`huffman_decode_tile`) stores a dense **padded
    tile** ``[MAX_SYMS, BLOCK_WORDS]`` and leaves compaction (exclusive
    prefix-sum of symlen + scatter) to the XLA level — exactly the paper's
    prefix-scan, lifted out of the kernel.  The *fused* kernel
    (:func:`huffman_decode_dense`) brings that stage back inside the
    ``pallas_call``: the symlen sidecar rides into the kernel, a
    VMEM-resident exclusive prefix-scan gives every word its output offset
    (a running base carried across the sequential TPU grid in SMEM
    scratch), and a cooperative word-major store compacts the tile — which
    now lives only in VMEM scratch — straight into the dense symbol
    stream.  One dispatch, no ``[max_symlen, W]`` HBM round trip (the
    coarse/fine fusion of Tian et al., "Revisiting Huffman Coding").

VMEM budget per block (BLOCK_WORDS=512, MAX_SYMS<=64, L_max<=16):
  in:  hi/lo/symlen          3 * 512 * 4 B            =   6 KiB
  tables: limits/first/rank/ symbols                  <   3 KiB
  tile (out or scratch)      64 * 512 * 4 B           = 128 KiB
well under the ~16 MiB VMEM of a TPU v5e core; BLOCK_WORDS can scale to 4096.
The fused kernel's dense output block additionally stays resident across
grid steps (each block writes a different run): ``4 B x num_symbols``, i.e.
a 1M-symbol bucket holds a 4 MiB output block — callers bound bucket sizes
(``repro.kernels.ops`` guards the int32 offset range long before VMEM does).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "huffman_decode_padded",
    "huffman_decode_tile",
    "huffman_decode_dense",
]

BLOCK_WORDS = 512


def _shl32(x, s):
    s = jnp.clip(s, 0, 31).astype(jnp.uint32)
    return x << s


def _shr32(x, s):
    s = jnp.clip(s, 0, 31).astype(jnp.uint32)
    return x >> s


def _decode_slot(cur_hi, cur_lo, dec_limit, dec_first, dec_rank, syms_f,
                 *, l_max: int):
    """Decode ONE symbol for every word in the block simultaneously.

    Returns (sym int32[BW], new_hi, new_lo) — the arithmetic canonical
    decode (vectorized length compare, rank arithmetic, one-hot MXU symbol
    lookup) plus the funnel shift that consumes the codeword.  Shared by
    the staged tile kernel and the fused dense kernels.
    """
    lengths_iota = jnp.arange(dec_first.shape[0], dtype=jnp.int32)  # [L+1]
    prefix = _shr32(cur_hi, 32 - l_max)  # uint32[BW]
    # --- code length: vectorized compares against limit boundaries ---
    ge = (prefix[None, :] >= dec_limit[:, None]).astype(jnp.int32)
    length = 1 + jnp.sum(ge, axis=0)  # int32[BW] in [1, L_max+1]
    length = jnp.minimum(length, l_max)  # clamp padding-bit garbage
    # --- first_code / rank_offset lookup via one-hot over lengths ---
    len_onehot = (
        length[:, None] == lengths_iota[None, :]
    )  # bool[BW, L+1]
    fcs = jnp.sum(
        jnp.where(len_onehot, dec_first[None, :], jnp.uint32(0)),
        axis=1,
        dtype=jnp.uint32,
    )
    roff = jnp.sum(
        jnp.where(len_onehot, dec_rank[None, :], 0), axis=1,
        dtype=jnp.int32,
    )
    rank = roff + _shr32(prefix - fcs, l_max - length).astype(jnp.int32)
    rank = jnp.clip(rank, 0, 255)
    # --- symbol: one-hot [BW, 256] @ table[256] on the MXU ---
    sym_onehot = (
        rank[:, None] == jnp.arange(256, dtype=jnp.int32)[None, :]
    ).astype(jnp.float32)
    sym = jnp.dot(
        sym_onehot, syms_f, preferred_element_type=jnp.float32
    ).astype(jnp.int32)
    # --- funnel-shift the (hi, lo) buffer left by `length` ---
    new_hi = _shl32(cur_hi, length) | _shr32(cur_lo, 32 - length)
    new_lo = _shl32(cur_lo, length)
    return sym, new_hi, new_lo


def _decode_kernel(
    hi_ref,
    lo_ref,
    dec_limit_ref,  # uint32[L_max]     limit_shifted[1:]
    dec_first_ref,  # uint32[L_max+1]   first_code_shifted
    dec_rank_ref,  # int32[L_max+1]     rank_offset
    dec_syms_ref,  # int32[256]         sorted_symbols
    out_ref,  # int32[MAX_SYMS, BLOCK_WORDS]
    *,
    l_max: int,
    max_symlen: int,
):
    dec_limit = dec_limit_ref[...]
    dec_first = dec_first_ref[...]
    dec_rank = dec_rank_ref[...]
    # symbol table as f32 matmul operand (one-hot lookup)
    syms_f = dec_syms_ref[...].astype(jnp.float32)  # [256]

    def slot(j, carry):
        cur_hi, cur_lo = carry
        sym, new_hi, new_lo = _decode_slot(
            cur_hi, cur_lo, dec_limit, dec_first, dec_rank, syms_f,
            l_max=l_max,
        )
        out_ref[pl.dslice(j, 1), :] = sym[None, :]
        return new_hi, new_lo

    jax.lax.fori_loop(0, max_symlen, slot, (hi_ref[...], lo_ref[...]))


def decode_block_to_dense(
    hi,
    lo,
    sl,
    dec_limit,
    dec_first,
    dec_rank,
    syms_f,
    out_ref,  # int32[cap] — the dense symbol stream (whole-array block)
    tile_ref,  # VMEM scratch int32[max_symlen, BLOCK_WORDS]
    base,  # int32 scalar: output offset of this block's first symbol
    *,
    l_max: int,
    max_symlen: int,
):
    """Decode one word block and compact it into ``out_ref`` at ``base``.

    The in-kernel form of the paper's prefix-scan + cooperative-write
    stage: an exclusive prefix-scan of the block's symlen sidecar (VMEM)
    gives every word its local output offset, the slot loop fills the
    padded tile in VMEM *scratch*, and a word-major loop stores each
    word's ``max_symlen``-wide row at ``base + local[w]``.  Fixed-width
    rows overlap: word ``w``'s garbage tail ``[symlen[w], max_symlen)`` is
    exactly covered by word ``w+1``'s row (which starts at
    ``local[w] + symlen[w]``), so every position before the stream's end
    holds its true symbol; the one row of spill past the block's end is
    re-zeroed (callers pad the dense capacity by ``max_symlen`` so the
    blanking store stays in bounds).

    Returns the number of symbols this block decoded (int32), so callers
    carrying a running base across sequential grid steps can advance it.
    """
    bw = hi.shape[0]
    local = jnp.cumsum(sl) - sl  # VMEM-resident exclusive prefix scan

    def slot(j, carry):
        cur_hi, cur_lo = carry
        sym, new_hi, new_lo = _decode_slot(
            cur_hi, cur_lo, dec_limit, dec_first, dec_rank, syms_f,
            l_max=l_max,
        )
        tile_ref[pl.dslice(j, 1), :] = sym[None, :]
        return new_hi, new_lo

    jax.lax.fori_loop(0, max_symlen, slot, (hi, lo))
    tile_t = tile_ref[...].T  # [BW, max_symlen], word-major

    def word(w, _):
        row = jax.lax.dynamic_slice(
            tile_t, (w, 0), (1, max_symlen)
        ).reshape(max_symlen)
        pl.store(out_ref, (pl.dslice(base + local[w], max_symlen),), row)
        return 0

    jax.lax.fori_loop(0, bw, word, 0)
    decoded = jnp.sum(sl)
    # the block's final row wrote < max_symlen junk symbols past its true
    # end; re-zero them.  For interior blocks the next block overwrites the
    # same region with real symbols either way — for the LAST block this is
    # what makes positions beyond the stream read exactly like the XLA
    # scatter's zero fill (so fused and unfused buckets match bit for bit
    # even in padding windows).
    pl.store(
        out_ref,
        (pl.dslice(base + decoded, max_symlen),),
        jnp.zeros((max_symlen,), jnp.int32),
    )
    return decoded


def _dense_kernel(
    hi_ref,
    lo_ref,
    sl_ref,  # int32[BLOCK_WORDS] — the symlen sidecar rides into the kernel
    dec_limit_ref,
    dec_first_ref,
    dec_rank_ref,
    dec_syms_ref,
    out_ref,  # int32[cap] — whole dense stream, revisited every grid step
    tile_ref,  # VMEM scratch int32[max_symlen, BLOCK_WORDS]
    base_ref,  # SMEM scratch int32[1]: running output offset across blocks
    *,
    l_max: int,
    max_symlen: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        base_ref[0] = 0
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    base = base_ref[0]
    decoded = decode_block_to_dense(
        hi_ref[...],
        lo_ref[...],
        sl_ref[...],
        dec_limit_ref[...],
        dec_first_ref[...],
        dec_rank_ref[...],
        dec_syms_ref[...].astype(jnp.float32),
        out_ref,
        tile_ref,
        base,
        l_max=l_max,
        max_symlen=max_symlen,
    )
    base_ref[0] = base + decoded


@functools.partial(
    jax.jit,
    static_argnames=(
        "l_max", "max_symlen", "num_symbols", "block_words", "interpret"
    ),
)
def huffman_decode_dense(
    hi: jnp.ndarray,  # uint32[W]
    lo: jnp.ndarray,  # uint32[W]
    symlen: jnp.ndarray,  # int32[W]
    dec_limit: jnp.ndarray,
    dec_first: jnp.ndarray,
    dec_rank: jnp.ndarray,
    dec_syms: jnp.ndarray,
    *,
    l_max: int,
    max_symlen: int,
    num_symbols: int,
    block_words: int = BLOCK_WORDS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused decode + compaction: packed words -> dense int32[num_symbols].

    ONE ``pallas_call``: the ``[max_symlen, W]`` tile only ever exists as a
    per-block VMEM scratch, and the dense output offsets come from the
    in-kernel prefix scan of the symlen sidecar (the running cross-block
    base rides SMEM scratch across the sequential grid).  Trailing padding
    words must carry ``symlen == 0``; every position past the true symbol
    total reads as zero (the cooperative store re-zeroes its one row of
    spill), exactly like ``compact_padded_scatter``'s zero fill.
    """
    w = hi.shape[0]
    block_words = min(block_words, max(w, 1))
    num_blocks = -(-w // block_words)
    wp = num_blocks * block_words
    if wp != w:
        hi = jnp.pad(hi, (0, wp - w))
        lo = jnp.pad(lo, (0, wp - w))
        symlen = jnp.pad(symlen, (0, wp - w))

    # over-allocate by one tile row for the final word's fixed-width spill,
    # rounded to the f32/i32 lane tile so the block shape is TPU-friendly
    cap = -(-(num_symbols + max_symlen) // 128) * 128
    kernel = functools.partial(
        _dense_kernel, l_max=l_max, max_symlen=max_symlen
    )
    out = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_words,), lambda i: (i,)),
            pl.BlockSpec((block_words,), lambda i: (i,)),
            pl.BlockSpec((block_words,), lambda i: (i,)),
            # small decode tables: replicated to every block
            pl.BlockSpec((dec_limit.shape[0],), lambda i: (0,)),
            pl.BlockSpec((dec_first.shape[0],), lambda i: (0,)),
            pl.BlockSpec((dec_rank.shape[0],), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((cap,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((cap,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((max_symlen, block_words), jnp.int32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
    )(hi, lo, symlen.astype(jnp.int32), dec_limit, dec_first, dec_rank,
      dec_syms)
    return out[:num_symbols]


@functools.partial(
    jax.jit,
    static_argnames=("l_max", "max_symlen", "block_words", "interpret"),
)
def huffman_decode_tile(
    hi: jnp.ndarray,  # uint32[W]
    lo: jnp.ndarray,  # uint32[W]
    dec_limit: jnp.ndarray,
    dec_first: jnp.ndarray,
    dec_rank: jnp.ndarray,
    dec_syms: jnp.ndarray,
    *,
    l_max: int,
    max_symlen: int,
    block_words: int = BLOCK_WORDS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Decode every word's symbols into a slot-major tile [max_symlen, W]
    (int32) — the kernel's native output layout, no transpose copy.

    The grid iterates over word blocks with no knowledge of container
    boundaries, so a batch of containers concatenated word-wise decodes in
    this single pallas_call; compaction (ops.py / core.symlen) carries the
    per-container structure via the symlen sidecar.

    Words are padded up to a multiple of ``block_words``; callers slice.
    """
    w = hi.shape[0]
    num_blocks = -(-w // block_words)
    wp = num_blocks * block_words
    if wp != w:
        hi = jnp.pad(hi, (0, wp - w))
        lo = jnp.pad(lo, (0, wp - w))

    kernel = functools.partial(
        _decode_kernel, l_max=l_max, max_symlen=max_symlen
    )
    out = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_words,), lambda i: (i,)),
            pl.BlockSpec((block_words,), lambda i: (i,)),
            # small decode tables: replicated to every block
            pl.BlockSpec((dec_limit.shape[0],), lambda i: (0,)),
            pl.BlockSpec((dec_first.shape[0],), lambda i: (0,)),
            pl.BlockSpec((dec_rank.shape[0],), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((max_symlen, block_words), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((max_symlen, wp), jnp.int32),
        interpret=interpret,
    )(hi, lo, dec_limit, dec_first, dec_rank, dec_syms)
    return out[:, :w]  # [max_symlen, W]


def huffman_decode_padded(*args, **kwargs) -> jnp.ndarray:
    """Word-major view of :func:`huffman_decode_tile`: [W, max_symlen]."""
    return huffman_decode_tile(*args, **kwargs).T
