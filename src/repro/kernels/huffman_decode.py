"""Pallas TPU kernel: SymLen word-parallel Huffman decode (paper §4.2.1).

GPU original: one CUDA thread per 64-bit word, serial LUT loop per thread,
warp-shuffle cooperative writes.  TPU adaptation (DESIGN.md §2):

  * one VPU **lane** per word — a block of ``BLOCK_WORDS`` words is decoded by
    looping over *symbol slots*; every iteration decodes one symbol for all
    words in the block simultaneously (branch-free, no divergence possible);
  * the 2^L_max shared-memory LUT is replaced by **arithmetic canonical
    decoding**: length = 1 + #(prefix >= limit_shifted[l]) via vectorized
    compares, then rank arithmetic; the final 256-way symbol lookup is a
    **one-hot matmul** against the symbol table (gather-via-one-hot — the MXU
    idiom for small-table lookups);
  * 64-bit words are processed as (hi, lo) uint32 pairs with funnel shifts
    (TPU int64 is emulated; uint32 is native VPU width);
  * the warp-cooperative coalesced write stage becomes a dense **padded tile**
    ``[MAX_SYMS, BLOCK_WORDS]`` store; compaction (exclusive prefix-sum of
    symlen + gather) happens at the XLA level in ``ops.huffman_decode`` —
    exactly the paper's prefix-scan, lifted out of the kernel.

VMEM budget per block (BLOCK_WORDS=512, MAX_SYMS<=64, L_max<=16):
  in:  hi/lo/symlen          3 * 512 * 4 B            =   6 KiB
  tables: limits/first/rank/ symbols                  <   3 KiB
  out: padded tile           64 * 512 * 4 B           = 128 KiB
well under the ~16 MiB VMEM of a TPU v5e core; BLOCK_WORDS can scale to 4096.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["huffman_decode_padded", "huffman_decode_tile"]

BLOCK_WORDS = 512


def _shl32(x, s):
    s = jnp.clip(s, 0, 31).astype(jnp.uint32)
    return x << s


def _shr32(x, s):
    s = jnp.clip(s, 0, 31).astype(jnp.uint32)
    return x >> s


def _decode_kernel(
    hi_ref,
    lo_ref,
    dec_limit_ref,  # uint32[L_max]     limit_shifted[1:]
    dec_first_ref,  # uint32[L_max+1]   first_code_shifted
    dec_rank_ref,  # int32[L_max+1]     rank_offset
    dec_syms_ref,  # int32[256]         sorted_symbols
    out_ref,  # int32[MAX_SYMS, BLOCK_WORDS]
    *,
    l_max: int,
    max_symlen: int,
):
    cur_hi = hi_ref[...]  # uint32[BW]
    cur_lo = lo_ref[...]
    bw = cur_hi.shape[0]

    dec_limit = dec_limit_ref[...]
    dec_first = dec_first_ref[...]
    dec_rank = dec_rank_ref[...]
    # symbol table as f32 matmul operand (one-hot lookup)
    syms_f = dec_syms_ref[...].astype(jnp.float32)  # [256]

    lengths_iota = jnp.arange(l_max + 1, dtype=jnp.int32)  # [L+1]

    def slot(j, carry):
        cur_hi, cur_lo = carry
        prefix = _shr32(cur_hi, 32 - l_max)  # uint32[BW]
        # --- code length: vectorized compares against limit boundaries ---
        ge = (prefix[None, :] >= dec_limit[:, None]).astype(jnp.int32)
        length = 1 + jnp.sum(ge, axis=0)  # int32[BW] in [1, L_max+1]
        length = jnp.minimum(length, l_max)  # clamp padding-bit garbage
        # --- first_code / rank_offset lookup via one-hot over lengths ---
        len_onehot = (
            length[:, None] == lengths_iota[None, :]
        )  # bool[BW, L+1]
        fcs = jnp.sum(
            jnp.where(len_onehot, dec_first[None, :], jnp.uint32(0)),
            axis=1,
            dtype=jnp.uint32,
        )
        roff = jnp.sum(
            jnp.where(len_onehot, dec_rank[None, :], 0), axis=1,
            dtype=jnp.int32,
        )
        rank = roff + _shr32(prefix - fcs, l_max - length).astype(jnp.int32)
        rank = jnp.clip(rank, 0, 255)
        # --- symbol: one-hot [BW, 256] @ table[256] on the MXU ---
        sym_onehot = (
            rank[:, None] == jnp.arange(256, dtype=jnp.int32)[None, :]
        ).astype(jnp.float32)
        sym = jnp.dot(
            sym_onehot, syms_f, preferred_element_type=jnp.float32
        ).astype(jnp.int32)
        out_ref[pl.dslice(j, 1), :] = sym[None, :]
        # --- funnel-shift the (hi, lo) buffer left by `length` ---
        new_hi = _shl32(cur_hi, length) | _shr32(cur_lo, 32 - length)
        new_lo = _shl32(cur_lo, length)
        return new_hi, new_lo

    jax.lax.fori_loop(0, max_symlen, slot, (cur_hi, cur_lo))


@functools.partial(
    jax.jit,
    static_argnames=("l_max", "max_symlen", "block_words", "interpret"),
)
def huffman_decode_tile(
    hi: jnp.ndarray,  # uint32[W]
    lo: jnp.ndarray,  # uint32[W]
    dec_limit: jnp.ndarray,
    dec_first: jnp.ndarray,
    dec_rank: jnp.ndarray,
    dec_syms: jnp.ndarray,
    *,
    l_max: int,
    max_symlen: int,
    block_words: int = BLOCK_WORDS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Decode every word's symbols into a slot-major tile [max_symlen, W]
    (int32) — the kernel's native output layout, no transpose copy.

    The grid iterates over word blocks with no knowledge of container
    boundaries, so a batch of containers concatenated word-wise decodes in
    this single pallas_call; compaction (ops.py / core.symlen) carries the
    per-container structure via the symlen sidecar.

    Words are padded up to a multiple of ``block_words``; callers slice.
    """
    w = hi.shape[0]
    num_blocks = -(-w // block_words)
    wp = num_blocks * block_words
    if wp != w:
        hi = jnp.pad(hi, (0, wp - w))
        lo = jnp.pad(lo, (0, wp - w))

    kernel = functools.partial(
        _decode_kernel, l_max=l_max, max_symlen=max_symlen
    )
    out = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_words,), lambda i: (i,)),
            pl.BlockSpec((block_words,), lambda i: (i,)),
            # small decode tables: replicated to every block
            pl.BlockSpec((dec_limit.shape[0],), lambda i: (0,)),
            pl.BlockSpec((dec_first.shape[0],), lambda i: (0,)),
            pl.BlockSpec((dec_rank.shape[0],), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((max_symlen, block_words), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((max_symlen, wp), jnp.int32),
        interpret=interpret,
    )(hi, lo, dec_limit, dec_first, dec_rank, dec_syms)
    return out[:, :w]  # [max_symlen, W]


def huffman_decode_padded(*args, **kwargs) -> jnp.ndarray:
    """Word-major view of :func:`huffman_decode_tile`: [W, max_symlen]."""
    return huffman_decode_tile(*args, **kwargs).T
