"""Pallas TPU kernel: fused forward DCT + 3-zone quantization.

The encoder-side mirror of ``idct_dequant``: windows are transformed on the
MXU and quantized on the VPU in one VMEM residency.  The paper runs encode on
embedded devices — this kernel exists for the *server-side* bulk-compression
paths the framework adds beyond the paper (checkpoint compression, gradient
compression calibration, KV-cache compression), where encode throughput on
the accelerator matters.

    f32[W_blk, N] @ dct_basis[N, E]  --(MXU)-->  coeffs f32[W_blk, E]
    coeffs --(3-zone quantize, elementwise)-->  levels int32[W_blk, E]

Two quantization arms share the tile: the default inlines the 3-zone math
(hand-written for the VPU; may differ from the reference by one level at a
cell boundary for a ~1e-3 fraction of samples), while ``exact=True``
traces ``repro.core.quantize.quantize`` itself inside the kernel — the
bit-parity arm the fused encode kernel (``repro.kernels.encode_fused``,
which extends this tile all the way into Huffman codeword emission) is
built on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import QuantTable, quantize as _quantize_exact

__all__ = ["dct_quant"]

BLOCK_WINDOWS = 256
_ZERO_BIN = 128.0


def _kernel(
    windows_ref,  # f32[BW, N]
    zone_ref,  # int32[E]
    scale_ref,  # f32[E]
    basis_ref,  # f32[N, E]
    mu_ref,  # f32[1]
    alpha1_ref,  # f32[1]
    out_ref,  # int32[BW, E]
    *,
    exact: bool = False,
):
    c = jnp.dot(
        windows_ref[...], basis_ref[...], preferred_element_type=jnp.float32
    )  # [BW, E]
    if exact:
        table = QuantTable(
            zone=zone_ref[...],
            scale=scale_ref[...],
            mu=mu_ref[0],
            alpha1=alpha1_ref[0],
        )
        out_ref[...] = _quantize_exact(c, table).astype(jnp.int32)
        return
    zone = zone_ref[...]
    a = scale_ref[...]
    mu = mu_ref[0]
    alpha1 = alpha1_ref[0]
    sign_pos = c > 0

    # zone 0: mu-law companding (Eq. 2)
    x = jnp.minimum(jnp.abs(c) / a, 1.0)
    q01 = jnp.log1p(mu * x) / jnp.log1p(mu)
    lvl0 = jnp.where(
        sign_pos, 129.0 + jnp.round(q01 * 126.0), 127.0 - jnp.round(q01 * 127.0)
    )
    lvl0 = jnp.where(c == 0, _ZERO_BIN, lvl0)

    # zone 1: linear deadzone (Eq. 3)
    d1 = alpha1 * a
    denom = jnp.maximum(a - d1, 1e-12)
    c_clip = jnp.clip(c, -a, a)
    mag = jnp.abs(c_clip)
    lvl1 = jnp.where(
        c_clip > d1,
        129.0 + jnp.floor((c_clip - d1) / denom * 126.0 + 0.5),
        jnp.where(
            c_clip < -d1,
            127.0 - jnp.floor((mag - d1) / denom * 127.0 + 0.5),
            _ZERO_BIN,
        ),
    )

    lvl = jnp.where(
        zone[None, :] == 0,
        lvl0,
        jnp.where(zone[None, :] == 1, lvl1, jnp.full_like(c, _ZERO_BIN)),
    )
    out_ref[...] = jnp.clip(lvl, 0.0, 255.0).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("e", "block_windows", "interpret", "exact")
)
def dct_quant(
    windows: jnp.ndarray,  # f32[W, N]
    zone: jnp.ndarray,  # int32[E]
    scale: jnp.ndarray,  # f32[E]
    basis: jnp.ndarray,  # f32[N, E] (dct_basis)
    mu: jnp.ndarray,
    alpha1: jnp.ndarray,
    *,
    e: int,
    block_windows: int = BLOCK_WINDOWS,
    interpret: bool = True,
    exact: bool = False,
) -> jnp.ndarray:
    """Fused forward DCT + 3-zone quantize: [W, N] samples -> [W, E] levels.

    ``exact=True`` selects the reference-parity quantization arm (see the
    module docstring)."""
    w, n = windows.shape
    num_blocks = -(-w // block_windows)
    wp = num_blocks * block_windows
    if wp != w:
        windows = jnp.pad(windows, ((0, wp - w), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, exact=exact),
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_windows, n), lambda i: (i, 0)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((n, e), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_windows, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wp, e), jnp.int32),
        interpret=interpret,
    )(
        windows,
        zone,
        scale,
        basis,
        jnp.reshape(mu.astype(jnp.float32), (1,)),
        jnp.reshape(alpha1.astype(jnp.float32), (1,)),
    )
    return out[:w]
