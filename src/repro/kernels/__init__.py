"""Pallas TPU kernels for FPTC's compute hot spots.

Layout per kernel:  <name>.py (pl.pallas_call + BlockSpec), ref.py (pure-jnp
oracles), ops.py (jit'd wrappers; auto interpret=True off-TPU).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
