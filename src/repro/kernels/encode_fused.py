"""Pallas TPU kernel: fused encode bucket — DCT + quantize + SymLen pack.

``kernels/dct_quant.py`` hand-tiles the lossy half of the encoder; this
kernel extends that tile all the way into Huffman codeword emission so a
whole encode bucket is ONE ``pallas_call``: windows -> DCT (MXU) ->
3-zone quantize -> per-symbol (length, code) lookup via the one-hot
matmul idiom -> chunk-parallel SymLen word materialization, all in one
VMEM residency.  The grid runs ``block_rows`` signals per step (1 by
default; the autotuner sweeps it — rows are independent, so the knob
trades VMEM footprint against per-step overhead without touching bytes);
each row packs its chunks concurrently (the scan carries only the O(1)
bit-offset/word-index recurrence, vectorized across the chunk axis).

Bit parity is by construction, not by luck:

  * the quantizer is ``repro.core.quantize.quantize`` itself (the exact
    reference math, traced inside the kernel);
  * the (code, length) lookup is a one-hot ``[C, 256]`` matmul whose f32
    sums are exact (codewords are < 2^l_max <= 2^24, lengths <= 64);
  * the word materialization calls ``repro.core.symlen._pack_chunk_emit``
    — literally the same segment-sum code the XLA path runs — under an
    in-kernel ``vmap`` over chunks.

So ``BatchEncoder(use_kernels=True)`` produces byte-identical streams to
the XLA engine path (pinned by the golden + conformance suites in
interpret mode).

VMEM budget per grid step (Wp windows, N, E <= 128, chunk C, B chunks):
  signal row                     4 B * Wp * N
  coeffs / levels                4 B * Wp * E (x2)
  one-hot lookup block           4 B * B * C * 256  (whole-signal; the
                                 kernel's largest transient — 4 MiB at
                                 Sp = 4096 symbols)
  chunk parts out                4 B * 3 * B * C
On real TPU the one-hot block wants per-chunk tiling (a ROADMAP
follow-up); in interpret mode (how these kernels are validated) XLA fuses
it and the block never materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import QuantTable, predict_levels, quantize
from repro.core.symlen import _pack_chunk_emit

__all__ = ["encode_fused"]

_TRIVIAL = (0, 0, False)  # no predictor, no zero planes: the v2 stream


def _kernel(
    sig_ref,  # f32[R, Wp * N] — R = block_rows signals per grid step
    counts_ref,  # int32[R] — true symbol count per signal
    codes_ref,  # uint32[256]
    lengths_ref,  # int32[256]
    zone_ref,  # int32[E]
    scale_ref,  # f32[E]
    mu_ref,  # f32[1]
    alpha1_ref,  # f32[1]
    basis_ref,  # f32[N, E] (dct_basis)
    # out refs: hi, lo, sl, wpc, bad [+ ncoded [+ zrow, zcol] under a
    # non-trivial coding] — arity is fixed at trace time by ``coding``
    *out_refs,
    n: int,
    e: int,
    num_chunks: int,
    chunk_size: int,
    check_gaps: bool,
    coding=_TRIVIAL,
):
    pred_id, bands, zplanes = coding
    if coding == _TRIVIAL:
        hi_ref, lo_ref, sl_ref, wpc_ref, bad_ref = out_refs
        nc_ref = zr_ref = zc_ref = None
    elif zplanes:
        (hi_ref, lo_ref, sl_ref, wpc_ref, bad_ref,
         nc_ref, zr_ref, zc_ref) = out_refs
    else:
        hi_ref, lo_ref, sl_ref, wpc_ref, bad_ref, nc_ref = out_refs
        zr_ref = zc_ref = None
    quant = QuantTable(
        zone=zone_ref[...],
        scale=scale_ref[...],
        mu=mu_ref[0],
        alpha1=alpha1_ref[0],
    )
    basis = basis_ref[...]
    codes_f = codes_ref[...].astype(jnp.float32)  # exact: < 2^l_max <= 2^24
    lengths_f = lengths_ref[...].astype(jnp.float32)
    sym_iota = jnp.arange(256, dtype=jnp.int32)
    cap = num_chunks * chunk_size

    def one_row(sig, count):
        windows = sig.reshape(-1, n)  # [Wp, N]
        coeffs = jnp.dot(
            windows, basis, preferred_element_type=jnp.float32
        )  # [Wp, E]
        # the exact reference quantizer — same ops the XLA path traces, so
        # the levels (hence every packed bit) are identical under jit
        levels = quantize(coeffs, quant)  # uint8[Wp, E]
        if coding == _TRIVIAL:
            syms = levels.reshape(-1).astype(jnp.int32)  # [Sp]
            if cap != syms.shape[0]:
                syms = jnp.pad(syms, (0, cap - syms.shape[0]))
            valid = jnp.arange(cap, dtype=jnp.int32) < count
            extras = ()
        else:
            # the v3 prologue — the SAME reference transform the XLA engine
            # arm traces (quantize.predict_levels + the zero-plane masks),
            # fused between quantization and the codeword lookup
            grid = predict_levels(levels, pred_id, bands)  # uint8[Wp, E]
            w = grid.shape[0]
            win_valid = (
                jnp.arange(w, dtype=jnp.int32) < count // e
            )  # true (non-padding) windows of this row
            if zplanes:
                is_zero = grid == jnp.uint8(128)
                zrow = jnp.all(is_zero, axis=1)  # [Wp]
                zcol = jnp.all(
                    is_zero | ~win_valid[:, None], axis=0
                )  # [E], over true windows only
                valid2 = (win_valid & ~zrow)[:, None] & ~zcol[None, :]
            else:
                valid2 = jnp.broadcast_to(win_valid[:, None], grid.shape)
            syms = grid.reshape(-1).astype(jnp.int32)
            valid = valid2.reshape(-1)
            if cap != syms.shape[0]:
                syms = jnp.pad(syms, (0, cap - syms.shape[0]))
                valid = jnp.pad(valid, (0, cap - valid.shape[0]))
            if zplanes:
                ncoded = jnp.sum(valid, dtype=jnp.int32)
                extras = (
                    ncoded, zrow.astype(jnp.int32), zcol.astype(jnp.int32)
                )
            else:
                extras = (count,)

        # one batched one-hot lookup for the whole signal (a single MXU
        # matmul equation — an unrolled per-chunk loop traces O(B) ops for
        # the same exact integer selections); the [cap, 256] block is the
        # kernel's largest transient, see the module docstring's VMEM note
        onehot = (syms[:, None] == sym_iota[None, :]).astype(jnp.float32)
        raw_code = (
            jnp.dot(onehot, codes_f, preferred_element_type=jnp.float32)
            .astype(jnp.uint32).reshape(num_chunks, chunk_size)
        )
        raw_len = (
            jnp.dot(onehot, lengths_f, preferred_element_type=jnp.float32)
            .astype(jnp.int32).reshape(num_chunks, chunk_size)
        )
        validr = valid.reshape(num_chunks, chunk_size)
        if check_gaps:
            bad = jnp.any((raw_len == 0) & validr).astype(jnp.int32)
        else:
            bad = jnp.zeros((), jnp.int32)
        # masked slots emit a zero-length, zero-valued code: a no-op (the
        # same masking _pack_chunk applies before its emit)
        code = jnp.where(validr, raw_code, jnp.uint32(0))
        clen = jnp.where(validr, raw_len, 0)
        hi, lo, sl, wpc = jax.vmap(_pack_chunk_emit)(code, clen, validr)
        return (hi, lo, sl, wpc, bad) + extras

    # rows are independent signals: vmap keeps every per-row selection /
    # pack identical to the one-row kernel while a tuned block_rows > 1
    # amortizes the per-step dispatch overhead across R rows
    outs = jax.vmap(one_row)(sig_ref[...], counts_ref[...])
    hi_ref[...] = outs[0]
    lo_ref[...] = outs[1]
    sl_ref[...] = outs[2]
    wpc_ref[...] = outs[3]
    bad_ref[...] = outs[4]
    if nc_ref is not None:
        nc_ref[...] = outs[5]
    if zr_ref is not None:
        zr_ref[...] = outs[6]
        zc_ref[...] = outs[7]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n", "e", "chunk_size", "check_gaps", "coding", "block_rows",
        "interpret",
    ),
)
def encode_fused(
    signals: jnp.ndarray,  # f32[K, Wp * N] (zero-padded signal rows)
    counts: jnp.ndarray,  # int32[K] true symbol count per signal
    codes: jnp.ndarray,  # uint32[256]
    lengths: jnp.ndarray,  # int32[256]
    zone: jnp.ndarray,  # int32[E]
    scale: jnp.ndarray,  # f32[E]
    mu: jnp.ndarray,
    alpha1: jnp.ndarray,
    basis: jnp.ndarray,  # f32[N, E] dct_basis
    *,
    n: int,
    e: int,
    chunk_size: int,
    check_gaps: bool,
    coding=_TRIVIAL,
    block_rows: int = 1,
    interpret: bool = True,
):
    """Fused bucket encode, one ``pallas_call``: signal rows -> chunk parts.

    Returns ``(hi uint32[K, B, C], lo uint32[K, B, C], symlen int32[K, B,
    C], words_per_chunk int32[K, B], bad bool[K])`` — exactly the contract
    of the XLA path (``vmap`` of :func:`repro.core.symlen.
    pack_symlen_chunked_parts` plus the batch-wide histogram-gap flag),
    byte for byte.  A non-trivial ``coding`` (container v3) appends the
    XLA arm's extra outputs: per-signal coded-symbol counts ``ncoded
    int32[K]`` and — with zero planes — ``zrow bool[K, Wp]`` / ``zcol
    bool[K, E]``; the v3 prologue (prediction + zero-plane masking) runs
    inside the same single ``pallas_call``.

    ``block_rows`` is the autotuner's knob: signals packed per grid step
    (rows are independent, so it trades per-step VMEM footprint against
    per-step dispatch overhead and NEVER changes bytes — the batch pads up
    to a row multiple with zero-count rows, which pack zero words, and the
    outputs slice back to ``K``).
    """
    coding = tuple(coding)
    zplanes = coding != _TRIVIAL and bool(coding[2])
    k, width = signals.shape
    wp = width // n
    sp = wp * e
    num_chunks = max(-(-sp // chunk_size), 1)
    br = max(min(int(block_rows), max(k, 1)), 1)
    kp = -(-k // br) * br
    if kp != k:
        signals = jnp.pad(signals, ((0, kp - k), (0, 0)))
        counts = jnp.pad(counts, (0, kp - k))
    kernel = functools.partial(
        _kernel,
        n=n,
        e=e,
        num_chunks=num_chunks,
        chunk_size=chunk_size,
        check_gaps=check_gaps,
        coding=coding,
    )

    def row(i):
        return (i, 0)

    def row3(i):
        return (i, 0, 0)

    def rep(i):
        return (0,)

    out_specs = [
        pl.BlockSpec((br, num_chunks, chunk_size), row3),
        pl.BlockSpec((br, num_chunks, chunk_size), row3),
        pl.BlockSpec((br, num_chunks, chunk_size), row3),
        pl.BlockSpec((br, num_chunks), row),
        pl.BlockSpec((br,), lambda i: (i,)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((kp, num_chunks, chunk_size), jnp.uint32),
        jax.ShapeDtypeStruct((kp, num_chunks, chunk_size), jnp.uint32),
        jax.ShapeDtypeStruct((kp, num_chunks, chunk_size), jnp.int32),
        jax.ShapeDtypeStruct((kp, num_chunks), jnp.int32),
        jax.ShapeDtypeStruct((kp,), jnp.int32),
    ]
    if coding != _TRIVIAL:
        out_specs.append(pl.BlockSpec((br,), lambda i: (i,)))
        out_shape.append(jax.ShapeDtypeStruct((kp,), jnp.int32))
    if zplanes:
        out_specs += [
            pl.BlockSpec((br, wp), row),
            pl.BlockSpec((br, e), row),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((kp, wp), jnp.int32),
            jax.ShapeDtypeStruct((kp, e), jnp.int32),
        ]
    outs = pl.pallas_call(
        kernel,
        grid=(kp // br,),
        in_specs=[
            pl.BlockSpec((br, width), row),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((256,), rep),
            pl.BlockSpec((256,), rep),
            pl.BlockSpec((e,), rep),
            pl.BlockSpec((e,), rep),
            pl.BlockSpec((1,), rep),
            pl.BlockSpec((1,), rep),
            pl.BlockSpec((n, e), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(
        signals,
        counts.astype(jnp.int32),
        codes,
        lengths,
        zone,
        scale,
        jnp.reshape(mu.astype(jnp.float32), (1,)),
        jnp.reshape(alpha1.astype(jnp.float32), (1,)),
        basis,
    )
    outs = [o[:k] for o in outs] if kp != k else list(outs)
    hi, lo, sl, wpc, bad = outs[:5]
    if coding == _TRIVIAL:
        return hi, lo, sl, wpc, bad > 0
    ncoded = outs[5]
    if zplanes:
        zrow = outs[6].astype(jnp.bool_)
        zcol = outs[7].astype(jnp.bool_)
    else:
        zrow = zcol = None
    return hi, lo, sl, wpc, bad > 0, ncoded, zrow, zcol
