"""Pallas TPU kernel: fused encode bucket — DCT + quantize + SymLen pack.

``kernels/dct_quant.py`` hand-tiles the lossy half of the encoder; this
kernel extends that tile all the way into Huffman codeword emission so a
whole encode bucket is ONE ``pallas_call``: windows -> DCT (MXU) ->
3-zone quantize -> per-symbol (length, code) lookup via the one-hot
matmul idiom -> chunk-parallel SymLen word materialization, all in one
VMEM residency.  The grid runs one signal per step; each step packs the
signal's chunks concurrently (the scan carries only the O(1)
bit-offset/word-index recurrence, vectorized across the chunk axis).

Bit parity is by construction, not by luck:

  * the quantizer is ``repro.core.quantize.quantize`` itself (the exact
    reference math, traced inside the kernel);
  * the (code, length) lookup is a one-hot ``[C, 256]`` matmul whose f32
    sums are exact (codewords are < 2^l_max <= 2^24, lengths <= 64);
  * the word materialization calls ``repro.core.symlen._pack_chunk_emit``
    — literally the same segment-sum code the XLA path runs — under an
    in-kernel ``vmap`` over chunks.

So ``BatchEncoder(use_kernels=True)`` produces byte-identical streams to
the XLA engine path (pinned by the golden + conformance suites in
interpret mode).

VMEM budget per grid step (Wp windows, N, E <= 128, chunk C, B chunks):
  signal row                     4 B * Wp * N
  coeffs / levels                4 B * Wp * E (x2)
  one-hot lookup block           4 B * B * C * 256  (whole-signal; the
                                 kernel's largest transient — 4 MiB at
                                 Sp = 4096 symbols)
  chunk parts out                4 B * 3 * B * C
On real TPU the one-hot block wants per-chunk tiling (a ROADMAP
follow-up); in interpret mode (how these kernels are validated) XLA fuses
it and the block never materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import QuantTable, quantize
from repro.core.symlen import _pack_chunk_emit

__all__ = ["encode_fused"]


def _kernel(
    sig_ref,  # f32[1, Wp * N]
    counts_ref,  # int32[1] — true symbol count for this signal
    codes_ref,  # uint32[256]
    lengths_ref,  # int32[256]
    zone_ref,  # int32[E]
    scale_ref,  # f32[E]
    mu_ref,  # f32[1]
    alpha1_ref,  # f32[1]
    basis_ref,  # f32[N, E] (dct_basis)
    hi_ref,  # uint32[1, B, C]
    lo_ref,  # uint32[1, B, C]
    sl_ref,  # int32[1, B, C]
    wpc_ref,  # int32[1, B]
    bad_ref,  # int32[1] — histogram-gap flag for this signal
    *,
    n: int,
    e: int,
    num_chunks: int,
    chunk_size: int,
    check_gaps: bool,
):
    windows = sig_ref[...].reshape(-1, n)  # [Wp, N]
    coeffs = jnp.dot(
        windows, basis_ref[...], preferred_element_type=jnp.float32
    )  # [Wp, E]
    quant = QuantTable(
        zone=zone_ref[...],
        scale=scale_ref[...],
        mu=mu_ref[0],
        alpha1=alpha1_ref[0],
    )
    # the exact reference quantizer — same ops the XLA path traces, so the
    # levels (hence every packed bit) are identical under jit
    syms = quantize(coeffs, quant).reshape(-1).astype(jnp.int32)  # [Sp]
    cap = num_chunks * chunk_size
    if cap != syms.shape[0]:
        syms = jnp.pad(syms, (0, cap - syms.shape[0]))
    valid = jnp.arange(cap, dtype=jnp.int32) < counts_ref[0]

    codes_f = codes_ref[...].astype(jnp.float32)  # exact: < 2^l_max <= 2^24
    lengths_f = lengths_ref[...].astype(jnp.float32)
    sym_iota = jnp.arange(256, dtype=jnp.int32)

    # one batched one-hot lookup for the whole signal (a single MXU matmul
    # equation — an unrolled per-chunk loop traces O(B) ops for the same
    # exact integer selections); the [cap, 256] block is the kernel's
    # largest transient, see the module docstring's VMEM note
    onehot = (syms[:, None] == sym_iota[None, :]).astype(jnp.float32)
    raw_code = (
        jnp.dot(onehot, codes_f, preferred_element_type=jnp.float32)
        .astype(jnp.uint32).reshape(num_chunks, chunk_size)
    )
    raw_len = (
        jnp.dot(onehot, lengths_f, preferred_element_type=jnp.float32)
        .astype(jnp.int32).reshape(num_chunks, chunk_size)
    )
    valid = valid.reshape(num_chunks, chunk_size)

    if check_gaps:
        bad_ref[...] = jnp.any((raw_len == 0) & valid).astype(
            jnp.int32
        )[None]
    else:
        bad_ref[...] = jnp.zeros((1,), jnp.int32)

    # masked slots emit a zero-length, zero-valued code: a no-op (the same
    # masking _pack_chunk applies before its emit)
    code = jnp.where(valid, raw_code, jnp.uint32(0))
    clen = jnp.where(valid, raw_len, 0)
    hi, lo, sl, wpc = jax.vmap(_pack_chunk_emit)(code, clen, valid)
    hi_ref[...] = hi[None]
    lo_ref[...] = lo[None]
    sl_ref[...] = sl[None]
    wpc_ref[...] = wpc[None]


@functools.partial(
    jax.jit,
    static_argnames=("n", "e", "chunk_size", "check_gaps", "interpret"),
)
def encode_fused(
    signals: jnp.ndarray,  # f32[K, Wp * N] (zero-padded signal rows)
    counts: jnp.ndarray,  # int32[K] true symbol count per signal
    codes: jnp.ndarray,  # uint32[256]
    lengths: jnp.ndarray,  # int32[256]
    zone: jnp.ndarray,  # int32[E]
    scale: jnp.ndarray,  # f32[E]
    mu: jnp.ndarray,
    alpha1: jnp.ndarray,
    basis: jnp.ndarray,  # f32[N, E] dct_basis
    *,
    n: int,
    e: int,
    chunk_size: int,
    check_gaps: bool,
    interpret: bool = True,
):
    """Fused bucket encode, one ``pallas_call``: signal rows -> chunk parts.

    Returns ``(hi uint32[K, B, C], lo uint32[K, B, C], symlen int32[K, B,
    C], words_per_chunk int32[K, B], bad bool[])`` — exactly the contract
    of the XLA path (``vmap`` of :func:`repro.core.symlen.
    pack_symlen_chunked_parts` plus the batch-wide histogram-gap flag),
    byte for byte.
    """
    k, width = signals.shape
    sp = (width // n) * e
    num_chunks = max(-(-sp // chunk_size), 1)
    kernel = functools.partial(
        _kernel,
        n=n,
        e=e,
        num_chunks=num_chunks,
        chunk_size=chunk_size,
        check_gaps=check_gaps,
    )

    def row(i):
        return (i, 0)

    def row3(i):
        return (i, 0, 0)

    def rep(i):
        return (0,)

    hi, lo, sl, wpc, bad = pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, width), row),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((256,), rep),
            pl.BlockSpec((256,), rep),
            pl.BlockSpec((e,), rep),
            pl.BlockSpec((e,), rep),
            pl.BlockSpec((1,), rep),
            pl.BlockSpec((1,), rep),
            pl.BlockSpec((n, e), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, num_chunks, chunk_size), row3),
            pl.BlockSpec((1, num_chunks, chunk_size), row3),
            pl.BlockSpec((1, num_chunks, chunk_size), row3),
            pl.BlockSpec((1, num_chunks), row),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, num_chunks, chunk_size), jnp.uint32),
            jax.ShapeDtypeStruct((k, num_chunks, chunk_size), jnp.uint32),
            jax.ShapeDtypeStruct((k, num_chunks, chunk_size), jnp.int32),
            jax.ShapeDtypeStruct((k, num_chunks), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=interpret,
    )(
        signals,
        counts.astype(jnp.int32),
        codes,
        lengths,
        zone,
        scale,
        jnp.reshape(mu.astype(jnp.float32), (1,)),
        jnp.reshape(alpha1.astype(jnp.float32), (1,)),
        basis,
    )
    return hi, lo, sl, wpc, jnp.any(bad > 0)
