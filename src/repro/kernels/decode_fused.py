"""Pallas TPU megakernel: single-dispatch bucket decode (paper §4.2 fused).

FPTC's decoder is "one massively parallel pass" in the paper, but the
serving engine's kernel path used to be three device programs stitched by
XLA — the Huffman tile, an XLA scatter compaction, and the iDCT kernel —
each paying an HBM round trip for the ``[max_symlen, W]`` padded tile.
This kernel is the single-dispatch shape: one ``pallas_call`` whose grid
has two *phases* (the coarse/fine fusion of Tian et al., "Revisiting
Huffman Coding", and cuSZ+'s fused gap-array design):

  phase 1 (steps ``0 .. num_word_blocks``): per word block, the arithmetic
    canonical Huffman decode fills a VMEM tile, a VMEM-resident exclusive
    prefix-scan of the symlen sidecar assigns output offsets (running base
    in SMEM scratch across the sequential TPU grid), and the cooperative
    word-major store compacts symbols into a dense VMEM *scratch* stream —
    the padded tile and the dense symbol stream never touch HBM;
  phase 2 (remaining steps): per window block, levels are read back out of
    the dense scratch, dequantized by *exact selection* from the
    materialized 256-level reconstruction LUT
    (``repro.core.quantize.quant_grid`` — precomputed once per decode
    plan, so the fused path and the XLA reference path consume literally
    the same float values and stay bit-identical under jit), and
    multiplied against the iDCT basis on the MXU into the output block.

VMEM budget per grid step (BLOCK_WORDS=512, BLOCK_WINDOWS=256, MS<=64,
N, E <= 128):
  word block in: hi/lo/symlen    3 * 512 * 4 B          =    6 KiB
  decode tables                                         <    3 KiB
  dequant LUT                    128 * 256 * 4 B        =  128 KiB
  tile scratch                   64 * 512 * 4 B         =  128 KiB
  dense symbol scratch           4 B * (Wn * E + MS)    = data-dependent
  idct basis                     128 * 128 * 4 B        =   64 KiB
  out window block               256 * 128 * 4 B        =  128 KiB
The dense scratch (and the resident output) scale with the bucket, so a
1M-symbol bucket costs ~4 MiB of VMEM — inside a v5e core's ~16 MiB, and
``repro.kernels.ops`` guards the int32 offset range long before VMEM does.

Like every kernel in this package the megakernel is validated in interpret
mode (CPU); ``core.symlen.compact_padded_scatter`` + the staged kernels
remain the interpret-mode oracle it is tested against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import expand_coded_stream, unpredict_levels
from repro.kernels.huffman_decode import BLOCK_WORDS, decode_block_to_dense

__all__ = ["decode_fused", "lut_dequant", "BLOCK_WINDOWS"]

BLOCK_WINDOWS = 256

_TRIVIAL = (0, 0, False)  # no predictor, no zero planes: the v1/v2 stream


def lut_dequant(levels: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Exact-selection dequant: levels int32[W, E], lut f32[E, 256] ->
    coeffs f32[W, E] with ``coeffs[w, k] = lut[k, levels[w, k]]``.

    A masked sum over the 256 level values — each element selects exactly
    one LUT entry, so the result is bit-identical to a gather while
    lowering to pure vector compares/selects (no per-element VMEM gather,
    which TPUs lack).  Both the fused kernel and the XLA bucket path
    dequantize through the same plan-resident LUT, which is what makes
    their float outputs identical.
    """

    def step(v, acc):
        return acc + jnp.where(levels == v, lut[:, v][None, :], 0.0)

    init = jnp.zeros(levels.shape, jnp.float32)
    return jax.lax.fori_loop(0, 256, step, init)


def _fused_kernel(
    hi_ref,
    lo_ref,
    sl_ref,
    dec_limit_ref,
    dec_first_ref,
    dec_rank_ref,
    dec_syms_ref,
    lut_ref,  # f32[E, 256] — quant_grid reconstruction values
    basis_ref,  # f32[E, N]
    # remaining refs: [idx_ref, seg_ref] (v3 coding only), then
    #   out_ref   f32[BLOCK_WINDOWS, N]
    #   syms_ref  VMEM scratch int32[cap]: the dense symbol stream
    #   tile_ref  VMEM scratch int32[max_symlen, BLOCK_WORDS]
    #   base_ref  SMEM scratch int32[1]
    *refs,
    l_max: int,
    max_symlen: int,
    num_word_blocks: int,
    block_windows: int,
    e: int,
    coding=_TRIVIAL,
):
    if coding == _TRIVIAL:
        out_ref, syms_ref, tile_ref, base_ref = refs
    else:
        idx_ref, seg_ref, out_ref, syms_ref, tile_ref, base_ref = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        base_ref[0] = 0
        syms_ref[...] = jnp.zeros(syms_ref.shape, syms_ref.dtype)

    @pl.when(i < num_word_blocks)
    def _decode_phase():
        base = base_ref[0]
        decoded = decode_block_to_dense(
            hi_ref[...],
            lo_ref[...],
            sl_ref[...],
            dec_limit_ref[...],
            dec_first_ref[...],
            dec_rank_ref[...],
            dec_syms_ref[...].astype(jnp.float32),
            syms_ref,
            tile_ref,
            base,
            l_max=l_max,
            max_symlen=max_symlen,
        )
        base_ref[0] = base + decoded

    if coding != _TRIVIAL:
        pred_id, bands, _ = coding

        # the v3 epilogue: one extra step's worth of work at the phase
        # boundary, still inside the same pallas_call.  The dense coded
        # stream is expanded to the full level grid (idx: -1 = zero-plane
        # suppressed or bucket padding -> zero bin 128) and un-predicted
        # per window segment — the SAME reference inverse the host decoder
        # and the XLA bucket arm call, so all three stay bit-identical.
        # Runs exactly once, on the first window-phase step, before any
        # window block reads the scratch back.
        @pl.when(i == num_word_blocks)
        def _recode_phase():
            dense = syms_ref[...]  # materialized value (no aliasing with
            # the write below)
            grid = expand_coded_stream(dense, idx_ref[...])
            grid = grid.reshape(-1, e)  # [nwp, e]
            lvl = unpredict_levels(
                grid.astype(jnp.uint32), seg_ref[...], pred_id, bands
            ).astype(jnp.int32)
            flat = lvl.reshape(-1)
            spill = syms_ref.shape[0] - flat.shape[0]
            if spill:
                flat = jnp.concatenate(
                    [flat, jnp.full((spill,), 128, jnp.int32)]
                )
            syms_ref[...] = flat

    @pl.when(i >= num_word_blocks)
    def _idct_phase():
        j = i - num_word_blocks
        levels = pl.load(
            syms_ref, (pl.dslice(j * block_windows * e, block_windows * e),)
        ).reshape(block_windows, e)
        coeffs = lut_dequant(levels, lut_ref[...])
        out_ref[...] = jnp.dot(
            coeffs, basis_ref[...], preferred_element_type=jnp.float32
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "l_max",
        "max_symlen",
        "num_windows",
        "n",
        "e",
        "coding",
        "block_words",
        "block_windows",
        "interpret",
    ),
)
def decode_fused(
    hi: jnp.ndarray,  # uint32[W] (concatenated, zero-padded bucket words)
    lo: jnp.ndarray,  # uint32[W]
    symlen: jnp.ndarray,  # int32[W] (0 on padding words)
    dec_limit: jnp.ndarray,
    dec_first: jnp.ndarray,
    dec_rank: jnp.ndarray,
    dec_syms: jnp.ndarray,
    lut: jnp.ndarray,  # f32[E, 256] quant_grid LUT
    basis: jnp.ndarray,  # f32[E, N] idct basis
    idx: jnp.ndarray = None,  # int32[num_windows * e] (v3 coding only)
    seg: jnp.ndarray = None,  # int32[num_windows] (v3 coding only)
    *,
    l_max: int,
    max_symlen: int,
    num_windows: int,
    n: int,
    e: int,
    coding=_TRIVIAL,
    block_words: int = BLOCK_WORDS,
    block_windows: int = BLOCK_WINDOWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """One ``pallas_call``: packed bucket words -> windows f32[num_windows, N].

    The whole decode bucket — Huffman + prefix-scan compaction + dequant +
    iDCT — in a single dispatch with no intermediate HBM tensor: the padded
    tile and the dense symbol stream live in VMEM scratch only.  Positions
    past the stream's true symbol total read as level 0 (zero-initialized
    scratch + re-zeroed spill, matching the XLA scatter's zero fill), so
    even padding windows come out bit-identical to the XLA bucket arm.

    A non-trivial ``coding`` (container v3) keeps the single-dispatch shape:
    the coded-stream expansion + un-prediction epilogue
    (``quantize.expand_coded_stream`` / ``unpredict_levels``) runs in-kernel
    on the first window-phase grid step, rewriting the dense scratch from
    coded symbols to plain levels before any window block dequantizes.
    ``idx``/``seg`` are the host-built expansion arrays
    (``symlen.v3_expand_index``); they are padded here to the kernel's
    window-block rounding (-1 / self-segments, which expand and un-predict
    to the zero bin 128 — exactly the XLA arm's padding semantics).
    """
    coding = tuple(coding)
    w = hi.shape[0]
    block_words = min(block_words, max(w, 1))
    num_word_blocks = -(-w // block_words)
    wp = num_word_blocks * block_words
    if wp != w:
        hi = jnp.pad(hi, (0, wp - w))
        lo = jnp.pad(lo, (0, wp - w))
        symlen = jnp.pad(symlen, (0, wp - w))
    block_windows = min(block_windows, max(num_windows, 1))
    num_win_blocks = -(-num_windows // block_windows)
    nwp = num_win_blocks * block_windows

    # dense symbol scratch: every window slot plus one tile row of spill
    cap = -(-(nwp * e + max_symlen) // 128) * 128
    nwb = num_word_blocks
    kernel = functools.partial(
        _fused_kernel,
        l_max=l_max,
        max_symlen=max_symlen,
        num_word_blocks=nwb,
        block_windows=block_windows,
        e=e,
        coding=coding,
    )

    def word_ix(i):
        return (jnp.minimum(i, nwb - 1),)

    def rep(i):
        return (0,)

    in_specs = [
        pl.BlockSpec((block_words,), word_ix),
        pl.BlockSpec((block_words,), word_ix),
        pl.BlockSpec((block_words,), word_ix),
        pl.BlockSpec((dec_limit.shape[0],), rep),
        pl.BlockSpec((dec_first.shape[0],), rep),
        pl.BlockSpec((dec_rank.shape[0],), rep),
        pl.BlockSpec((256,), rep),
        pl.BlockSpec((e, 256), lambda i: (0, 0)),
        pl.BlockSpec((e, n), lambda i: (0, 0)),
    ]
    operands = [
        hi,
        lo,
        symlen.astype(jnp.int32),
        dec_limit,
        dec_first,
        dec_rank,
        dec_syms,
        lut,
        basis,
    ]
    if coding != _TRIVIAL:
        if idx is None or seg is None:
            raise ValueError(
                "v3-coded decode_fused needs the idx/seg expansion arrays "
                "(symlen.v3_expand_index)"
            )
        idx = jnp.asarray(idx, jnp.int32)
        seg = jnp.asarray(seg, jnp.int32)
        if idx.shape[0] < nwp * e:
            idx = jnp.pad(
                idx, (0, nwp * e - idx.shape[0]), constant_values=-1
            )
        if seg.shape[0] < nwp:
            seg = jnp.concatenate(
                [seg, jnp.arange(seg.shape[0], nwp, dtype=jnp.int32)]
            )
        in_specs += [
            pl.BlockSpec((nwp * e,), rep),
            pl.BlockSpec((nwp,), rep),
        ]
        operands += [idx, seg]

    out = pl.pallas_call(
        kernel,
        grid=(nwb + num_win_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (block_windows, n),
            lambda i: (jnp.maximum(i - nwb, 0), 0),
        ),
        out_shape=jax.ShapeDtypeStruct((nwp, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((cap,), jnp.int32),
            pltpu.VMEM((max_symlen, block_words), jnp.int32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return out[:num_windows]
