"""Pallas TPU kernel: fused dequantization + inverse DCT (paper §4.2.2).

The paper's second "lossy" kernel fuses per-sample dequantization with the
inverse DCT because both have uniform work.  On TPU the natural realization
is stronger: the inverse DCT over a window is a linear map, so the whole
stage is a **matmul on the MXU** with the 3-zone inverse quantization fused
into its prologue on the VPU:

    levels int32[W_blk, E]  --(3-zone dequant, elementwise)-->  f32[W_blk, E]
    f32[W_blk, E] @ idct_basis[E, N]  --(MXU)-->  f32[W_blk, N]

BlockSpec tiling: the window axis is tiled by ``block_windows`` (default 256,
a multiple of the 8-sublane f32 tile); E and N are kept whole per block (both
<= 128 by Table 1, i.e. a single lane tile).  VMEM per block at the default:
in 256*128*4 = 128 KiB, basis 64 KiB, out 128 KiB — far under v5e VMEM, and
the matmul contraction dim E is the workload's intrinsic size.

The window axis carries no per-container structure, so the batched decode
engine (serving.batch_decode) feeds this kernel the *concatenated* window
tensor of a whole bucket — N containers, one grid sweep — passing the
device-resident basis from its plan cache instead of re-deriving it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["idct_dequant"]

BLOCK_WINDOWS = 256
_ZERO_BIN = 128.0


def _kernel(
    levels_ref,  # int32[BW, E]
    zone_ref,  # int32[E]
    scale_ref,  # f32[E]
    basis_ref,  # f32[E, N]
    mu_ref,  # f32[1]
    alpha1_ref,  # f32[1]
    out_ref,  # f32[BW, N]
):
    lvl = levels_ref[...].astype(jnp.float32)  # [BW, E]
    zone = zone_ref[...]  # [E]
    a = scale_ref[...]  # [E]
    mu = mu_ref[0]
    alpha1 = alpha1_ref[0]

    pos = lvl > _ZERO_BIN
    neg = lvl < _ZERO_BIN

    # zone 0: inverse mu-law companding
    q01 = jnp.where(pos, (lvl - 129.0) / 126.0, (127.0 - lvl) / 127.0)
    q01 = jnp.clip(q01, 0.0, 1.0)
    mag0 = a * (jnp.expm1(q01 * jnp.log1p(mu)) / mu)
    c0 = jnp.where(pos, mag0, -mag0)
    c0 = jnp.where(lvl == _ZERO_BIN, 0.0, c0)

    # zone 1: inverse linear deadzone
    d1 = alpha1 * a
    span = a - d1
    mag1 = jnp.where(
        pos,
        d1 + (lvl - 129.0) / 126.0 * span,
        d1 + (127.0 - lvl) / 127.0 * span,
    )
    c1 = jnp.where(pos, mag1, jnp.where(neg, -mag1, 0.0))

    coeffs = jnp.where(
        zone[None, :] == 0, c0, jnp.where(zone[None, :] == 1, c1, 0.0)
    )

    out_ref[...] = jnp.dot(
        coeffs, basis_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("n", "block_windows", "interpret")
)
def idct_dequant(
    levels: jnp.ndarray,  # int32/uint8 [W, E]
    zone: jnp.ndarray,  # int32[E]
    scale: jnp.ndarray,  # f32[E]
    basis: jnp.ndarray,  # f32[E, N] (idct_basis)
    mu: jnp.ndarray,  # f32 scalar
    alpha1: jnp.ndarray,  # f32 scalar
    *,
    n: int,
    block_windows: int = BLOCK_WINDOWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused 3-zone dequant + inverse DCT: [W, E] levels -> [W, N] samples."""
    w, e = levels.shape
    num_blocks = -(-w // block_windows)
    wp = num_blocks * block_windows
    levels = levels.astype(jnp.int32)
    if wp != w:
        levels = jnp.pad(levels, ((0, wp - w), (0, 0)))

    out = pl.pallas_call(
        _kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_windows, e), lambda i: (i, 0)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((e, n), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_windows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wp, n), jnp.float32),
        interpret=interpret,
    )(
        levels,
        zone,
        scale,
        basis,
        jnp.reshape(mu.astype(jnp.float32), (1,)),
        jnp.reshape(alpha1.astype(jnp.float32), (1,)),
    )
    return out[:w]
