"""Fault-tolerant checkpointing with optional FPTC compression.

Layout:  <dir>/step_<k>/
            manifest.json        — step, leaf index, shapes/dtypes, CRCs
            <leaf-hash>.npy      — raw leaf (default)
            state.fptc           — compress=True: every large float leaf of
                                   the tree, sharded + batch-encoded as ONE
                                   engine dispatch into concatenated FPTC
                                   containers (manifest v2); tables are
                                   calibrated once per checkpoint over the
                                   whole tree (``train_state`` domain) and
                                   serialized in the manifest sidecar
            <leaf-hash>.fptc     — legacy per-leaf containers (manifest v1,
                                   still restorable)
Writes are atomic: a temp dir is populated, fsync'd, then renamed; a restart
that died mid-write can never observe a torn checkpoint.  ``restore_latest``
scans for the newest complete manifest (fault tolerance: crash -> restart ->
resume from last durable step).  Every blob's CRC is verified on load.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.codec import decode as fptc_decode
from repro.core.config import CodecConfig
from repro.core.container import Container
from repro.core.domains import TRAIN_STATE_DOMAIN_ID, calibrate_train_state

PyTree = Any

__all__ = ["save_checkpoint", "restore_latest", "restore_checkpoint",
           "latest_step", "CKPT_CODEC_CONFIG"]

# near-lossless operating point for state compression: full retention, heavy
# mu-law resolution.  PRD on optimizer state ~0.1%, CR ~2-3x on smooth
# accumulators (bench_checkpoint_compression reports the exact numbers).
# This is the same operating point as DOMAIN_DEFAULTS["train_state"].
CKPT_CODEC_CONFIG = CodecConfig(
    n=64, e=64, b1=64, b2=64, mu=255.0, a0_percentile=100.0,
    scale_headroom=1.05, l_max=12,
)

# leaves below this many elements are stored raw: per-leaf container overhead
# and calibration noise dominate any savings
_COMPRESS_MIN_SIZE = 4096


def _leaf_paths(tree: PyTree):
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def _fname(key: str) -> str:
    return hashlib.sha1(key.encode()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    *, compress: bool = False) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "version": 2}
    try:
        to_compress: Dict[str, np.ndarray] = {}
        for key, leaf in _leaf_paths(tree):
            arr = np.asarray(leaf)
            name = _fname(key)
            entry = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "file": name,
            }
            if (
                compress
                and arr.dtype in (np.float32, np.float16)
                and arr.size >= _COMPRESS_MIN_SIZE
            ):
                # routed into the shared sharded/batched state blob below
                entry["codec"] = "fptc_state"
                del entry["file"]
                to_compress[key] = arr
            else:
                path = os.path.join(tmp, name + ".npy")
                np.save(path, arr)
                with open(path, "rb") as f:
                    entry["crc"] = zlib.crc32(f.read())
            manifest["leaves"][key] = entry
        if to_compress:
            manifest["state"] = _write_state_blob(tmp, to_compress)
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def _write_state_blob(tmp: str, arrays: Dict[str, np.ndarray]
                      ) -> Dict[str, Any]:
    """Encode every large float leaf as ONE batched engine call.

    Tables are calibrated once over the whole tree (``train_state``
    domain), leaves shard into fixed-length strips, and all shards ride a
    single :class:`~repro.serving.batch_encode.BatchEncoder` encode —
    uniform shard lengths mean one bucket shape, so the whole checkpoint
    compresses in a handful of fused dispatches instead of a per-leaf
    calibrate+encode.  Containers concatenate into ``state.fptc``; the
    manifest sidecar carries per-shard offsets/CRCs plus the serialized
    calibration (per-bin scales + smoothed histogram — the codebook
    rebuilds deterministically on restore).
    """
    from repro.serving.workloads import state_to_containers

    tables = calibrate_train_state(arrays, CKPT_CODEC_CONFIG)
    containers, leaf_manifest = state_to_containers(arrays, tables)
    shards = []
    offset = 0
    with open(os.path.join(tmp, "state.fptc"), "wb") as f:
        for cont in containers:
            blob = cont.to_bytes()
            f.write(blob)
            shards.append({
                "offset": offset,
                "size": len(blob),
                "crc": zlib.crc32(blob),
            })
            offset += len(blob)
        f.flush()
        os.fsync(f.fileno())
    return {
        "file": "state.fptc",
        "domain_id": int(tables.domain_id),
        "leaves": leaf_manifest,
        "shards": shards,
        "tables": {
            "scale": np.asarray(tables.quant.scale).tolist(),
            "hist": np.asarray(tables.hist).tolist(),
        },
    }


def _read_state_blob(base: str, state: Dict[str, Any]
                     ) -> Dict[str, np.ndarray]:
    """Inverse of :func:`_write_state_blob`: one batched decode."""
    from repro.core.calibration import tables_from_hist
    from repro.serving.workloads import state_from_containers

    with open(os.path.join(base, state["file"]), "rb") as f:
        raw = f.read()
    containers = []
    for shard in state["shards"]:
        blob = raw[shard["offset"]:shard["offset"] + shard["size"]]
        if zlib.crc32(blob) != shard["crc"]:
            raise ValueError(
                f"CRC mismatch in {state['file']} shard at "
                f"offset {shard['offset']}"
            )
        containers.append(Container.from_bytes(blob))
    tables = tables_from_hist(
        CKPT_CODEC_CONFIG,
        np.asarray(state["tables"]["scale"], np.float32),
        np.asarray(state["tables"]["hist"], np.int64),
        domain_id=int(state.get("domain_id", TRAIN_STATE_DOMAIN_ID)),
    )
    return state_from_containers(containers, state["leaves"], tables)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
            os.path.join(directory, name, "manifest.json")
        ):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, tree_like: PyTree) -> PyTree:
    """Restore into the structure of ``tree_like`` (shapes/dtypes verified)."""
    base = os.path.join(directory, f"step_{step:012d}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)

    state_arrays: Dict[str, np.ndarray] = {}
    if manifest.get("state"):
        state_arrays = _read_state_blob(base, manifest["state"])

    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for path, proto in leaves:
        key = jax.tree_util.keystr(path)
        entry = manifest["leaves"].get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        if entry.get("codec") == "fptc_state":
            # manifest v2: leaf lives in the shared batched state blob
            arr = state_arrays[key]
            expected_shape = tuple(entry["shape"])
            if tuple(arr.shape) != expected_shape:
                raise ValueError(
                    f"{key}: shape {arr.shape} != manifest {expected_shape}"
                )
            out.append(arr.astype(np.dtype(entry["dtype"])))
            continue
        name = entry["file"]
        if entry.get("codec") == "fptc":
            fpath = os.path.join(base, name + ".fptc")
            with open(fpath, "rb") as f:
                blob = f.read()
            if zlib.crc32(blob) != entry["crc"]:
                raise ValueError(f"CRC mismatch for {key}")
            cont = Container.from_bytes(blob)
            from repro.core.calibration import tables_from_hist

            tables = tables_from_hist(
                CKPT_CODEC_CONFIG,
                np.asarray(entry["aux"]["scale"], np.float32),
                np.asarray(entry["aux"]["hist"], np.int64),
            )
            arr = fptc_decode(cont, tables).astype(
                np.dtype(entry["dtype"])
            ).reshape(entry["shape"])
        else:
            fpath = os.path.join(base, name + ".npy")
            with open(fpath, "rb") as f:
                raw = f.read()
            if zlib.crc32(raw) != entry["crc"]:
                raise ValueError(f"CRC mismatch for {key}")
            arr = np.load(fpath)
            if arr.dtype.kind == "V":
                # numpy round-trips ml_dtypes (bfloat16, fp8) as raw void
                # bytes; re-view through the manifest dtype
                import ml_dtypes

                arr = arr.view(np.dtype(entry["dtype"]))
        expected_shape = tuple(entry["shape"])
        if tuple(arr.shape) != expected_shape:
            raise ValueError(
                f"{key}: shape {arr.shape} != manifest {expected_shape}"
            )
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(directory: str, tree_like: PyTree
                   ) -> Optional[Tuple[int, PyTree]]:
    step = latest_step(directory)
    if step is None:
        return None
    return step, restore_checkpoint(directory, step, tree_like)
