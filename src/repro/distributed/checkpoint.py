"""Fault-tolerant checkpointing with optional FPTC compression.

Layout:  <dir>/step_<k>/
            manifest.json        — step, leaf index, shapes/dtypes, CRCs
            <leaf-hash>.npy      — raw leaf (default)
            <leaf-hash>.fptc     — FPTC container (compress=True, float
                                   leaves; quantization-light config so the
                                   checkpoint roundtrip is visually lossless)
Writes are atomic: a temp dir is populated, fsync'd, then renamed; a restart
that died mid-write can never observe a torn checkpoint.  ``restore_latest``
scans for the newest complete manifest (fault tolerance: crash -> restart ->
resume from last durable step).  Every leaf's CRC is verified on load.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.calibration import calibrate
from repro.core.codec import decode as fptc_decode, encode as fptc_encode
from repro.core.config import CodecConfig
from repro.core.container import Container

PyTree = Any

__all__ = ["save_checkpoint", "restore_latest", "restore_checkpoint",
           "latest_step", "CKPT_CODEC_CONFIG"]

# near-lossless operating point for state compression: full retention, heavy
# mu-law resolution.  PRD on optimizer state ~0.1%, CR ~2-3x on smooth
# accumulators (bench_checkpoint_compression reports the exact numbers).
CKPT_CODEC_CONFIG = CodecConfig(
    n=64, e=64, b1=64, b2=64, mu=255.0, a0_percentile=100.0,
    scale_headroom=1.05, l_max=12,
)


def _leaf_paths(tree: PyTree):
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def _fname(key: str) -> str:
    return hashlib.sha1(key.encode()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    *, compress: bool = False) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "version": 1}
    try:
        for key, leaf in _leaf_paths(tree):
            arr = np.asarray(leaf)
            name = _fname(key)
            entry = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "file": name,
            }
            if (
                compress
                and arr.dtype in (np.float32, np.float16)
                and arr.size >= 4096
            ):
                flat = arr.astype(np.float32).ravel()
                tables = calibrate(flat, CKPT_CODEC_CONFIG, max_windows=4096)
                cont = fptc_encode(flat, tables)
                blob = cont.to_bytes()
                # serialize the calibrated structures: per-bin scales + the
                # smoothed histogram (codebook rebuilds deterministically)
                entry["codec"] = "fptc"
                entry["aux"] = {
                    "scale": np.asarray(tables.quant.scale).tolist(),
                    "hist": np.asarray(tables.hist).tolist(),
                }
                path = os.path.join(tmp, name + ".fptc")
                with open(path, "wb") as f:
                    f.write(blob)
                entry["crc"] = zlib.crc32(blob)
            else:
                path = os.path.join(tmp, name + ".npy")
                np.save(path, arr)
                with open(path, "rb") as f:
                    entry["crc"] = zlib.crc32(f.read())
            manifest["leaves"][key] = entry
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
            os.path.join(directory, name, "manifest.json")
        ):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, tree_like: PyTree) -> PyTree:
    """Restore into the structure of ``tree_like`` (shapes/dtypes verified)."""
    base = os.path.join(directory, f"step_{step:012d}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for path, proto in leaves:
        key = jax.tree_util.keystr(path)
        entry = manifest["leaves"].get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        name = entry["file"]
        if entry.get("codec") == "fptc":
            fpath = os.path.join(base, name + ".fptc")
            with open(fpath, "rb") as f:
                blob = f.read()
            if zlib.crc32(blob) != entry["crc"]:
                raise ValueError(f"CRC mismatch for {key}")
            cont = Container.from_bytes(blob)
            from repro.core.calibration import tables_from_hist

            tables = tables_from_hist(
                CKPT_CODEC_CONFIG,
                np.asarray(entry["aux"]["scale"], np.float32),
                np.asarray(entry["aux"]["hist"], np.int64),
            )
            arr = fptc_decode(cont, tables).astype(
                np.dtype(entry["dtype"])
            ).reshape(entry["shape"])
        else:
            fpath = os.path.join(base, name + ".npy")
            with open(fpath, "rb") as f:
                raw = f.read()
            if zlib.crc32(raw) != entry["crc"]:
                raise ValueError(f"CRC mismatch for {key}")
            arr = np.load(fpath)
            if arr.dtype.kind == "V":
                # numpy round-trips ml_dtypes (bfloat16, fp8) as raw void
                # bytes; re-view through the manifest dtype
                import ml_dtypes

                arr = arr.view(np.dtype(entry["dtype"]))
        expected_shape = tuple(entry["shape"])
        if tuple(arr.shape) != expected_shape:
            raise ValueError(
                f"{key}: shape {arr.shape} != manifest {expected_shape}"
            )
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(directory: str, tree_like: PyTree
                   ) -> Optional[Tuple[int, PyTree]]:
    step = latest_step(directory)
    if step is None:
        return None
    return step, restore_checkpoint(directory, step, tree_like)
