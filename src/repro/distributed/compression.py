"""FPTC gradient compression for the slow inter-pod axis.

The paper's pipeline is transform -> quantize -> entropy-code.  Applied to a
cross-pod all-reduce, the stages map as:

  * **windowed DCT + spectral truncation** (transform): linear, therefore
    commutes with summation — the all-reduce runs *in the truncated spectral
    domain* and moves E/N of the bytes.  The windowing/transform math is the
    shared :mod:`repro.core.dct` used by every other FPTC path.
  * **quantization**: int8 wire format with a pod-agreed scale (pmax of local
    scales, then quantize -> psum in int32 -> dequant).  Non-linear, so it is
    applied around the collective, not inside it.
  * **entropy coding**: cannot ride a summing collective (codewords are not
    additive) — Huffman stays OFF the collective path by design and lives in
    the checkpoint/offline paths (see ``distributed.checkpoint`` and
    ``serving.workloads``).

**Error feedback** keeps convergence: the compression residual is added back
to the next step's gradient (standard EF-SGD; residual lives in OptState).

Wire-byte accounting per gradient element (fp32 baseline = 4 B):
  truncate:      4 * E/N bytes as f32  (or 2 * E/N as bf16)
  truncate_int8: 1 * E/N bytes (plus one scalar scale per 2^15 window chunk)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dct as _dct

__all__ = ["CompressionConfig", "GradCompressor"]

PyTree = Any


def _replicate(x: jnp.ndarray) -> jnp.ndarray:
    """Constrain to fully-replicated: GSPMD lowers this to an all-gather of
    ``x`` in its OWN dtype (int8 for the quantized spectra — the compressed
    wire)."""
    from repro.distributed.sharding import current_policy

    policy = current_policy()
    if policy is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(
            policy.mesh, jax.sharding.PartitionSpec()
        )
    )


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    mode: str = "truncate_int8"
    # none            — GSPMD baseline (params FSDP-sharded over pod too)
    # replicated_f32  — pod-replicated DP, UNcompressed f32 wire (the classic
    #                   cross-pod gradient all-reduce FPTC is compared against)
    # truncate        — DCT + spectral truncation, bf16 wire
    # truncate_int8   — DCT + truncation + int8 wire (full FPTC lossy stack)
    n: int = 64  # DCT window over the flattened parameter axis
    e: int = 32  # retained spectral coefficients
    wire_dtype: Any = jnp.bfloat16  # for mode == "truncate"
    min_size: int = 4096  # leaves smaller than this skip compression
    axis: str = "pod"
    # Error-feedback decay: spectral truncation is a FIXED projection, so
    # the orthogonal component of the residual can never re-enter the wire
    # — without decay it grows linearly.  beta < 1 bounds it at
    # 1/(1-beta) x the per-step filtered mass; EF still fully recovers the
    # (state-dependent) int8 quantization error.
    ef_decay: float = 0.9

    @property
    def ratio(self) -> float:
        base = self.e / self.n
        if self.mode == "truncate_int8":
            return base / 4.0  # int8 vs f32
        if self.mode == "truncate":
            return base / 2.0  # bf16 vs f32
        return 1.0


@dataclasses.dataclass(frozen=True)
class GradCompressor:
    config: CompressionConfig

    # -- single-leaf transform ------------------------------------------
    def _to_spectrum(self, g: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
        c = self.config
        flat = g.reshape(-1).astype(jnp.float32)
        size = flat.shape[0]
        wins = _dct.window_signal(flat, c.n)  # zero-pads the tail window
        return _dct.forward_dct(wins, c.e), size  # [W, E]

    def _from_spectrum(self, spec: jnp.ndarray, size: int,
                       shape, dtype) -> jnp.ndarray:
        c = self.config
        wins = _dct.inverse_dct(spec.astype(jnp.float32), c.n)
        return _dct.unwindow_signal(wins, size).reshape(shape).astype(dtype)

    # -- compressed cross-pod all-reduce --------------------------------
    def _allreduce_leaf(self, g: jnp.ndarray, npods: int) -> jnp.ndarray:
        c = self.config
        if c.mode == "none" or g.size < c.min_size:
            return jax.lax.psum(g, c.axis) / npods

        spec, size = self._to_spectrum(g)
        if c.mode == "truncate":
            wire = spec.astype(c.wire_dtype)
            summed = jax.lax.psum(wire, c.axis).astype(jnp.float32) / npods
        elif c.mode == "truncate_int8":
            local_amax = jnp.max(jnp.abs(spec)) + 1e-12
            amax = jax.lax.pmax(local_amax, c.axis)  # pod-agreed scale
            scale = amax / 127.0
            q = jnp.clip(jnp.round(spec / scale), -127, 127).astype(jnp.int8)
            acc = jax.lax.psum(q.astype(jnp.int32), c.axis)
            summed = acc.astype(jnp.float32) * scale / npods
        else:
            raise ValueError(f"unknown compression mode {c.mode!r}")
        return self._from_spectrum(summed, size, g.shape, g.dtype)

    def all_reduce(
        self, grads: PyTree, npods: int,
        residual: Optional[PyTree] = None,
    ) -> Tuple[PyTree, Optional[PyTree]]:
        """Compressed mean-all-reduce over the pod axis, with error feedback.

        Must be called inside a shard_map manual over ``config.axis``.
        Returns (reduced grads, new residual tree or None).
        """
        if self.config.mode == "none":
            out = jax.tree_util.tree_map(
                lambda g: jax.lax.psum(g, self.config.axis) / npods, grads
            )
            return out, residual

        if residual is None:
            out = jax.tree_util.tree_map(
                lambda g: self._allreduce_leaf(g, npods), grads
            )
            return out, None

        def one(g, r):
            g_eff = g.astype(jnp.float32) + r.astype(jnp.float32)
            g_hat = self._allreduce_leaf(g_eff, npods)
            # residual: what THIS pod's contribution lost (local view),
            # decayed — see CompressionConfig.ef_decay
            new_r = (
                self.config.ef_decay * (g_eff - g_hat.astype(jnp.float32))
            ).astype(r.dtype)
            return g_hat.astype(g.dtype), new_r

        pairs = jax.tree_util.tree_map(one, grads, residual)
        out = jax.tree_util.tree_map(
            lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_res = jax.tree_util.tree_map(
            lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )
        return out, new_res

    # -- replica-axis formulation (pure GSPMD; no manual region) ---------
    def replica_sum(
        self, grads: PyTree, residual: Optional[PyTree],
    ) -> Tuple[PyTree, Optional[PyTree]]:
        """Compressed mean over a leading pod-replica axis.

        Every gradient leaf has shape [P, ...] with dim 0 sharded over
        "pod" (produced by vmap-ing the loss over pod-local batches).  The
        sum over dim 0 — lowered by GSPMD to the cross-pod all-reduce —
        happens on the int8/truncated representation, so the slow inter-pod
        links carry compressed bytes.  Error feedback is per-replica
        (residual leaves also [P, ...]).
        """
        c = self.config

        def one(g, r):
            p = g.shape[0]
            if c.mode in ("none",) or g[0].size < c.min_size:
                return jnp.mean(g.astype(jnp.float32), axis=0).astype(
                    g.dtype
                ), r
            gf = g.astype(jnp.float32)
            if r is not None:
                gf = gf + r.astype(jnp.float32)
            if c.mode == "replicated_f32":
                rep = _replicate(gf)  # f32 all-gather across pods (baseline)
                mean0 = jnp.mean(rep, axis=0)
                return mean0.astype(g.dtype), (
                    jnp.zeros_like(r) if r is not None else None
                )
            wins = _dct.window_signal(gf.reshape(p, -1), c.n)  # [P, W, N]
            spec = _dct.forward_dct(wins, c.e)  # [P, W, E]
            if c.mode == "truncate_int8":
                amax = jnp.max(jnp.abs(spec)) + 1e-12  # pod-agreed scale
                scale = amax / 127.0
                q = jnp.clip(jnp.round(spec / scale), -127, 127).astype(
                    jnp.int8
                )
                # replicate the INT8 spectra across pods (GSPMD lowers the
                # constraint to an int8 all-gather — the actual compressed
                # wire), then reduce locally.  A jnp.sum over the sharded
                # dim would all-reduce in int32: 4x the bytes.
                q = _replicate(q)
                acc = jnp.sum(q.astype(jnp.int32), axis=0)  # local now
                summed = acc.astype(jnp.float32) * scale / p
                spec_hat = q.astype(jnp.float32) * scale
            else:  # truncate
                wire = _replicate(spec.astype(c.wire_dtype))
                acc = jnp.sum(wire.astype(jnp.float32), axis=0)
                summed = acc / p
                spec_hat = wire.astype(jnp.float32)
            mean = _dct.inverse_dct(summed, c.n).reshape(-1)[
                : g[0].size
            ].reshape(g.shape[1:])
            new_r = None
            if r is not None:
                dec = _dct.inverse_dct(spec_hat, c.n).reshape(p, -1)[
                    :, : g[0].size
                ].reshape(g.shape)
                new_r = (c.ef_decay * (gf - dec)).astype(r.dtype)
            return mean.astype(g.dtype), new_r

        if residual is None:
            out = jax.tree_util.tree_map(lambda g: one(g, None)[0], grads)
            return out, None
        pairs = jax.tree_util.tree_map(one, grads, residual)
        out = jax.tree_util.tree_map(
            lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_res = jax.tree_util.tree_map(
            lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )
        return out, new_res

    # -- wire accounting for the roofline -------------------------------
    def wire_bytes(self, num_elems: int) -> int:
        """Bytes this mode moves over the pod axis for one leaf.

        ``none`` and ``replicated_f32`` are both uncompressed f32 wires
        (the GSPMD and replicated-DP baselines) — true f32 bytes, not a
        KeyError.  Unknown modes raise, matching the collective paths.
        """
        c = self.config
        if c.mode in ("none", "replicated_f32"):
            return num_elems * 4
        w = -(-num_elems // c.n)
        if c.mode == "truncate":
            per = jnp.dtype(c.wire_dtype).itemsize
        elif c.mode == "truncate_int8":
            per = 1
        else:
            raise ValueError(f"unknown compression mode {c.mode!r}")
        return w * c.e * per
