"""Elastic scaling + straggler mitigation utilities.

Elasticity contract: checkpoints are *sharding-agnostic*
(host numpy trees), so a job restarted with a different device count simply
rebuilds the mesh from the surviving hosts and re-device_puts — provided the
new axis sizes still divide the dims they shard (power-of-two meshes keep
this true in practice).  ``remesh`` performs that re-placement and
``validate_mesh_for`` pre-checks divisibility so a bad mesh fails fast
instead of mid-restore.

Straggler mitigation: the data pipeline is index-addressed (host h of H draws
strips h::H), so a replacement host resumes the dead host's stream with no
coordination; step barriers are the collectives themselves.  A lightweight
``StepTimer`` keeps an EWMA of step latency and flags outliers — on a real
cluster this feeds the controller's preemption/respawn decision.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.distributed.sharding import ShardingPolicy, resolve_param_specs
from repro.models.common import ParamSpec

PyTree = Any

__all__ = ["remesh", "validate_mesh_for", "StepTimer"]


def validate_mesh_for(policy: ShardingPolicy, specs: PyTree) -> List[str]:
    """Return a list of human-readable problems (empty == mesh is valid).

    A dim that *loses* sharding under the new mesh is allowed (replication is
    always legal); what we check is that every sharded dim divides evenly —
    NamedSharding would fail later and less legibly.
    """
    problems: List[str] = []

    def check(path, s: ParamSpec):
        spec = policy.spec_for(s.names, s.shape)
        for dim, entry in zip(s.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= policy.axis_sizes[a]
            if dim % total:
                problems.append(
                    f"{jax.tree_util.keystr(path)}: dim {dim} not divisible "
                    f"by mesh axes {axes} (={total})"
                )

    leaves, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    for path, leaf in leaves:
        check(path, leaf)
    return problems


def remesh(host_tree: PyTree, specs: PyTree, new_policy: ShardingPolicy
           ) -> PyTree:
    """Place a host (numpy) tree onto a new mesh per the policy's shardings.

    This is the elastic-restart path: restore_latest() -> remesh() -> resume.
    """
    problems = validate_mesh_for(new_policy, specs)
    if problems:
        raise ValueError(
            "mesh incompatible with parameter shapes:\n  " + "\n  ".join(problems)
        )
    shardings = resolve_param_specs(new_policy, specs)
    return jax.tree_util.tree_map(
        lambda arr, sh: jax.device_put(np.asarray(arr), sh),
        host_tree, shardings,
    )


@dataclasses.dataclass
class StepTimer:
    """EWMA step-latency tracker; flags straggling steps."""

    alpha: float = 0.1
    threshold: float = 2.0  # x EWMA => straggler
    ewma: Optional[float] = None
    _t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> Tuple[float, bool]:
        dt = time.monotonic() - self._t0
        straggler = self.ewma is not None and dt > self.threshold * self.ewma
        self.ewma = dt if self.ewma is None else (
            self.alpha * dt + (1 - self.alpha) * self.ewma
        )
        return dt, straggler
