"""AdamW in pure JAX with configurable accumulator dtype + LR schedule.

At 671B scale the fp32 m/v accumulators alone are 5.4 TB; the largest
configs therefore run bf16 accumulators (a deliberate storage/precision
trade).  Updates are always computed in fp32 regardless of storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamW", "OptState", "cosine_schedule"]

PyTree = Any


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    ))
    return jnp.where(step < warmup, warm, cos)


class OptState(NamedTuple):
    step: jnp.ndarray  # int32[]
    m: PyTree
    v: PyTree
    residual: Optional[PyTree] = None  # error-feedback (grad compression)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    base_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    acc_dtype: Any = jnp.float32  # bf16 for the largest configs


@dataclasses.dataclass(frozen=True)
class AdamW:
    config: AdamWConfig = AdamWConfig()

    def init(self, params: PyTree, with_residual: bool = False,
             replicas: int = 1) -> OptState:
        """``replicas > 1``: error-feedback residuals are per pod replica
        (leading [P, ...] dim, pod-sharded) — the vmap'd compressed-DP path."""
        zeros = lambda p: jnp.zeros(p.shape, self.config.acc_dtype)
        res = (
            jax.tree_util.tree_map(
                lambda p: jnp.zeros((replicas,) + p.shape, jnp.bfloat16),
                params,
            )
            if with_residual
            else None
        )
        return OptState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
            residual=res,
        )

    def state_specs(self, param_specs: PyTree, with_residual: bool = False,
                    replicas: int = 1):
        """ParamSpec tree for the optimizer state (drives dry-run shardings)."""
        from repro.models.common import ParamSpec

        c = self.config

        def acc(s: ParamSpec) -> ParamSpec:
            return ParamSpec(s.shape, s.names, dtype=c.acc_dtype, init="zeros")

        def res(s: ParamSpec) -> ParamSpec:
            return ParamSpec(
                (replicas,) + s.shape, ("replicas",) + s.names,
                dtype=jnp.bfloat16, init="zeros",
            )

        is_spec = lambda x: isinstance(x, ParamSpec)
        return OptState(
            step=ParamSpec((), (), dtype=jnp.int32, init="zeros"),
            m=jax.tree_util.tree_map(acc, param_specs, is_leaf=is_spec),
            v=jax.tree_util.tree_map(acc, param_specs, is_leaf=is_spec),
            residual=(
                jax.tree_util.tree_map(res, param_specs, is_leaf=is_spec)
                if with_residual
                else None
            ),
        )

    def update(self, params: PyTree, state: OptState, grads: PyTree,
               residual: Optional[PyTree] = None):
        c = self.config
        step = state.step + 1
        lr = cosine_schedule(
            step, base_lr=c.base_lr, warmup=c.warmup, total=c.total_steps
        )

        # global-norm clip (fp32)
        sq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)
        )
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, c.clip_norm / (gnorm + 1e-12))

        b1c = 1.0 - c.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - c.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * g
            v32 = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * jnp.square(g)
            mhat = m32 / b1c
            vhat = v32 / b2c
            delta = mhat / (jnp.sqrt(vhat) + c.eps)
            delta = delta + c.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return (
                new_p.astype(p.dtype),
                m32.astype(c.acc_dtype),
                v32.astype(c.acc_dtype),
            )

        out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(
            step=step, m=new_m, v=new_v,
            residual=residual if residual is not None else state.residual,
        ), gnorm
