"""Sharding policy: logical dim names -> mesh axes, with divisibility checks.

Parameters and activations are annotated with *logical* dim names
("hidden", "ffn", "heads", "batch", "seq", ...).  A :class:`ShardingPolicy`
resolves those names against the active mesh:

  * params:     FSDP over the ("pod","data") axes on the first shardable dim
                + tensor parallelism over "model" on ffn/head/expert/vocab dims
  * activations: batch over ("pod","data"), sequence over "model"
                (sequence parallelism for the residual stream), and head/ffn
                dims over "model" inside blocks.

A name only maps to a mesh axis if the dim size is divisible by the axis
size — otherwise the dim is replicated (e.g. qwen's 20 heads on a 16-way
model axis).  This rule-resolution is what lets one model library serve ten
architectures on arbitrary meshes without per-arch sharding tables.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingPolicy",
    "activate",
    "current_policy",
    "constrain",
    "resolve_param_specs",
]

# logical name -> candidate mesh axes, tried in order (first divisible wins)
DEFAULT_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    # activation dims
    "batch": (("pod", "data"), ("data",)),
    "seq": (("model",),),
    # param dims — TP
    "ffn": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "qk_dim": (("model",),),
    # prefer whole-expert sharding over (data, model) — full EP, no weight
    # gathers (§Perf iteration 6); fall back to model-axis EP for small E
    "experts": (("data", "model"), ("model",)),
    "vocab": (("model",),),
    # param dims — FSDP (weight-sharded data parallelism)
    "hidden": (("pod", "data"), ("data",)),
    "embed_fsdp": (("pod", "data"), ("data",)),
    # pod-replica axis (compressed-DP grads / residuals / batches)
    "replicas": (("pod",),),
    # never sharded
    "window": (),
    "state": (),
    "conv": (),
    "layers": (),
    "rank": (),
}


class ShardingPolicy:
    """Resolves logical dim names to mesh axes.

    ``exclude`` removes axes from consideration — used (a) inside a shard_map
    region that is already *manual* over those axes, and (b) for the
    pod-replicated parameter mode (FPTC-compressed pod all-reduce), where
    params must not be sharded over "pod".
    """

    def __init__(self, mesh: Mesh, rules: Optional[Dict] = None,
                 exclude: Tuple[str, ...] = (),
                 allow_shard_map: bool = True):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        self.exclude = frozenset(exclude)
        # False under the vmap'd compressed-DP train step: vmap over an
        # inner shard_map crashes the SPMD partitioner in this XLA version
        # (documented in EXPERIMENTS.md §Perf iteration 7) — MoE falls back
        # to the dense dispatch there.
        self.allow_shard_map = allow_shard_map
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def without(self, *axes: str) -> "ShardingPolicy":
        return ShardingPolicy(
            self.mesh, rules=self.rules,
            exclude=tuple(self.exclude | set(axes)),
            allow_shard_map=self.allow_shard_map,
        )

    @property
    def fsdp_axes(self) -> Tuple[str, ...]:
        return tuple(
            a for a in ("pod", "data")
            if a in self.axis_sizes and a not in self.exclude
        )

    def _axes_size(self, axes: Tuple[str, ...]) -> Optional[int]:
        total = 1
        for a in axes:
            if a not in self.axis_sizes or a in self.exclude:
                return None
            total *= self.axis_sizes[a]
        return total

    def spec_for(self, names: Sequence[Optional[str]],
                 shape: Sequence[int]) -> P:
        """Resolve logical names + concrete shape to a PartitionSpec."""
        used_axes: set = set()
        out = []
        for name, dim in zip(names, shape):
            entry: Any = None
            if name is not None:
                for cand in self.rules.get(name, ()):
                    size = self._axes_size(cand)
                    if size is None or dim % size != 0:
                        continue
                    if any(a in used_axes for a in cand):
                        continue
                    entry = cand if len(cand) > 1 else cand[0]
                    used_axes.update(cand)
                    break
            out.append(entry)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding_for(self, names, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(names, shape))


_state = threading.local()


@contextlib.contextmanager
def activate(policy: Optional[ShardingPolicy]):
    prev = getattr(_state, "policy", None)
    _state.policy = policy
    try:
        yield policy
    finally:
        _state.policy = prev


def current_policy() -> Optional[ShardingPolicy]:
    return getattr(_state, "policy", None)


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Apply a with_sharding_constraint from logical dim names (no-op when no
    policy is active — keeps the model library mesh-agnostic)."""
    policy = current_policy()
    if policy is None:
        return x
    spec = policy.spec_for(names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(policy.mesh, spec)
    )


def resolve_param_specs(policy: ShardingPolicy, specs: Any) -> Any:
    """ParamSpec tree -> NamedSharding tree (for jit in_shardings)."""
    from repro.models.common import ParamSpec

    def one(s: ParamSpec) -> NamedSharding:
        return policy.sharding_for(s.names, s.shape)

    return jax.tree_util.tree_map(
        one, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
