"""Distributed train/serve step factories.

Two distribution modes:

  * ``fsdp_all`` — parameters (and optimizer state) fully sharded over every
    data-parallel axis, including "pod"; gradients reduce via GSPMD-inserted
    collectives.  The memory-optimal baseline.
  * ``pod_compressed`` — parameters replicated over "pod" (FSDP over "data"
    only, TP over "model"); the cross-pod gradient all-reduce is explicit,
    runs through the **FPTC compressor** (windowed-DCT truncation + int8
    wire) with error feedback.  The paper's technique on the slowest links.

Both modes return a jitted step plus the NamedSharding trees needed for init
and for the dry-run's ``.lower().compile()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shlib
from repro.distributed.compression import CompressionConfig, GradCompressor
from repro.distributed.optimizer import AdamW, OptState
from repro.models.api import Model
from repro.models.common import ParamSpec, abstract_params

PyTree = Any

__all__ = ["TrainStep", "make_train_step", "make_serve_fns"]


def _named_tree(policy: shlib.ShardingPolicy, specs: PyTree) -> PyTree:
    return shlib.resolve_param_specs(policy, specs)


def _pod_replicated_tree(mesh: Mesh, tree: PyTree) -> PyTree:
    """PartitionSpec tree for shard_map over pod: everything replicated."""
    return jax.tree_util.tree_map(lambda _: P(), tree)


@dataclasses.dataclass
class TrainStep:
    step_fn: Callable  # (params, opt_state, batch) -> (params, opt, metrics)
    param_shardings: PyTree
    opt_shardings: PyTree
    batch_shardings: PyTree
    policy: shlib.ShardingPolicy
    model: Model
    optimizer: AdamW
    compressor: Optional[GradCompressor]
    replicas: int = 1  # >1: batch carries a leading pod-replica dim

    def batch_specs(self, batch_size: int, seq_len: int):
        """Batch ParamSpec tree; compressed mode adds the replica dim."""
        m = self.model
        if self.replicas > 1:
            per = m.batch_specs(batch_size // self.replicas, seq_len)
            return jax.tree_util.tree_map(
                lambda s: ParamSpec(
                    (self.replicas,) + s.shape, ("replicas",) + s.names,
                    dtype=s.dtype, init=s.init,
                ),
                per, is_leaf=lambda x: isinstance(x, ParamSpec),
            )
        return m.batch_specs(batch_size, seq_len)

    def abstract_inputs(self, batch_size: int, seq_len: int):
        """ShapeDtypeStructs (with shardings) for the dry-run."""
        m = self.model
        pspecs = m.param_specs()
        ospecs = self.optimizer.state_specs(
            pspecs,
            with_residual=self.compressor is not None
            and self.compressor.config.mode != "none",
            replicas=self.replicas,
        )
        bspecs = self.batch_specs(batch_size, seq_len)
        batch_policy = shlib.ShardingPolicy(self.policy.mesh)
        b_sh = jax.tree_util.tree_map(
            lambda s: batch_policy.sharding_for(s.names, s.shape),
            bspecs, is_leaf=lambda x: isinstance(x, ParamSpec),
        )

        def conv(spec_tree, shard_tree):
            return jax.tree_util.tree_map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                spec_tree, shard_tree,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            )

        return (
            conv(pspecs, self.param_shardings),
            conv(ospecs, self.opt_shardings),
            conv(bspecs, b_sh),
        )


def make_train_step(
    model: Model,
    optimizer: AdamW,
    mesh: Mesh,
    *,
    compression: Optional[CompressionConfig] = None,
    donate: bool = True,
) -> TrainStep:
    has_pod = "pod" in mesh.axis_names and dict(
        zip(mesh.axis_names, mesh.devices.shape)
    ).get("pod", 1) > 1
    compressed = (
        compression is not None and compression.mode != "none" and has_pod
    )
    compressor = GradCompressor(compression) if compression else None

    # parameter sharding policy: pod excluded iff pod-replicated mode.
    # Compressed mode also disables inner shard_maps (vmap-of-shard_map
    # crashes this XLA's partitioner — MoE uses the dense dispatch there).
    policy = (
        shlib.ShardingPolicy(mesh, exclude=("pod",), allow_shard_map=False)
        if compressed
        else shlib.ShardingPolicy(mesh)
    )
    # batch stays sharded over pod+data in both modes
    batch_policy = shlib.ShardingPolicy(mesh)

    npods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    replicas = npods if compressed else 1
    pspecs = model.param_specs()
    with_res = compressed
    ospecs = optimizer.state_specs(
        pspecs, with_residual=with_res, replicas=replicas
    )
    param_sh = _named_tree(policy, pspecs)

    # optimizer m/v follow the (possibly pod-excluded) param policy; the
    # residual's leading replica dim needs the full policy to reach "pod"
    def _opt_shard(s: ParamSpec):
        p = batch_policy if "replicas" in s.names else policy
        return p.sharding_for(s.names, s.shape)

    opt_sh = jax.tree_util.tree_map(
        _opt_shard, ospecs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )

    if compressed:
        # Pod-compressed data parallelism, pure GSPMD (no manual region):
        # the loss is vmapped over a leading pod-replica axis of the batch
        # (dim 0 sharded over "pod"), producing per-replica gradients
        # [P, ...]; the compressor truncates/quantizes per replica and the
        # dim-0 sum — which GSPMD lowers to the cross-pod all-reduce — runs
        # on the int8/truncated representation.  Slow inter-pod links carry
        # compressed bytes; error feedback lives in OptState.residual
        # (per-replica, pod-sharded).
        def step_inner(params, opt_state, batch):
            with shlib.activate(policy):
                losses, grads = jax.vmap(
                    lambda b: jax.value_and_grad(model.loss)(params, b)
                )(batch)
                grads = jax.tree_util.tree_map(
                    lambda g: jax.lax.with_sharding_constraint(
                        g, NamedSharding(mesh, P("pod"))
                    ),
                    grads,
                )
                loss = jnp.mean(losses)
                grads, residual = compressor.replica_sum(
                    grads, opt_state.residual
                )
                new_params, new_state, gnorm = optimizer.update(
                    params, opt_state, grads, residual
                )
            return new_params, new_state, {"loss": loss, "grad_norm": gnorm}
    else:

        def step_inner(params, opt_state, batch):
            with shlib.activate(policy):
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
                new_params, new_state, gnorm = optimizer.update(
                    params, opt_state, grads, opt_state.residual
                )
            return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    batch_sh = jax.tree_util.tree_map(
        lambda s: batch_policy.sharding_for(s.names, s.shape),
        model.batch_specs(8, 8),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    # NB: batch shardings are shape-independent (batch dim over (pod, data))
    # — recompute per concrete shape at call sites via .batch_shardings_for.

    jit_kwargs = dict(
        in_shardings=(param_sh, opt_sh, None),
        out_shardings=(param_sh, opt_sh, None),
    )
    if donate:
        jit_kwargs["donate_argnums"] = (0, 1)
    step_fn = jax.jit(step_inner, **jit_kwargs)

    return TrainStep(
        step_fn=step_fn,
        param_shardings=param_sh,
        opt_shardings=opt_sh,
        batch_shardings=batch_sh,
        policy=policy,
        model=model,
        optimizer=optimizer,
        compressor=compressor if compressed else None,
        replicas=replicas,
    )


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def make_serve_fns(model: Model, mesh: Mesh):
    """(prefill_fn, decode_fn) jitted with cache/param shardings.

    decode_fn(params, cache, tokens, pos) is the ``serve_step`` the decode
    dry-run shapes lower.
    """
    policy = shlib.ShardingPolicy(mesh)
    pspecs = model.param_specs()
    param_sh = _named_tree(policy, pspecs)

    def prefill(params, batch, max_len):
        with shlib.activate(policy):
            return model.prefill(params, batch, max_len)

    def decode(params, cache, tokens, pos):
        with shlib.activate(policy):
            return model.decode_step(params, cache, tokens, pos)

    # NB: static max_len must be passed POSITIONALLY — pjit rejects kwargs
    # when in_shardings is specified.
    prefill_fn = jax.jit(
        prefill, static_argnums=(2,), in_shardings=(param_sh, None)
    )
    decode_fn = jax.jit(decode, in_shardings=(param_sh, None, None, None),
                        donate_argnums=(1,))
    return prefill_fn, decode_fn, policy, param_sh
