from repro.distributed.sharding import (
    ShardingPolicy,
    activate,
    constrain,
    current_policy,
    resolve_param_specs,
)

__all__ = [
    "ShardingPolicy",
    "activate",
    "constrain",
    "current_policy",
    "resolve_param_specs",
]
