"""Synthetic signal generators for the paper's four domains (Table 2).

The paper evaluates on ten datasets across biomedical / seismic / power /
meteorological domains.  Those corpora are not redistributable here, so each
dataset is modeled by a generator that reproduces the *statistical structure
the codec exploits*: spectral decay rate, local smoothness, stationarity,
amplitude distribution, and characteristic waveform features (QRS complexes,
seismic wavelets, diurnal cycles, ...).  Generators are deterministic given a
seed, so calibration/eval splits are reproducible.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

__all__ = ["DATASETS", "make_signal"]


def _ecg(rng: np.random.Generator, n: int, fs: float = 360.0) -> np.ndarray:
    """MIT-BIH-like ECG: quasi-periodic PQRST via Gaussian bumps + drift."""
    t = np.arange(n) / fs
    hr = 1.1 + 0.1 * np.sin(2 * np.pi * 0.1 * t)  # beats/sec with HRV
    phase = np.cumsum(hr) / fs
    beat_phase = phase % 1.0
    sig = np.zeros(n)
    # (center, width, amplitude) of P, Q, R, S, T waves in beat-phase units
    for c, w, a in [
        (0.15, 0.025, 0.12),
        (0.235, 0.010, -0.18),
        (0.25, 0.008, 1.20),
        (0.265, 0.010, -0.25),
        (0.45, 0.045, 0.30),
    ]:
        sig += a * np.exp(-0.5 * ((beat_phase - c) / w) ** 2)
    baseline = 0.08 * np.sin(2 * np.pi * 0.25 * t + rng.uniform(0, 6))
    noise = 0.01 * rng.standard_normal(n)
    return (sig + baseline + noise).astype(np.float32)


def _eeg(rng: np.random.Generator, n: int, fs: float = 250.0) -> np.ndarray:
    """EEG-MAT-like: 1/f background + alpha/beta band oscillations."""
    freqs = np.fft.rfftfreq(n, 1 / fs)
    spec = rng.standard_normal(freqs.size) + 1j * rng.standard_normal(freqs.size)
    mag = np.zeros_like(freqs)
    nz = freqs > 0
    mag[nz] = 1.0 / freqs[nz]  # 1/f
    mag += 2.0 * np.exp(-0.5 * ((freqs - 10.0) / 1.5) ** 2)  # alpha
    mag += 0.6 * np.exp(-0.5 * ((freqs - 22.0) / 3.0) ** 2)  # beta
    sig = np.fft.irfft(spec * mag, n)
    sig = sig / (np.std(sig) + 1e-9) * 20.0  # ~20 uV
    return sig.astype(np.float32)


def _seismic(rng: np.random.Generator, n: int, fs: float = 500.0) -> np.ndarray:
    """Seismic reflection trace: sparse reflectivity * Ricker wavelet + AGC-ish
    amplitude decay.  Low smoothness, broadband — the paper's hardest domain."""
    refl = np.zeros(n)
    k = max(n // 200, 4)
    pos = rng.choice(n, size=k, replace=False)
    refl[pos] = rng.laplace(0, 1.0, size=k)
    fm = 30.0  # Ricker dominant frequency
    tw = (np.arange(-127, 128)) / fs
    ricker = (1 - 2 * (np.pi * fm * tw) ** 2) * np.exp(-((np.pi * fm * tw) ** 2))
    sig = np.convolve(refl, ricker, mode="same")
    decay = np.exp(-np.arange(n) / (n * 0.7))
    noise = 0.02 * rng.standard_normal(n)
    return ((sig * decay) + noise).astype(np.float32)


def _power(
    rng: np.random.Generator, n: int, fs: float = 1.0 / 60, kind: str = "load"
) -> np.ndarray:
    """PSML-like power telemetry: smooth diurnal + weekly structure + ramps."""
    t = np.arange(n) * 60.0  # seconds at 1-min sampling
    day = 86400.0
    sig = 50.0 + 12.0 * np.sin(2 * np.pi * t / day - 1.2)
    sig += 4.0 * np.sin(4 * np.pi * t / day + 0.4)
    sig += 2.5 * np.sin(2 * np.pi * t / (7 * day))
    if kind == "solar":
        sig = np.maximum(0.0, 40.0 * np.sin(2 * np.pi * t / day - np.pi / 2))
        cloud = np.convolve(
            rng.standard_normal(n), np.ones(30) / 30, mode="same"
        )
        sig *= np.clip(1.0 - 0.3 * np.abs(cloud), 0.2, 1.0)
    elif kind == "wind":
        w = np.convolve(rng.standard_normal(n), np.ones(120) / 120, mode="same")
        sig = 25.0 + 18.0 * np.tanh(2.0 * w)
    ar = np.zeros(n)
    for i in range(1, n):
        ar[i] = 0.98 * ar[i - 1] + rng.standard_normal() * 0.15
    return (sig + ar).astype(np.float32)


def _meteo(
    rng: np.random.Generator, n: int, fs: float = 1.0 / 60, kind: str = "temp"
) -> np.ndarray:
    """Meteorological: strong diurnal/seasonal cycles, very smooth."""
    t = np.arange(n) * 60.0
    day = 86400.0
    if kind == "temp":
        sig = 15.0 + 8.0 * np.sin(2 * np.pi * t / day - 2.0)
        sig += 10.0 * np.sin(2 * np.pi * t / (365 * day))
        rough = 0.05
    elif kind == "irradiance":
        sig = np.maximum(0.0, 800.0 * np.sin(2 * np.pi * t / day - np.pi / 2))
        rough = 5.0
    else:  # wind speed
        w = np.convolve(rng.standard_normal(n), np.ones(60) / 60, mode="same")
        sig = 6.0 + 4.0 * np.abs(w)
        rough = 0.1
    ar = np.zeros(n)
    for i in range(1, n):
        ar[i] = 0.995 * ar[i - 1] + rng.standard_normal() * rough * 0.1
    return (sig + ar).astype(np.float32)


# name -> (domain, generator)
DATASETS: Dict[str, tuple] = {
    "mitbih": ("biomedical", _ecg),
    "ecg_arth": ("biomedical", lambda r, n: _ecg(r, n, fs=500.0)),
    "eeg_mat": ("biomedical", _eeg),
    "seismic": ("seismic", _seismic),
    "wind_power": ("power", lambda r, n: _power(r, n, kind="wind")),
    "solar_power": ("power", lambda r, n: _power(r, n, kind="solar")),
    "load_power": ("power", lambda r, n: _power(r, n, kind="load")),
    "temperature": ("meteorological", lambda r, n: _meteo(r, n, kind="temp")),
    "irradiance": (
        "meteorological",
        lambda r, n: _meteo(r, n, kind="irradiance"),
    ),
    "wind_speed": ("meteorological", lambda r, n: _meteo(r, n, kind="wind")),
}


def make_signal(name: str, num_samples: int, seed: int = 0) -> np.ndarray:
    """Generate `num_samples` of the named dataset's synthetic analog."""
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    _, gen = DATASETS[name]
    rng = np.random.default_rng(seed)
    return gen(rng, num_samples)


def domain_of(name: str) -> str:
    return DATASETS[name][0]
