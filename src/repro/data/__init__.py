from repro.data.signals import DATASETS, make_signal
from repro.data.pipeline import SignalPipeline, TokenPipeline

__all__ = ["DATASETS", "make_signal", "SignalPipeline", "TokenPipeline"]
