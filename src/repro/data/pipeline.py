"""Data pipelines: signal strips for the codec, token batches for LM training.

Both pipelines are deterministic, shardable by (host_id, num_hosts) for
multi-host data parallelism, and restartable from a step index (fault
tolerance: a restore at step k re-produces batch k exactly).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.data import signals

__all__ = ["SignalPipeline", "TokenPipeline"]


@dataclasses.dataclass
class SignalPipeline:
    """Streams fixed-length signal strips from a (synthetic) dataset.

    Mirrors the paper's acquisition model: each strip is one encoder unit of
    work.  Sharding: host h of H draws strips h, h+H, h+2H, ...
    """

    dataset: str
    strip_length: int = 65536
    host_id: int = 0
    num_hosts: int = 1
    seed: int = 0

    def strip(self, index: int) -> np.ndarray:
        global_index = index * self.num_hosts + self.host_id
        return signals.make_signal(
            self.dataset, self.strip_length, seed=self.seed + global_index
        )

    def __iter__(self) -> Iterator[np.ndarray]:
        i = 0
        while True:
            yield self.strip(i)
            i += 1

    def calibration_strip(self, length: Optional[int] = None) -> np.ndarray:
        """A held-out strip (negative seed space) for table calibration."""
        return signals.make_signal(
            self.dataset, length or self.strip_length, seed=self.seed - 1_000_003
        )


@dataclasses.dataclass
class TokenPipeline:
    """Deterministic synthetic token batches for LM train/dry-run drivers.

    Batch b is a pure function of (seed, step, host shard) — restartable and
    shardable without coordination.  Tokens follow a Zipfian marginal so the
    loss curves are non-degenerate.
    """

    vocab_size: int
    batch_size: int  # per-host batch
    seq_len: int
    host_id: int = 0
    num_hosts: int = 1
    seed: int = 0

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65537 + self.host_id
        )
        # Zipf-ish marginal via exponential of uniform
        u = rng.random((self.batch_size, self.seq_len + 1))
        ranks = np.floor(
            np.exp(u * np.log(self.vocab_size)) - 1.0
        ).astype(np.int32)
        tokens = np.clip(ranks, 0, self.vocab_size - 1)
        return tokens[:, :-1], tokens[:, 1:]

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
