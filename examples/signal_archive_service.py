"""The paper's deployment scenario end to end: a fleet of embedded sensors
compresses signal strips; a central server batch-decompresses them.

Simulates E encoders (sequential, table-driven — paper Fig. 5) streaming
containers into an archive, then drains the whole archive through the
batched bucketed decode engine (``repro.serving.BatchDecoder``): the fleet's
containers ride ONE fused device dispatch per (domain, config) group, with
tables and iDCT bases resident in the decoder's plan cache and outputs
staying on device until the final ``to_host()`` drain.

  PYTHONPATH=src python examples/signal_archive_service.py [--fleet 8]
"""
import argparse
import time

import numpy as np

from repro.core import DOMAIN_DEFAULTS, calibrate, encode
from repro.core.metrics import prd
from repro.data import SignalPipeline, make_signal
from repro.data.signals import domain_of
from repro.serving import BatchDecoder


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", type=int, default=8)
    ap.add_argument("--dataset", default="temperature")
    ap.add_argument("--strip", type=int, default=65536)
    args = ap.parse_args()

    dom = domain_of(args.dataset)
    tables = calibrate(
        np.concatenate(
            [make_signal(args.dataset, 65536, seed=90 + i) for i in range(4)]
        ),
        DOMAIN_DEFAULTS[dom],
    )

    # --- acquisition fleet: one pipeline per device, sharded streams ------
    archive = []
    originals = []
    t0 = time.time()
    for dev_id in range(args.fleet):
        pipe = SignalPipeline(
            args.dataset, strip_length=args.strip,
            host_id=dev_id, num_hosts=args.fleet,
        )
        strip = pipe.strip(0)
        originals.append(strip)
        archive.append(encode(strip, tables).to_bytes())
    enc_s = time.time() - t0
    raw_mb = args.fleet * args.strip * 4 / 1e6
    comp_mb = sum(len(b) for b in archive) / 1e6
    print(f"fleet of {args.fleet} encoders: {raw_mb:.1f} MB raw -> "
          f"{comp_mb:.2f} MB archived (CR {raw_mb/comp_mb:.1f}x) "
          f"in {enc_s:.2f}s")

    # --- server-side batch decompression ----------------------------------
    from repro.core.container import Container

    decoder = BatchDecoder()
    t0 = time.time()
    containers = [Container.from_bytes(blob) for blob in archive]
    batch = decoder.decode(containers, tables)  # fused dispatch(es), on device
    recs = batch.to_host()  # single drain
    dec_s = time.time() - t0
    out_mb = sum(r.nbytes for r in recs) / 1e6
    print(f"server decode: {out_mb:.1f} MB reconstructed in {dec_s:.2f}s "
          f"({out_mb/dec_s/1e3:.3f} GB/s on this host; "
          f"{decoder.stats.dispatches} fused dispatch(es) for "
          f"{len(containers)} containers)")

    worst = max(prd(o, r) for o, r in zip(originals, recs))
    print(f"worst-strip PRD: {worst:.3f}% "
          f"(domain threshold: {'2%' if dom == 'seismic' else '5%'})")


if __name__ == "__main__":
    main()
