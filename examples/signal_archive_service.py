"""The paper's deployment scenario end to end: a fleet of sensors streams
signal strips to a central server, which batch-compresses them into an
archive, later batch-decompresses it, and eventually MIGRATES it to a new
codec config — all through the batched serving engines.

Server-side ingest arrives through the always-on serving front-end
(``repro.serving.ServingFrontend``): each sensor submits its strip from
its own thread (admission is thread-safe and bounded — a flooded queue
sheds with a typed error instead of silently dropping), and the
front-end's deadline micro-batcher forms the buckets that ride the
batched bucketed *encode* engine (``repro.serving.BatchEncoder``): each
bucket is ONE fused DCT+quant+pack dispatch, with chunk-parallel SymLen
packing (decoder-compatible by construction — see
core.symlen.pack_symlen_chunked) and encode tables resident in the plan
cache.  Micro-batching changes only when buckets run: the archived
containers are byte-identical to an offline ``BatchEncoder.encode`` of
the same strips (asserted below).  The archive drain mirrors it through
the batched decode engine (``repro.serving.BatchDecoder``): one fused
dispatch per (domain, config) group, outputs staying on device until the
final ``to_host()`` drain.

The migration stage is the transcode pipeline
(``repro.serving.Transcoder``): the archive is re-encoded under a coarser
cold-storage config (half the retained coefficients) with decode and
re-encode composed ON DEVICE — no decoded-signal drain, no host re-stage,
byte-identical to the decode-to-host-then-re-encode round trip, one drain
at the end.

All three stages ride the shared serving-engine layer
(``repro.serving.engine``): bucket staging/upload double-buffers against
device compute (``--no-pipeline`` to compare against the strict serial
loop), and with more than one visible device each bucket's batch axis
shards across them (try ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
to fake a 4-device host on CPU) — neither changes a single output byte.

  PYTHONPATH=src python examples/signal_archive_service.py [--fleet 8]
"""
import argparse
import threading
import time

import numpy as np

from repro.core import DOMAIN_DEFAULTS, calibrate
from repro.core.metrics import prd
from repro.data import SignalPipeline, make_signal
from repro.data.signals import domain_of
from repro.serving import (
    BatchDecoder,
    BatchEncoder,
    FrontendConfig,
    ServingFrontend,
    Transcoder,
    serving_devices,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", type=int, default=8)
    ap.add_argument("--dataset", default="temperature")
    ap.add_argument("--strip", type=int, default=65536)
    ap.add_argument(
        "--no-pipeline", action="store_true",
        help="disable the double-buffered bucket staging (serial loop)",
    )
    args = ap.parse_args()
    pipeline = not args.no_pipeline

    shards = serving_devices("auto")
    print(f"serving engines: pipeline={'on' if pipeline else 'off'}, "
          f"{len(shards)} shard(s)"
          + ("" if shards == (None,) else f" over {list(shards)}"))

    dom = domain_of(args.dataset)
    tables = calibrate(
        np.concatenate(
            [make_signal(args.dataset, 65536, seed=90 + i) for i in range(4)]
        ),
        DOMAIN_DEFAULTS[dom],
    )

    # --- acquisition fleet: one pipeline per device, sharded streams ------
    originals = []
    for dev_id in range(args.fleet):
        pipe = SignalPipeline(
            args.dataset, strip_length=args.strip,
            host_id=dev_id, num_hosts=args.fleet,
        )
        originals.append(pipe.strip(0))

    # --- server-side ingest through the serving front-end ------------------
    # every sensor submits from its own thread; the deadline micro-batcher
    # forms the encode buckets (fill at the policy edge, or the oldest
    # deadline's slack — whichever first)
    encoder = BatchEncoder(pipeline=pipeline)
    frontend = ServingFrontend(
        tables, encoder=encoder, pipeline=pipeline,
        config=FrontendConfig(
            max_batch=max(args.fleet, 1), default_slo_ms=60_000.0,
        ),
    )
    t0 = time.time()
    futures = [None] * args.fleet
    threads = [
        threading.Thread(
            target=lambda i=i: futures.__setitem__(
                i, frontend.submit_encode(originals[i], tables.domain_id)
            )
        )
        for i in range(args.fleet)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    frontend.flush()
    containers = [f.result() for f in futures]
    archive = [c.to_bytes() for c in containers]
    enc_s = time.time() - t0
    fstats = frontend.stats_snapshot()
    frontend.close()
    raw_mb = args.fleet * args.strip * 4 / 1e6
    comp_mb = sum(len(b) for b in archive) / 1e6
    print(f"front-end ingest of {args.fleet} strips: {raw_mb:.1f} MB raw -> "
          f"{comp_mb:.2f} MB archived (CR {raw_mb/comp_mb:.1f}x) "
          f"in {enc_s:.2f}s ({fstats.batches} micro-batch(es), "
          f"{encoder.stats.dispatches} fused dispatch(es))")

    # micro-batching changes scheduling, never bytes: the served archive
    # matches an offline batch encode of the same strips
    offline = BatchEncoder(pipeline=pipeline).encode(
        originals, tables
    ).to_host()
    assert [c.to_bytes() for c in offline] == archive, (
        "front-end ingest must be byte-identical to offline batch encode"
    )

    # --- server-side batch decompression ----------------------------------
    from repro.core.container import Container

    decoder = BatchDecoder(pipeline=pipeline)
    t0 = time.time()
    containers = [Container.from_bytes(blob) for blob in archive]
    batch = decoder.decode(containers, tables)  # fused dispatch(es), on device
    recs = batch.to_host()  # single drain
    dec_s = time.time() - t0
    out_mb = sum(r.nbytes for r in recs) / 1e6
    print(f"server decode: {out_mb:.1f} MB reconstructed in {dec_s:.2f}s "
          f"({out_mb/dec_s/1e3:.3f} GB/s on this host; "
          f"{decoder.stats.dispatches} fused dispatch(es) for "
          f"{len(containers)} containers)")

    worst = max(prd(o, r) for o, r in zip(originals, recs))
    print(f"worst-strip PRD: {worst:.3f}% "
          f"(domain threshold: {'2%' if dom == 'seismic' else '5%'})")

    # --- archive migration: coarser config for cold storage ---------------
    # e.g. a biomedical-grade config migrating to power-grid-style coarse
    # quantization: half the retained coefficients, fresh domain id
    cold_cfg = tables.config.replace(
        e=max(tables.config.e // 2, 1),
        b1=min(tables.config.b1, max(tables.config.e // 2, 1)),
        b2=max(tables.config.e // 2, 1),
    )
    cold_tables = calibrate(
        np.concatenate(
            [make_signal(args.dataset, 65536, seed=90 + i) for i in range(4)]
        ),
        cold_cfg,
        domain_id=tables.domain_id + 1,
    )

    transcoder = Transcoder(pipeline=pipeline)
    t0 = time.time()
    migrated = transcoder.transcode(containers, tables, cold_tables)
    cold_archive = [c.to_bytes() for c in migrated.to_host()]  # one drain
    mig_s = time.time() - t0

    # the round trip it replaces must produce byte-identical containers
    sigs = BatchDecoder().decode(containers, tables).to_host()
    rt = BatchEncoder().encode(sigs, cold_tables).to_host()
    assert all(
        blob == c.to_bytes() for blob, c in zip(cold_archive, rt)
    ), "device-resident migration must match the host round trip"

    cold_mb = sum(len(b) for b in cold_archive) / 1e6
    print(f"archive migration e={tables.config.e}->{cold_cfg.e}: "
          f"{comp_mb:.2f} MB -> {cold_mb:.2f} MB "
          f"(CR {raw_mb/cold_mb:.1f}x) in {mig_s:.2f}s, decode and "
          "re-encode composed on device — byte-identical to the host "
          "round trip, 0 host syncs between decode and re-encode "
          "(see bench_throughput --mode transcode for the pipeline "
          "comparison)")


if __name__ == "__main__":
    main()
