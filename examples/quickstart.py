"""FPTC quickstart: calibrate -> encode -> decode -> metrics.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    DOMAIN_DEFAULTS,
    calibrate,
    decode,
    decode_device,
    encode,
)
from repro.core.metrics import prd
from repro.data import make_signal

# 1. calibrate once per signal domain on representative data (paper §3.4)
calib_signal = np.concatenate(
    [make_signal("load_power", 65536, seed=90 + i) for i in range(4)]
)
tables = calibrate(calib_signal, DOMAIN_DEFAULTS["power"])
print(f"codebook: {tables.book.num_active} symbols, "
      f"L_max={tables.book.l_max}, "
      f"avg codeword {tables.book.expected_bits(tables.hist):.2f} bits")

# 2. encode on the (simulated) embedded device — single pass, table-driven
signal = make_signal("load_power", 1 << 18, seed=7)
container = encode(signal, tables)
print(f"compressed {container.original_bytes/1e6:.2f} MB -> "
      f"{container.compressed_bytes/1e6:.3f} MB "
      f"(CR {container.compression_ratio:.1f}x, "
      f"{container.num_words} SymLen words)")

# 3. container bytes travel to the server...
blob = container.to_bytes()

# 4. ...which decodes at scale with the word-parallel pipeline
from repro.core.container import Container

received = Container.from_bytes(blob)
rec_ref = decode(received, tables)  # host reference decoder
rec_par = decode_device(received, tables)  # word-parallel XLA decoder
print(f"PRD {prd(signal, rec_par):.3f}%  "
      f"(ref vs parallel max diff "
      f"{np.abs(rec_ref - rec_par).max():.2e})")
