"""FPTC-compressed sharded checkpoints — the training-state workload path.

Trains a real ``configs/`` smoke model for a few steps so the optimizer
state has realistic (smooth-accumulator) statistics, then round-trips the
full train state through :func:`repro.distributed.checkpoint.save_checkpoint`
with ``compress=True``: tables are calibrated ONCE per checkpoint over the
whole tree (``train_state`` domain), every large float leaf shards into
fixed-length strips, and all shards ride one batched engine encode into a
single ``state.fptc`` blob (manifest v2).

Reports bytes saved vs the raw checkpoint, restore reconstruction error,
and the save-overhead-per-step into ``BENCH_workloads.json``.

  PYTHONPATH=src python examples/checkpoint_compression.py [--smoke]
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.distributed import checkpoint as ckpt
from repro.distributed.optimizer import AdamW, AdamWConfig
from repro.models import build_model
from repro.models.common import init_params
from repro.serving.workloads import write_workloads_report

parser = argparse.ArgumentParser()
parser.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer train steps / timing repeats")
parser.add_argument("--model", default="qwen15_4b")
parser.add_argument("--dir", default="/tmp/fptc_ckpt_example")
args = parser.parse_args()

cfg = get_smoke(args.model)
model = build_model(cfg)
opt = AdamW(AdamWConfig(base_lr=1e-3, warmup=1, total_steps=20))

params = init_params(model.param_specs(), jax.random.PRNGKey(0))
state = opt.init(params)


@jax.jit
def step_fn(params, state, batch):
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    p2, s2, _ = opt.update(params, state, grads)
    return p2, s2


steps = 2 if args.smoke else 6
for s in range(steps):
    rng = np.random.default_rng(s)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    params, state = step_fn(params, state, {"tokens": toks, "labels": toks})

host = jax.tree_util.tree_map(
    np.asarray, {"p": params, "m": state.m, "v": state.v}
)
raw_bytes = sum(a.nbytes for a in jax.tree_util.tree_leaves(host))


def _dir_bytes(path):
    return sum(
        os.path.getsize(os.path.join(path, f)) for f in os.listdir(path)
    )


# -- raw vs compressed checkpoint ------------------------------------------
base = ckpt.save_checkpoint(os.path.join(args.dir, "raw"), steps, host)
raw_disk = _dir_bytes(base)

repeats = 1 if args.smoke else 3
t0 = time.perf_counter()
for _ in range(repeats):
    comp = ckpt.save_checkpoint(
        os.path.join(args.dir, "comp"), steps, host, compress=True
    )
save_ms = (time.perf_counter() - t0) / repeats * 1e3
comp_disk = _dir_bytes(comp)
state_blob = os.path.getsize(os.path.join(comp, "state.fptc"))

# -- restore + reconstruction error ----------------------------------------
t0 = time.perf_counter()
step, restored = ckpt.restore_latest(os.path.join(args.dir, "comp"), host)
restore_ms = (time.perf_counter() - t0) * 1e3
assert step == steps

num = den = 0.0
for a, b in zip(jax.tree_util.tree_leaves(host),
                jax.tree_util.tree_leaves(restored)):
    num += float(np.sum((a.astype(np.float32) - b.astype(np.float32)) ** 2))
    den += float(np.sum(a.astype(np.float32) ** 2))
rel = (num / max(den, 1e-30)) ** 0.5

print(f"train state: {raw_bytes/1e6:.2f} MB raw "
      f"({raw_disk/1e6:.2f} MB on disk)")
print(f"compressed checkpoint: {comp_disk/1e6:.2f} MB "
      f"(state.fptc {state_blob/1e6:.2f} MB, CR {raw_disk/comp_disk:.2f}x), "
      f"restore rel err {rel:.5f}")
print(f"save {save_ms:.1f} ms / restore {restore_ms:.1f} ms "
      f"(per checkpoint step)")

path = write_workloads_report("checkpoint", {
    "model": args.model,
    "train_steps": steps,
    "raw_bytes": int(raw_bytes),
    "raw_disk_bytes": int(raw_disk),
    "compressed_disk_bytes": int(comp_disk),
    "state_blob_bytes": int(state_blob),
    "bytes_saved": int(raw_disk - comp_disk),
    "ratio": comp_disk / raw_disk,
    "restore_rel_error": rel,
    "save_ms": save_ms,
    "restore_ms": restore_ms,
})
print(f"report -> {path}")
