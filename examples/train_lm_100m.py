"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full distributed stack — FSDP sharding rules, AdamW, deterministic data
pipeline, fault-tolerant FPTC-compressed checkpoints, straggler timing.

  PYTHONPATH=src python examples/train_lm_100m.py --steps 300
  (kill it mid-run and relaunch: it resumes from the last checkpoint)
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import TokenPipeline
from repro.distributed import checkpoint as ckpt
from repro.distributed.elastic import StepTimer
from repro.distributed.optimizer import AdamW, AdamWConfig
from repro.distributed.train import make_train_step
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models.common import init_params
from repro.models.config import ArchConfig

CKPT_DIR = os.environ.get("CKPT_DIR", "/tmp/fptc_lm_100m")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    # ~100M params: 12L x d768 x ff3072, 32k vocab (GPT-2-small class)
    cfg = ArchConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32768,
        head_dim=64,
    )
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params")

    mesh = make_local_mesh(1, 1)
    opt = AdamW(AdamWConfig(base_lr=6e-4, warmup=20, total_steps=args.steps))
    ts = make_train_step(model, opt, mesh)
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq)

    with mesh:
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        start = 0
        restored = ckpt.restore_latest(
            CKPT_DIR, {"p": params, "m": opt_state.m, "v": opt_state.v}
        )
        if restored:
            start, tree = restored
            params = jax.tree_util.tree_map(jnp.asarray, tree["p"])
            opt_state = opt_state._replace(
                m=jax.tree_util.tree_map(jnp.asarray, tree["m"]),
                v=jax.tree_util.tree_map(jnp.asarray, tree["v"]),
                step=jnp.asarray(start, jnp.int32),
            )
            print(f"resumed from step {start}")

        timer = StepTimer()
        for step in range(start, args.steps):
            tokens, labels = pipe.batch(step)
            batch = {"tokens": jnp.asarray(tokens),
                     "labels": jnp.asarray(labels)}
            timer.start()
            params, opt_state, metrics = ts.step_fn(params, opt_state, batch)
            dt, straggler = timer.stop()
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(metrics['loss']):7.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"{dt:6.2f}s" + ("  [straggler]" if straggler else ""),
                      flush=True)
            if (step + 1) % args.ckpt_every == 0:
                host = jax.tree_util.tree_map(
                    np.asarray,
                    {"p": params, "m": opt_state.m, "v": opt_state.v},
                )
                t0 = time.time()
                path = ckpt.save_checkpoint(CKPT_DIR, step + 1, host,
                                            compress=True)
                raw = sum(x.nbytes for x in jax.tree_util.tree_leaves(host))
                disk = sum(
                    os.path.getsize(os.path.join(path, f))
                    for f in os.listdir(path)
                )
                print(f"  ckpt@{step+1}: {raw/1e6:.0f} MB state -> "
                      f"{disk/1e6:.0f} MB on disk "
                      f"(FPTC CR {raw/disk:.2f}x, {time.time()-t0:.1f}s)",
                      flush=True)
    print("done.")


if __name__ == "__main__":
    main()
