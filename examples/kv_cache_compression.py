"""FPTC KV-cache compression for long-context serving — the workload path.

Prefills a real ``configs/`` model, calibrates the ``kv`` domain on its
cache, then compresses every cold KV block through the batched engines'
fixed-rate mode (:class:`repro.serving.workloads.KVCacheCodec`: windowed
token-axis DCT + calibrated 3-zone table quantization to uint8, entropy
coding OFF so blocks stay fixed-size for O(1) random access).  The whole
compress/decompress sweep runs with the JAX transfer guard pinned to
``disallow`` — zero device->host bounces mid-pipeline.

Reports bytes saved, reconstruction error, decode-logit drift, and the
per-step compress/decompress overhead into ``BENCH_workloads.json``.

  PYTHONPATH=src python examples/kv_cache_compression.py [--smoke]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import build_model
from repro.models.common import init_params
from repro.serving.workloads import KVCacheCodec, write_workloads_report

parser = argparse.ArgumentParser()
parser.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer timing repeats")
parser.add_argument("--model", default="granite_8b")
parser.add_argument("--tokens", type=int, default=64)
args = parser.parse_args()

cfg = get_smoke(args.model)
model = build_model(cfg)
params = init_params(model.param_specs(), jax.random.PRNGKey(0))

B, S = 2, args.tokens
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
logits, cache = model.prefill(params, batch, max_len=S + 8)

# Quantization-only operating point (the "kv" domain default has n == e):
# a random-init smoke model has a rough KV timeline, so spectral truncation
# (e < n) is reserved for TRAINED models whose adjacent-token keys/values
# are smooth — the paper's premise applied to caches.  uint8 levels alone
# halve a bf16 cache, with no per-block sidecar (scales live in the tables).
codec = KVCacheCodec()

# calibrate once per (cache group, k/v) table group — keys and values have
# different distributions, layers within a group share tables
for gname, group in cache.items():
    for key in ("k", "v"):
        codec.calibrate(group[key][0][:, :S], layer=(gname, key))

# -- compress + decompress every layer's cold block, device-resident -------
# transfer guard pinned: any host bounce mid-pipeline fails loudly
compressed = {}
jax.config.update("jax_transfer_guard_device_to_host", "disallow")
try:
    for gname, group in cache.items():
        for key in ("k", "v"):
            kv = group[key]  # [L, B, T, H, D]
            compressed[(gname, key)] = [
                codec.compress(kv[l][:, :S], layer=(gname, key))
                for l in range(kv.shape[0])
            ]
    restored = {
        lk: [codec.decompress(ckv, layer=lk) for ckv in blocks]
        for lk, blocks in compressed.items()
    }
    for blocks in restored.values():
        for b in blocks:
            b.block_until_ready()  # device sync, not a transfer
finally:
    jax.config.update("jax_transfer_guard_device_to_host", None)

# -- accounting + reconstruction error (host fetches allowed now) ----------
raw_bytes = comp_bytes = 0
max_rel = 0.0
new_cache = {}
for gname, group in cache.items():
    new_group = dict(group)
    for key in ("k", "v"):
        kv = group[key]
        outs = []
        for l in range(kv.shape[0]):
            block = kv[l][:, :S]
            ckv = compressed[(gname, key)][l]
            rec = restored[(gname, key)][l]
            rel = float(
                jnp.linalg.norm((rec - block).astype(jnp.float32))
                / (jnp.linalg.norm(block.astype(jnp.float32)) + 1e-9)
            )
            max_rel = max(max_rel, rel)
            raw_bytes += ckv.raw_nbytes()
            comp_bytes += ckv.nbytes
            outs.append(jnp.zeros_like(kv[l]).at[:, :S].set(rec))
        new_group[key] = jnp.stack(outs)
    new_cache[gname] = new_group

print(f"KV cache: {raw_bytes/1e6:.2f} MB -> {comp_bytes/1e6:.2f} MB "
      f"(CR {raw_bytes/comp_bytes:.2f}x), worst block rel err {max_rel:.4f}")

# -- effect on decode logits ------------------------------------------------
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
lg_ref, _ = model.decode_step(params, cache, tok, jnp.int32(S))
lg_cmp, _ = model.decode_step(params, new_cache, tok, jnp.int32(S))
agree = float(jnp.mean(
    (jnp.argmax(lg_ref, -1) == jnp.argmax(lg_cmp, -1)).astype(jnp.float32)
))
drift = float(jnp.max(jnp.abs(
    jax.nn.log_softmax(lg_ref.astype(jnp.float32))
    - jax.nn.log_softmax(lg_cmp.astype(jnp.float32))
)))
print(f"decode with compressed cache: top-1 agreement {agree*100:.0f}%, "
      f"max log-prob drift {drift:.3f}")

# -- per-step overhead: compress+decompress one block, steady state --------
lk = next(iter(compressed))
one = cache[lk[0]][lk[1]][0][:, :S]
repeats = 3 if args.smoke else 20
codec.decompress(codec.compress(one, layer=lk), layer=lk).block_until_ready()
t0 = time.perf_counter()
for _ in range(repeats):
    codec.decompress(codec.compress(one, layer=lk), layer=lk
                     ).block_until_ready()
per_block_ms = (time.perf_counter() - t0) / repeats * 1e3
print(f"compress+decompress one block: {per_block_ms:.3f} ms")

path = write_workloads_report("kv_cache", {
    "model": args.model,
    "tokens": S,
    "raw_bytes": int(raw_bytes),
    "compressed_bytes": int(comp_bytes),
    "bytes_saved": int(raw_bytes - comp_bytes),
    "ratio": comp_bytes / raw_bytes,
    "max_rel_error": max_rel,
    "top1_agreement": agree,
    "max_logprob_drift": drift,
    "per_block_roundtrip_ms": per_block_ms,
    "encode_dispatches": codec.encoder.stats.dispatches,
})
print(f"report -> {path}")
