"""FPTC KV-cache compression for long-context serving.

Prefills a smoke model, compresses the KV cache blocks with the windowed-DCT
quantizer, decompresses, and measures (a) cache memory saved and (b) the
effect on decode logits — the serving-side analog of the paper's
rate-distortion trade.

  PYTHONPATH=src python examples/kv_cache_compression.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import build_model
from repro.models.common import init_params
from repro.serving import (
    KVCompressionConfig,
    compress_kv_block,
    decompress_kv_block,
)

cfg = get_smoke("granite_8b")
model = build_model(cfg)
params = init_params(model.param_specs(), jax.random.PRNGKey(0))

B, S = 2, 64
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
logits, cache = model.prefill(params, batch, max_len=S + 8)

# Quantization-only here (n == e): a random-init smoke model has a rough
# KV timeline, so spectral truncation (e < n) is only appropriate for
# TRAINED models whose adjacent-token keys/values are smooth (the paper's
# premise applied to caches).  int8 quantization alone halves the cache.
kcfg = KVCompressionConfig(n=16, e=16)
raw_bytes = 0
comp_bytes = 0
max_rel = 0.0
new_cache = {}
for gname, group in cache.items():
    new_group = dict(group)
    for key in ("k", "v"):
        kv = group[key]  # [L, B, T, H, D]
        L = kv.shape[0]
        outs = []
        for l in range(L):
            block = kv[l][:, :S]  # valid prefix
            levels, scale = compress_kv_block(block, kcfg)
            rec = decompress_kv_block(levels, scale, kcfg, dtype=kv.dtype)
            rel = float(
                jnp.linalg.norm((rec - block).astype(jnp.float32))
                / (jnp.linalg.norm(block.astype(jnp.float32)) + 1e-9)
            )
            max_rel = max(max_rel, rel)
            raw_bytes += block.size * 2
            comp_bytes += levels.size + scale.size * 4
            padded = jnp.zeros_like(kv[l]).at[:, :S].set(rec)
            outs.append(padded)
        new_group[key] = jnp.stack(outs)
    new_cache[gname] = new_group

print(f"KV cache: {raw_bytes/1e6:.2f} MB -> {comp_bytes/1e6:.2f} MB "
      f"(CR {raw_bytes/comp_bytes:.2f}x), worst block rel err {max_rel:.4f}")

tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
lg_ref, _ = model.decode_step(params, cache, tok, jnp.int32(S))
lg_cmp, _ = model.decode_step(params, new_cache, tok, jnp.int32(S))
agree = float(jnp.mean(
    (jnp.argmax(lg_ref, -1) == jnp.argmax(lg_cmp, -1)).astype(jnp.float32)
))
drift = float(jnp.max(jnp.abs(
    jax.nn.log_softmax(lg_ref.astype(jnp.float32))
    - jax.nn.log_softmax(lg_cmp.astype(jnp.float32))
)))
print(f"decode with compressed cache: top-1 agreement {agree*100:.0f}%, "
      f"max log-prob drift {drift:.3f}")
