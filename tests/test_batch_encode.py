"""BatchEncoder: parity vs the host encoder, chunk-padding bounds, plan
cache, routing, and the loud-failure paths.  (Tentpole coverage for the
batched bucketed encode engine.)"""
import numpy as np
import pytest

from repro.core import (
    DOMAIN_DEFAULTS,
    calibrate,
    decode,
    encode,
    encode_device,
)
from repro.core.calibration import DomainTables
from repro.core.config import CodecConfig
from repro.core.huffman import build_codebook
from repro.core.quantize import build_quant_table
from repro.data import make_signal
from repro.serving.batch_decode import BatchDecoder
from repro.serving.batch_encode import DEFAULT_CHUNK_SIZE, BatchEncoder


@pytest.fixture(scope="module")
def power_tables():
    return calibrate(
        make_signal("load_power", 65536, seed=7),
        DOMAIN_DEFAULTS["power"],
        domain_id=0,
    )


@pytest.fixture(scope="module")
def meteo_tables():
    return calibrate(
        make_signal("temperature", 65536, seed=8),
        DOMAIN_DEFAULTS["meteorological"],
        domain_id=1,
    )


def test_exact_mode_bit_identical_to_host(power_tables):
    """chunk_size=None packs each signal as one chunk: the engine must
    reproduce the host encoder's containers bit for bit."""
    lengths = [4096, 16384, 5000, 8191, 333]
    sigs = [make_signal("load_power", n, seed=i) for i, n in enumerate(lengths)]
    enc = BatchEncoder(chunk_size=None)
    cs = enc.encode(sigs, power_tables).to_host()
    assert len(cs) == len(sigs)
    for sig, c in zip(sigs, cs):
        ref = encode(sig, power_tables)
        np.testing.assert_array_equal(c.words, ref.words)
        np.testing.assert_array_equal(c.symlen, ref.symlen)
        assert c.num_symbols == ref.num_symbols
        assert c.num_windows == ref.num_windows
        assert c.signal_length == ref.signal_length
        assert c.plan_key == ref.plan_key


def test_chunked_mode_roundtrips_with_bounded_padding(power_tables):
    """Chunk-parallel containers decode (host decoder, unchanged) to exactly
    what the host-encoded containers decode to, and cost < 1 extra word per
    chunk."""
    lengths = [16384, 65536, 5000]
    sigs = [
        make_signal("load_power", n, seed=10 + i)
        for i, n in enumerate(lengths)
    ]
    enc = BatchEncoder()  # DEFAULT_CHUNK_SIZE
    cs = enc.encode(sigs, power_tables).to_host()
    for sig, c in zip(sigs, cs):
        ref = encode(sig, power_tables)
        np.testing.assert_allclose(
            decode(c, power_tables), decode(ref, power_tables), atol=0
        )
        num_chunks = -(-ref.num_symbols // DEFAULT_CHUNK_SIZE)
        assert c.num_words - ref.num_words < num_chunks
        assert c.num_symbols == ref.num_symbols


def test_chunked_to_batch_decoder_roundtrip(power_tables, meteo_tables):
    """The full serving loop: BatchEncoder -> containers -> BatchDecoder,
    mixed domains and lengths, order preserved."""
    sigs, doms = [], []
    for i, n in enumerate([4096, 6000, 12288, 3001]):
        if i % 2 == 0:
            sigs.append(make_signal("load_power", n, seed=i))
            doms.append(0)
        else:
            sigs.append(make_signal("temperature", n, seed=i))
            doms.append(1)
    tables = {0: power_tables, 1: meteo_tables}
    enc = BatchEncoder()
    cs = enc.encode(sigs, tables, domain_ids=doms).to_host()
    outs = BatchDecoder().decode(cs, tables).to_host()
    for sig, out, dom in zip(sigs, outs, doms):
        tab = tables[dom]
        ref = decode(encode(sig, tab), tab)
        assert out.shape == sig.shape
        np.testing.assert_allclose(out, ref, atol=1e-4)


def test_encode_device_is_batch_of_one(power_tables):
    sig = make_signal("load_power", 10000, seed=3)
    c = encode_device(sig, power_tables)
    ref = encode(sig, power_tables)
    np.testing.assert_array_equal(c.words, ref.words)
    np.testing.assert_array_equal(c.symlen, ref.symlen)


def test_bucketing_bounds_dispatches(power_tables):
    """Same (domain, config) and same window bucket -> one fused dispatch,
    regardless of exact lengths."""
    sigs = [
        make_signal("load_power", n, seed=20 + i)
        for i, n in enumerate([30000, 32768, 28111, 20000])
    ]  # all land in the 1024-window bucket (n=32)
    enc = BatchEncoder()
    enc.encode(sigs, power_tables).to_host()
    # one fused dispatch per shard of the single bucket (shard count > 1
    # only under the multi-device CI leg)
    assert enc.stats.dispatches == min(
        len(sigs), enc.scheduler.num_shards
    )


def test_plan_cache_reuse(power_tables):
    enc = BatchEncoder()
    sig = make_signal("load_power", 2048, seed=51)
    enc.encode([sig], power_tables).to_host()
    enc.encode([sig], power_tables).to_host()
    assert enc.stats.plan_misses == 1
    assert enc.stats.plan_hits >= 1


def test_empty_batch(power_tables):
    enc = BatchEncoder()
    batch = enc.encode([], power_tables)
    assert len(batch) == 0 and batch.to_host() == []


def test_mapping_requires_domain_ids(power_tables):
    with pytest.raises(ValueError, match="domain_ids"):
        BatchEncoder().encode(
            [make_signal("load_power", 512, seed=0)], {0: power_tables}
        )
    with pytest.raises(KeyError, match="domain_id=9"):
        BatchEncoder().encode(
            [make_signal("load_power", 512, seed=0)],
            {0: power_tables},
            domain_ids=[9],
        )


def _gap_tables(n=8, e=8, l_max=8):
    """Tables whose Huffman book covers ONLY the zero bin (128): any signal
    that quantizes off-zero hits a histogram gap."""
    hist = np.zeros(256, dtype=np.int64)
    hist[128] = 100
    book = build_codebook(hist, l_max=l_max)
    rng = np.random.default_rng(0)
    quant = build_quant_table(
        rng.standard_normal((64, e)), b1=2, b2=e, mu=50.0, alpha1=0.004,
        percentile=99.9,
    )
    cfg = CodecConfig(n=n, e=e, b1=2, b2=e, l_max=l_max)
    return DomainTables(config=cfg, quant=quant, book=book, domain_id=0)


def test_double_drain_raises(power_tables):
    """Satellite bugfix: a second to_host() must fail loudly instead of
    silently re-syncing possibly stale/donated device buffers."""
    enc = BatchEncoder()
    batch = enc.encode([make_signal("load_power", 2048, seed=70)],
                       power_tables)
    first = batch.to_host()
    assert len(first) == 1
    with pytest.raises(RuntimeError, match="already drained"):
        batch.to_host()
    # reading device parts after the drain is equally invalid
    with pytest.raises(RuntimeError, match="already drained"):
        batch.device_parts()


def test_drain_after_transcode_donation_raises(power_tables, meteo_tables):
    """Handing an EncodedBatch to a Transcoder consumes it: the stitched
    buffers now feed the device pipeline, so a later drain must raise."""
    from repro.serving import Transcoder

    sig = make_signal("load_power", 2048, seed=71)
    batch = BatchEncoder().encode([sig], power_tables)
    out = Transcoder().transcode(batch, power_tables, meteo_tables)
    with pytest.raises(RuntimeError, match="donated to a Transcoder"):
        batch.to_host()
    # the transcode result itself drains once, then raises too
    assert len(out.to_host()) == 1
    with pytest.raises(RuntimeError, match="already drained"):
        out.to_host()


def test_device_parts_expose_stream_contract(power_tables):
    """device_parts + signal_slices are the device-resident mirror of
    to_host(): stitching each signal's chunk runs reproduces the drained
    containers' words exactly (no host sync needed to get there)."""
    from repro.core import symlen as symlib

    sigs = [
        make_signal("load_power", n, seed=80 + i)
        for i, n in enumerate([4096, 5000])
    ]
    enc = BatchEncoder(chunk_size=64)  # force several chunks per signal
    batch = enc.encode(sigs, power_tables)
    parts = batch.device_parts()
    slices = batch.signal_slices()
    assert len(slices) == len(sigs)
    # per-signal word extents are device arrays summing words_per_chunk
    for p in parts:
        np.testing.assert_array_equal(
            np.asarray(p.words_per_signal()),
            np.asarray(p.words_per_chunk).sum(axis=1),
        )
    containers = batch.to_host()
    for c, s in zip(containers, slices):
        p = parts[s.bucket]
        hi, lo, sl, nw = symlib.stitch_chunk_parts(
            p.hi[s.row], p.lo[s.row], p.symlen[s.row],
            p.words_per_chunk[s.row],
            capacity=p.num_chunks * p.chunk_size,
        )
        nw = int(nw)
        assert nw == c.num_words
        np.testing.assert_array_equal(
            symlib.u32_to_words(np.asarray(hi[:nw]), np.asarray(lo[:nw])),
            c.words,
        )
        np.testing.assert_array_equal(np.asarray(sl[:nw]), c.symlen)


def test_drain_raises_on_histogram_gap():
    """Satellite bugfix parity, batched arm: a symbol with no codeword must
    fail loudly at drain instead of emitting a garbage stream (the host
    encoder raises the same way inside pack_symlen_np)."""
    tables = _gap_tables()
    sig = np.sin(np.linspace(0, 30, 512)).astype(np.float32) * 5
    with pytest.raises(ValueError, match="no codeword"):
        encode(sig, tables)  # host oracle rejects
    enc = BatchEncoder()
    batch = enc.encode([sig], tables)
    with pytest.raises(ValueError, match="histogram gap"):
        batch.to_host()
    # a failed drain returned nothing, so a retry re-raises the REAL error
    # (not a bogus "already drained")
    with pytest.raises(ValueError, match="histogram gap"):
        batch.to_host()
    # and a gap book with in-coverage data still encodes
    zeros = np.zeros(512, np.float32)
    cs = BatchEncoder().encode([zeros], tables).to_host()
    np.testing.assert_allclose(decode(cs[0], tables), zeros, atol=1e-6)
