"""3-zone hybrid quantizer invariants."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.quantize import build_quant_table, dequantize, quantize


def _table(e=16, b1=4, b2=12, mu=50.0, alpha1=0.004, seed=0):
    rng = np.random.default_rng(seed)
    calib = rng.standard_normal((4096, e)) * np.linspace(2, 0.1, e)
    return build_quant_table(
        calib, b1=b1, b2=b2, mu=mu, alpha1=alpha1, percentile=99.9
    )


def test_zone2_always_zero_bin():
    t = _table()
    x = jnp.asarray(np.random.default_rng(1).standard_normal((256, 16)) * 5)
    q = np.asarray(quantize(x, t))
    assert np.all(q[:, 12:] == 128)
    d = np.asarray(dequantize(jnp.asarray(q), t))
    assert np.all(d[:, 12:] == 0.0)


def test_deadzone_collapses_to_zero():
    t = _table(alpha1=0.1)
    scale = np.asarray(t.scale)
    # values inside the deadzone of zone-1 bins map to 128 and decode to 0
    x = np.zeros((4, 16), np.float32)
    x[:, 4:12] = scale[4:12] * 0.05  # well inside 0.1 * A1
    q = np.asarray(quantize(jnp.asarray(x), t))
    assert np.all(q[:, 4:12] == 128)


def test_zero_maps_to_zero_bin_everywhere():
    t = _table()
    q = np.asarray(quantize(jnp.zeros((2, 16)), t))
    assert np.all(q == 128)
    d = np.asarray(dequantize(jnp.asarray(q), t))
    assert np.allclose(d, 0.0)


def test_sign_symmetry():
    t = _table()
    x = np.abs(np.random.default_rng(2).standard_normal((64, 16))).astype(
        np.float32
    )
    qp = np.asarray(quantize(jnp.asarray(x), t)).astype(np.int32)
    qn = np.asarray(quantize(jnp.asarray(-x), t)).astype(np.int32)
    # positive bins 129..255 mirror negative bins 127..0 around 128
    pos_off = qp - 128
    neg_off = 128 - qn
    # mu-law mapping has 127 negative vs 126 positive levels; allow 1 level
    assert np.all(np.abs(pos_off - neg_off) <= 1)


def test_mulaw_monotone():
    t = _table(b1=16, b2=16)  # all zone-0
    x = np.linspace(-3, 3, 512, dtype=np.float32)[:, None].repeat(16, 1)
    q = np.asarray(quantize(jnp.asarray(x), t)).astype(np.int32)
    assert np.all(np.diff(q[:, 0]) >= 0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.floats(1.0, 400.0))
def test_property_roundtrip_error_bounded(seed, mu):
    """|dequant(quant(x)) - x| is bounded by the local cell width."""
    rng = np.random.default_rng(seed)
    e = 8
    calib = rng.standard_normal((2048, e)).astype(np.float32)
    t = build_quant_table(
        calib, b1=e, b2=e, mu=mu, alpha1=0.004, percentile=100.0
    )
    x = rng.standard_normal((128, e)).astype(np.float32)
    scale = np.asarray(t.scale)
    x = np.clip(x, -scale, scale)  # in-range values
    q = quantize(jnp.asarray(x), t)
    d = np.asarray(dequantize(q, t))
    # mu-law max cell width at the extremes: A * (exp(ln(1+mu)/126) - 1) *
    # (1+mu)/mu — conservative bound of ~4% of A for mu<=400
    bound = scale * (np.log1p(mu) / 126.0) * (1 + mu) / mu * 1.5 + 1e-5
    assert np.all(np.abs(d - x) <= bound + np.abs(x) * 0.05)
