"""Container-v3 coverage: the fused predictor + zero-plane coding stage.

Pins the ISSUE's acceptance criteria end to end:

  * the v3 re-coding primitives (predict/unpredict, zero-plane masks,
    expansion index) are exact inverses;
  * unknown container versions fail loudly, naming the version byte and
    the supported set;
  * the kernel-path v3 decode/encode buckets still lower to EXACTLY one
    ``pallas_call`` each (the coding stage fused as prologue/epilogue,
    never a second dispatch), bit-identical to the XLA arms;
  * device-resident v2 -> v3 archive upgrades are byte-identical to the
    host decode + re-encode round trip with zero device->host transfers,
    including streams landing exactly at the 255/256/257 word marks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _synth import uniform_code_container
from repro.core import calibrate, decode, encode, symlen
from repro.core.calibration import DomainTables
from repro.core.config import DOMAIN_DEFAULTS, PREDICTORS, CodecConfig
from repro.core.container import (
    _HDR,
    HEADER_BYTES,
    SUPPORTED_VERSIONS,
    Container,
)
from repro.core.quantize import (
    expand_coded_stream,
    predict_levels,
    unpredict_levels,
)
from repro.data import make_signal
from repro.serving import BatchDecoder, BatchEncoder, Transcoder

CODINGS = [
    dict(predictor="delta", predict_bands=2, zero_planes=True),
    dict(predictor="delta", predict_bands=1, zero_planes=False),
    dict(predictor="linear2", predict_bands=3, zero_planes=True),
    dict(predictor="none", predict_bands=0, zero_planes=True),
]


@pytest.fixture(scope="module")
def power_tables():
    return calibrate(
        make_signal("load_power", 32768, seed=11), DOMAIN_DEFAULTS["power"]
    )


def _retable(tables: DomainTables, **coding) -> DomainTables:
    """Same quant/book/domain, a different (v3) coding on the config."""
    return dataclasses.replace(
        tables, config=tables.config.replace(**coding)
    )


# ---------------------------------------------------------------------------
# Re-coding primitives
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pred", ["delta", "linear2"])
@pytest.mark.parametrize("bands", [1, 3, 8])
def test_predict_unpredict_roundtrip(pred, bands):
    rng = np.random.default_rng(3)
    levels = rng.integers(0, 256, (37, 8)).astype(np.uint8)
    pred_id = PREDICTORS[pred]
    grid = np.asarray(predict_levels(jnp.asarray(levels), pred_id, bands))
    # untouched high bands pass through verbatim
    np.testing.assert_array_equal(grid[:, bands:], levels[:, bands:])
    seg = jnp.zeros((37,), jnp.int32)  # one segment starting at window 0
    back = np.asarray(unpredict_levels(
        jnp.asarray(grid, jnp.uint32), seg, pred_id, bands
    ))
    np.testing.assert_array_equal(back.astype(np.uint8), levels)


def test_zero_plane_masks_and_expansion_are_inverse():
    rng = np.random.default_rng(5)
    e = 6
    grids = []
    for nw in [4, 9, 1]:
        g = rng.integers(0, 256, (nw, e)).astype(np.uint8)
        g[1 % nw, :] = 128  # an all-zero window row
        g[:, 2] = 128  # an all-zero coefficient column
        grids.append(g)
    members = []
    coded_all = []
    for g in grids:
        zrow, zcol = symlen.zero_plane_masks(g)
        assert zrow.any() and zcol.any()
        members.append((g.shape[0], zrow, zcol))
        coded_all.append(g[~zrow, :][:, ~zcol].ravel())
    dense = np.concatenate(coded_all).astype(np.int32)
    total = sum(g.shape[0] for g in grids) + 3  # 3 padding windows
    idx, seg = symlen.v3_expand_index(members, e, total_windows=total)
    out = np.asarray(
        expand_coded_stream(jnp.asarray(dense), jnp.asarray(idx))
    ).reshape(total, e)
    np.testing.assert_array_equal(
        out[: sum(g.shape[0] for g in grids)],
        np.concatenate(grids).astype(np.int32),
    )
    # padding windows expand to the zero bin and are their own segments
    np.testing.assert_array_equal(out[-3:], 128)
    np.testing.assert_array_equal(
        seg[-3:], np.arange(total - 3, total, dtype=np.int32)
    )


# ---------------------------------------------------------------------------
# Versioning
# ---------------------------------------------------------------------------
def test_unknown_version_error_names_byte_and_supported_set(power_tables):
    """Satellite regression: an unreadable version byte must be NAMED in
    the error together with the supported set — not a bare magic/parse
    failure three layers down."""
    blob = bytearray(
        encode(make_signal("load_power", 2048, seed=6), power_tables)
        .to_bytes()
    )
    (magic, _version, *rest) = _HDR.unpack_from(bytes(blob), 0)
    for bad in (0, 4, 7, 255):
        blob[:HEADER_BYTES] = _HDR.pack(magic, bad, *rest)
        with pytest.raises(ValueError) as exc:
            Container.from_bytes(bytes(blob))
        assert f"version {bad}" in str(exc.value)
        assert str(SUPPORTED_VERSIONS) in str(exc.value)
    assert SUPPORTED_VERSIONS == (1, 2, 3)


def test_v3_reserved_flag_bits_rejected(power_tables):
    t3 = _retable(power_tables, **CODINGS[0])
    c = encode(make_signal("load_power", 2048, seed=6), t3)
    assert c.version == 3
    blob = bytearray(c.to_bytes())
    blob[HEADER_BYTES] |= 0x40  # a reserved flag bit inside _EXT3
    with pytest.raises(ValueError, match="reserved flag"):
        Container.from_bytes(bytes(blob))


# ---------------------------------------------------------------------------
# Fused kernels: still one pallas_call, still bit-identical
# ---------------------------------------------------------------------------
def _v3_bucket_operands(tables, seed=3):
    """One v3 decode bucket (p2-padded) + its plan and expansion arrays."""
    from repro.serving.batch_decode import _build_decode_plan
    from repro.core.symlen import words_to_u32
    from repro.serving.engine import p2, symlen_bucket

    c = encode(make_signal("load_power", 6000, seed=seed), tables)
    assert c.version == 3
    plan = _build_decode_plan(tables, c.plan_key, None)
    wp, nwp = p2(c.num_words), p2(c.num_windows)
    hi, lo = words_to_u32(c.words)
    hi2 = np.zeros(wp, np.uint32); hi2[: c.num_words] = hi
    lo2 = np.zeros(wp, np.uint32); lo2[: c.num_words] = lo
    sl2 = np.zeros(wp, np.int32); sl2[: c.num_words] = c.symlen
    idx, seg = symlen.v3_expand_index(
        [(c.num_windows, c.zrow, c.zcol)], c.e, total_windows=nwp
    )
    statics = dict(
        l_max=c.l_max, max_symlen=symlen_bucket(c.max_symlen),
        num_windows=nwp, n=c.n, e=c.e,
        coding=tables.config.coding,
    )
    return (
        plan, jnp.asarray(hi2), jnp.asarray(lo2), jnp.asarray(sl2),
        (jnp.asarray(idx), jnp.asarray(seg)), statics,
    )


@pytest.mark.parametrize("coding", CODINGS)
def test_v3_decode_bucket_is_one_pallas_call(power_tables, coding):
    """Acceptance: the v3 epilogue (expansion + un-prediction) fuses INTO
    the decode megakernel — still exactly one pallas_call, and the XLA arm
    stays pallas-free."""
    import functools

    from test_kernels import _count_eqns
    from repro.serving.batch_decode import _decode_bucket_math

    t3 = _retable(power_tables, **coding)
    plan, hi, lo, sl, v3, statics = _v3_bucket_operands(t3)
    fused = jax.make_jaxpr(functools.partial(
        _decode_bucket_math, use_kernels=True, **statics
    ))(hi, lo, sl, plan.tables, plan.lut, plan.basis, v3)
    assert _count_eqns(fused.jaxpr, "pallas_call") == 1

    unfused = jax.make_jaxpr(functools.partial(
        _decode_bucket_math, use_kernels=False, **statics
    ))(hi, lo, sl, plan.tables, plan.lut, plan.basis, v3)
    assert _count_eqns(unfused.jaxpr, "pallas_call") == 0


@pytest.mark.parametrize("coding", CODINGS)
def test_v3_decode_bucket_kernel_bit_identical(power_tables, coding):
    from repro.serving.batch_decode import _decode_bucket

    t3 = _retable(power_tables, **coding)
    plan, hi, lo, sl, v3, statics = _v3_bucket_operands(t3)
    ref = _decode_bucket(
        hi, lo, sl, plan.tables, plan.lut, plan.basis, v3,
        use_kernels=False, **statics,
    )
    got = _decode_bucket(
        hi, lo, sl, plan.tables, plan.lut, plan.basis, v3,
        use_kernels=True, **statics,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("coding", CODINGS)
def test_v3_encode_bucket_is_one_pallas_call(power_tables, coding):
    """Acceptance: the v3 prologue (prediction + zero-plane masks) fuses
    INTO the encode megakernel — still exactly one pallas_call."""
    import functools

    from test_kernels import _count_eqns
    from repro.serving.batch_encode import (
        _build_encode_plan,
        _encode_bucket_kernels_math,
    )

    t3 = _retable(power_tables, **coding)
    cfg = t3.config
    plan = _build_encode_plan(
        t3, (0, cfg.n, cfg.e, cfg.l_max, cfg.coding), None
    )
    x = jnp.zeros((2, 4 * cfg.n), jnp.float32)
    counts = jnp.zeros((2,), jnp.int32)
    traced = jax.make_jaxpr(functools.partial(
        _encode_bucket_kernels_math,
        n=cfg.n, e=cfg.e, chunk_size=64, check_gaps=True,
        coding=cfg.coding,
    ))(x, counts, plan.tables, plan.basis)
    assert _count_eqns(traced.jaxpr, "pallas_call") == 1


@pytest.mark.parametrize("coding", CODINGS)
def test_v3_encode_bucket_kernel_bit_identical(power_tables, coding):
    from repro.serving.batch_encode import (
        _build_encode_plan,
        _encode_bucket,
        _encode_bucket_kernels,
    )
    from repro.serving.engine import p2

    t3 = _retable(power_tables, **coding)
    cfg = t3.config
    n, e = cfg.n, cfg.e
    plan = _build_encode_plan(
        t3, (0, n, e, cfg.l_max, cfg.coding), None
    )
    sigs = [make_signal("load_power", L, seed=40 + i)
            for i, L in enumerate([1500, 700, 2048])]
    wp = p2(max(-(-s.shape[0] // n) for s in sigs))
    kp = p2(len(sigs))
    x = np.zeros((kp, wp * n), np.float32)
    counts = np.zeros((kp,), np.int32)
    for row, s in enumerate(sigs):
        x[row, : s.shape[0]] = s
        counts[row] = -(-s.shape[0] // n) * e
    for chunk in [64, wp * e]:
        ref = _encode_bucket(
            jnp.asarray(x), jnp.asarray(counts), plan.tables,
            n=n, e=e, chunk_size=chunk, check_gaps=False,
            coding=cfg.coding,
        )
        got = _encode_bucket_kernels(
            jnp.asarray(x), jnp.asarray(counts), plan.tables, plan.basis,
            n=n, e=e, chunk_size=chunk, check_gaps=False,
            coding=cfg.coding,
        )
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Engine round trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("coding", CODINGS)
def test_engine_v3_roundtrip_matches_host(power_tables, coding):
    """Both engine arms encode the exact host v3 bytes and decode them
    float-identically to the host decoder, across mixed lengths."""
    t3 = _retable(power_tables, **coding)
    sigs = [make_signal("load_power", L, seed=70 + i).astype(np.float32)
            for i, L in enumerate([5000, 777, 63])]
    host = [encode(s, t3) for s in sigs]
    for uk in (False, True):
        outs = BatchEncoder(chunk_size=None, use_kernels=uk).encode(
            sigs, t3
        ).to_host()
        for h, o in zip(host, outs):
            assert h.to_bytes() == o.to_bytes()
        parsed = [Container.from_bytes(h.to_bytes()) for h in host]
        recons = BatchDecoder(use_kernels=uk).decode(parsed, t3).to_host()
        for c, r in zip(parsed, recons):
            np.testing.assert_array_equal(
                np.asarray(r), np.asarray(decode(c, t3))
            )


# ---------------------------------------------------------------------------
# Device-resident v2 -> v3 archive upgrade
# ---------------------------------------------------------------------------
def test_v2_to_v3_transcode_byte_identity_zero_transfers(power_tables):
    """Satellite acceptance: upgrading a v2 archive to v3 on device is
    byte-identical to host decode + re-encode, with the decode -> re-encode
    stretch pinned transfer-free."""
    t2 = power_tables
    t3 = _retable(power_tables, **CODINGS[0])
    containers = [
        encode(make_signal("load_power", L, seed=80 + i), t2)
        for i, L in enumerate([6000, 1234, 257])
    ]
    ref = [encode(np.asarray(decode(c, t2)), t3) for c in containers]
    tc = Transcoder(chunk_size=None)
    with jax.transfer_guard_device_to_host("disallow"):
        batch = tc.transcode(containers, t2, t3)
    got = batch.to_host()
    for r, o in zip(ref, got):
        assert o.version == 3
        assert r.to_bytes() == o.to_bytes()


@pytest.mark.parametrize("num_words", [255, 256, 257])
def test_v2_to_v3_transcode_word_boundaries(num_words):
    """Streams landing exactly at / straddling the 256-word mark upgrade
    byte-identically (the stitch capacity and decode staging boundaries)."""
    c, tables = uniform_code_container(num_words, seed=num_words)
    t3 = _retable(tables, **CODINGS[0])
    ref = encode(np.asarray(decode(c, tables)), t3)
    got = Transcoder(chunk_size=None).transcode_to_host([c], tables, t3)[0]
    assert got.version == 3
    assert ref.to_bytes() == got.to_bytes()


def test_v3_encoded_batch_source_refuses_device_transcode(power_tables):
    """A v3-coded EncodedBatch source would need a host sync to rebuild
    the decode expansion — the zero-transfer path refuses loudly and
    leaves the source drainable."""
    t3 = _retable(power_tables, **CODINGS[0])
    sigs = [make_signal("load_power", 3000, seed=90).astype(np.float32)]
    batch = BatchEncoder(chunk_size=64).encode(sigs, t3)
    with pytest.raises(NotImplementedError, match="v3-coded"):
        Transcoder().transcode(batch, t3, power_tables)
    assert len(batch.to_host()) == 1  # refusal did not consume the source
