"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True.

The fused-kernel section additionally pins the megakernel acceptance
criteria: a kernel-path decode bucket lowers to exactly ONE pallas_call
with no ``[max_symlen, W]`` intermediate (jaxpr inspection), and the fused
encode/decode paths are BIT-identical to the XLA engine paths."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dct as dctlib
from repro.core.huffman import build_codebook
from repro.core.quantize import build_quant_table
from repro.core.symlen import pack_symlen_np, words_to_u32
from repro.kernels import ref as kref
from repro.kernels.dct_quant import dct_quant
from repro.kernels.huffman_decode import (
    huffman_decode_dense,
    huffman_decode_padded,
)
from repro.kernels.idct_dequant import idct_dequant


def _quant_table(e, seed=0):
    rng = np.random.default_rng(seed)
    calib = rng.standard_normal((2048, e)) * np.linspace(2, 0.2, e)
    return build_quant_table(
        calib, b1=max(e // 4, 1), b2=max(e // 2, 1), mu=50.0, alpha1=0.004,
        percentile=99.9,
    )


@pytest.mark.parametrize("l_max", [8, 12])
@pytest.mark.parametrize("n_syms", [100, 4096, 7000])
def test_huffman_decode_kernel_vs_ref(l_max, n_syms):
    rng = np.random.default_rng(l_max * 1000 + n_syms)
    syms = np.clip(rng.zipf(1.4, n_syms), 0, 255).astype(np.uint8)
    freqs = np.bincount(syms, minlength=256).astype(np.int64) + 1
    book = build_codebook(freqs, l_max=l_max)
    stream = pack_symlen_np(syms, book)
    hi, lo = words_to_u32(stream.words)
    args = (
        jnp.asarray(hi), jnp.asarray(lo),
        jnp.asarray(book.limit_shifted[1:], jnp.uint32),
        jnp.asarray(book.first_code_shifted, jnp.uint32),
        jnp.asarray(book.rank_offset, jnp.int32),
        jnp.asarray(book.sorted_symbols, jnp.int32),
    )
    kw = dict(l_max=l_max, max_symlen=stream.max_symlen)
    out_kernel = huffman_decode_padded(*args, **kw, block_words=128)
    out_ref = kref.huffman_decode_padded_ref(*args, **kw)
    np.testing.assert_array_equal(np.asarray(out_kernel), np.asarray(out_ref))
    # compacted stream equals original symbols
    valid = []
    for w, sl in enumerate(stream.symlen):
        valid.append(np.asarray(out_kernel)[w, :sl])
    np.testing.assert_array_equal(
        np.concatenate(valid).astype(np.uint8), syms
    )


@pytest.mark.parametrize("n,e", [(8, 4), (32, 16), (32, 32), (64, 24),
                                 (128, 128)])
@pytest.mark.parametrize("w", [16, 300, 1024])
def test_idct_dequant_kernel_vs_ref(n, e, w):
    rng = np.random.default_rng(n * e + w)
    t = _quant_table(e)
    levels = rng.integers(0, 256, (w, e)).astype(np.int32)
    basis = dctlib.idct_basis(n, e)
    out_k = idct_dequant(
        jnp.asarray(levels), t.zone, t.scale, basis, t.mu, t.alpha1,
        n=n, block_windows=256,
    )
    out_r = kref.idct_dequant_ref(jnp.asarray(levels), t, n=n)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("n,e", [(8, 4), (32, 16), (64, 64)])
@pytest.mark.parametrize("w", [64, 777])
def test_dct_quant_kernel_vs_ref(n, e, w):
    rng = np.random.default_rng(n + e + w)
    t = _quant_table(e, seed=n)
    windows = rng.standard_normal((w, n)).astype(np.float32)
    basis = dctlib.dct_basis(n, e)
    out_k = dct_quant(
        jnp.asarray(windows), t.zone, t.scale, basis, t.mu, t.alpha1,
        e=e, block_windows=128,
    )
    out_r = kref.dct_quant_ref(jnp.asarray(windows), t, e=e)
    k, r = np.asarray(out_k), np.asarray(out_r)
    # rounding at cell boundaries may differ by 1 level for a tiny fraction
    diff = np.abs(k - r)
    assert (diff > 1).mean() == 0.0
    assert (diff == 1).mean() < 2e-3


def test_kernel_end_to_end_codec_path():
    """decode_device(use_kernels=True) == host reference decode."""
    from repro.core import DOMAIN_DEFAULTS, calibrate, decode, decode_device, encode
    from repro.data import make_signal

    sig = make_signal("temperature", 8192, seed=11)
    tables = calibrate(
        make_signal("temperature", 32768, seed=12),
        DOMAIN_DEFAULTS["meteorological"],
    )
    c = encode(sig, tables)
    ref_out = decode(c, tables)
    k_out = decode_device(c, tables, use_kernels=True)
    np.testing.assert_allclose(ref_out, k_out, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Fused megakernels: single-dispatch decode, kernel-parity fused encode.
# ---------------------------------------------------------------------------
def _stream(l_max, n_syms, seed=0, pad_words=23):
    rng = np.random.default_rng(seed + l_max * 1000 + n_syms)
    syms = np.clip(rng.zipf(1.4, n_syms), 0, 255).astype(np.uint8)
    freqs = np.bincount(syms, minlength=256).astype(np.int64) + 1
    book = build_codebook(freqs, l_max=l_max)
    stream = pack_symlen_np(syms, book)
    hi, lo = words_to_u32(stream.words)
    # trailing padding words (symlen == 0), as bucket concatenation adds
    hi = np.concatenate([hi, np.zeros(pad_words, np.uint32)])
    lo = np.concatenate([lo, np.zeros(pad_words, np.uint32)])
    sl = np.concatenate([stream.symlen, np.zeros(pad_words, np.int32)])
    return syms, book, stream, hi, lo, sl


@pytest.mark.parametrize("l_max,n_syms", [(8, 100), (12, 4096), (12, 7001)])
def test_huffman_decode_dense_fused_compaction(l_max, n_syms):
    """The dense kernel (in-kernel prefix scan + cooperative store) equals
    the staged oracle: tile kernel + compact_padded_scatter."""
    syms, book, stream, hi, lo, sl = _stream(l_max, n_syms)
    out = huffman_decode_dense(
        jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(sl),
        jnp.asarray(book.limit_shifted[1:], jnp.uint32),
        jnp.asarray(book.first_code_shifted, jnp.uint32),
        jnp.asarray(book.rank_offset, jnp.int32),
        jnp.asarray(book.sorted_symbols, jnp.int32),
        l_max=l_max, max_symlen=stream.max_symlen,
        num_symbols=n_syms, block_words=128,
    )
    np.testing.assert_array_equal(
        np.asarray(out).astype(np.uint8), syms
    )


def _bucket_operands(seed=3):
    """One realistic decode bucket (p2-padded words/windows) + its plan."""
    from repro.core import DOMAIN_DEFAULTS, calibrate, encode
    from repro.data import make_signal
    from repro.serving.batch_decode import _build_decode_plan
    from repro.serving.engine import p2, symlen_bucket

    tables = calibrate(
        make_signal("load_power", 32768, seed=seed), DOMAIN_DEFAULTS["power"]
    )
    c = encode(make_signal("load_power", 6000, seed=seed + 1), tables)
    plan = _build_decode_plan(tables, c.plan_key, None)
    wp, nwp = p2(c.num_words), p2(c.num_windows)
    hi, lo = words_to_u32(c.words)
    hi2 = np.zeros(wp, np.uint32); hi2[:c.num_words] = hi
    lo2 = np.zeros(wp, np.uint32); lo2[:c.num_words] = lo
    sl2 = np.zeros(wp, np.int32); sl2[:c.num_words] = c.symlen
    statics = dict(
        l_max=c.l_max, max_symlen=symlen_bucket(c.max_symlen),
        num_windows=nwp, n=c.n, e=c.e,
    )
    return plan, jnp.asarray(hi2), jnp.asarray(lo2), jnp.asarray(sl2), statics


def test_decode_megakernel_bit_identical_to_xla_bucket():
    """The fused decode (ONE pallas_call: huffman + compaction + LUT
    dequant + iDCT) returns bit-identical windows to the XLA bucket arm."""
    from repro.serving.batch_decode import _decode_bucket

    plan, hi, lo, sl, statics = _bucket_operands()
    ref = _decode_bucket(
        hi, lo, sl, plan.tables, plan.lut, plan.basis,
        use_kernels=False, **statics,
    )
    got = _decode_bucket(
        hi, lo, sl, plan.tables, plan.lut, plan.basis,
        use_kernels=True, **statics,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def _count_eqns(jaxpr, name):
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            total += 1
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", v)
            if hasattr(inner, "eqns"):
                total += _count_eqns(inner, name)
    return total


def _all_avals(jaxpr, out):
    """Shapes of every inter-op tensor.  Deliberately does NOT recurse into
    pallas_call bodies: refs/scratch inside the kernel are VMEM-resident by
    construction — the assertion is about tensors BETWEEN device programs
    (the HBM round trips the fusion exists to remove)."""
    for eqn in jaxpr.eqns:
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(tuple(aval.shape))
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", v)
            if hasattr(inner, "eqns"):
                _all_avals(inner, out)
    return out


def test_decode_bucket_kernel_path_is_one_pallas_call():
    """Acceptance: the kernel-path decode bucket lowers to EXACTLY one
    pallas_call, and no jaxpr intermediate carries the ``[max_symlen, W]``
    padded-tile shape (the HBM round trip the fusion removes).  The XLA
    arm of the same bucket is pallas-free."""
    from repro.serving.batch_decode import _decode_bucket_math

    plan, hi, lo, sl, statics = _bucket_operands()
    fused = jax.make_jaxpr(functools.partial(
        _decode_bucket_math, use_kernels=True, **statics
    ))(hi, lo, sl, plan.tables, plan.lut, plan.basis)
    assert _count_eqns(fused.jaxpr, "pallas_call") == 1

    w = int(hi.shape[0])
    ms = statics["max_symlen"]
    tile_shapes = {(ms, w), (w, ms)}
    seen = set(_all_avals(fused.jaxpr, []))
    assert not (seen & tile_shapes), (
        f"fused path materializes the padded tile: {seen & tile_shapes}"
    )

    unfused = jax.make_jaxpr(functools.partial(
        _decode_bucket_math, use_kernels=False, **statics
    ))(hi, lo, sl, plan.tables, plan.lut, plan.basis)
    assert _count_eqns(unfused.jaxpr, "pallas_call") == 0


def test_encode_fused_kernel_bit_identical_to_xla_bucket():
    """The fused encode tile (DCT + quantize + one-hot codeword lookup +
    chunk-parallel pack in one pallas_call) emits the exact chunk parts of
    the XLA engine path, across chunk sizes including exact mode."""
    from repro.core import DOMAIN_DEFAULTS, calibrate
    from repro.data import make_signal
    from repro.serving.batch_encode import (
        _build_encode_plan,
        _encode_bucket,
        _encode_bucket_kernels,
    )
    from repro.serving.engine import p2

    tables = calibrate(
        make_signal("temperature", 32768, seed=5),
        DOMAIN_DEFAULTS["meteorological"],
    )
    cfg = tables.config
    n, e = cfg.n, cfg.e
    key = (tables.domain_id, n, e, cfg.l_max)
    plan = _build_encode_plan(tables, key, None)
    sigs = [make_signal("temperature", L, seed=40 + i)
            for i, L in enumerate([1500, 700, 2048])]
    wp = p2(max(-(-s.shape[0] // n) for s in sigs))
    kp = p2(len(sigs))
    x = np.zeros((kp, wp * n), np.float32)
    counts = np.zeros((kp,), np.int32)
    for row, s in enumerate(sigs):
        x[row, : s.shape[0]] = s
        counts[row] = -(-s.shape[0] // n) * e
    for chunk in [64, 1024, wp * e]:
        ref = _encode_bucket(
            jnp.asarray(x), jnp.asarray(counts), plan.tables,
            n=n, e=e, chunk_size=chunk, check_gaps=False,
        )
        got = _encode_bucket_kernels(
            jnp.asarray(x), jnp.asarray(counts), plan.tables, plan.basis,
            n=n, e=e, chunk_size=chunk, check_gaps=False,
        )
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_encode_kernel_path_is_one_pallas_call():
    from repro.core import DOMAIN_DEFAULTS, calibrate
    from repro.data import make_signal
    from repro.serving.batch_encode import (
        _build_encode_plan,
        _encode_bucket_kernels_math,
    )

    tables = calibrate(
        make_signal("load_power", 16384, seed=9), DOMAIN_DEFAULTS["power"]
    )
    cfg = tables.config
    plan = _build_encode_plan(
        tables, (0, cfg.n, cfg.e, cfg.l_max), None
    )
    x = jnp.zeros((2, 4 * cfg.n), jnp.float32)
    counts = jnp.zeros((2,), jnp.int32)
    traced = jax.make_jaxpr(functools.partial(
        _encode_bucket_kernels_math,
        n=cfg.n, e=cfg.e, chunk_size=64, check_gaps=True,
    ))(x, counts, plan.tables, plan.basis)
    assert _count_eqns(traced.jaxpr, "pallas_call") == 1


def test_dct_quant_exact_arm_matches_reference():
    """dct_quant(exact=True) traces the reference quantizer inside the
    tile: levels equal the XLA forward_dct+quantize bit for bit."""
    from repro.core.quantize import quantize

    rng = np.random.default_rng(17)
    n, e, w = 32, 16, 700
    t = _quant_table(e, seed=2)
    windows = rng.standard_normal((w, n)).astype(np.float32)
    basis = dctlib.dct_basis(n, e)
    out = dct_quant(
        jnp.asarray(windows), t.zone, t.scale, basis, t.mu, t.alpha1,
        e=e, block_windows=128, exact=True,
    )
    ref = jax.jit(
        lambda win: quantize(dctlib.forward_dct(win, e), t).astype(jnp.int32)
    )(jnp.asarray(windows))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# int32 offset guard: the 2^31-byte boundary must raise loudly.
# ---------------------------------------------------------------------------
def test_i32_offset_guard_at_2gb_boundary():
    from repro.core.calibration import DeviceTables
    from repro.core.quantize import QuantTable
    from repro.kernels import ops as kops

    i32_max = np.iinfo(np.int32).max
    # just under the mark (mock arithmetic only — nothing is allocated)
    kops.check_i32_offsets(i32_max - 64, 64)
    with pytest.raises(ValueError, match="int32 offset range"):
        kops.check_i32_offsets(i32_max - 63, 64)
    with pytest.raises(ValueError, match="int32 offset range"):
        kops.check_i32_offsets(2 ** 31, 0)  # the 2^31-byte mark itself

    # and through the real decode entry point, with mocked (abstract)
    # shapes via eval_shape — no 2 GiB buffers are ever allocated
    spec = functools.partial(jax.ShapeDtypeStruct)
    w = 1 << 26
    tables = DeviceTables(
        codes=spec((256,), jnp.uint32),
        lengths=spec((256,), jnp.int32),
        dec_limit=spec((12,), jnp.uint32),
        dec_first=spec((13,), jnp.uint32),
        dec_rank=spec((13,), jnp.int32),
        dec_syms=spec((256,), jnp.int32),
        quant=QuantTable(
            zone=spec((16,), jnp.int32),
            scale=spec((16,), jnp.float32),
            mu=spec((), jnp.float32),
            alpha1=spec((), jnp.float32),
        ),
    )
    with pytest.raises(ValueError, match="int32 offset range"):
        jax.eval_shape(
            functools.partial(
                kops.huffman_decode,
                l_max=12, max_symlen=64, num_symbols=2 ** 31,
            ),
            spec((w,), jnp.uint32),
            spec((w,), jnp.uint32),
            spec((w,), jnp.int32),
            tables,
        )
