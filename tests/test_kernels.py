"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dct as dctlib
from repro.core.huffman import build_codebook
from repro.core.quantize import build_quant_table
from repro.core.symlen import pack_symlen_np, words_to_u32
from repro.kernels import ref as kref
from repro.kernels.dct_quant import dct_quant
from repro.kernels.huffman_decode import huffman_decode_padded
from repro.kernels.idct_dequant import idct_dequant


def _quant_table(e, seed=0):
    rng = np.random.default_rng(seed)
    calib = rng.standard_normal((2048, e)) * np.linspace(2, 0.2, e)
    return build_quant_table(
        calib, b1=max(e // 4, 1), b2=max(e // 2, 1), mu=50.0, alpha1=0.004,
        percentile=99.9,
    )


@pytest.mark.parametrize("l_max", [8, 12])
@pytest.mark.parametrize("n_syms", [100, 4096, 7000])
def test_huffman_decode_kernel_vs_ref(l_max, n_syms):
    rng = np.random.default_rng(l_max * 1000 + n_syms)
    syms = np.clip(rng.zipf(1.4, n_syms), 0, 255).astype(np.uint8)
    freqs = np.bincount(syms, minlength=256).astype(np.int64) + 1
    book = build_codebook(freqs, l_max=l_max)
    stream = pack_symlen_np(syms, book)
    hi, lo = words_to_u32(stream.words)
    args = (
        jnp.asarray(hi), jnp.asarray(lo),
        jnp.asarray(book.limit_shifted[1:], jnp.uint32),
        jnp.asarray(book.first_code_shifted, jnp.uint32),
        jnp.asarray(book.rank_offset, jnp.int32),
        jnp.asarray(book.sorted_symbols, jnp.int32),
    )
    kw = dict(l_max=l_max, max_symlen=stream.max_symlen)
    out_kernel = huffman_decode_padded(*args, **kw, block_words=128)
    out_ref = kref.huffman_decode_padded_ref(*args, **kw)
    np.testing.assert_array_equal(np.asarray(out_kernel), np.asarray(out_ref))
    # compacted stream equals original symbols
    valid = []
    for w, sl in enumerate(stream.symlen):
        valid.append(np.asarray(out_kernel)[w, :sl])
    np.testing.assert_array_equal(
        np.concatenate(valid).astype(np.uint8), syms
    )


@pytest.mark.parametrize("n,e", [(8, 4), (32, 16), (32, 32), (64, 24),
                                 (128, 128)])
@pytest.mark.parametrize("w", [16, 300, 1024])
def test_idct_dequant_kernel_vs_ref(n, e, w):
    rng = np.random.default_rng(n * e + w)
    t = _quant_table(e)
    levels = rng.integers(0, 256, (w, e)).astype(np.int32)
    basis = dctlib.idct_basis(n, e)
    out_k = idct_dequant(
        jnp.asarray(levels), t.zone, t.scale, basis, t.mu, t.alpha1,
        n=n, block_windows=256,
    )
    out_r = kref.idct_dequant_ref(jnp.asarray(levels), t, n=n)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("n,e", [(8, 4), (32, 16), (64, 64)])
@pytest.mark.parametrize("w", [64, 777])
def test_dct_quant_kernel_vs_ref(n, e, w):
    rng = np.random.default_rng(n + e + w)
    t = _quant_table(e, seed=n)
    windows = rng.standard_normal((w, n)).astype(np.float32)
    basis = dctlib.dct_basis(n, e)
    out_k = dct_quant(
        jnp.asarray(windows), t.zone, t.scale, basis, t.mu, t.alpha1,
        e=e, block_windows=128,
    )
    out_r = kref.dct_quant_ref(jnp.asarray(windows), t, e=e)
    k, r = np.asarray(out_k), np.asarray(out_r)
    # rounding at cell boundaries may differ by 1 level for a tiny fraction
    diff = np.abs(k - r)
    assert (diff > 1).mean() == 0.0
    assert (diff == 1).mean() < 2e-3


def test_kernel_end_to_end_codec_path():
    """decode_device(use_kernels=True) == host reference decode."""
    from repro.core import DOMAIN_DEFAULTS, calibrate, decode, decode_device, encode
    from repro.data import make_signal

    sig = make_signal("temperature", 8192, seed=11)
    tables = calibrate(
        make_signal("temperature", 32768, seed=12),
        DOMAIN_DEFAULTS["meteorological"],
    )
    c = encode(sig, tables)
    ref_out = decode(c, tables)
    k_out = decode_device(c, tables, use_kernels=True)
    np.testing.assert_allclose(ref_out, k_out, rtol=1e-4, atol=1e-4)
