"""Degenerate-input coverage: empty signals, sub-window signals, and a
single-symbol alphabet, through every encode/decode path (host, device
batch-of-one, and the batched engines), asserting host/device parity and
exact word counts."""
import numpy as np
import pytest

from repro.core import (
    DOMAIN_DEFAULTS,
    calibrate,
    decode,
    decode_device,
    encode,
    encode_device,
)
from repro.core.calibration import DomainTables
from repro.core.config import CodecConfig
from repro.core.huffman import build_codebook
from repro.core.quantize import build_quant_table
from repro.data import make_signal
from repro.serving import BatchDecoder, BatchEncoder


@pytest.fixture(scope="module")
def power_tables():
    return calibrate(
        make_signal("load_power", 65536, seed=99), DOMAIN_DEFAULTS["power"]
    )


def _roundtrip_everywhere(sig, tables, expect_words=None):
    """Encode via host / encode_device / BatchEncoder (exact + chunked),
    assert the containers agree, then decode via host / decode_device /
    BatchDecoder and assert the reconstructions agree."""
    sig = np.asarray(sig, np.float32)
    c_host = encode(sig, tables)
    c_dev = encode_device(sig, tables)
    c_exact = BatchEncoder(chunk_size=None).encode([sig], tables).to_host()[0]
    c_chunk = BatchEncoder(chunk_size=16).encode([sig], tables).to_host()[0]
    for c in (c_dev, c_exact):  # exact paths: bit-identical
        np.testing.assert_array_equal(c.words, c_host.words)
        np.testing.assert_array_equal(c.symlen, c_host.symlen)
    for c in (c_dev, c_exact, c_chunk):
        assert c.num_symbols == c_host.num_symbols
        assert c.num_windows == c_host.num_windows
        assert c.signal_length == sig.shape[0]
    if expect_words is not None:
        assert c_host.num_words == expect_words
        assert c_exact.num_words == expect_words
    # decode every container on every path
    ref = decode(c_host, tables)
    assert ref.shape == sig.shape
    for c in (c_host, c_dev, c_exact, c_chunk):
        np.testing.assert_allclose(decode(c, tables), ref, atol=0)
        np.testing.assert_allclose(decode_device(c, tables), ref, atol=1e-5)
    outs = BatchDecoder().decode([c_chunk, c_exact], tables).to_host()
    for out in outs:
        assert out.shape == sig.shape
        np.testing.assert_allclose(out, ref, atol=1e-5)
    return c_host, ref


def test_empty_signal(power_tables):
    c, rec = _roundtrip_everywhere(
        np.empty(0, np.float32), power_tables, expect_words=0
    )
    assert c.num_windows == 0 and c.num_symbols == 0
    assert rec.shape == (0,)
    # serialization of an empty container survives too
    from repro.core.container import Container

    c2 = Container.from_bytes(c.to_bytes())
    assert c2.num_words == 0 and c2.signal_length == 0


def test_signal_shorter_than_one_window(power_tables):
    n = power_tables.config.n
    sig = make_signal("load_power", n // 4, seed=3)
    c, rec = _roundtrip_everywhere(sig, power_tables)
    assert c.num_windows == 1  # zero-padded to one window
    assert c.num_symbols == power_tables.config.e
    assert rec.shape == sig.shape


def _single_symbol_tables(n=8, e=8, l_max=8):
    """A Huffman book whose alphabet is ONLY the zero bin: every codeword is
    the single 1-bit code, so a zero signal packs 64 symbols per word."""
    hist = np.zeros(256, dtype=np.int64)
    hist[128] = 1000
    book = build_codebook(hist, l_max=l_max)
    assert book.num_active == 1 and int(book.lengths[128]) == 1
    rng = np.random.default_rng(0)
    quant = build_quant_table(
        rng.standard_normal((64, e)), b1=2, b2=e, mu=50.0, alpha1=0.004,
        percentile=99.9,
    )
    cfg = CodecConfig(n=n, e=e, b1=2, b2=e, l_max=l_max)
    return DomainTables(config=cfg, quant=quant, book=book, domain_id=0)


def test_single_symbol_alphabet():
    tables = _single_symbol_tables()
    sig = np.zeros(100, np.float32)  # quantizes to all-128
    num_symbols = -(-100 // 8) * 8  # 13 windows * e=8
    c, rec = _roundtrip_everywhere(
        sig, tables, expect_words=-(-num_symbols // 64)
    )
    assert c.num_symbols == num_symbols
    assert int(c.symlen[0]) == 64  # 1-bit codes: 64 symbols per full word
    np.testing.assert_allclose(rec, sig, atol=1e-6)
