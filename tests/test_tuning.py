"""The autotuning layer: the persisted TuningCache (hit/reject/concurrency
contracts), the tune() sweep, the BucketPolicy ladders and the cost model
the scheduler's shape decisions ride on.

Byte-identity of tuned kernels and the policy/compile-count contracts of
the live engines are pinned in tests/test_engine.py; this file covers the
tuning package's own units.
"""
import json
import os
import threading

import pytest

from repro.tuning.autotune import (
    CACHE_VERSION,
    TuningCache,
    decode_block_candidates,
    encode_block_candidates,
    epoch,
    set_default_cache,
    tune,
    tuned_blocks,
)
from repro.tuning.cost_model import CostModel, default_cost_model
from repro.tuning.policy import (
    BucketPolicy,
    COST_BALANCED,
    HALF_OCTAVE,
    P2,
    POLICY_NAMES,
    cost_balanced_policy,
)


# ---------------------------------------------------------------------------
# TuningCache: store/lookup, persistence, rejection of bad state.
# ---------------------------------------------------------------------------
def test_cache_roundtrip_and_persistence(tmp_path):
    cache = TuningCache(str(tmp_path))
    assert cache.lookup("decode", "cpu", (32, 6), (1024, 256)) is None
    cache.store(
        "decode", "cpu", (32, 6), (1024, 256),
        {"block_words": 512, "block_windows": 128},
    )
    assert cache.lookup("decode", "cpu", (32, 6), (1024, 256)) == {
        "block_words": 512, "block_windows": 128
    }
    # a different shape is a different entry
    assert cache.lookup("decode", "cpu", (32, 6), (2048, 256)) is None

    # a fresh instance reads the persisted file
    again = TuningCache(str(tmp_path))
    assert len(again) == 1
    assert again.lookup("decode", "cpu", (32, 6), (1024, 256)) == {
        "block_words": 512, "block_windows": 128
    }
    with open(cache.path) as f:
        data = json.load(f)
    assert data["version"] == CACHE_VERSION


def test_cache_memory_only_without_directory(monkeypatch):
    monkeypatch.delenv("FPTC_TUNING_CACHE", raising=False)
    cache = TuningCache()
    assert cache.path is None
    cache.store("encode", "cpu", (32, 6, 64), (8, 1024), {"block_rows": 4})
    assert cache.lookup("encode", "cpu", (32, 6, 64), (8, 1024)) == {
        "block_rows": 4
    }


def test_corrupt_cache_file_rejected_not_trusted(tmp_path):
    path = tmp_path / "fptc_tuning.json"
    path.write_text("{ not json !!!")
    cache = TuningCache(str(tmp_path))
    assert cache.lookup("decode", "cpu", (32,), (64,)) is None  # no raise
    # the cache stays writable and overwrites the corrupt file
    cache.store("decode", "cpu", (32,), (64,), {"block_words": 64})
    again = TuningCache(str(tmp_path))
    assert again.lookup("decode", "cpu", (32,), (64,)) == {"block_words": 64}


def test_stale_schema_version_rejected_wholesale(tmp_path):
    path = tmp_path / "fptc_tuning.json"
    path.write_text(json.dumps({
        "version": CACHE_VERSION + 999,
        "entries": {
            "decode|cpu|plan(32)|shape(64)": {"blocks": {"block_words": 64}}
        },
    }))
    cache = TuningCache(str(tmp_path))
    assert len(cache) == 0
    assert cache.lookup("decode", "cpu", (32,), (64,)) is None


def test_invalid_entries_dropped_and_retuned(tmp_path):
    path = tmp_path / "fptc_tuning.json"
    path.write_text(json.dumps({
        "version": CACHE_VERSION,
        "entries": {
            # block size 0, a string block, a bool, and a missing map
            "decode|cpu|plan(1)|shape(1)": {"blocks": {"block_words": 0}},
            "decode|cpu|plan(2)|shape(2)": {"blocks": {"block_words": "x"}},
            "decode|cpu|plan(3)|shape(3)": {"blocks": {"block_words": True}},
            "decode|cpu|plan(4)|shape(4)": {},
            "decode|cpu|plan(5)|shape(5)": {"blocks": {"block_words": 32}},
        },
    }))
    cache = TuningCache(str(tmp_path))
    assert len(cache) == 1  # only the valid entry survives the load
    for plan in (1, 2, 3, 4):
        assert cache.lookup("decode", "cpu", (plan,), (plan,)) is None
    assert cache.lookup("decode", "cpu", (5,), (5,)) == {"block_words": 32}


def test_store_refuses_invalid_blocks(tmp_path):
    cache = TuningCache(str(tmp_path))
    for bad in ({}, {"block_words": 0}, {"block_words": "big"}, "nope"):
        with pytest.raises((ValueError, TypeError)):
            cache.store("decode", "cpu", (1,), (1,), bad)
    assert len(cache) == 0


def test_store_bumps_epoch(tmp_path):
    cache = TuningCache(str(tmp_path))
    e0 = epoch()
    cache.store("decode", "cpu", (1,), (1,), {"block_words": 8})
    assert epoch() > e0


def test_concurrent_readers_and_writers_safe(tmp_path):
    """The PlanCache discipline: N reader threads race a writer through
    lookup/store with file IO underneath — no exceptions, and every
    observed value is a valid stored entry."""
    cache = TuningCache(str(tmp_path))
    cache.store("decode", "cpu", (0,), (0,), {"block_words": 1})
    errors = []
    seen = set()
    stop = threading.Event()

    def reader():
        try:
            while not stop.is_set():
                got = cache.lookup("decode", "cpu", (0,), (0,))
                if got is not None:
                    seen.add(got["block_words"])
        except Exception as exc:  # pragma: no cover - the failure signal
            errors.append(exc)

    def writer():
        try:
            for i in range(1, 50):
                cache.store(
                    "decode", "cpu", (0,), (0,), {"block_words": i}
                )
        except Exception as exc:  # pragma: no cover
            errors.append(exc)

    readers = [threading.Thread(target=reader) for _ in range(4)]
    w = threading.Thread(target=writer)
    for t in readers:
        t.start()
    w.start()
    w.join()
    stop.set()
    for t in readers:
        t.join()
    assert not errors
    assert seen <= set(range(1, 50)) | {1}
    # the persisted file is whole and valid after the race (atomic replace)
    again = TuningCache(str(tmp_path))
    assert again.lookup("decode", "cpu", (0,), (0,)) == {"block_words": 49}


# ---------------------------------------------------------------------------
# tune(): the sweep contract.
# ---------------------------------------------------------------------------
def test_tune_hit_returns_without_running(tmp_path):
    cache = TuningCache(str(tmp_path))
    cache.store(
        "decode", "cpu", (32,), (64, 64), {"block_words": 512},
    )
    calls = []
    got = tune(
        "decode", (32,), (64, 64),
        runner=lambda blocks: calls.append(blocks),
        candidates=[{"block_words": 1}, {"block_words": 2}],
        cache=cache, backend="cpu",
    )
    assert got == {"block_words": 512}
    assert calls == []  # the hit path never executed a candidate


def test_tune_force_retunes_and_stores(tmp_path):
    cache = TuningCache(str(tmp_path))
    cache.store("decode", "cpu", (32,), (64, 64), {"block_words": 512})
    calls = []
    cands = [{"block_words": 1}, {"block_words": 2}]
    got = tune(
        "decode", (32,), (64, 64),
        runner=calls.append, candidates=cands,
        cache=cache, backend="cpu", force=True, trials=1, warmup=0,
    )
    assert got in cands
    assert calls  # the sweep actually ran
    assert cache.lookup("decode", "cpu", (32,), (64, 64)) == got


def test_tune_rank_and_top_k_prune_the_sweep(tmp_path):
    cache = TuningCache(str(tmp_path))
    cands = [{"block_words": w} for w in (1, 2, 4, 8)]
    calls = []
    got = tune(
        "decode", (33,), (64, 64),
        runner=calls.append, candidates=cands,
        cache=cache, backend="cpu", trials=1, warmup=0,
        rank=lambda b: -b["block_words"],  # model says: biggest first
        top_k=1,
    )
    assert got == {"block_words": 8}
    assert calls == [{"block_words": 8}]  # pruned to the model's pick


def test_tune_requires_candidates(tmp_path):
    cache = TuningCache(str(tmp_path))
    with pytest.raises(ValueError, match="candidate"):
        tune("decode", (1,), (1,), lambda b: None, [], cache=cache,
             backend="cpu")


def test_tuned_blocks_consults_pinned_default_cache(tmp_path):
    import jax

    backend = jax.default_backend()
    cache = TuningCache(str(tmp_path))
    set_default_cache(cache)
    try:
        assert tuned_blocks("decode", (32, 6), (128, 64)) == {}
        cache.store(
            "decode", backend, (32, 6), (128, 64), {"block_words": 64}
        )
        assert tuned_blocks("decode", (32, 6), (128, 64)) == {
            "block_words": 64
        }
    finally:
        set_default_cache(None)


def test_block_candidates_clip_and_dedupe():
    for c in decode_block_candidates(100, 50):
        assert c["block_words"] <= 100 and c["block_windows"] <= 50
    small = decode_block_candidates(1, 1)
    assert small == [{"block_words": 1, "block_windows": 1}]
    assert encode_block_candidates(3) == [
        {"block_rows": 1}, {"block_rows": 2}, {"block_rows": 3}
    ]


# ---------------------------------------------------------------------------
# BucketPolicy ladders.
# ---------------------------------------------------------------------------
def test_policy_round_contracts():
    for pol in (P2, HALF_OCTAVE, COST_BALANCED):
        prev = 0
        for x in (1, 2, 3, 5, 7, 12, 100, 1000, 4097):
            r = pol.round(x)
            assert r >= x  # never below the input
            assert pol.round(r) == r  # idempotent on edges
            assert r >= prev  # monotone
            prev = r
    # p2 parity with the engine's historical rounding
    from repro.serving.engine import p2

    for x in (1, 2, 3, 5, 100, 1000, 4097):
        assert P2.round(x) == p2(x)
    assert HALF_OCTAVE.round(5) == 6
    assert HALF_OCTAVE.round(100) == 128
    assert COST_BALANCED.round(5) == 5


def test_policy_variant_bound_is_density_times_octaves():
    hi = 1 << 16
    p2_variants = P2.max_variants(1, hi)
    assert p2_variants <= 17
    assert HALF_OCTAVE.max_variants(1, hi) <= 2 * p2_variants
    assert COST_BALANCED.max_variants(1, hi) <= (
        len(COST_BALANCED.multipliers) * p2_variants
    )


def test_policy_resolution_and_env(monkeypatch):
    assert BucketPolicy.of(P2) is P2
    assert BucketPolicy.of("half_octave") is HALF_OCTAVE  # normalized
    monkeypatch.setenv("FPTC_BUCKET_POLICY", "cost-balanced")
    assert BucketPolicy.of(None) is COST_BALANCED
    monkeypatch.delenv("FPTC_BUCKET_POLICY")
    assert BucketPolicy.of(None) is P2
    with pytest.raises(ValueError, match="unknown bucket policy"):
        BucketPolicy.of("bogus")


def test_policy_validates_multipliers():
    with pytest.raises(ValueError):
        BucketPolicy("empty", ())
    with pytest.raises(ValueError):
        BucketPolicy("bad", (2.0,))
    with pytest.raises(ValueError):
        BucketPolicy("bad", (0.5,))


def test_cost_balanced_ladder_from_model():
    pol = cost_balanced_policy()
    d = len(pol.multipliers)
    assert 1 <= d <= 4
    assert pol.multipliers[0] == 1.0
    assert all(
        pol.multipliers[i] < pol.multipliers[i + 1] for i in range(d - 1)
    )
    assert POLICY_NAMES == ("p2", "half-octave", "cost-balanced")


# ---------------------------------------------------------------------------
# Cost model.
# ---------------------------------------------------------------------------
def test_cost_model_monotone_in_shape():
    cm = CostModel(backend="cpu")
    base = cm.decode_bucket_cost(1024, 256, e=6, n=32)
    assert cm.decode_bucket_cost(2048, 256, e=6, n=32) > base
    assert cm.decode_bucket_cost(1024, 512, e=6, n=32) > base
    enc = cm.encode_bucket_cost(8, 128, e=6, n=32)
    assert cm.encode_bucket_cost(16, 128, e=6, n=32) > enc
    assert cm.signal_decode_cost(100, 50, e=6, n=32) > 0
    assert cm.signal_encode_cost(50, e=6, n=32) > 0


def test_cost_model_seed_rescales():
    cm = CostModel(backend="cpu")
    raw = cm.signal_decode_cost(100, 50, e=6, n=32)
    cm.seed(
        "decode",
        2.0 * cm.decode_flops(100, 50, e=6, n=32),
        cm.decode_bytes(100, 50, e=6, n=32),
        words=100, windows=50, e=6, n=32,
    )
    assert cm.signal_decode_cost(100, 50, e=6, n=32) == pytest.approx(
        2.0 * raw
    )


def test_cost_model_observe_calibrates():
    cm = CostModel(backend="cpu")
    t = cm.decode_bucket_cost(1024, 256, e=6, n=32)
    cm.observe("decode", predicted_s=1.0, measured_s=3.0)
    assert cm.calibration("decode") == pytest.approx(3.0)
    assert cm.decode_bucket_cost(1024, 256, e=6, n=32) == pytest.approx(
        3.0 * t
    )
    cm.observe("decode", predicted_s=0.0, measured_s=1.0)  # ignored
    assert cm.calibration("decode") == pytest.approx(3.0)


def test_edges_per_octave_bounded():
    for backend in ("cpu", "gpu", "tpu"):
        d = CostModel(backend=backend).edges_per_octave()
        assert 1 <= d <= 4
    assert default_cost_model() is default_cost_model()
