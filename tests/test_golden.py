"""Golden bit-exactness fixtures: frozen v1 and v2 container blobs, one
per domain (tests/golden/, regenerated only via tests/golden/regen.py).

A tripwire for the container format and the chunked packer: today's
encoder must reproduce the v2 bytes EXACTLY, and both container versions
must keep reading and decoding identically.  Any diff here means the
on-wire format changed — which is either an intentional version bump
(regen the fixtures, document the bump) or a silent-corruption regression.
"""
import os

import numpy as np
import pytest

from _synth import (
    GOLDEN_DOMAINS,
    container_v1_bytes,
    golden_signal,
    golden_tables,
)
from repro.core import decode, decode_device, encode
from repro.core.container import Container
from repro.serving import BatchDecoder, BatchEncoder

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _blob(name):
    with open(os.path.join(GOLDEN_DIR, name), "rb") as f:
        return f.read()


@pytest.mark.parametrize("domain_key,dom_id", GOLDEN_DOMAINS)
def test_encoder_reproduces_v2_bytes(domain_key, dom_id):
    """The host encoder — and the exact-mode batch engine — must emit the
    frozen v2 blob byte for byte."""
    tables = golden_tables(domain_key, dom_id)
    syms, sig = golden_signal(tables)
    container = encode(sig, tables)
    assert container.to_bytes() == _blob(f"{domain_key}_v2.fptc")
    batch = BatchEncoder(chunk_size=None).encode([sig], tables).to_host()[0]
    assert batch.to_bytes() == _blob(f"{domain_key}_v2.fptc")


@pytest.mark.parametrize("domain_key,dom_id", GOLDEN_DOMAINS)
def test_v1_construction_matches_frozen(domain_key, dom_id):
    """The v1 writer used for the fixtures is itself frozen: a drifting
    legacy serializer would quietly invalidate the compatibility test."""
    tables = golden_tables(domain_key, dom_id)
    _, sig = golden_signal(tables)
    container = encode(sig, tables)
    assert container_v1_bytes(container) == _blob(f"{domain_key}_v1.fptc")


@pytest.mark.parametrize("domain_key,dom_id", GOLDEN_DOMAINS)
def test_both_versions_read_and_decode(domain_key, dom_id):
    """from_bytes accepts v1 and v2; every decoder (host, device
    batch-of-one, batch engine) reconstructs the same samples from both."""
    tables = golden_tables(domain_key, dom_id)
    c_v1 = Container.from_bytes(_blob(f"{domain_key}_v1.fptc"))
    c_v2 = Container.from_bytes(_blob(f"{domain_key}_v2.fptc"))
    np.testing.assert_array_equal(c_v1.words, c_v2.words)
    np.testing.assert_array_equal(c_v1.symlen, c_v2.symlen)
    assert c_v1.plan_key == c_v2.plan_key

    ref = decode(c_v2, tables)
    np.testing.assert_array_equal(decode(c_v1, tables), ref)
    np.testing.assert_allclose(decode_device(c_v2, tables), ref, atol=1e-4)
    outs = BatchDecoder().decode([c_v1, c_v2], tables).to_host()
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_allclose(outs[0], ref, atol=1e-4)


@pytest.mark.parametrize("domain_key,dom_id", GOLDEN_DOMAINS)
def test_golden_symbols_roundtrip(domain_key, dom_id):
    """The inverse construction is exact: the frozen stream decodes to the
    drawn symbols, so any future byte diff is a REAL encoding change, not
    fixture noise."""
    from repro.core.symlen import PackedStream, unpack_symlen_np

    tables = golden_tables(domain_key, dom_id)
    syms, _ = golden_signal(tables)
    c = Container.from_bytes(_blob(f"{domain_key}_v2.fptc"))
    back = unpack_symlen_np(
        PackedStream(
            words=c.words, symlen=c.symlen.astype(np.int32),
            num_symbols=c.num_symbols,
        ),
        tables.book,
    )
    np.testing.assert_array_equal(back, syms.ravel())


@pytest.mark.parametrize("domain_key,dom_id", GOLDEN_DOMAINS)
def test_golden_kernel_paths_byte_identical(domain_key, dom_id):
    """Acceptance (megakernel PR): every golden blob decodes and re-encodes
    byte-identically with ``use_kernels=True`` (interpret mode) — the fused
    encode tile reproduces the frozen v2 bytes exactly, the megakernel
    decode matches the XLA engine decode bit for bit, and the
    decode -> re-encode loop is byte-stable across the kernel toggle."""
    tables = golden_tables(domain_key, dom_id)
    _, sig = golden_signal(tables)
    c = BatchEncoder(chunk_size=None, use_kernels=True).encode(
        [sig], tables
    ).to_host()[0]
    assert c.to_bytes() == _blob(f"{domain_key}_v2.fptc")

    blob = Container.from_bytes(_blob(f"{domain_key}_v2.fptc"))
    k = BatchDecoder(use_kernels=True).decode([blob], tables).to_host()[0]
    x = BatchDecoder(use_kernels=False).decode([blob], tables).to_host()[0]
    np.testing.assert_array_equal(k, x)

    rk = BatchEncoder(chunk_size=None, use_kernels=True).encode(
        [k], tables
    ).to_host()[0]
    rx = BatchEncoder(chunk_size=None, use_kernels=False).encode(
        [x], tables
    ).to_host()[0]
    assert rk.to_bytes() == rx.to_bytes()


@pytest.mark.parametrize("domain_key,dom_id", GOLDEN_DOMAINS)
def test_encoder_reproduces_v3_bytes(domain_key, dom_id):
    """Container-v3 tripwire: under the frozen GOLDEN_V3_CODING the host
    encoder, the exact-mode batch engine, and the fused encode megakernel
    must all emit the frozen v3 blob byte for byte."""
    tables = golden_tables(domain_key, dom_id, v3=True)
    _, sig = golden_signal(tables)
    c = encode(sig, tables)
    assert c.version == 3
    assert c.to_bytes() == _blob(f"{domain_key}_v3.fptc")
    for uk in (False, True):
        batch = BatchEncoder(chunk_size=None, use_kernels=uk).encode(
            [sig], tables
        ).to_host()[0]
        assert batch.to_bytes() == _blob(f"{domain_key}_v3.fptc"), uk


@pytest.mark.parametrize("domain_key,dom_id", GOLDEN_DOMAINS)
def test_v3_decodes_identically_to_v2(domain_key, dom_id):
    """The v3 stage is a LOSSLESS re-coding of the quantized levels: the
    frozen v3 blob must reconstruct float-for-float the same samples as
    the frozen v2 blob (same signal, same quant/book), on the host decoder
    and both engine arms."""
    t2 = golden_tables(domain_key, dom_id)
    t3 = golden_tables(domain_key, dom_id, v3=True)
    c2 = Container.from_bytes(_blob(f"{domain_key}_v2.fptc"))
    c3 = Container.from_bytes(_blob(f"{domain_key}_v3.fptc"))
    assert c3.plan_key[:4] == c2.plan_key[:4]
    assert c3.plan_key[4] != c2.plan_key[4]

    ref = decode(c2, t2)
    np.testing.assert_array_equal(decode(c3, t3), ref)
    for uk in (False, True):
        out = BatchDecoder(use_kernels=uk).decode([c3], t3).to_host()[0]
        np.testing.assert_array_equal(out, np.asarray(
            BatchDecoder(use_kernels=uk).decode([c2], t2).to_host()[0]
        ))
        np.testing.assert_allclose(out, ref, atol=1e-4)


def test_corrupt_golden_blob_rejected():
    """Bit flips in the frozen payload fail the CRC on v2, and the header
    magic check everywhere."""
    blob = bytearray(_blob("power_v2.fptc"))
    blob[60] ^= 0x40  # payload word flip
    with pytest.raises(ValueError, match="CRC"):
        Container.from_bytes(bytes(blob))
    blob = bytearray(_blob("power_v2.fptc"))
    blob[0] ^= 0xFF
    with pytest.raises(ValueError, match="magic"):
        Container.from_bytes(bytes(blob))
