import os
import sys

# Tests run single-device CPU (the dry-run alone uses 512 fake devices, in
# its own process).  Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make the _hypothesis_compat shim importable regardless of rootdir layout
sys.path.insert(0, os.path.dirname(__file__))

# CI runs the property suite under `--hypothesis-profile=ci`: enough
# examples to exercise the strategies, bounded so the matrix leg stays
# well under its time budget (each example may trigger fresh XLA bucket
# compilations).
try:
    import hypothesis

    hypothesis.settings.register_profile(
        "ci", max_examples=15, deadline=None
    )
except ImportError:  # pragma: no cover - shim path (see _hypothesis_compat)
    pass
