import os
import sys

# Tests run single-device CPU (the dry-run alone uses 512 fake devices, in
# its own process).  Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make the _hypothesis_compat shim importable regardless of rootdir layout
sys.path.insert(0, os.path.dirname(__file__))
