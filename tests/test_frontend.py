"""Serving front-end (tentpole coverage): deadline micro-batching over
the pipelined engines.

The contract under test: the front-end changes *when* requests dispatch
(policy-edge fill vs deadline slack), *whether* they are admitted
(bounded queues shed with typed errors, never silently), and *nothing
else* — every admitted request's response is byte-identical to the
offline engine path on the same input, for decode, encode and transcode
alike, in any interleaving, on one device or sharded across several (the
CI 4-fake-device leg runs this file too).  Plus the cache layers the
front-end leans on: concurrent same-key warming of ``PlanCache`` and
``tune()`` must coalesce to one build/sweep.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import DOMAIN_DEFAULTS, calibrate
from repro.data import make_signal
from repro.serving import BatchDecoder, BatchEncoder, Transcoder
from repro.serving._plans import PlanCache
from repro.serving.frontend import (
    DeadlineExpiredError,
    FrontendClosedError,
    FrontendConfig,
    QueueFullError,
    ServingFrontend,
    policy_fill_target,
)
from repro.serving.traffic import TrafficConfig, generate, replay
from repro.tuning.autotune import TuningCache, tune
from repro.tuning.policy import BucketPolicy


@pytest.fixture(scope="module")
def tables():
    power = calibrate(
        make_signal("load_power", 65536, seed=7),
        DOMAIN_DEFAULTS["power"],
        domain_id=0,
    )
    meteo = calibrate(
        make_signal("temperature", 65536, seed=8),
        DOMAIN_DEFAULTS["meteorological"],
        domain_id=1,
    )
    return {0: power, 1: meteo}


@pytest.fixture(scope="module")
def offline(tables):
    """Offline engines + reference payloads: the byte-identity baseline."""
    enc = BatchEncoder(pipeline=False, devices=None)
    dec = BatchDecoder(pipeline=False, devices=None)
    tr = Transcoder(decoder=dec, encoder=enc)
    n0 = tables[0].config.n
    signals = [
        make_signal("load_power", nw * n0, seed=40 + i)
        for i, nw in enumerate([2, 5, 3, 8, 1, 4])
    ]
    containers = enc.encode_to_host(signals, tables[0])
    return {
        "enc": enc, "dec": dec, "tr": tr,
        "signals": signals, "containers": containers,
        "decoded": dec.decode_to_host(containers, tables[0]),
        "transcoded": tr.transcode_to_host(containers, tables[0], tables[1]),
    }


# ---------------------------------------------------------------------------
# Admission edges: typed rejections, never silent drops.
# ---------------------------------------------------------------------------
def test_expired_deadline_rejected_at_admission(tables, offline):
    with ServingFrontend(tables) as fe:
        with pytest.raises(DeadlineExpiredError):
            fe.submit_decode(offline["containers"][0], deadline_ms=0.0)
        with pytest.raises(DeadlineExpiredError):
            fe.submit_decode(offline["containers"][0], deadline_ms=-5.0)
        st = fe.stats_snapshot()
        assert st.rejected_expired == 2
        assert st.admitted == 0 and not fe.queue_depths()


def test_load_shed_error_surfaces_queue_depth(tables, offline):
    # deadlines far out and fill target above the bound: the dispatcher
    # leaves the queue alone, so the third submit must shed
    cfg = FrontendConfig(
        max_batch=8, max_queue_depth=2, default_slo_ms=60_000.0
    )
    with ServingFrontend(tables, config=cfg) as fe:
        futs = [
            fe.submit_decode(c) for c in offline["containers"][:2]
        ]
        with pytest.raises(QueueFullError) as exc:
            fe.submit_decode(offline["containers"][2])
        assert exc.value.depth == 2
        assert exc.value.bound == 2
        assert exc.value.queue == ("decode", offline["containers"][2].plan_key)
        assert "2 pending" in str(exc.value)
        assert fe.stats_snapshot().shed == 1
        fe.flush()
        for f, ref in zip(futs, offline["decoded"][:2]):
            assert f.result(timeout=60).tobytes() == ref.tobytes()


def test_closed_frontend_rejects_and_nodrain_fails_pending(tables, offline):
    fe = ServingFrontend(
        tables, config=FrontendConfig(default_slo_ms=60_000.0)
    )
    fut = fe.submit_decode(offline["containers"][0])
    fe.close(drain=False)
    with pytest.raises(FrontendClosedError):
        fut.result(timeout=60)
    with pytest.raises(FrontendClosedError):
        fe.submit_decode(offline["containers"][0])
    fe.close()  # idempotent


# ---------------------------------------------------------------------------
# Dispatch triggers.
# ---------------------------------------------------------------------------
def test_single_request_flushes_on_deadline(tables, offline):
    # no fill pressure (fill target 16): the lone request must dispatch
    # off its own deadline and still complete correctly
    cfg = FrontendConfig(
        max_batch=16, default_slo_ms=150.0, flush_slack_ms=120.0
    )
    with ServingFrontend(tables, config=cfg) as fe:
        fut = fe.submit_decode(offline["containers"][0])
        out = fut.result(timeout=60)
        st = fe.stats_snapshot()
    assert out.tobytes() == offline["decoded"][0].tobytes()
    assert st.deadline_dispatches == 1 and st.batches == 1
    assert st.batch_size_sum == 1


def test_fill_dispatch_at_policy_edge(tables, offline):
    cfg = FrontendConfig(max_batch=4, default_slo_ms=60_000.0)
    with ServingFrontend(tables, config=cfg) as fe:
        assert fe.fill_target == 4  # p2 edge at max_batch
        futs = [fe.submit_decode(c) for c in offline["containers"][:4]]
        # deadlines are an hour out: only the fill edge can dispatch these
        outs = [f.result(timeout=60) for f in futs]
        st = fe.stats_snapshot()
    for out, ref in zip(outs, offline["decoded"][:4]):
        assert out.tobytes() == ref.tobytes()
    assert st.fill_dispatches >= 1
    assert st.deadline_dispatches == 0


def test_flush_and_drain_of_empty_queue_are_noops(tables):
    with ServingFrontend(tables) as fe:
        fe.flush()  # nothing queued: must not dispatch or wedge
        fe.flush()
        time.sleep(0.05)
        st = fe.stats_snapshot()
        assert st.batches == 0 and st.admitted == 0
    # context exit drained (empty) queues and joined cleanly
    st = fe.stats_snapshot()
    assert st.batches == 0 and st.completed == 0


def test_policy_fill_target_snaps_to_edges():
    p2 = BucketPolicy.of("p2")
    assert policy_fill_target(p2, 64) == 64
    assert policy_fill_target(p2, 48) == 32  # down, never up
    assert policy_fill_target(p2, 1) == 1


# ---------------------------------------------------------------------------
# Byte identity: micro-batching never changes bytes.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("devices", [None, "auto"])
def test_mixed_interleaving_byte_identity(tables, offline, devices):
    """Decode/encode/transcode interleaved through one front-end, fill
    and deadline dispatches mixed, single-device and sharded ("auto" is
    the 4-fake-device leg in CI): every response byte-identical to the
    offline engines."""
    cfg = FrontendConfig(max_batch=4, default_slo_ms=2_000.0)
    with ServingFrontend(tables, config=cfg, devices=devices) as fe:
        futs = []
        for i, c in enumerate(offline["containers"]):
            futs.append(("decode", i, fe.submit_decode(c)))
            futs.append((
                "encode", i, fe.submit_encode(offline["signals"][i], 0),
            ))
            futs.append(("transcode", i, fe.submit_transcode(c, 1)))
        fe.flush()
        results = [(k, i, f.result(timeout=120)) for k, i, f in futs]
        st = fe.stats_snapshot()
    assert st.completed == len(results) and st.failed == 0
    for kind, i, got in results:
        if kind == "decode":
            assert got.tobytes() == offline["decoded"][i].tobytes()
        elif kind == "encode":
            assert got.to_bytes() == offline["containers"][i].to_bytes()
        else:
            assert got.to_bytes() == offline["transcoded"][i].to_bytes()


def test_open_loop_replay_byte_identity(tables):
    """The synthetic traffic path end-to-end: generate a small mixed
    stream, replay it, and pin goodput accounting (all admitted requests
    complete, nothing silently vanishes)."""
    cfg = TrafficConfig(
        rate=200.0, duration_s=0.3, seed=3, fixed_windows=4,
        domains=(0, 1),
        mix={"decode": 0.5, "encode": 0.3, "transcode": 0.2},
    )
    reqs = generate(cfg, tables)
    assert reqs, "stream came out empty"
    with ServingFrontend(
        tables, config=FrontendConfig(default_slo_ms=5_000.0)
    ) as fe:
        report = replay(fe, reqs)
        st = fe.stats_snapshot()
    assert report.completed == report.submitted == len(reqs)
    assert report.shed == 0 and report.failed == 0
    assert st.completed == st.admitted == len(reqs)


# ---------------------------------------------------------------------------
# Cache layers under concurrent submitters.
# ---------------------------------------------------------------------------
def test_plan_cache_single_flight_under_contention():
    builds = []
    gate = threading.Event()

    def factory(tables, key, device):
        builds.append(key)
        gate.wait(5)  # hold every racer at the build point
        return ("plan", key)

    cache = PlanCache(factory)
    tab = object()
    results = [None] * 16
    errs = []

    def racer(i):
        try:
            results[i] = cache.get(tab, "k", None)
        except BaseException as e:  # pragma: no cover - fails the assert
            errs.append(e)

    threads = [
        threading.Thread(target=racer, args=(i,)) for i in range(16)
    ]
    for t in threads:
        t.start()
    time.sleep(0.1)  # let every racer reach get()
    gate.set()
    for t in threads:
        t.join(10)
    assert not errs
    assert len(builds) == 1, "same-key warm raced to duplicate builds"
    assert all(r == ("plan", "k") for r in results)
    assert cache.misses == 1
    # every non-leader counted exactly once: either it coalesced onto the
    # in-flight build, or it arrived after completion and plainly hit
    assert cache.coalesced + cache.hits == 15
    assert cache.coalesced >= 1


def test_plan_cache_failed_build_lets_waiters_retry():
    calls = []

    def factory(tables, key, device):
        calls.append(key)
        if len(calls) == 1:
            raise RuntimeError("leader loses")
        return "plan"

    cache = PlanCache(factory)
    tab = object()
    outcomes = []

    def racer():
        try:
            outcomes.append(cache.get(tab, "k", None))
        except RuntimeError:
            outcomes.append("raised")

    threads = [threading.Thread(target=racer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    # exactly one racer saw the leader's failure; the rest share a plan
    # built by a retrying waiter
    assert outcomes.count("raised") == 1
    assert outcomes.count("plan") == 3
    assert len(cache._building) == 0


def test_tune_coalesces_concurrent_same_key_sweeps(tmp_path):
    cache = TuningCache(directory=str(tmp_path))
    sweeps = []
    gate = threading.Event()

    def runner(blocks):
        if not sweeps:
            gate.wait(5)
        sweeps.append(blocks)

    results = []

    def racer():
        results.append(tune(
            "kind", (0, 8, 8, 8), (128,), runner,
            [{"bm": 8}, {"bm": 16}], cache=cache, trials=1, warmup=0,
        ))

    threads = [threading.Thread(target=racer) for _ in range(8)]
    for t in threads:
        t.start()
    time.sleep(0.1)
    gate.set()
    for t in threads:
        t.join(10)
    # one sweep total (2 candidates x (warmup 0 + 1 trial) runs), not 8
    assert len(sweeps) == 2, f"retrace storm: {len(sweeps)} runs"
    assert len(results) == 8
    assert all(r == results[0] for r in results)
