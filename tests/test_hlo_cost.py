"""The trip-count-aware HLO cost model vs known-FLOP programs, and its
pre-lowering twin ``analyze_jaxpr`` — the only analyzer that can see into
a ``pallas_call`` (opaque by the time it reaches HLO text)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import analyze_hlo, analyze_jaxpr


def _hlo(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_single_matmul_exact():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = analyze_hlo(_hlo(lambda a, b: a @ b, x, w))
    assert c.flops == pytest.approx(2 * 256 * 512 * 128, rel=1e-6)


def test_scan_multiplies_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(a, b):
        def body(c, _):
            return c @ b, None
        out, _ = jax.lax.scan(body, a, None, length=7)
        return out

    c = analyze_hlo(_hlo(f, x, w))
    assert c.flops == pytest.approx(7 * 2 * 128 ** 3, rel=0.01)
    assert c.num_whiles == 1
    assert c.unknown_trip_whiles == 0


def test_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a, b):
        def outer(c, _):
            def inner(d, _):
                return d @ b, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        out, _ = jax.lax.scan(outer, a, None, length=5)
        return out

    c = analyze_hlo(_hlo(f, x, w))
    assert c.flops == pytest.approx(15 * 2 * 64 ** 3, rel=0.01)


def test_batched_dot_flops():
    x = jax.ShapeDtypeStruct((8, 32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 64, 16), jnp.float32)
    c = analyze_hlo(_hlo(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), x, w))
    assert c.flops == pytest.approx(2 * 8 * 32 * 64 * 16, rel=1e-6)


def test_bytes_lower_bounded_by_io():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = analyze_hlo(_hlo(lambda a: a * 2.0 + 1.0, x))
    # one fusion: read 4MB, write 4MB
    assert c.hbm_bytes >= 2 * 1024 * 1024 * 4
    assert c.hbm_bytes <= 4 * 1024 * 1024 * 4  # no pathological double count


# ---------------------------------------------------------------------------
# analyze_jaxpr: the pre-lowering twin.
# ---------------------------------------------------------------------------
def test_jaxpr_single_matmul_exact():
    a = jnp.zeros((256, 512), jnp.float32)
    b = jnp.zeros((512, 128), jnp.float32)
    c = analyze_jaxpr(lambda x, w: x @ w, a, b)
    assert c.flops == pytest.approx(2 * 256 * 512 * 128, rel=1e-6)
    assert c.pallas_calls == 0
    # boundary bytes: at least the two operands + the output, once
    io = 4 * (256 * 512 + 512 * 128 + 256 * 128)
    assert c.hbm_bytes >= io


def test_jaxpr_scan_multiplies_trip_count():
    a = jnp.zeros((128, 128), jnp.float32)
    b = jnp.zeros((128, 128), jnp.float32)

    def f(x, w):
        def body(carry, _):
            return carry @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    c = analyze_jaxpr(f, a, b)
    assert c.flops == pytest.approx(7 * 2 * 128 ** 3, rel=0.01)
    assert c.num_whiles == 1
    assert c.unknown_trip_whiles == 0


def test_jaxpr_attributes_pallas_call_from_grid():
    """A pallas_call's cost comes from (body cost) x prod(grid) and the
    declared BlockSpec traffic — the exact model the cost-model seeding
    path relies on for the fused megakernels."""
    pl = pytest.importorskip("jax.experimental.pallas")

    def kernel(a_ref, b_ref, o_ref):
        o_ref[...] = a_ref[...] @ b_ref[...]

    def f(a, b):
        return pl.pallas_call(
            kernel,
            grid=(2,),
            in_specs=[
                pl.BlockSpec((64, 32), lambda i: (i, 0)),
                pl.BlockSpec((32, 16), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((64, 16), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((128, 16), jnp.float32),
            interpret=True,
        )(a, b)

    a = jnp.zeros((128, 32), jnp.float32)
    b = jnp.zeros((32, 16), jnp.float32)
    c = analyze_jaxpr(f, a, b)
    assert c.pallas_calls == 1
    # per grid step one 64x32 @ 32x16 matmul, two steps
    assert c.flops == pytest.approx(2 * (2 * 64 * 32 * 16), rel=1e-6)
    # block pipeline: 2 steps x (a + b + out block bytes), plus whole-jaxpr
    # I/O (a, b, out arrays once)
    blocks = 2 * 4 * (64 * 32 + 32 * 16 + 64 * 16)
    io = 4 * (128 * 32 + 32 * 16 + 128 * 16)
    assert c.hbm_bytes == pytest.approx(blocks + io, rel=1e-6)


def test_jaxpr_fused_decode_megakernel_is_one_dispatch():
    """The real consumer: the decode megakernel traces to exactly one
    pallas_call with nonzero attributed flops (the HLO parser can't see
    this — in interpret mode the kernel lowers to an unrelated while-nest).
    """
    from repro.core import DOMAIN_DEFAULTS, calibrate, codec, dct
    from repro.core.quantize import quant_grid
    from repro.kernels import ops as kops
    from repro.serving.engine import symlen_bucket

    rng = np.random.default_rng(77)
    tables = calibrate(
        rng.standard_normal(4096).astype(np.float32),
        DOMAIN_DEFAULTS["default"],
    )
    cfg = tables.config
    sig = rng.standard_normal(16 * cfg.n).astype(np.float32)
    cont = codec.encode(sig, tables)
    hi, lo = cont.words_u32()
    ms = symlen_bucket(cont.max_symlen)
    dev = tables.device_tables()
    lut, _ = quant_grid(tables.quant)
    basis = dct.idct_basis(cfg.n, cfg.e)

    def run(hi, lo, sl):
        return kops.decode_bucket_fused(
            hi, lo, sl, dev, lut, basis,
            l_max=cfg.l_max, max_symlen=ms,
            num_windows=cont.num_windows, n=cfg.n, e=cfg.e,
        )

    c = analyze_jaxpr(
        run,
        jnp.asarray(hi),
        jnp.asarray(lo),
        jnp.asarray(cont.symlen, jnp.int32),
    )
    assert c.pallas_calls == 1
    assert c.flops > 0
    assert c.hbm_bytes > 0
