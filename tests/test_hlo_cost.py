"""The trip-count-aware HLO cost model vs known-FLOP programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import analyze_hlo


def _hlo(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_single_matmul_exact():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = analyze_hlo(_hlo(lambda a, b: a @ b, x, w))
    assert c.flops == pytest.approx(2 * 256 * 512 * 128, rel=1e-6)


def test_scan_multiplies_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(a, b):
        def body(c, _):
            return c @ b, None
        out, _ = jax.lax.scan(body, a, None, length=7)
        return out

    c = analyze_hlo(_hlo(f, x, w))
    assert c.flops == pytest.approx(7 * 2 * 128 ** 3, rel=0.01)
    assert c.num_whiles == 1
    assert c.unknown_trip_whiles == 0


def test_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a, b):
        def outer(c, _):
            def inner(d, _):
                return d @ b, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        out, _ = jax.lax.scan(outer, a, None, length=5)
        return out

    c = analyze_hlo(_hlo(f, x, w))
    assert c.flops == pytest.approx(15 * 2 * 64 ** 3, rel=0.01)


def test_batched_dot_flops():
    x = jax.ShapeDtypeStruct((8, 32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 64, 16), jnp.float32)
    c = analyze_hlo(_hlo(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), x, w))
    assert c.flops == pytest.approx(2 * 8 * 32 * 64 * 16, rel=1e-6)


def test_bytes_lower_bounded_by_io():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = analyze_hlo(_hlo(lambda a: a * 2.0 + 1.0, x))
    # one fusion: read 4MB, write 4MB
    assert c.hbm_bytes >= 2 * 1024 * 1024 * 4
    assert c.hbm_bytes <= 4 * 1024 * 1024 * 4  # no pathological double count
