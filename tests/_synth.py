"""Shared synthetic codec fixtures: containers with exact word counts,
pathological codebooks, and the deterministic golden-fixture builders,
used by the batch-engine, transcode and golden tests (importable because
conftest puts tests/ on sys.path)."""
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core.calibration import DomainTables
from repro.core.config import CodecConfig
from repro.core.container import Container
from repro.core.dct import inverse_dct
from repro.core.huffman import build_codebook
from repro.core.quantize import build_quant_table, dequantize
from repro.core.symlen import pack_symlen_np


def uniform_code_container(num_words, n=8, e=8, l_max=8, seed=0, domain_id=0):
    """A synthetic container with EXACTLY ``num_words`` payload words.

    A uniform 256-symbol histogram under l_max=8 yields a canonical code
    where every codeword is 8 bits, so each 64-bit word holds exactly 8
    symbols and word count is num_symbols / 8 precisely.  With n = e = 8,
    one window is one word — letting tests hit bucket boundaries exactly.
    """
    rng = np.random.default_rng(seed)
    hist = np.full(256, 10, dtype=np.int64)
    book = build_codebook(hist, l_max=l_max)
    assert int(book.lengths.max()) == 8 and int(book.lengths.min()) == 8
    syms = rng.integers(0, 256, num_words * 8).astype(np.uint8)
    stream = pack_symlen_np(syms, book)
    assert stream.num_words == num_words
    quant = build_quant_table(
        rng.standard_normal((512, e)) * np.linspace(2.0, 0.2, e),
        b1=2, b2=e, mu=50.0, alpha1=0.004, percentile=99.9,
    )
    cfg = CodecConfig(n=n, e=e, b1=2, b2=e, l_max=l_max)
    tables = DomainTables(
        config=cfg, quant=quant, book=book, domain_id=domain_id
    )
    num_windows = num_words  # 8 symbols per window == 8 symbols per word
    container = Container(
        words=stream.words,
        symlen=stream.symlen.astype(np.uint8),
        num_symbols=stream.num_symbols,
        num_windows=num_windows,
        signal_length=num_windows * n,
        n=n, e=e, l_max=l_max, domain_id=domain_id,
    )
    return container, tables


# ---------------------------------------------------------------------------
# Golden bit-exactness fixtures (tests/golden/): deterministic construction.
#
# The frozen blobs are a regression tripwire for the container format and
# the packer: today's encoder must reproduce the v2 bytes EXACTLY on any
# platform.  That rules out dataset-calibrated tables (BLAS-dependent in
# the last ulp, which can flip a symbol at a cell boundary).  Instead the
# golden signal is *inverse-constructed*: draw target symbols, place each
# retained DCT coefficient exactly at its reconstruction point
# (dequantize), and synthesize the signal by inverse DCT.  Re-encoding
# recovers the coefficients up to ~1e-6 relative (DCT basis
# orthogonality), while every quantizer cell is wider than ~1e-4 of the
# bin scale — hundreds of times the float noise — so quantize() maps back
# to the drawn symbols bit-exactly, everywhere.
# ---------------------------------------------------------------------------
GOLDEN_DOMAINS = [
    # (domain_key in DOMAIN_DEFAULTS, domain_id used in the fixture)
    ("biomedical", 0),
    ("seismic", 1),
    ("power", 2),
    ("meteorological", 3),
    ("default", 4),
    # workload domains (PR 8): fixture ids continue the archival sequence
    # (the runtime ids live in repro.core.domains; golden_tables takes the
    # id explicitly so the blobs are insensitive to that mapping)
    ("kv", 5),
    ("train_state", 6),
]
GOLDEN_WINDOWS = 16  # windows per golden signal (tiny, checked-in blobs)

# the v3 coding every golden domain's _v3 fixture freezes: delta predictor
# on the two leading bands + zero-plane suppression (predict_bands=2 fits
# every golden config's e)
GOLDEN_V3_CODING = dict(
    predictor="delta", predict_bands=2, zero_planes=True
)


def golden_tables(domain_key, domain_id, v3=False):
    """Deterministic DomainTables for one golden domain: quant scales from
    a seeded standard-normal coefficient draw (identical bit stream on
    every platform per the numpy Generator stability guarantee), codebook
    from a seeded integer histogram (pure integer construction).

    ``v3=True`` overlays :data:`GOLDEN_V3_CODING` on the config — same
    quant/book (the coding is post-quantization), so the v3 fixture freezes
    ONLY the re-coding stage's bytes."""
    from repro.core import DOMAIN_DEFAULTS

    cfg = DOMAIN_DEFAULTS[domain_key]
    if v3:
        cfg = cfg.replace(**GOLDEN_V3_CODING)
    rng = np.random.default_rng(1000 + domain_id)
    calib = rng.standard_normal((256, cfg.e)) * np.linspace(
        4.0, 0.5, cfg.e
    )
    quant = build_quant_table(
        calib, b1=cfg.b1, b2=cfg.b2, mu=cfg.mu, alpha1=cfg.alpha1,
        percentile=cfg.a0_percentile, scale_headroom=cfg.scale_headroom,
    )
    hist = rng.integers(1, 1000, 256).astype(np.int64)
    book = build_codebook(hist, l_max=cfg.l_max)
    return DomainTables(
        config=cfg, quant=quant, book=book, domain_id=domain_id
    )


def golden_signal(tables, num_windows=GOLDEN_WINDOWS):
    """The signal whose encode is frozen: symbols drawn per (window, bin),
    zone-2 bins pinned to the zero bin (their reconstruction is 0
    regardless of level, so any other symbol could not round-trip)."""
    cfg = tables.config
    rng = np.random.default_rng(2000 + tables.domain_id)
    syms = rng.integers(0, 256, (num_windows, cfg.e)).astype(np.uint8)
    # levels 127/129 reconstruct exactly ONTO the deadzone boundary (+-d1
    # in zone 1, 0 in zone 0), where quantize() tips to the zero bin — no
    # margin, so they cannot round-trip stably; steer clear of them
    syms[syms == 127] = 126
    syms[syms == 129] = 130
    if cfg.mu >= 200:
        # at near-lossless mu (train_state: mu=255) the innermost mu-law
        # cell is narrower than the DCT round-trip noise, so the zero
        # level itself cannot round-trip stably in zone 0/1 — steer it out
        # two cells (cell widths grow away from zero)
        syms[syms == 128] = 130
    zone2 = np.asarray(tables.quant.zone) == 2
    syms[:, zone2] = 128
    coeffs = dequantize(jnp.asarray(syms), tables.quant)
    windows = np.asarray(inverse_dct(coeffs, cfg.n), dtype=np.float32)
    return syms, windows.reshape(-1)


def container_v1_bytes(container):
    """Serialize a container in the legacy v1 layout (crc over the symlen
    sidecar only) — the format PR 2's v2 checksum superseded but both
    decoders must keep reading."""
    from repro.core.container import _HDR, _MAGIC

    words_b = container.words.astype("<u8").tobytes()
    symlen_b = container.symlen.astype(np.uint8).tobytes()
    hdr = _HDR.pack(
        _MAGIC,
        1,
        container.l_max,
        container.n,
        container.e,
        container.num_words,
        container.num_symbols,
        container.num_windows,
        container.signal_length,
        container.max_symlen,
        container.domain_id,
        zlib.crc32(symlen_b),
    )
    return hdr + words_b + symlen_b


def gap_tables(n=8, e=8, l_max=8, domain_id=0):
    """Tables whose Huffman book covers ONLY the zero bin (128): any signal
    that quantizes off-zero hits a histogram gap."""
    hist = np.zeros(256, dtype=np.int64)
    hist[128] = 100
    book = build_codebook(hist, l_max=l_max)
    rng = np.random.default_rng(0)
    quant = build_quant_table(
        rng.standard_normal((64, e)), b1=2, b2=e, mu=50.0, alpha1=0.004,
        percentile=99.9,
    )
    cfg = CodecConfig(n=n, e=e, b1=2, b2=e, l_max=l_max)
    return DomainTables(
        config=cfg, quant=quant, book=book, domain_id=domain_id
    )


def single_symbol_tables(n=8, e=8, l_max=8, domain_id=0):
    """A Huffman book whose alphabet is ONLY the zero bin: every codeword is
    the single 1-bit code, so a zero signal packs 64 symbols per word."""
    hist = np.zeros(256, dtype=np.int64)
    hist[128] = 1000
    book = build_codebook(hist, l_max=l_max)
    assert book.num_active == 1 and int(book.lengths[128]) == 1
    rng = np.random.default_rng(0)
    quant = build_quant_table(
        rng.standard_normal((64, e)), b1=2, b2=e, mu=50.0, alpha1=0.004,
        percentile=99.9,
    )
    cfg = CodecConfig(n=n, e=e, b1=2, b2=e, l_max=l_max)
    return DomainTables(
        config=cfg, quant=quant, book=book, domain_id=domain_id
    )
