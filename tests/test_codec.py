"""End-to-end codec behaviour: roundtrips, containers, domain thresholds."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    DOMAIN_DEFAULTS,
    CodecConfig,
    Container,
    calibrate,
    decode,
    decode_device,
    encode,
    encode_device,
)
from repro.core.codec import roundtrip_metrics
from repro.core.metrics import compression_ratio, prd
from repro.data import make_signal
from repro.data.signals import DATASETS, domain_of


@pytest.fixture(scope="module")
def power_tables():
    return calibrate(
        make_signal("load_power", 65536, seed=99), DOMAIN_DEFAULTS["power"]
    )


def test_host_device_encode_bit_identical(power_tables):
    sig = make_signal("load_power", 16384, seed=1)
    c_host = encode(sig, power_tables)
    c_dev = encode_device(sig, power_tables)
    np.testing.assert_array_equal(c_host.words, c_dev.words)
    np.testing.assert_array_equal(c_host.symlen, c_dev.symlen)


def test_host_device_decode_agree(power_tables):
    sig = make_signal("load_power", 16384, seed=2)
    c = encode(sig, power_tables)
    r1 = decode(c, power_tables)
    r2 = decode_device(c, power_tables)
    np.testing.assert_allclose(r1, r2, atol=1e-4)


def test_container_serialization_roundtrip(power_tables):
    sig = make_signal("load_power", 4096, seed=3)
    c = encode(sig, power_tables)
    c2 = Container.from_bytes(c.to_bytes())
    np.testing.assert_array_equal(c.words, c2.words)
    np.testing.assert_array_equal(c.symlen, c2.symlen)
    assert c2.num_symbols == c.num_symbols
    assert c2.signal_length == c.signal_length


def test_container_detects_corruption(power_tables):
    sig = make_signal("load_power", 4096, seed=4)
    blob = bytearray(encode(sig, power_tables).to_bytes())
    blob[-1] ^= 0xFF  # flip a symlen byte
    with pytest.raises(ValueError):
        Container.from_bytes(bytes(blob))


def test_container_detects_payload_word_corruption(power_tables):
    """Satellite bugfix: v1's crc covered only the symlen sidecar, so bit
    flips in the words payload decoded silently to garbage.  v2's crc covers
    words + sidecar."""
    from repro.core.container import HEADER_BYTES

    sig = make_signal("load_power", 4096, seed=4)
    blob = bytearray(encode(sig, power_tables).to_bytes())
    blob[HEADER_BYTES + 3] ^= 0x40  # flip a bit inside the first word
    with pytest.raises(ValueError, match="CRC"):
        Container.from_bytes(bytes(blob))


def test_container_reads_v1_blobs(power_tables):
    """Version-1 containers (sidecar-only crc) must stay readable."""
    import struct
    import zlib

    from repro.core.container import _HDR, HEADER_BYTES

    c = encode(make_signal("load_power", 4096, seed=5), power_tables)
    blob = bytearray(c.to_bytes())
    (magic, version, *rest) = _HDR.unpack_from(bytes(blob), 0)
    assert version == 2
    # rewrite the header as v1 with the legacy sidecar-only checksum
    v1_crc = zlib.crc32(c.symlen.astype(np.uint8).tobytes())
    blob[:HEADER_BYTES] = _HDR.pack(magic, 1, *rest[:-1], v1_crc)
    c1 = Container.from_bytes(bytes(blob))
    np.testing.assert_array_equal(c1.words, c.words)
    np.testing.assert_array_equal(c1.symlen, c.symlen)
    # unknown versions still fail loudly, naming the byte and the
    # supported set (v3 is a real version now — probe with 4)
    blob[:HEADER_BYTES] = _HDR.pack(magic, 4, *rest[:-1], v1_crc)
    with pytest.raises(ValueError, match=r"version 4.*\(1, 2, 3\)"):
        Container.from_bytes(bytes(blob))


def test_decode_rejects_mismatched_tables(power_tables):
    """Satellite bugfix: decoding a container with tables built for a
    different config used to produce silent garbage (or an opaque shape
    error).  Host, device, and batched decode all fail loudly now."""
    from repro.core import decode_device
    from repro.serving import BatchDecoder

    sig = make_signal("load_power", 4096, seed=6)
    c = encode(sig, power_tables)
    other_cfg = CodecConfig(n=32, e=4, b1=2, b2=4)
    other = calibrate(make_signal("load_power", 32768, seed=7), other_cfg)
    with pytest.raises(ValueError, match="plan_key"):
        decode(c, other)
    with pytest.raises(ValueError, match="plan_key"):
        decode_device(c, other)
    with pytest.raises(ValueError, match="plan_key"):
        BatchDecoder().decode([c], other)
    # coincident (n, e, l_max) but a different domain: different book/quant,
    # so this must ALSO fail loudly instead of decoding to garbage
    relabeled = calibrate(
        make_signal("temperature", 32768, seed=8),
        power_tables.config,
        domain_id=7,
    )
    with pytest.raises(ValueError, match="domain_id"):
        decode(c, relabeled)
    with pytest.raises(ValueError, match="domain_id"):
        BatchDecoder().decode([c], relabeled)


@pytest.mark.parametrize("dataset", sorted(DATASETS))
def test_domain_prd_thresholds(dataset):
    """Every dataset reconstructs within its domain's PRD threshold
    (paper §6.1.3) at the domain default operating point."""
    dom = domain_of(dataset)
    thresholds = {
        "biomedical": 5.0,
        "seismic": 2.0,
        "power": 5.0,
        "meteorological": 5.0,
    }
    calib = np.concatenate(
        [make_signal(dataset, 65536, seed=90 + i) for i in range(4)]
    )
    tables = calibrate(calib, DOMAIN_DEFAULTS[dom])
    cr, p = roundtrip_metrics(make_signal(dataset, 32768, seed=1), tables)
    assert p < thresholds[dom], f"{dataset}: PRD {p:.2f}% over threshold"
    assert cr > 2.0, f"{dataset}: CR {cr:.2f} too low to be useful"


def test_cr_improves_with_truncation():
    sig = make_signal("temperature", 32768, seed=5)
    calib = make_signal("temperature", 65536, seed=6)
    crs = []
    for e in (16, 8, 4):
        cfg = CodecConfig(n=32, e=e, b1=2, b2=e)
        cr, _ = roundtrip_metrics(sig, calibrate(calib, cfg))
        crs.append(cr)
    assert crs[0] < crs[1] < crs[2]


def test_metrics_definitions():
    x = np.array([3.0, 4.0])
    assert prd(x, x) == 0.0
    assert prd(x, np.zeros(2)) == pytest.approx(100.0)
    assert compression_ratio(1000, 100) == 10.0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_property_decode_is_deterministic(seed):
    sig = make_signal("eeg_mat", 8192, seed=seed)
    tables = calibrate(
        make_signal("eeg_mat", 32768, seed=123), DOMAIN_DEFAULTS["biomedical"]
    )
    c = encode(sig, tables)
    r1 = decode(c, tables)
    r2 = decode(c, tables)
    np.testing.assert_array_equal(r1, r2)
    assert c.compression_ratio > 1.0
