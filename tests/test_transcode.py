"""Transcoder conformance: device-resident decode->re-encode must be
byte-identical to the host round trip (decode to host, re-encode), with
zero device->host syncs in between — over every (domain, config) pair in
the test tables, mixed-domain batches straddling bucket boundaries, and
the degenerate inputs of test_degenerate.py.  (Tentpole coverage for the
transcode pipeline.)"""
import jax
import numpy as np
import pytest

from _synth import gap_tables, single_symbol_tables, uniform_code_container
from repro.core import (
    DOMAIN_DEFAULTS,
    calibrate,
    decode,
    encode,
    transcode as codec_transcode,
)
from repro.serving import (
    BatchDecoder,
    BatchEncoder,
    Transcoder,
)
from repro.serving.batch_encode import DEFAULT_CHUNK_SIZE

# (domain_id, dataset, DOMAIN_DEFAULTS key): one calibrated table set per
# (domain, config) pair under test — distinct n/e/l_max operating points
_DOMAINS = [
    (0, "load_power", "power"),
    (1, "temperature", "meteorological"),
    (2, "mitbih", "biomedical"),
]
_LENGTHS = [2048, 1533, 700]  # mixed window buckets, one sub-window tail


@pytest.fixture(scope="module")
def domain_tables():
    from repro.data import make_signal

    out = {}
    for dom_id, dataset, key in _DOMAINS:
        out[dom_id] = calibrate(
            make_signal(dataset, 65536, seed=7 + dom_id),
            DOMAIN_DEFAULTS[key],
            domain_id=dom_id,
        )
    return out


def _src_containers(dom_id, tables):
    from repro.data import make_signal

    dataset = next(ds for d, ds, _ in _DOMAINS if d == dom_id)
    sigs = [
        make_signal(dataset, n, seed=100 * dom_id + i)
        for i, n in enumerate(_LENGTHS)
    ]
    return [encode(s, tables[dom_id]) for s in sigs]


def _reference(containers, src_tables, dst_tables, *, dst_domain_ids=None,
               chunk_size=DEFAULT_CHUNK_SIZE, use_kernels=False):
    """The host round trip the Transcoder must reproduce byte for byte:
    batch-decode to host signals, then batch re-encode them (same packing
    chunk size as the transcoder's encoder — Transcoder() defaults to
    DEFAULT_CHUNK_SIZE)."""
    sigs = BatchDecoder(use_kernels=use_kernels).decode(
        containers, src_tables
    ).to_host()
    return BatchEncoder(chunk_size=chunk_size).encode(
        sigs, dst_tables, domain_ids=dst_domain_ids
    ).to_host()


def _assert_identical(got, ref):
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a.words, b.words)
        np.testing.assert_array_equal(a.symlen, b.symlen)
        assert a.num_symbols == b.num_symbols
        assert a.num_windows == b.num_windows
        assert a.signal_length == b.signal_length
        assert a.plan_key == b.plan_key
        assert a.to_bytes() == b.to_bytes()


@pytest.mark.parametrize("src_dom", [d for d, _, _ in _DOMAINS])
@pytest.mark.parametrize("dst_dom", [d for d, _, _ in _DOMAINS])
def test_conformance_every_domain_pair(domain_tables, src_dom, dst_dom):
    """Acceptance: for every (domain, config) source/target pairing,
    Transcoder output containers are byte-identical to the host round
    trip, with zero device->host transfers between decode and re-encode."""
    containers = _src_containers(src_dom, domain_tables)
    src = domain_tables[src_dom]
    dst = domain_tables[dst_dom]
    ref = _reference(containers, src, dst)

    tc = Transcoder()
    with jax.transfer_guard_device_to_host("disallow"):
        batch = tc.transcode(containers, src, dst)
    _assert_identical(batch.to_host(), ref)


@pytest.mark.parametrize("chunk_size", [None, 64])
def test_conformance_explicit_chunk_sizes(domain_tables, chunk_size):
    """Exact mode (None) and a chunk size small enough to force multi-chunk
    re-packing both stay byte-identical to the equally-configured round
    trip."""
    containers = _src_containers(0, domain_tables)
    src, dst = domain_tables[0], domain_tables[2]
    ref = _reference(containers, src, dst, chunk_size=chunk_size)
    got = Transcoder(chunk_size=chunk_size).transcode_to_host(
        containers, src, dst
    )
    _assert_identical(got, ref)


def test_mixed_domain_batch_straddling_bucket_boundaries(domain_tables):
    """A mixed-domain archive whose per-group word counts land exactly at /
    one over a power of two (255/256/257 words): padding words must
    contribute no symbols through the whole transcode pipeline."""
    c255, t255 = uniform_code_container(255, seed=255, domain_id=10)
    c256, t256 = uniform_code_container(256, seed=256, domain_id=11)
    c257, _ = uniform_code_container(257, seed=257, domain_id=10)
    containers = [c255, c256, c257]
    src = {10: t255, 11: t256}
    dst = domain_tables[1]

    ref = _reference(containers, src, dst)
    tc = Transcoder()
    with jax.transfer_guard_device_to_host("disallow"):
        batch = tc.transcode(containers, src, dst)
    _assert_identical(batch.to_host(), ref)


def test_degenerate_inputs(domain_tables):
    """test_degenerate.py's pathological shapes through the transcoder:
    empty signal, shorter-than-one-window signal, single-symbol alphabet."""
    power = domain_tables[0]
    n = power.config.n
    from repro.data import make_signal

    sub_window = make_signal("load_power", n // 4, seed=3)
    containers = [
        encode(np.empty(0, np.float32), power),
        encode(sub_window, power),
    ]
    ref = _reference(containers, power, domain_tables[1])
    got = Transcoder().transcode_to_host(containers, power, domain_tables[1])
    _assert_identical(got, ref)
    assert got[0].num_windows == 0 and got[0].num_words == 0

    # single-symbol alphabet: 1-bit codes, 64 symbols per word
    ss = single_symbol_tables(domain_id=5)
    c = encode(np.zeros(100, np.float32), ss)
    ref = _reference([c], ss, power)
    got = Transcoder().transcode_to_host([c], ss, power)
    _assert_identical(got, ref)
    rec = decode(got[0], power)
    np.testing.assert_allclose(rec, np.zeros(100, np.float32), atol=1e-5)


def test_encoded_batch_source_multi_chunk(domain_tables):
    """The EncodedBatch source path: un-stitched chunk parts feed the
    decoder through the device-side stitch (chunk_size small enough that
    every signal spans many chunks), byte-identical to draining the batch
    to containers and round-tripping those."""
    from repro.data import make_signal

    sigs = [
        make_signal("load_power", n, seed=40 + i)
        for i, n in enumerate([4096, 3001, 500])
    ]
    power, dst = domain_tables[0], domain_tables[2]

    # reference: an identically-encoded batch drained to containers, then
    # the host round trip
    ref_containers = BatchEncoder(chunk_size=32).encode(
        sigs, power
    ).to_host()
    ref = _reference(ref_containers, power, dst)

    batch = BatchEncoder(chunk_size=32).encode(sigs, power)
    tc = Transcoder()
    with jax.transfer_guard_device_to_host("disallow"):
        out = tc.transcode(batch, power, dst)
    assert tc.stats.stitches >= 1
    _assert_identical(out.to_host(), ref)

    # the source batch was consumed by the stitch
    with pytest.raises(RuntimeError, match="donated"):
        batch.to_host()


def test_encoded_batch_source_mixed_domains(domain_tables):
    """Mixed-domain EncodedBatch source: several encode buckets per
    plan_key merge into per-(domain, config) decode groups."""
    from repro.data import make_signal

    sigs, doms = [], []
    for i, n in enumerate([2048, 1000, 3000, 257 * 8]):
        dom = i % 2
        ds = "load_power" if dom == 0 else "temperature"
        sigs.append(make_signal(ds, n, seed=50 + i))
        doms.append(dom)
    src = {0: domain_tables[0], 1: domain_tables[1]}
    dst = domain_tables[1]

    ref_containers = BatchEncoder(chunk_size=128).encode(
        sigs, src, domain_ids=doms
    ).to_host()
    ref = _reference(ref_containers, src, dst)

    batch = BatchEncoder(chunk_size=128).encode(sigs, src, domain_ids=doms)
    got = Transcoder().transcode_to_host(batch, src, dst)
    _assert_identical(got, ref)


def test_dst_domain_routing(domain_tables):
    """Mapping dst_tables: explicit per-signal routing, and the default
    (source domain ids) when dst_domain_ids is omitted."""
    containers = (
        _src_containers(0, domain_tables) + _src_containers(1, domain_tables)
    )
    src = {0: domain_tables[0], 1: domain_tables[1]}

    # default routing: re-encode each signal under its own domain's tables
    ref = _reference(
        containers, src, src, dst_domain_ids=[0] * 3 + [1] * 3
    )
    got = Transcoder().transcode_to_host(containers, src, src)
    _assert_identical(got, ref)
    assert [c.domain_id for c in got] == [0] * 3 + [1] * 3

    # explicit cross-routing: swap the domains
    swap = [1] * 3 + [0] * 3
    ref = _reference(containers, src, src, dst_domain_ids=swap)
    got = Transcoder().transcode_to_host(
        containers, src, src, dst_domain_ids=swap
    )
    _assert_identical(got, ref)
    assert [c.domain_id for c in got] == swap


def test_use_kernels_parity(domain_tables):
    """Pallas (interpret) decode inside the transcoder matches the kernel
    round trip byte for byte."""
    containers = _src_containers(0, domain_tables)[:2]
    src, dst = domain_tables[0], domain_tables[1]
    ref = _reference(containers, src, dst, use_kernels=True)
    got = Transcoder(use_kernels=True).transcode_to_host(
        containers, src, dst
    )
    _assert_identical(got, ref)


def test_codec_transcode_batch_of_one(domain_tables):
    """core.codec.transcode is the exact-mode container-of-one wrapper."""
    c = _src_containers(0, domain_tables)[0]
    src, dst = domain_tables[0], domain_tables[1]
    got = codec_transcode(c, src, dst)
    ref = _reference([c], src, dst, chunk_size=None)[0]
    np.testing.assert_array_equal(got.words, ref.words)
    np.testing.assert_array_equal(got.symlen, ref.symlen)
    # and exact mode means the output matches the host encoder bit for bit
    sig = BatchDecoder().decode([c], src).to_host()[0]
    host = encode(sig, dst)
    np.testing.assert_array_equal(got.words, host.words)


def test_transcoded_containers_decode_everywhere(domain_tables):
    """Transcoded containers are ordinary containers: both the host
    decoder and the batch decoder read them, and the reconstruction stays
    within the error of re-quantizing the decoded signal."""
    containers = _src_containers(1, domain_tables)
    src, dst = domain_tables[1], domain_tables[0]
    got = Transcoder().transcode_to_host(containers, src, dst)
    sigs = BatchDecoder().decode(containers, src).to_host()
    for c, sig in zip(got, sigs):
        host_rec = decode(c, dst)
        ref_rec = decode(encode(sig, dst), dst)
        np.testing.assert_allclose(host_rec, ref_rec, atol=1e-5)
        outs = BatchDecoder().decode([c], dst).to_host()[0]
        np.testing.assert_allclose(outs, host_rec, atol=1e-4)


def test_empty_batch(domain_tables):
    tc = Transcoder()
    out = tc.transcode([], domain_tables[0], domain_tables[1])
    assert len(out) == 0 and out.to_host() == []


def test_failed_transcode_leaves_source_drainable(domain_tables):
    """A transcode that dies on bad routing must NOT consume the source
    batch — the archive stays drainable after, say, a tables-mapping
    typo."""
    power = domain_tables[0]
    sig_batch = BatchEncoder().encode(
        [np.cumsum(np.ones(512, np.float32))], power
    )
    with pytest.raises(KeyError, match="domain_id=0"):
        # dst mapping has no entry for the defaulted dst domain id (0)
        Transcoder().transcode(sig_batch, power, {5: domain_tables[1]})
    assert len(sig_batch.to_host()) == 1  # still drainable


def test_chained_transcode_propagates_gap_flags(domain_tables):
    """A histogram-gap flag survives ANY number of device-resident hops:
    transcoding a bad batch (and transcoding the result again) must still
    fail loudly at the final drain, never laundering the garbage stream
    into clean containers."""
    bad_tables = gap_tables(domain_id=7)
    sig = np.sin(np.linspace(0, 30, 512)).astype(np.float32) * 5
    batch = BatchEncoder().encode([sig], bad_tables)  # device-side bad flag
    dst1, dst2 = domain_tables[0], domain_tables[1]

    once = Transcoder().transcode(batch, bad_tables, dst1)
    twice = Transcoder().transcode(once, dst1, dst2)
    with pytest.raises(ValueError, match="histogram gap"):
        twice.to_host()


def test_plan_pairing_cache(domain_tables):
    """TranscodePlan pairs the decode/encode plans under one key and is
    reused across batches."""
    src, dst = domain_tables[0], domain_tables[1]
    tc = Transcoder()
    plan = tc.plan_for(src, dst)
    assert plan.src_key == (
        0, src.config.n, src.config.e, src.config.l_max, src.config.coding
    )
    assert plan.dst_key == (
        1, dst.config.n, dst.config.e, dst.config.l_max, dst.config.coding
    )
    assert plan.decode.n == src.config.n
    assert plan.encode.n == dst.config.n

    containers = _src_containers(0, domain_tables)
    tc.transcode(containers, src, dst).to_host()
    misses_after_first = tc._plans.misses
    tc.transcode(containers, src, dst).to_host()
    assert tc._plans.misses == misses_after_first  # pure cache hits
    assert tc.stats.batches == 2
    assert tc.stats.signals == 2 * len(containers)
    # the pairing shares device state with the engines' own caches
    assert plan.decode is tc.decoder._plans.get(
        src, plan.src_key
    )
    assert plan.encode is tc.encoder.plan_for(dst)
