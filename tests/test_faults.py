"""The fault taxonomy as a contract: frozen corrupt blobs decode to their
pinned typed errors, quarantine isolates poison per request with
byte-identity for batch-mates, the retry policy absorbs transient faults
(and never re-runs poison), and the watchdog cuts hung dispatches loose.

The frozen-blob tests run identically on the host/XLA path and under
``FPTC_USE_KERNELS=1`` (the kernels-interpret CI leg re-executes this
file) — the error taxonomy must not depend on which arm decodes.
"""
import os

import numpy as np
import pytest

from _synth import golden_tables
from repro.core import DOMAIN_DEFAULTS, calibrate
from repro.core.container import Container, ContainerFormatError
from repro.data import make_signal
from repro.serving.batch_decode import BatchDecoder
from repro.serving.batch_encode import BatchEncoder
from repro.serving.frontend import (
    DispatchFailedError,
    FrontendConfig,
    RetryPolicy,
    ServingFrontend,
)
from repro.serving.quarantine import (
    PoisonedContainerError,
    validate_or_poison,
)
from repro.serving.transcode import Transcoder
from repro.testing.faults import (
    CONTAINER_FAULTS,
    EXPECTED_FAULT,
    DispatcherFaultInjector,
    InjectedDispatchError,
    corrupt,
)

CORRUPT_DIR = os.path.join(os.path.dirname(__file__), "golden", "corrupt")
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
PINNED_SEED = 13  # regen.py's seed — part of the frozen contract


def _frozen(fault: str) -> bytes:
    with open(os.path.join(CORRUPT_DIR, f"{fault}.fptc"), "rb") as f:
        return f.read()


@pytest.fixture(scope="module")
def power_v2_tables():
    return golden_tables("power", 2)


@pytest.fixture(scope="module")
def power_v3_tables():
    return golden_tables("power", 2, v3=True)


@pytest.fixture(scope="module")
def serving_tables():
    sig = make_signal("load_power", 65536, seed=7)
    return calibrate(sig, DOMAIN_DEFAULTS["power"], domain_id=0)


def _tables_for_fault(fault, v2, v3):
    return v3 if fault == "reserved-flags" else v2


# ---------------------------------------------------------------------------
# The frozen corrupt-blob suite.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fault", CONTAINER_FAULTS)
def test_frozen_blob_bytes_are_pinned(fault):
    """corrupt() is deterministic: regenerating a frozen blob from its
    golden source and pinned seed reproduces it byte for byte."""
    src = "power_v3.fptc" if fault == "reserved-flags" else "power_v2.fptc"
    with open(os.path.join(GOLDEN_DIR, src), "rb") as f:
        golden = f.read()
    assert corrupt(golden, fault, seed=PINNED_SEED) == _frozen(fault)


@pytest.mark.parametrize("fault", CONTAINER_FAULTS)
def test_frozen_blob_validates_to_expected_fault(
    fault, power_v2_tables, power_v3_tables
):
    """Each frozen blob surfaces exactly its pinned fault class from the
    quarantine staging pre-pass, with the container index threaded in."""
    tables = _tables_for_fault(fault, power_v2_tables, power_v3_tables)
    container, err = validate_or_poison(_frozen(fault), 5, tables)
    assert container is None
    assert isinstance(err, PoisonedContainerError)
    assert err.fault in EXPECTED_FAULT[fault], (
        f"{fault}: got [{err.fault}] {err}"
    )
    assert err.index == 5


@pytest.mark.parametrize("fault", CONTAINER_FAULTS)
def test_frozen_blob_poisons_engine_decode(
    fault, power_v2_tables, power_v3_tables
):
    """The engine path (BatchDecoder under quarantine — host, XLA, or
    FPTC_USE_KERNELS=1, whichever this process runs) delivers the same
    typed per-request outcome at drain."""
    tables = _tables_for_fault(fault, power_v2_tables, power_v3_tables)
    dec = BatchDecoder(pipeline=False)
    out = dec.decode([_frozen(fault)], tables, quarantine=True).to_host()
    assert isinstance(out[0], PoisonedContainerError)
    assert out[0].fault in EXPECTED_FAULT[fault]


def test_wire_faults_raise_typed_without_quarantine(power_v2_tables):
    """The offline contract is unchanged: without quarantine a corrupt
    blob raises out of parsing — but now as ContainerFormatError (still a
    ValueError) carrying fault class, byte offset and container index."""
    with pytest.raises(ContainerFormatError) as exc:
        Container.from_bytes(_frozen("flip-crc"), index=3)
    assert exc.value.fault == "crc-mismatch"
    assert exc.value.offset == 40
    assert exc.value.index == 3
    assert isinstance(exc.value, ValueError)  # old except clauses still fire
    with pytest.raises(ContainerFormatError) as exc:
        Container.from_bytes(_frozen("truncate"))
    assert exc.value.fault == "truncated"


def test_peek_parses_header_without_crc(power_v2_tables):
    """Container.peek: O(1) admission routing — reads the header (and
    rejects header faults) without touching the payload CRC."""
    with open(os.path.join(GOLDEN_DIR, "power_v2.fptc"), "rb") as f:
        golden = f.read()
    hdr = Container.peek(golden)
    ref = Container.from_bytes(golden)
    assert hdr.plan_key == ref.plan_key
    assert hdr.domain_id == ref.domain_id
    # payload corruption is invisible to peek (caught later, at staging)
    assert Container.peek(
        corrupt(golden, "flip-words", seed=1)
    ).plan_key == ref.plan_key
    # header corruption is typed at peek time
    with pytest.raises(ContainerFormatError):
        Container.peek(corrupt(golden, "bad-magic", seed=1))


# ---------------------------------------------------------------------------
# Quarantine semantics: per-request poison, byte-identical batch-mates.
# ---------------------------------------------------------------------------
def test_quarantine_excludes_poison_and_keeps_batch_byte_identical(
    serving_tables,
):
    rng = np.random.default_rng(0)
    sigs = [rng.standard_normal(500).astype(np.float32) for _ in range(5)]
    enc = BatchEncoder(pipeline=False)
    blobs = [c.to_bytes() for c in enc.encode(sigs, serving_tables).to_host()]
    dec = BatchDecoder(pipeline=False)
    ref = dec.decode(
        [Container.from_bytes(b) for b in blobs], serving_tables
    ).to_host()

    items = list(blobs)
    items[1] = corrupt(blobs[1], "flip-words", seed=2)
    items[3] = corrupt(blobs[3], "truncate", seed=2)
    out = dec.decode(items, serving_tables, quarantine=True).to_host()
    assert isinstance(out[1], PoisonedContainerError)
    assert isinstance(out[3], PoisonedContainerError)
    assert out[1].index == 1 and out[3].index == 3
    for i in (0, 2, 4):
        np.testing.assert_array_equal(out[i], ref[i])
    assert dec.stats.quarantined == 2


def test_quarantine_transcode_excludes_poison_byte_identical(serving_tables):
    rng = np.random.default_rng(1)
    sigs = [rng.standard_normal(400).astype(np.float32) for _ in range(3)]
    dst = calibrate(
        make_signal("temperature", 65536, seed=8),
        DOMAIN_DEFAULTS["meteorological"],
        domain_id=1,
    )
    tabs = {0: serving_tables, 1: dst}
    enc = BatchEncoder(pipeline=False)
    blobs = [
        c.to_bytes()
        for c in enc.encode(
            sigs, tabs, domain_ids=[0, 0, 0]
        ).to_host()
    ]
    tr = Transcoder(pipeline=False)
    ref = [
        c.to_bytes()
        for c in tr.transcode(
            [Container.from_bytes(b) for b in blobs], tabs, tabs,
            dst_domain_ids=[1, 1, 1],
        ).to_host()
    ]
    items = [blobs[0], corrupt(blobs[1], "flip-sidecar", seed=3), blobs[2]]
    out = tr.transcode(
        items, tabs, tabs, dst_domain_ids=[1, 1, 1], quarantine=True
    ).to_host()
    assert isinstance(out[1], PoisonedContainerError)
    assert out[0].to_bytes() == ref[0]
    assert out[2].to_bytes() == ref[2]


def test_quarantine_demotes_histogram_gap_per_signal():
    """The device-side gap flag: batch-fatal offline, per-signal typed
    outcome under quarantine — and the clean co-batched signal's bytes
    are identical to encoding it alone."""
    from test_batch_encode import _gap_tables

    tables = _gap_tables()
    gap_sig = np.sin(np.linspace(0, 30, 512)).astype(np.float32) * 5
    ok_sig = np.zeros(512, np.float32)
    # offline contract preserved: batch-fatal
    batch = BatchEncoder(pipeline=False).encode([gap_sig, ok_sig], tables)
    with pytest.raises(ValueError, match="histogram gap"):
        batch.to_host()
    # quarantine: per-signal typed outcome
    out = BatchEncoder(pipeline=False).encode(
        [gap_sig, ok_sig], tables, quarantine=True
    ).to_host()
    assert isinstance(out[0], PoisonedContainerError)
    assert out[0].fault == "histogram-gap"
    solo = BatchEncoder(pipeline=False).encode([ok_sig], tables).to_host()
    assert out[1].to_bytes() == solo[0].to_bytes()


def test_all_poisoned_batch_drains_typed(serving_tables):
    dec = BatchDecoder(pipeline=False)
    out = dec.decode(
        [_frozen("bad-magic"), _frozen("flip-crc")],
        serving_tables.config and serving_tables,  # single tables arg
        quarantine=True,
    ).to_host()
    assert all(isinstance(o, PoisonedContainerError) for o in out)


# ---------------------------------------------------------------------------
# Dispatcher fault injection: retry + watchdog.
# ---------------------------------------------------------------------------
def _frontend(tables, injector=None, **cfg):
    return ServingFrontend(
        tables, pipeline=False, fault_injector=injector,
        config=FrontendConfig(**cfg),
    )


def test_injector_counts_and_fires_on_nth():
    inj = DispatcherFaultInjector(fail_on={2})
    inj.on_dispatch(("decode", ()), [])
    with pytest.raises(InjectedDispatchError):
        inj.on_dispatch(("decode", ()), [])
    inj.on_dispatch(("decode", ()), [])
    assert inj.dispatches == 3
    assert inj.injected == [(2, "fail")]


def test_retry_absorbs_transient_fault(serving_tables):
    rng = np.random.default_rng(4)
    sig = rng.standard_normal(300).astype(np.float32)
    inj = DispatcherFaultInjector(fail_on={2})  # 1: encode, 2: decode fails
    with _frontend(
        serving_tables, inj,
        retry=RetryPolicy(max_retries=2, base_backoff_ms=1.0),
    ) as fe:
        blob = fe.submit_encode(sig).result(60).to_bytes()
        ref = fe.submit_decode(blob)
        fe.flush()
        np.testing.assert_array_equal(
            ref.result(60),
            BatchDecoder(pipeline=False).decode(
                [Container.from_bytes(blob)], serving_tables
            ).to_host()[0],
        )
        stats = fe.stats_snapshot()
        assert stats.retries >= 1
        assert stats.retry_successes >= 1
        assert stats.failed == 0


def test_retry_exhaustion_is_typed_dispatch_failure(serving_tables):
    rng = np.random.default_rng(5)
    sig = rng.standard_normal(300).astype(np.float32)
    inj = DispatcherFaultInjector(fail_on={2, 3, 4})
    with _frontend(
        serving_tables, inj,
        retry=RetryPolicy(max_retries=2, base_backoff_ms=1.0),
    ) as fe:
        blob = fe.submit_encode(sig).result(60).to_bytes()
        fut = fe.submit_decode(blob)
        fe.flush()
        with pytest.raises(DispatchFailedError) as exc:
            fut.result(60)
        assert isinstance(exc.value.__cause__, InjectedDispatchError)
        stats = fe.stats_snapshot()
        assert stats.dispatch_failures == 1
        assert fe.health()["status"] == "degraded"


def test_retry_never_reruns_poisoned_payloads(serving_tables):
    """A poisoned request is a RESULT (typed error on its future), not a
    dispatch fault — the retry machinery must never see it."""
    rng = np.random.default_rng(6)
    sig = rng.standard_normal(300).astype(np.float32)
    with _frontend(serving_tables) as fe:
        blob = fe.submit_encode(sig).result(60).to_bytes()
        fut = fe.submit_decode(corrupt(blob, "flip-words", seed=7))
        fe.flush()
        with pytest.raises(PoisonedContainerError):
            fut.result(60)
        stats = fe.stats_snapshot()
        assert stats.retries == 0  # poison never re-dispatches
        assert stats.quarantined == 1


def test_watchdog_cuts_hung_dispatch_and_frontend_survives(serving_tables):
    rng = np.random.default_rng(7)
    sig = rng.standard_normal(300).astype(np.float32)
    # warm the jit caches outside the instrumented frontend so the watchdog
    # budget below only has to cover a warm dispatch, not a cold compile
    warm = BatchEncoder(pipeline=False).encode([sig], serving_tables)
    BatchDecoder(pipeline=False).decode(
        list(warm.to_host()), serving_tables
    ).to_host()
    inj = DispatcherFaultInjector(hang_on={2}, hang_timeout_s=30.0)
    with _frontend(
        serving_tables, inj,
        watchdog_timeout_ms=1500.0, watchdog_poll_ms=25.0,
        retry=RetryPolicy(max_retries=1, base_backoff_ms=1.0),
    ) as fe:
        blob = fe.submit_encode(sig).result(60).to_bytes()
        hung = fe.submit_decode(blob)
        fe.flush()
        with pytest.raises(DispatchFailedError, match="watchdog"):
            hung.result(30)
        # the replacement dispatcher generation keeps draining the queues
        again = fe.submit_decode(blob)
        fe.flush()
        assert again.result(60).shape == sig.shape
        stats = fe.stats_snapshot()
        assert stats.watchdog_restarts == 1
        health = fe.health()
        assert health["status"] == "degraded"
        assert health["watchdog_restarts"] == 1
        inj.release()  # unblock the abandoned daemon before exiting


def test_health_ok_and_sheds_reported(serving_tables):
    with _frontend(serving_tables) as fe:
        h = fe.health()
        assert h["status"] == "ok"
        assert h["shed_rate"] == 0.0
        assert h["quarantined"] == 0
