"""Hypothesis import shim: property tests degrade to skips when absent.

The test modules import ``given``/``settings``/``strategies`` from here
instead of from ``hypothesis`` directly, so the suite still *collects* (and
the non-property tests still run) on machines without hypothesis installed.
With ``pip install -e .[test]`` the real library is used unchanged.
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: any strategy constructor
        returns None — the decorated test is skipped before arguments are
        ever drawn."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    strategies = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[test])"
            )(fn)

        return deco
