"""Checkpointing: atomicity, CRC verification, FPTC compression, resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": rng.standard_normal((128, 64)).astype(np.float32),
            "b": rng.standard_normal((64,)).astype(np.float32),
        },
        "m": {"w": rng.standard_normal((128, 64)).astype(np.float32) * 0.01},
        "step_tokens": np.arange(10, dtype=np.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save_checkpoint(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    step, restored = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(
        jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)
    ):
        np.testing.assert_array_equal(a, b)


def test_latest_wins(tmp_path):
    tree = _tree()
    ckpt.save_checkpoint(str(tmp_path), 5, tree)
    tree2 = _tree(1)
    ckpt.save_checkpoint(str(tmp_path), 12, tree2)
    step, restored = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 12
    np.testing.assert_array_equal(
        restored["params"]["w"], tree2["params"]["w"]
    )


def test_torn_write_invisible(tmp_path):
    """A temp dir from a crashed writer is never picked up."""
    tree = _tree()
    ckpt.save_checkpoint(str(tmp_path), 3, tree)
    os.makedirs(tmp_path / ".tmp_ckpt_dead", exist_ok=True)
    os.makedirs(tmp_path / "step_000000000099")  # no manifest -> incomplete
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_crc_detects_corruption(tmp_path):
    tree = _tree()
    path = ckpt.save_checkpoint(str(tmp_path), 1, tree)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    victim = next(iter(manifest["leaves"].values()))["file"] + ".npy"
    fp = os.path.join(path, victim)
    raw = bytearray(open(fp, "rb").read())
    raw[-1] ^= 0xFF
    open(fp, "wb").write(bytes(raw))
    with pytest.raises(ValueError, match="CRC"):
        ckpt.restore_checkpoint(str(tmp_path), 1, tree)


def test_fptc_compressed_checkpoint(tmp_path):
    """Compressed float leaves restore within near-lossless tolerance and
    actually shrink on disk."""
    rng = np.random.default_rng(3)
    # smooth-ish accumulator-like tensor (what opt state looks like)
    t = np.cumsum(rng.standard_normal((256, 64)), axis=0).astype(np.float32)
    t /= np.abs(t).max()
    tree = {"m": t}
    path = ckpt.save_checkpoint(str(tmp_path), 2, tree, compress=True)
    files = os.listdir(path)
    assert any(f.endswith(".fptc") for f in files)
    _, restored = ckpt.restore_latest(str(tmp_path), tree)
    rel = np.linalg.norm(restored["m"] - t) / np.linalg.norm(t)
    assert rel < 0.02, f"compressed ckpt rel error {rel}"  # ~1% class
    blob = os.path.getsize(
        os.path.join(path, [f for f in files if f.endswith(".fptc")][0])
    )
    assert blob < t.nbytes * 0.8  # actually compressed


def test_resume_reproduces_uninterrupted_run(tmp_path):
    """Fault-tolerance determinism: save at step k, 'crash', restore, and the
    final params match a run that never crashed."""
    from repro.configs import get_smoke
    from repro.distributed.optimizer import AdamW, AdamWConfig
    from repro.models import build_model
    from repro.models.common import init_params

    cfg = get_smoke("qwen15_4b")
    model = build_model(cfg)
    opt = AdamW(AdamWConfig(base_lr=1e-3, warmup=1, total_steps=20))

    def batch(step):
        rng = np.random.default_rng(step)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
        return {"tokens": toks, "labels": toks}

    @jax.jit
    def step_fn(params, state, b):
        loss, grads = jax.value_and_grad(model.loss)(params, b)
        p2, s2, _ = opt.update(params, state, grads)
        return p2, s2

    # uninterrupted run: 6 steps
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    state = opt.init(params)
    for s in range(6):
        params, state = step_fn(params, state, batch(s))
    ref = jax.tree_util.tree_map(np.asarray, params)

    # interrupted run: 3 steps, checkpoint, "crash", restore, 3 more
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    state = opt.init(params)
    for s in range(3):
        params, state = step_fn(params, state, batch(s))
    host = jax.tree_util.tree_map(
        np.asarray, {"p": params, "m": state.m, "v": state.v}
    )
    ckpt.save_checkpoint(str(tmp_path), 3, host)
    del params, state

    step, tree = ckpt.restore_latest(str(tmp_path), host)
    params = jax.tree_util.tree_map(jnp.asarray, tree["p"])
    state = opt.init(params)._replace(
        m=jax.tree_util.tree_map(jnp.asarray, tree["m"]),
        v=jax.tree_util.tree_map(jnp.asarray, tree["v"]),
        step=jnp.asarray(step, jnp.int32),
    )
    for s in range(3, 6):
        params, state = step_fn(params, state, batch(s))
    for a, b in zip(
        jax.tree_util.tree_leaves(ref),
        jax.tree_util.tree_leaves(jax.tree_util.tree_map(np.asarray, params)),
    ):
        np.testing.assert_allclose(
            a.astype(np.float32), b.astype(np.float32), atol=1e-6
        )
