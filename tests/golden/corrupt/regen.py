"""Regenerate the frozen corrupt-container fixtures.

    PYTHONPATH=src python tests/golden/corrupt/regen.py

One blob per fault class in :data:`repro.testing.faults.CONTAINER_FAULTS`,
derived from the frozen golden containers (``power_v2.fptc``, or
``power_v3.fptc`` for the v3-only ``reserved-flags`` fault) with a PINNED
seed — so the expected typed error for each blob is a frozen contract,
like the golden blobs' bytes themselves.  Only rerun this when the golden
sources or the corruption functions intentionally change; ``faults.py``'s
determinism means an unintended diff here is a harness regression.
"""
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(__file__), "..", "..", "..", "src")
)

from repro.testing.faults import CONTAINER_FAULTS, corrupt  # noqa: E402

SEED = 13  # pinned: the frozen blobs' bytes depend on it


def main():
    out_dir = os.path.dirname(os.path.abspath(__file__))
    golden_dir = os.path.dirname(out_dir)
    v2 = open(os.path.join(golden_dir, "power_v2.fptc"), "rb").read()
    v3 = open(os.path.join(golden_dir, "power_v3.fptc"), "rb").read()
    for fault in CONTAINER_FAULTS:
        src = v3 if fault == "reserved-flags" else v2
        blob = corrupt(src, fault, seed=SEED)
        path = os.path.join(out_dir, f"{fault}.fptc")
        with open(path, "wb") as f:
            f.write(blob)
        print(f"wrote {path} ({len(blob)} bytes)")


if __name__ == "__main__":
    main()
