"""Regenerate the golden container fixtures.

    PYTHONPATH=src python tests/golden/regen.py

Only run this when the container format version is INTENTIONALLY bumped or
the golden construction itself changes — the whole point of the frozen
blobs is that today's encoder reproduces them byte for byte, so a diff
here is a format/packer regression until proven otherwise (see
test_golden.py).
"""
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(__file__), "..", "..", "src")
)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from _synth import (  # noqa: E402
    GOLDEN_DOMAINS,
    container_v1_bytes,
    golden_signal,
    golden_tables,
)
from repro.core import encode  # noqa: E402


def main():
    out_dir = os.path.dirname(os.path.abspath(__file__))
    for key, dom_id in GOLDEN_DOMAINS:
        tables = golden_tables(key, dom_id)
        syms, sig = golden_signal(tables)
        container = encode(sig, tables)
        got = syms.ravel()
        import numpy as np

        from repro.core.symlen import PackedStream, unpack_symlen_np

        back = unpack_symlen_np(
            PackedStream(
                words=container.words,
                symlen=container.symlen.astype(np.int32),
                num_symbols=container.num_symbols,
            ),
            tables.book,
        )
        assert np.array_equal(back, got), key  # construction is exact
        v2 = container.to_bytes()
        v1 = container_v1_bytes(container)
        # v3 fixture: SAME signal and quant/book, the GOLDEN_V3_CODING
        # re-coding stage on top — freezes the v3 wire bytes per domain
        c3 = encode(sig, golden_tables(key, dom_id, v3=True))
        assert c3.version == 3, key
        v3 = c3.to_bytes()
        with open(os.path.join(out_dir, f"{key}_v2.fptc"), "wb") as f:
            f.write(v2)
        with open(os.path.join(out_dir, f"{key}_v1.fptc"), "wb") as f:
            f.write(v1)
        with open(os.path.join(out_dir, f"{key}_v3.fptc"), "wb") as f:
            f.write(v3)
        print(f"{key}: {container.num_words} words, v2 {len(v2)} B, "
              f"v1 {len(v1)} B, v3 {len(v3)} B")


if __name__ == "__main__":
    main()
