"""Workload conformance: KV-cache & train-state domains, fixed-rate engine
modes, checkpoint v2, and the satellite regression pins (wire_bytes,
KV ratio, legacy shim semantics)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DOMAIN_DEFAULTS, encode
from repro.core.dct import forward_dct, window_signal
from repro.core.domains import (
    KV_DOMAIN_ID,
    TRAIN_STATE_DOMAIN_ID,
    calibrate_kv,
    calibrate_train_state,
    kv_channel_strips,
)
from repro.core.quantize import quantize
from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import CompressionConfig, GradCompressor
from repro.serving import BatchDecoder, BatchEncoder
from repro.serving.workloads import (
    KVCacheCodec,
    shard_state,
    state_from_containers,
    state_to_containers,
    unshard_state,
    write_workloads_report,
)


def _kv_block(seed=0, b=2, t=64, h=4, d=8, dtype=jnp.bfloat16):
    """A smooth-ish token timeline per channel (what trained caches look
    like): walk along the token axis."""
    rng = np.random.default_rng(seed)
    walk = np.cumsum(
        rng.standard_normal((b, t, h, d)).astype(np.float32), axis=1
    ) * np.float32(4.0 / t ** 0.5)
    return jnp.asarray(walk, dtype)


# ---------------------------------------------------------------------------
# KV domain: calibration + fixed-rate engine round trip.
# ---------------------------------------------------------------------------
def test_kv_roundtrip_bf16():
    kv = _kv_block()
    codec = KVCacheCodec()
    tables = codec.calibrate(kv, layer="attn")
    assert tables.domain_id == KV_DOMAIN_ID
    ckv = codec.compress(kv, layer="attn")
    assert ckv.levels.dtype == jnp.uint8
    assert ckv.levels.shape == (2, 4, 8, 64 // codec.config.n,
                                codec.config.e)
    out = codec.decompress(ckv, layer="attn")
    assert out.shape == kv.shape and out.dtype == kv.dtype
    rel = float(
        jnp.linalg.norm((out - kv).astype(jnp.float32))
        / jnp.linalg.norm(kv.astype(jnp.float32))
    )
    assert rel < 0.05, rel


def test_kv_ratio_measured_from_actual_bytes():
    """Satellite pin: the compressed/raw ratio comes from real array bytes
    — for bf16 at the quantization-only point (n == e) that is exactly
    1 uint8 per 2-byte sample = 0.5, with NO per-block scale sidecar and
    no hard-coded head_dim anywhere."""
    for d in (8, 128):  # ratio must be head_dim-independent
        kv = _kv_block(d=d)
        codec = KVCacheCodec()
        codec.calibrate(kv)
        ckv = codec.compress(kv)
        assert ckv.raw_nbytes() == kv.size * 2
        assert ckv.nbytes == kv.size  # one byte per sample
        assert ckv.ratio == pytest.approx(0.5)


def test_kv_engine_levels_match_reference_math():
    """Byte-identity: the engine-routed fixed-rate path produces exactly
    the symbols of the reference core pipeline (windowed DCT + table
    quantize) on the channel strips."""
    kv = _kv_block(dtype=jnp.float32)
    codec = KVCacheCodec()
    tables = codec.calibrate(kv)
    ckv = codec.compress(kv)

    strips = kv_channel_strips(np.asarray(kv, np.float32), codec.config.n)
    coeffs = forward_dct(
        window_signal(jnp.asarray(strips), codec.config.n), codec.config.e
    )
    ref = np.asarray(quantize(coeffs, tables.quant))
    got = np.asarray(ckv.levels).reshape(ref.shape)
    np.testing.assert_array_equal(got, ref)


def test_kv_kernels_byte_identical_levels():
    """use_kernels=True (Pallas, interpret on CPU) produces byte-identical
    levels to the XLA arm; decoded floats agree to float tolerance."""
    kv = _kv_block()
    xla = KVCacheCodec(use_kernels=False)
    tab = xla.calibrate(kv)
    ker = KVCacheCodec(use_kernels=True)
    ker.set_tables(tab, dtype=kv.dtype)

    c_x = xla.compress(kv)
    c_k = ker.compress(kv)
    np.testing.assert_array_equal(
        np.asarray(c_x.levels), np.asarray(c_k.levels)
    )
    d_x = np.asarray(xla.decompress(c_x), np.float32)
    d_k = np.asarray(ker.decompress(c_k), np.float32)
    np.testing.assert_allclose(d_x, d_k, atol=1e-4)


def test_kv_zero_host_bounces():
    """Acceptance: compress + decompress with the transfer guard pinned to
    disallow — the whole pipeline is device-resident."""
    kv = _kv_block()
    codec = KVCacheCodec()
    codec.calibrate(kv, layer="l0")
    jax.config.update("jax_transfer_guard_device_to_host", "disallow")
    try:
        ckv = codec.compress(kv, layer="l0")
        out = codec.decompress(ckv, layer="l0")
        out.block_until_ready()  # device sync, not a transfer
    finally:
        jax.config.update("jax_transfer_guard_device_to_host", None)
    assert out.shape == kv.shape


def test_kv_tables_per_layer_and_dtype():
    """Tables — and therefore engine plans — are keyed per (layer group,
    dtype); an uncalibrated group fails loudly."""
    kv16 = _kv_block(seed=1)
    kv32 = _kv_block(seed=2, dtype=jnp.float32)
    codec = KVCacheCodec()
    t_a = codec.calibrate(kv16, layer="a")
    t_b = codec.calibrate(kv32, layer="a")  # same layer, other dtype
    assert codec.tables_for(layer="a", dtype=jnp.bfloat16) is t_a
    assert codec.tables_for(layer="a", dtype=jnp.float32) is t_b
    with pytest.raises(KeyError, match="no KV tables"):
        codec.compress(kv16, layer="uncalibrated")
    # shared engine plan cache: both table sets resolve plans through the
    # SAME encoder (one plan per tables identity)
    codec.compress(kv16, layer="a")
    codec.compress(kv32, layer="a")
    assert codec.encoder.stats.dispatches >= 2


def test_kv_shape_validation():
    codec = KVCacheCodec()
    kv = _kv_block()
    codec.calibrate(kv)
    with pytest.raises(ValueError, match=r"\[B, T, H, D\]"):
        codec.compress(kv[0])  # 3-D
    with pytest.raises(ValueError):
        codec.compress(kv[:, :30])  # T % n != 0
    with pytest.raises(ValueError):
        kv_channel_strips(np.zeros((2, 30, 4, 8), np.float32), 16)
    with pytest.raises(ValueError):
        calibrate_kv(np.zeros((4, 8), np.float32))


# ---------------------------------------------------------------------------
# Train-state domain: sharding + batched container path.
# ---------------------------------------------------------------------------
def test_train_state_shard_roundtrip_exact():
    rng = np.random.default_rng(0)
    arrays = {
        "w": rng.standard_normal((33, 17)).astype(np.float32),
        "b": rng.standard_normal(5).astype(np.float16),
    }
    shards, manifest = shard_state(arrays, shard_len=128)
    back = unshard_state(shards, manifest)
    np.testing.assert_array_equal(back["w"], arrays["w"])
    np.testing.assert_array_equal(
        back["b"], arrays["b"].astype(np.float32).astype(np.float16)
    )
    assert back["b"].dtype == np.float16
    with pytest.raises(ValueError):
        unshard_state(shards[:-1], manifest)


def test_train_state_containers_roundtrip():
    rng = np.random.default_rng(1)
    arrays = {
        "m": np.cumsum(
            rng.standard_normal((64, 64)), axis=0
        ).astype(np.float32),
    }
    arrays["m"] /= np.abs(arrays["m"]).max()
    tables = calibrate_train_state(arrays)
    assert tables.domain_id == TRAIN_STATE_DOMAIN_ID
    conts, manifest = state_to_containers(arrays, tables, shard_len=1024)
    assert len(conts) == 4
    assert all(c.domain_id == TRAIN_STATE_DOMAIN_ID for c in conts)
    rec = state_from_containers(conts, manifest, tables)
    rel = np.linalg.norm(rec["m"] - arrays["m"]) / np.linalg.norm(
        arrays["m"]
    )
    assert rel < 0.02, rel
    blob = sum(len(c.to_bytes()) for c in conts)
    assert blob < arrays["m"].nbytes * 0.8  # actually compressed


def test_calibrate_train_state_needs_float_leaves():
    with pytest.raises(ValueError, match="float"):
        calibrate_train_state({"steps": np.arange(10, dtype=np.int32)})


# ---------------------------------------------------------------------------
# Checkpoint v2: batched sharded state blob + legacy v1 restore.
# ---------------------------------------------------------------------------
def _smooth(rng, shape):
    t = np.cumsum(rng.standard_normal(shape), axis=0).astype(np.float32)
    return t / np.abs(t).max()


def test_checkpoint_v2_roundtrip(tmp_path):
    rng = np.random.default_rng(3)
    tree = {
        "p": {"w": _smooth(rng, (256, 64)), "b": _smooth(rng, (64,))},
        "m": {"w": _smooth(rng, (256, 64)) * 0.01},
        "step_tokens": np.arange(10, dtype=np.int32),
    }
    path = ckpt.save_checkpoint(str(tmp_path), 2, tree, compress=True)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 2
    # both big float leaves share ONE state blob; small/int leaves are raw
    assert os.path.exists(os.path.join(path, "state.fptc"))
    assert manifest["leaves"]["['p']['w']"]["codec"] == "fptc_state"
    assert manifest["leaves"]["['m']['w']"]["codec"] == "fptc_state"
    assert "codec" not in manifest["leaves"]["['p']['b']"]  # < min size
    assert "codec" not in manifest["leaves"]["['step_tokens']"]

    _, restored = ckpt.restore_latest(str(tmp_path), tree)
    np.testing.assert_array_equal(
        restored["step_tokens"], tree["step_tokens"]
    )
    np.testing.assert_array_equal(restored["p"]["b"], tree["p"]["b"])
    for key in (("p", "w"), ("m", "w")):
        a, b = tree[key[0]][key[1]], restored[key[0]][key[1]]
        rel = np.linalg.norm(a - b) / np.linalg.norm(a)
        assert rel < 0.02, (key, rel)
    # the state blob actually shrinks the float payload
    blob = os.path.getsize(os.path.join(path, "state.fptc"))
    float_bytes = tree["p"]["w"].nbytes + tree["m"]["w"].nbytes
    assert blob < float_bytes * 0.8


def test_checkpoint_v2_crc_detects_state_corruption(tmp_path):
    rng = np.random.default_rng(4)
    tree = {"m": _smooth(rng, (256, 64))}
    path = ckpt.save_checkpoint(str(tmp_path), 1, tree, compress=True)
    fp = os.path.join(path, "state.fptc")
    raw = bytearray(open(fp, "rb").read())
    raw[-1] ^= 0xFF
    open(fp, "wb").write(bytes(raw))
    with pytest.raises(ValueError, match="CRC"):
        ckpt.restore_checkpoint(str(tmp_path), 1, tree)


def test_checkpoint_v1_manifest_still_restores(tmp_path):
    """A pre-v2 checkpoint (per-leaf .fptc containers with inline aux
    tables) written by the old code must keep restoring."""
    import zlib

    from repro.core.calibration import calibrate

    rng = np.random.default_rng(5)
    arr = _smooth(rng, (256, 64))
    tree = {"m": arr}
    (key, _), = ckpt._leaf_paths(tree)
    name = ckpt._fname(key)

    final = tmp_path / "step_000000000007"
    os.makedirs(final)
    flat = arr.astype(np.float32).ravel()
    tables = calibrate(flat, ckpt.CKPT_CODEC_CONFIG, max_windows=4096)
    blob = encode(flat, tables).to_bytes()
    with open(final / f"{name}.fptc", "wb") as f:
        f.write(blob)
    manifest = {"step": 7, "version": 1, "leaves": {key: {
        "shape": list(arr.shape), "dtype": str(arr.dtype), "file": name,
        "codec": "fptc", "crc": zlib.crc32(blob),
        "aux": {
            "scale": np.asarray(tables.quant.scale).tolist(),
            "hist": np.asarray(tables.hist).tolist(),
        },
    }}}
    with open(final / "manifest.json", "w") as f:
        json.dump(manifest, f)

    step, restored = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 7
    rel = np.linalg.norm(restored["m"] - arr) / np.linalg.norm(arr)
    assert rel < 0.02, rel


# ---------------------------------------------------------------------------
# Satellite regressions: wire_bytes, legacy KV shim.
# ---------------------------------------------------------------------------
def test_wire_bytes_all_modes():
    """Satellite pin: every declared mode has a wire-byte account — the
    uncompressed baselines report true f32 bytes instead of KeyError."""
    n, e, num = 64, 16, 1000
    mk = lambda mode: GradCompressor(CompressionConfig(mode=mode, n=n, e=e))
    assert mk("none").wire_bytes(num) == num * 4
    assert mk("replicated_f32").wire_bytes(num) == num * 4
    w = -(-num // n)
    assert mk("truncate").wire_bytes(num) == w * e * 2  # bf16
    assert mk("truncate_int8").wire_bytes(num) == w * e * 1
    with pytest.raises(ValueError, match="unknown compression mode"):
        mk("gzip").wire_bytes(num)


def test_legacy_kv_shim_ratio_and_mapping():
    from repro.serving.kv_compression import (
        KVCompressionConfig,
        compress_kv_block,
        decompress_kv_block,
    )

    cfg = KVCompressionConfig(n=16, e=8)
    # scale overhead is per channel: 4 bytes per N-token window vs 2N raw
    # bytes — NOT divided by a hard-coded head_dim
    assert cfg.ratio == pytest.approx(8 / 32 + 4 / 32)

    kv = _kv_block(dtype=jnp.float32)
    with pytest.warns(DeprecationWarning, match="KVCacheCodec"):
        levels, scale = compress_kv_block(kv, cfg)
    # documented shapes: [B, W, H, D, E] levels, [B, W, H, D] scale
    assert levels.shape == (2, 4, 4, 8, 8)
    assert scale.shape == (2, 4, 4, 8)
    # symmetric mapping: level 0 unreachable, 128 is exact zero, every
    # stored level decodes inside [-1, 1] of the window scale
    assert int(levels.min()) >= 1
    norm = (np.asarray(levels, np.float32) - 128.0) / 127.0
    assert np.all(np.abs(norm) <= 1.0)
    with pytest.warns(DeprecationWarning):
        rec = decompress_kv_block(levels, scale, cfg, dtype=jnp.float32)
    assert rec.shape == kv.shape


# ---------------------------------------------------------------------------
# Report writer.
# ---------------------------------------------------------------------------
def test_write_workloads_report_merges_sections(tmp_path):
    path = str(tmp_path / "BENCH_workloads.json")
    write_workloads_report("kv_cache", {"ratio": 0.5}, path)
    write_workloads_report("checkpoint", {"ratio": 0.3}, path)
    write_workloads_report("kv_cache", {"ratio": 0.25}, path)  # overwrite
    with open(path) as f:
        report = json.load(f)
    assert report == {
        "kv_cache": {"ratio": 0.25}, "checkpoint": {"ratio": 0.3}
    }
